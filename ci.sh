#!/usr/bin/env sh
# CI gate for the rust tree: build, test, docs (warnings as errors),
# formatting, and a fast bench smoke. Run from the repo root.
set -eu

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> cargo fmt unavailable (rustfmt component missing) — skipped"
fi

echo "==> bench smoke (DISKPCA_BENCH_FAST=1, single-thread sweep)"
DISKPCA_BENCH_FAST=1 DISKPCA_BENCH_THREADS=1,2 cargo bench --bench sketches
DISKPCA_BENCH_FAST=1 DISKPCA_BENCH_THREADS=1,2 cargo bench --bench linalg

echo "CI OK"
