#!/usr/bin/env sh
# CI gate for the rust tree: build, test, lints, docs (warnings as
# errors), formatting, and a fast bench smoke with a regression diff.
# Run from the repo root. `.github/workflows/ci.yml` runs exactly this
# script on every push/PR.
set -eu

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Elastic fault-tolerance matrix: kill-at-every-round × transport ×
# streaming, plus the seeded soak and checkpoint replay properties.
# Runs as its own step (already covered by `cargo test` above only if
# nothing hangs) under a hard timeout: a recovery bug here shows up as
# a deadlocked revive/settle loop, and the timeout turns that hang
# into a CI failure instead of a stalled runner. `timeout` is
# coreutils; if the runner lacks it, run un-timed rather than skip.
echo "==> fault-injection matrix (hard timeout 900s)"
if command -v timeout >/dev/null 2>&1; then
    timeout 900 cargo test -q --test fault_injection --test elastic_soak --test checkpoint_properties
else
    cargo test -q --test fault_injection --test elastic_soak --test checkpoint_properties
fi

# Seeded chaos soak: the same multi-job service sequence as
# elastic_soak, but every master→worker link sits behind the seeded
# fault-injection transport (severed links + delayed sends at a fixed
# seed). Own step under a hard timeout for the same reason as the
# matrix above: a healing-liveness bug is a hang, and the timeout
# turns it into a failure.
echo "==> chaos soak (hard timeout 600s)"
if command -v timeout >/dev/null 2>&1; then
    timeout 600 cargo test -q --test chaos_soak
else
    cargo test -q --test chaos_soak
fi

# Fast-tier accuracy gate: the explicit-SIMD compute tier is only
# allowed to ship while every vectorized kernel stays inside its
# documented ulp/relative-norm bound vs the exact tier (and the FWHT
# stays bit-identical). Own [[test]] binary — the tier is
# process-global state, so the suite serializes on a mutex and must
# not share a process with exact-tier suites.
echo "==> fast-tier accuracy suite"
cargo test -q --test fast_tier_accuracy

# Concurrent scheduler suite under its own hard timeout for the same
# reason: a dispatch/heal liveness bug shows up as a parked-runner
# deadlock, and the timeout turns that into a CI failure instead of a
# stalled runner. (Also part of `cargo test` above when nothing hangs.)
echo "==> concurrent scheduler suite (hard timeout 600s)"
if command -v timeout >/dev/null 2>&1; then
    timeout 600 cargo test -q --test serve_concurrent
else
    cargo test -q --test serve_concurrent
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets (-D warnings; bug-finding groups — see [lints] in Cargo.toml)"
    cargo clippy --all-targets --quiet -- -D warnings
else
    echo "==> cargo clippy unavailable (clippy component missing) — skipped"
fi

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> cargo fmt unavailable (rustfmt component missing) — skipped"
fi

echo "==> bench smoke (DISKPCA_BENCH_FAST=1, single-thread sweep)"
DISKPCA_BENCH_FAST=1 DISKPCA_BENCH_THREADS=1,2 cargo bench --bench sketches
DISKPCA_BENCH_FAST=1 DISKPCA_BENCH_THREADS=1,2 cargo bench --bench linalg

# Streaming + protocol benches: each emits a BENCH_*.json (median ns
# per row) and diffs it against its checked-in baseline in
# bench_baseline/, printing a WARNING for any row >25% slower.
# Warn-only — shared runners are too noisy for a hard wall-time gate;
# copy the fresh BENCH_*.json over the baseline when a slowdown is
# intended. The protocol rows track broadcast/gather fan-out, so
# session-layer refactors are trend-recorded.
echo "==> gemm bench smoke + baseline diff (warn-only, threshold 25%; GFLOP/s per row, both compute tiers)"
DISKPCA_BENCH_FAST=1 DISKPCA_BENCH_THREADS=1,4 cargo bench --bench gemm
echo "==> streaming bench smoke + baseline diff (warn-only, threshold 25%; both compute tiers)"
DISKPCA_BENCH_FAST=1 cargo bench --bench streaming

# --compute-tier fast end-to-end smoke: one tiny disKPCA run through
# the CLI with the fast tier selected — exercises the flag plumbing
# (config key -> set_compute_tier) and the SIMD kernels in a real
# protocol round, not just the microbenches.
echo "==> --compute-tier fast CLI smoke"
cargo run --release -- run protein_like --scale 0.02 --compute-tier fast \
    --k 3 --t 16 --p 32 --n_lev 8 --n_adapt 12 --m_rff 128 --t2 64
echo "==> protocol bench smoke + baseline diff (warn-only, threshold 25%)"
DISKPCA_BENCH_FAST=1 cargo bench --bench protocol
echo "==> serve bench smoke + baseline diff (warn-only, threshold 25%)"
DISKPCA_BENCH_FAST=1 cargo bench --bench serve
echo "==> elastic bench smoke + baseline diff (warn-only, threshold 25%; tree vs flat gather)"
DISKPCA_BENCH_FAST=1 cargo bench --bench elastic
echo "==> qps bench smoke + baseline diff (warn-only, threshold 25%; seq vs concurrent serving)"
DISKPCA_BENCH_FAST=1 cargo bench --bench qps
echo "==> incremental bench smoke + baseline diff (warn-only, threshold 25%; warm refit vs cold fit)"
DISKPCA_BENCH_FAST=1 cargo bench --bench incremental
echo "==> degraded bench smoke + baseline diff (warn-only, threshold 25%; revival vs rebalance healing)"
DISKPCA_BENCH_FAST=1 cargo bench --bench degraded

# Serve-layer smoke: the example runs a real multi-job session and
# asserts the warm-state invariant (second same-spec job performs zero
# 1-embed communication, solution unchanged) plus transform parity.
echo "==> serve example smoke (multi-job warm-state session)"
cargo run --release --example serve_jobs

echo "CI OK"
