"""L1: TensorSketch (pallas countsketch + FFT combine) vs oracle."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, tensorsketch as ts
from .conftest import f32a, rng, tiled_dims


def ts_params(r, q, m, t):
    hs = r.integers(0, t, (q, m)).astype(np.int32)
    ss = (r.integers(0, 2, (q, m)) * 2 - 1).astype(np.float32)
    return hs, ss


@settings(max_examples=10, deadline=None)
@given(
    nd=tiled_dims(),
    md=tiled_dims(),
    q=st.sampled_from([2, 3, 4]),
    t=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31),
)
def test_tensorsketch_matches_ref(nd, md, q, t, seed):
    (n, bn), (m, bm) = nd, md
    r = rng(seed)
    x = f32a(r, n, m, scale=0.5)
    hs, ss = ts_params(r, q, m, t)
    got = ts.tensorsketch(x, hs, ss, t, block_n=bn, block_m=bm)
    want = ref.tensorsketch(x, hs, ss, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tensorsketch_unbiased_for_poly_kernel():
    """E[TS(x)ᵀTS(y)] = (xᵀy)^q — check with averaging over sketches."""
    r = rng(11)
    m, t, q, trials = 8, 64, 2, 600
    x = f32a(r, 1, m, scale=0.5)
    y = f32a(r, 1, m, scale=0.5)
    exact = float((x @ y.T)[0, 0]) ** q
    est = []
    for _ in range(trials):
        hs, ss = ts_params(r, q, m, t)
        tx = np.asarray(ref.tensorsketch(x, hs, ss, t))
        ty = np.asarray(ref.tensorsketch(y, hs, ss, t))
        est.append(float((tx @ ty.T)[0, 0]))
    # var of TS is O(‖x‖²q‖y‖²q/t); generous 3σ-style bound
    assert abs(np.mean(est) - exact) < 0.3, (np.mean(est), exact)


def test_tensorsketch_degree1_is_countsketch():
    """q=1 TensorSketch degenerates to a plain CountSketch."""
    r = rng(4)
    x = f32a(r, 8, 16)
    hs, ss = ts_params(r, 1, 16, 8)
    got = np.asarray(ts.tensorsketch(x, hs, ss, 8, block_n=8, block_m=16))
    want = np.asarray(ref.countsketch(x, hs[0], ss[0], 8))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
