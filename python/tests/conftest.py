"""Shared fixtures/strategies for the L1/L2 test suite."""

import numpy as np
import pytest
from hypothesis import strategies as st


def rng(seed=0):
    return np.random.default_rng(seed)


@pytest.fixture
def nprng():
    return rng(0)


# Dimensions are drawn as (multiplier, block) pairs so the pallas grids
# always tile exactly; blocks are kept small — interpret mode is slow.
def tiled_dims(max_blocks=3, blocks=(4, 8, 16)):
    return st.tuples(
        st.integers(1, max_blocks), st.sampled_from(blocks)
    ).map(lambda t: (t[0] * t[1], t[1]))


def f32a(r, *shape, scale=1.0):
    return (r.standard_normal(shape) * scale).astype(np.float32)
