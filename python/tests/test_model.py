"""L2: the jax compute graphs (model.py) — shape + semantics checks."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref
from .conftest import f32a, rng


def test_embed_rff_shapes_and_semantics():
    r = rng(0)
    n, d, m, t = 16, 5, 32, 8
    x = f32a(r, n, d)
    omega = f32a(r, d, m)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    h = r.integers(0, t, m).astype(np.int32)
    s = (r.integers(0, 2, m) * 2 - 1).astype(np.float32)
    e = np.asarray(model.embed_rff(x, omega, b, h, s, t=t))
    assert e.shape == (n, t)
    want = ref.countsketch(ref.rff_features(x, omega, b), h, s, t)
    np.testing.assert_allclose(e, want, rtol=1e-4, atol=1e-5)


def test_embed_rff_preserves_gram():
    """E·Eᵀ ≈ K for large m, t: the whole point of §5.1."""
    r = rng(1)
    n, d, m, t = 16, 4, 2048, 256
    sigma = 2.0
    x = f32a(r, n, d)
    omega = (r.standard_normal((d, m)) / sigma).astype(np.float32)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    h = r.integers(0, t, m).astype(np.int32)
    s = (r.integers(0, 2, m) * 2 - 1).astype(np.float32)
    e = np.asarray(model.embed_rff(x, omega, b, h, s, t=t))
    k_approx = e @ e.T
    k = np.asarray(ref.gram_gauss(x, x, 1.0 / (2 * sigma**2)))
    assert np.max(np.abs(k_approx - k)) < 0.35


def test_embed_poly_shapes():
    r = rng(2)
    n, d, q, t2, t = 8, 16, 2, 64, 8
    x = f32a(r, n, d, scale=0.5)
    hs = r.integers(0, t2, (q, d)).astype(np.int32)
    ss = (r.integers(0, 2, (q, d)) * 2 - 1).astype(np.float32)
    g = (r.standard_normal((t2, t)) / np.sqrt(t)).astype(np.float32)
    e = np.asarray(model.embed_poly(x, hs, ss, g))
    assert e.shape == (n, t)
    want = np.asarray(ref.tensorsketch(x, hs, ss, t2)) @ np.asarray(g)
    np.testing.assert_allclose(e, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_leverage_norms_matches_ref(seed):
    r = rng(seed)
    t, n = 6, 20
    zinv = f32a(r, t, t)
    e = f32a(r, t, n)
    got = np.asarray(model.leverage_norms(zinv, e))
    want = np.asarray(ref.leverage_norms(zinv, e))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_project_residual_matches_ref(seed):
    r = rng(seed)
    y, n = 5, 12
    rinv = f32a(r, y, y)
    k_ya = f32a(r, y, n)
    diag = np.abs(f32a(r, n)) + 5.0
    got_pi, got_res = model.project_residual(rinv, k_ya, diag)
    want_pi, want_res = ref.project_residual(rinv, k_ya, diag)
    np.testing.assert_allclose(got_pi, want_pi, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_res, want_res, rtol=1e-4, atol=1e-4)


def test_project_residual_exact_for_points_in_span():
    """Residual of a point that *is* in Y must be ~0 (gauss kernel)."""
    r = rng(3)
    yv = f32a(r, 4, 3)
    k_yy = np.asarray(ref.gram_gauss(yv, yv, 1.0)) + 1e-6 * np.eye(4)
    rchol = np.linalg.cholesky(k_yy).T  # K = RᵀR
    rinv_t = np.linalg.inv(rchol.T).astype(np.float32)
    k_ya = np.asarray(ref.gram_gauss(yv, yv, 1.0))  # A = Y
    diag = np.ones(4, np.float32)
    _, res = model.project_residual(rinv_t.astype(np.float32), k_ya, diag)
    assert np.max(np.asarray(res)) < 1e-3
