"""L1: Pallas CountSketch kernel vs oracle + sketch invariants."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import countsketch as cs
from compile.kernels import ref
from .conftest import f32a, rng, tiled_dims


def cs_params(r, m, t):
    h = r.integers(0, t, m).astype(np.int32)
    s = (r.integers(0, 2, m) * 2 - 1).astype(np.float32)
    return h, s


@settings(max_examples=15, deadline=None)
@given(
    nd=tiled_dims(),
    md=tiled_dims(),
    t=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31),
)
def test_countsketch_matches_ref(nd, md, t, seed):
    (n, bn), (m, bm) = nd, md
    r = rng(seed)
    x = f32a(r, n, m)
    h, s = cs_params(r, m, t)
    got = cs.countsketch(x, h, s, t, block_n=bn, block_m=bm)
    want = ref.countsketch(x, h, s, t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_countsketch_exact_scatter_semantics():
    """Hand-checkable case: every column to bucket 0 sums the row."""
    x = np.arange(12, dtype=np.float32).reshape(2, 6)
    h = np.zeros(6, np.int32)
    s = np.ones(6, np.float32)
    got = np.asarray(cs.countsketch(x, h, s, 4, block_n=2, block_m=6))
    want = np.zeros((2, 4), np.float32)
    want[:, 0] = x.sum(1)
    np.testing.assert_allclose(got, want)


def test_countsketch_sign_sensitivity():
    x = np.ones((2, 2), np.float32)
    h = np.array([1, 1], np.int32)
    s = np.array([1.0, -1.0], np.float32)
    got = np.asarray(cs.countsketch(x, h, s, 2, block_n=2, block_m=2))
    np.testing.assert_allclose(got, 0.0)


def test_countsketch_inner_product_unbiased():
    """E[CS(x)ᵀCS(y)] = xᵀy: average over many independent sketches."""
    r = rng(7)
    m, t, trials = 64, 16, 400
    x = f32a(r, 1, m)
    y = f32a(r, 1, m)
    exact = float((x @ y.T)[0, 0])
    est = []
    for _ in range(trials):
        h, s = cs_params(r, m, t)
        cx = ref.countsketch(x, h, s, t)
        cy = ref.countsketch(y, h, s, t)
        est.append(float((np.asarray(cx) @ np.asarray(cy).T)[0, 0]))
    assert abs(np.mean(est) - exact) < 0.5


def test_countsketch_accumulates_across_m_blocks():
    """Grid revisiting: m split over 4 blocks must equal single block."""
    r = rng(3)
    x = f32a(r, 8, 32)
    h, s = cs_params(r, 32, 8)
    a = np.asarray(cs.countsketch(x, h, s, 8, block_n=8, block_m=8))
    b = np.asarray(cs.countsketch(x, h, s, 8, block_n=8, block_m=32))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
