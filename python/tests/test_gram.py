"""L1: Pallas gram-block kernels vs oracle + kernel-math invariants."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gram, ref
from .conftest import f32a, rng, tiled_dims


@settings(max_examples=12, deadline=None)
@given(
    nyd=tiled_dims(),
    nxd=tiled_dims(),
    d=st.integers(2, 16),
    seed=st.integers(0, 2**31),
)
def test_gram_gauss_matches_ref(nyd, nxd, d, seed):
    (ny, by), (nx, bx) = nyd, nxd
    r = rng(seed)
    y, x = f32a(r, ny, d), f32a(r, nx, d)
    got = gram.gram_block(y, x, "gauss", gamma=0.7, block_y=by, block_x=bx)
    want = ref.gram_gauss(y, x, 0.7)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    nyd=tiled_dims(),
    nxd=tiled_dims(),
    q=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31),
)
def test_gram_poly_matches_ref(nyd, nxd, q, seed):
    (ny, by), (nx, bx) = nyd, nxd
    r = rng(seed)
    y, x = f32a(r, ny, 5, scale=0.5), f32a(r, nx, 5, scale=0.5)
    got = gram.gram_block(y, x, "poly", c=0.0, q=q, block_y=by, block_x=bx)
    want = ref.gram_poly(y, x, 0.0, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    nyd=tiled_dims(),
    degree=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31),
)
def test_gram_arccos_matches_ref(nyd, degree, seed):
    (ny, by) = nyd
    r = rng(seed)
    y, x = f32a(r, ny, 6), f32a(r, 8, 6)
    got = gram.gram_block(
        y, x, "arccos", degree=degree, block_y=by, block_x=8
    )
    want = ref.gram_arccos(y, x, degree)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gram_gauss_diagonal_ones():
    r = rng(2)
    x = f32a(r, 16, 4)
    k = np.asarray(gram.gram_block(x, x, "gauss", gamma=1.0, block_y=8, block_x=8))
    np.testing.assert_allclose(np.diag(k), 1.0, rtol=1e-5)
    assert np.all(k <= 1.0 + 1e-6) and np.all(k >= 0.0)


def test_gram_gauss_psd():
    """Gram matrices are PSD — eigenvalues ≥ -tol."""
    r = rng(5)
    x = f32a(r, 24, 6)
    k = np.asarray(gram.gram_block(x, x, "gauss", gamma=0.5, block_y=8, block_x=8))
    w = np.linalg.eigvalsh((k + k.T) / 2)
    assert w.min() > -1e-4


def test_gram_arccos_known_identical_points():
    """κ₂(x,x) = ‖x‖⁴·(1/π)·(0 + π·3) = 3‖x‖⁴? No: θ=0 ⇒ J₂ = 3π ⇒ κ = 3‖x‖⁴...

    J₂(0) = 3·0·1 + π(1+2) = 3π, κ = (1/π)‖x‖⁴·3π = 3‖x‖⁴.
    """
    x = np.array([[1.0, 1.0]], np.float32)  # ‖x‖² = 2
    k = np.asarray(gram.gram_block(x, x, "arccos", degree=2, block_y=1, block_x=1))
    np.testing.assert_allclose(k[0, 0], 3.0 * 4.0, rtol=1e-5)


def test_gram_poly_known_value():
    y = np.array([[1.0, 2.0]], np.float32)
    x = np.array([[3.0, 1.0]], np.float32)
    k = np.asarray(gram.gram_block(y, x, "poly", c=0.0, q=4, block_y=1, block_x=1))
    np.testing.assert_allclose(k[0, 0], 5.0**4, rtol=1e-6)
