"""L1: Pallas RFF / arc-cos feature kernels vs pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, rff
from .conftest import f32a, rng, tiled_dims


@settings(max_examples=15, deadline=None)
@given(nd=tiled_dims(), md=tiled_dims(), d=st.integers(3, 24), seed=st.integers(0, 2**31))
def test_rff_matches_ref(nd, md, d, seed):
    (n, bn), (m, bm) = nd, md
    r = rng(seed)
    x = f32a(r, n, d)
    omega = f32a(r, d, m)
    b = (r.uniform(0, 2 * np.pi, m)).astype(np.float32)
    got = rff.rff_features(x, omega, b, block_n=bn, block_m=bm)
    want = ref.rff_features(x, omega, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    nd=tiled_dims(),
    md=tiled_dims(),
    degree=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31),
)
def test_arccos_features_match_ref(nd, md, degree, seed):
    (n, bn), (m, bm) = nd, md
    r = rng(seed)
    x = f32a(r, n, 7)
    omega = f32a(r, 7, m)
    got = rff.arccos_features(x, omega, degree, block_n=bn, block_m=bm)
    want = ref.arccos_features(x, omega, degree)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rff_approximates_gaussian_kernel():
    """Statistical: z(x)ᵀz(y) ≈ exp(-‖x-y‖²/2σ²) with m=4096 features."""
    r = rng(1)
    d, m, n = 6, 4096, 16
    sigma = 1.5
    x = f32a(r, n, d)
    omega = (r.standard_normal((d, m)) / sigma).astype(np.float32)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    z = np.asarray(rff.rff_features(x, omega, b, block_n=8, block_m=128))
    approx = z @ z.T
    exact = np.asarray(ref.gram_gauss(x, x, 1.0 / (2 * sigma**2)))
    assert np.max(np.abs(approx - exact)) < 0.15


def test_rff_approximates_laplace_kernel():
    """The same Pallas cos-feature kernel serves the Laplacian kernel
    when ω is Cauchy-distributed (the rust coordinator's
    Kernel::Laplace path): z(x)ᵀz(y) ≈ exp(-γ‖x-y‖₁)."""
    r = rng(2)
    d, m, n = 5, 8192, 12
    gamma = 0.5
    x = (0.5 * r.standard_normal((n, d))).astype(np.float32)
    omega = (gamma * np.tan(np.pi * (r.uniform(size=(d, m)) - 0.5))).astype(np.float32)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    z = np.asarray(rff.rff_features(x, omega, b, block_n=4, block_m=128))
    approx = z @ z.T
    l1 = np.abs(x[:, None, :] - x[None, :, :]).sum(-1)
    exact = np.exp(-gamma * l1)
    assert np.max(np.abs(approx - exact)) < 0.15


def test_rff_value_known_case():
    """d=1, ω=0, b=0 ⇒ all features = sqrt(2/m)·cos(0)."""
    x = np.ones((8, 1), np.float32)
    omega = np.zeros((1, 8), np.float32)
    b = np.zeros(8, np.float32)
    z = np.asarray(rff.rff_features(x, omega, b, block_n=8, block_m=8))
    np.testing.assert_allclose(z, np.sqrt(2 / 8), rtol=1e-6)


def test_rff_rejects_untileable():
    with pytest.raises(AssertionError):
        rff.rff_features(
            np.zeros((9, 3), np.float32),
            np.zeros((3, 8), np.float32),
            np.zeros(8, np.float32),
            block_n=8,
            block_m=8,
        )


def test_vmem_estimate_positive():
    assert rff.vmem_estimate_bytes(128) > 0
