"""Padding equivalence — the contract the rust runtime relies on.

`rust/src/runtime/xla.rs` pads inputs to the artifact grid's static
shapes (zero feature-rows, zero point-columns). These tests pin the
mathematical facts that make that sound:
- zero-padding the feature dim of X and Ω/Y leaves RFF features, gram
  blocks and TensorSketch outputs unchanged;
- zero-padded point-columns produce garbage only in their own output
  columns (which rust slices away).
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import countsketch as cs
from compile.kernels import gram, ref, rff
from .conftest import f32a, rng


def pad_rows(a, rows):
    out = np.zeros((rows, a.shape[1]), np.float32)
    out[: a.shape[0]] = a
    return out


def pad_cols(a, cols):
    out = np.zeros((a.shape[0], cols), np.float32)
    out[:, : a.shape[1]] = a
    return out


@settings(max_examples=10, deadline=None)
@given(d=st.integers(2, 12), dpad=st.integers(0, 8), seed=st.integers(0, 2**31))
def test_rff_feature_dim_padding_invariant(d, dpad, seed):
    r = rng(seed)
    n, m = 8, 16
    x = f32a(r, n, d)
    omega = f32a(r, d, m)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    base = np.asarray(rff.rff_features(x, omega, b, block_n=8, block_m=16))
    xp = pad_cols(x, d + dpad)  # features are x columns here ([n, d])
    op = pad_rows(omega, d + dpad)
    padded = np.asarray(rff.rff_features(xp, op, b, block_n=8, block_m=16))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(d=st.integers(2, 10), dpad=st.integers(0, 6), seed=st.integers(0, 2**31))
def test_gram_feature_dim_padding_invariant(d, dpad, seed):
    r = rng(seed)
    y = f32a(r, 8, d)
    x = f32a(r, 8, d)
    for kind, params in [("gauss", dict(gamma=0.8)), ("poly", dict(c=0.0, q=4)), ("arccos", dict(degree=2))]:
        base = np.asarray(gram.gram_block(y, x, kind, block_y=8, block_x=8, **params))
        yp = pad_cols(y, d + dpad)
        xp = pad_cols(x, d + dpad)
        padded = np.asarray(gram.gram_block(yp, xp, kind, block_y=8, block_x=8, **params))
        np.testing.assert_allclose(padded, base, rtol=1e-5, atol=1e-5, err_msg=kind)


def test_point_column_padding_isolated():
    """Padded point-rows only affect their own output rows."""
    r = rng(3)
    n, d, m = 6, 4, 8
    x = f32a(r, n, d)
    omega = f32a(r, d, m)
    b = r.uniform(0, 2 * np.pi, m).astype(np.float32)
    base = np.asarray(rff.rff_features(x, omega, b, block_n=6, block_m=8))
    xp = pad_rows(x, 8)
    padded = np.asarray(rff.rff_features(xp, omega, b, block_n=8, block_m=8))
    np.testing.assert_allclose(padded[:n], base, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_countsketch_padded_inputs_zero_contribution(seed):
    """Extra sketch columns mapped anywhere contribute 0 for zero data."""
    r = rng(seed)
    n, m, t = 8, 16, 8
    x = f32a(r, n, m)
    h = r.integers(0, t, m).astype(np.int32)
    s = (r.integers(0, 2, m) * 2 - 1).astype(np.float32)
    base = np.asarray(cs.countsketch(x, h, s, t, block_n=8, block_m=16))
    # pad 8 zero feature-columns with arbitrary tables
    xp = pad_cols(x, m + 8)
    hp = np.concatenate([h, r.integers(0, t, 8).astype(np.int32)])
    sp = np.concatenate([s, np.ones(8, np.float32)])
    padded = np.asarray(cs.countsketch(xp, hp, sp, t, block_n=8, block_m=24))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)


def test_tensorsketch_feature_padding_invariant():
    r = rng(5)
    n, m, t, q = 4, 8, 16, 3
    x = f32a(r, n, m, scale=0.5)
    hs = r.integers(0, t, (q, m)).astype(np.int32)
    ss = (r.integers(0, 2, (q, m)) * 2 - 1).astype(np.float32)
    base = np.asarray(ref.tensorsketch(x, hs, ss, t))
    xp = pad_cols(x, m + 6)
    hsp = np.concatenate([hs, np.zeros((q, 6), np.int32)], axis=1)
    ssp = np.concatenate([ss, np.ones((q, 6), np.float32)], axis=1)
    padded = np.asarray(ref.tensorsketch(xp, hsp, ssp, t))
    np.testing.assert_allclose(padded, base, rtol=1e-5, atol=1e-5)
