"""AOT pipeline: grid construction, lowering, manifest integrity."""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile import aot


def test_grid_covers_design():
    names = [n for n, _, _ in aot.build_grid()]
    for d in aot.D_GRID:
        for fam in (
            "embed_rff",
            "embed_arccos",
            "embed_poly",
            "gram_gauss",
            "gram_poly",
            "gram_arccos",
        ):
            assert f"{fam}_d{d}" in names
    assert "leverage_norms" in names and "project_residual" in names


def test_lower_one_artifact_to_hlo_text():
    name, fn, specs = aot.build_grid()[0]
    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    ),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files():
    adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(adir, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert len(man["artifacts"]) == len(aot.build_grid())
    for art in man["artifacts"]:
        path = os.path.join(adir, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
        assert art["inputs"] and art["outputs"]
        for spec in art["inputs"] + art["outputs"]:
            assert all(dim > 0 for dim in spec["shape"])


def test_out_specs_shapes():
    # project_residual returns a 2-tuple
    name, fn, specs = [a for a in aot.build_grid() if a[0] == "project_residual"][0]
    outs = aot.out_specs(fn, [s for _, s in specs])
    assert len(outs) == 2
    assert outs[0]["shape"] == [aot.Y_PAD, aot.BLOCK_N]
    assert outs[1]["shape"] == [aot.BLOCK_N]


def test_cli_filter_runs(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--only", "leverage"],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "leverage_norms.hlo.txt").exists()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert [a["name"] for a in man["artifacts"]] == ["leverage_norms"]
