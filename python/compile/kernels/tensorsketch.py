"""TensorSketch for polynomial kernels (Pham–Pagh / Avron et al.).

TS(x) = IFFT( Π_{i<q} FFT(CS_i(x)) ) satisfies
E[TS(x)ᵀTS(y)] = (xᵀy)^q — a subspace embedding of the degree-q
polynomial feature map (paper Lemma 4). The q component CountSketches
are the Pallas hot path (MXU matmul formulation, see countsketch.py);
the FFT combine stays at the jnp level — XLA's native FFT is already a
tuned custom-call, re-deriving it in Pallas buys nothing on TPU.
"""

import jax.numpy as jnp

from . import countsketch as cs


def tensorsketch(x, hs, ss, t, *, block_n=128, block_m=128):
    """TensorSketch: x [n,m], hs/ss [q,m] -> [n,t] (real f32)."""
    q = hs.shape[0]
    acc = None
    for i in range(q):
        c = cs.countsketch(
            x, hs[i], ss[i], t, block_n=block_n, block_m=block_m
        )
        f = jnp.fft.fft(c, axis=1)
        acc = f if acc is None else acc * f
    return jnp.real(jnp.fft.ifft(acc, axis=1)).astype(jnp.float32)
