"""Pallas kernel: tiled random-feature blocks (RFF and arc-cos).

The hot loop of the worker-local kernel subspace embedding (paper §5.1)
is Z = sqrt(2/m)·cos(XΩ + b): an [n,d]×[d,m] matmul with a fused
elementwise epilogue. We tile over (n, m) with BlockSpec so each grid
step keeps one (bn,d)·(d,bm) tile pair VMEM-resident and applies the
epilogue while the tile is still on-chip (single HBM pass).

TPU mapping (DESIGN.md §Hardware-Adaptation): the matmul feeds the MXU
in (bn×d)·(d×bm) tiles; cos/relu-power run on the VPU over the same
VMEM tile. interpret=True everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rff_kernel(x_ref, omega_ref, b_ref, o_ref, *, scale):
    """One (bn, bm) output tile: scale * cos(x @ omega + b)."""
    acc = jnp.dot(x_ref[...], omega_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = scale * jnp.cos(acc + b_ref[...][None, :])


def _arccos_kernel(x_ref, omega_ref, o_ref, *, scale, degree):
    """One (bn, bm) output tile: scale * Θ(x@omega)·(x@omega)^degree."""
    acc = jnp.dot(x_ref[...], omega_ref[...], preferred_element_type=jnp.float32)
    pos = (acc > 0).astype(jnp.float32)
    r = pos if degree == 0 else pos * acc**degree
    o_ref[...] = scale * r


def _grid_specs(n, d, m, bn, bm, with_bias):
    grid = (n // bn, m // bm)
    in_specs = [
        pl.BlockSpec((bn, d), lambda i, j: (i, 0)),  # X tile: row block, full d
        pl.BlockSpec((d, bm), lambda i, j: (0, j)),  # Ω tile: full d, col block
    ]
    if with_bias:
        in_specs.append(pl.BlockSpec((bm,), lambda i, j: (j,)))
    out_spec = pl.BlockSpec((bn, bm), lambda i, j: (i, j))
    return grid, in_specs, out_spec


def rff_features(x, omega, b, *, block_n=128, block_m=128):
    """Pallas RFF features: [n,d],[d,m],[m] -> [n,m]. Shapes must tile."""
    n, d = x.shape
    m = omega.shape[1]
    bn, bm = min(block_n, n), min(block_m, m)
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid, in_specs, out_spec = _grid_specs(n, d, m, bn, bm, True)
    scale = float(2.0 / m) ** 0.5  # python scalar: pallas kernels must not capture tracers
    return pl.pallas_call(
        functools.partial(_rff_kernel, scale=scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, omega, b)


def arccos_features(x, omega, degree, *, block_n=128, block_m=128):
    """Pallas arc-cos random features: [n,d],[d,m] -> [n,m]."""
    n, d = x.shape
    m = omega.shape[1]
    bn, bm = min(block_n, n), min(block_m, m)
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid, in_specs, out_spec = _grid_specs(n, d, m, bn, bm, False)
    scale = float(2.0 / m) ** 0.5
    return pl.pallas_call(
        functools.partial(_arccos_kernel, scale=scale, degree=float(degree)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, omega)


def vmem_estimate_bytes(d, bn=128, bm=128):
    """Estimated VMEM residency of one grid step (f32): X + Ω + b + out."""
    return 4 * (bn * d + d * bm + bm + bn * bm)
