"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact (up to float assoc.)
counterpart here; pytest asserts allclose between the two across a
hypothesis-driven shape sweep. These are also the semantic spec for the
native rust fallbacks in ``rust/src/kernels``.
"""

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- RFF ----
def rff_features(x, omega, b):
    """Random Fourier features for a shift-invariant kernel.

    x: [n, d], omega: [d, m], b: [m]  ->  [n, m]
    z(x) = sqrt(2/m) * cos(x @ omega + b); E[z(x)ᵀz(y)] = κ(x - y).
    """
    m = omega.shape[1]
    return jnp.sqrt(2.0 / m) * jnp.cos(x @ omega + b[None, :])


def arccos_features(x, omega, degree):
    """Arc-cosine random features (Cho & Saul): sqrt(2/m)·Θ(wᵀx)(wᵀx)^deg.

    degree 0 is the pure Heaviside indicator — (relu(a))**0 would
    wrongly map clamped zeros to one.
    """
    m = omega.shape[1]
    a = x @ omega
    pos = (a > 0).astype(jnp.float32)
    if degree == 0:
        feats = pos
    else:
        feats = pos * a**degree
    return jnp.sqrt(2.0 / m) * feats


# --------------------------------------------------------- CountSketch ----
def countsketch_matrix(h, s, t):
    """Dense [m, t] CountSketch matrix: S[j, h[j]] = s[j]."""
    return (s[:, None] * (h[:, None] == jnp.arange(t)[None, :])).astype(
        jnp.float32
    )


def countsketch(x, h, s, t):
    """Apply CountSketch along the feature axis: [n, m] -> [n, t].

    out[:, h[j]] += s[j] * x[:, j]   (h: [m] buckets, s: [m] ±1 signs)
    """
    return x @ countsketch_matrix(h, s, t)


# -------------------------------------------------------- Gram blocks ----
def sqdist(x, y):
    """Pairwise squared euclidean distances. x: [nx, d], y: [ny, d]."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    yy = jnp.sum(y * y, axis=1)[None, :]
    return jnp.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)


def gram_gauss(x, y, gamma):
    """Gaussian RBF gram block: exp(-gamma * ||x - y||²)."""
    return jnp.exp(-gamma * sqdist(x, y))


def gram_poly(x, y, c, q):
    """Polynomial gram block: (xᵀy + c)^q."""
    return (x @ y.T + c) ** q


def gram_arccos(x, y, degree):
    """Arc-cosine gram block of degree 0, 1 or 2 (Cho & Saul 2009).

    κ_n(x,y) = (1/π) ‖x‖ⁿ‖y‖ⁿ J_n(θ),  θ = arccos(xᵀy / ‖x‖‖y‖)
      J_0 = π - θ
      J_1 = sin θ + (π - θ) cos θ
      J_2 = 3 sinθ cosθ + (π - θ)(1 + 2cos²θ)
    """
    nx = jnp.sqrt(jnp.sum(x * x, axis=1))[:, None]
    ny = jnp.sqrt(jnp.sum(y * y, axis=1))[None, :]
    denom = jnp.maximum(nx * ny, 1e-30)
    cos_t = jnp.clip((x @ y.T) / denom, -1.0, 1.0)
    theta = jnp.arccos(cos_t)
    sin_t = jnp.sqrt(jnp.maximum(1.0 - cos_t * cos_t, 0.0))
    if degree == 0:
        j = jnp.pi - theta
        scale = 1.0
    elif degree == 1:
        j = sin_t + (jnp.pi - theta) * cos_t
        scale = nx * ny
    elif degree == 2:
        j = 3.0 * sin_t * cos_t + (jnp.pi - theta) * (1.0 + 2.0 * cos_t**2)
        scale = (nx * ny) ** 2
    else:
        raise ValueError(f"unsupported arc-cos degree {degree}")
    return (1.0 / jnp.pi) * scale * j


# -------------------------------------------------------- TensorSketch ----
def tensorsketch(x, hs, ss, t):
    """TensorSketch of the degree-q polynomial feature map (Pham–Pagh).

    x: [n, m]; hs, ss: [q, m] independent CountSketch params.
    Returns [n, t] with E[TS(x)ᵀTS(y)] = (xᵀy)^q.
    Computed as IFFT( Π_q FFT(CS_q(x)) ).
    """
    q = hs.shape[0]
    acc = None
    for i in range(q):
        c = countsketch(x, hs[i], ss[i], t)
        f = jnp.fft.fft(c, axis=1)
        acc = f if acc is None else acc * f
    return jnp.real(jnp.fft.ifft(acc, axis=1))


# ------------------------------------------------- protocol-side math ----
def leverage_norms(zinv_t, e):
    """Column squared norms of (Zᵀ)⁻¹E.  zinv_t: [t, t], e: [t, n] -> [n]."""
    u = zinv_t @ e
    return jnp.sum(u * u, axis=0)


def project_residual(rinv_t, k_ya, diag_a):
    """Kernel-trick projection onto span φ(Y) + squared residuals.

    rinv_t: [y, y] = R⁻ᵀ from K(Y,Y) = RᵀR;  k_ya: [y, n];  diag_a: [n]
    Returns (Π = R⁻ᵀ K(Y,A): [y, n], residuals: [n]).
    """
    pi = rinv_t @ k_ya
    res = jnp.maximum(diag_a - jnp.sum(pi * pi, axis=0), 0.0)
    return pi, res


# --------------------------------------------------------------- numpy ----
def np_median_pairwise(x, sample=None, seed=0):
    """Median pairwise distance ("median trick") — numpy helper for tests."""
    rng = np.random.default_rng(seed)
    x = np.asarray(x)
    if sample is not None and x.shape[0] > sample:
        x = x[rng.choice(x.shape[0], sample, replace=False)]
    d2 = np.maximum(
        (x * x).sum(1)[:, None] + (x * x).sum(1)[None, :] - 2 * x @ x.T, 0
    )
    iu = np.triu_indices(x.shape[0], 1)
    return float(np.sqrt(np.median(d2[iu])))
