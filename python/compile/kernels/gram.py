"""Pallas kernels: tiled gram (kernel-matrix) blocks.

K(Y, X) blocks drive both RepSample's adaptive sampling and disLR's
projection (paper §5.3–5.4, Appendix A). Each is an MXU matmul
Yᵀ·X tile with a fused elementwise kernel-map epilogue (exp / integer
power / arc-cos closed form) applied while the tile is VMEM-resident.
Row norms needed by the gauss/arccos maps are computed per-tile from
the same VMEM-resident operands — cheaper than a second HBM pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gauss_kernel(y_ref, x_ref, o_ref, *, gamma):
    y = y_ref[...]  # [by, d]
    x = x_ref[...]  # [bx, d]
    dots = jnp.dot(y, x.T, preferred_element_type=jnp.float32)
    yy = jnp.sum(y * y, axis=1)[:, None]
    xx = jnp.sum(x * x, axis=1)[None, :]
    d2 = jnp.maximum(yy + xx - 2.0 * dots, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


def _poly_kernel(y_ref, x_ref, o_ref, *, c, q):
    dots = jnp.dot(y_ref[...], x_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = (dots + c) ** q


def _arccos_kernel(y_ref, x_ref, o_ref, *, degree):
    y = y_ref[...]
    x = x_ref[...]
    dots = jnp.dot(y, x.T, preferred_element_type=jnp.float32)
    ny = jnp.sqrt(jnp.sum(y * y, axis=1))[:, None]
    nx = jnp.sqrt(jnp.sum(x * x, axis=1))[None, :]
    denom = jnp.maximum(ny * nx, 1e-30)
    cos_t = jnp.clip(dots / denom, -1.0, 1.0)
    theta = jnp.arccos(cos_t)
    sin_t = jnp.sqrt(jnp.maximum(1.0 - cos_t * cos_t, 0.0))
    if degree == 0:
        j = jnp.pi - theta
        scale = jnp.ones_like(denom)
    elif degree == 1:
        j = sin_t + (jnp.pi - theta) * cos_t
        scale = ny * nx
    else:  # degree 2
        j = 3.0 * sin_t * cos_t + (jnp.pi - theta) * (1.0 + 2.0 * cos_t**2)
        scale = (ny * nx) ** 2
    o_ref[...] = (1.0 / jnp.pi) * scale * j


_KERNELS = {
    "gauss": _gauss_kernel,
    "poly": _poly_kernel,
    "arccos": _arccos_kernel,
}


def gram_block(y, x, kind, *, block_y=128, block_x=128, **params):
    """Pallas gram block K(y, x): [ny,d],[nx,d] -> [ny,nx].

    kind: "gauss" (gamma=), "poly" (c=, q=), "arccos" (degree=).
    """
    ny, d = y.shape
    nx = x.shape[0]
    by, bx = min(block_y, ny), min(block_x, nx)
    assert ny % by == 0 and nx % bx == 0, (ny, nx, by, bx)
    kern = functools.partial(_KERNELS[kind], **params)
    return pl.pallas_call(
        kern,
        grid=(ny // by, nx // bx),
        in_specs=[
            pl.BlockSpec((by, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((by, bx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ny, nx), jnp.float32),
        interpret=True,
    )(y, x)


def vmem_estimate_bytes(d, by=128, bx=128):
    """VMEM residency of one grid step: Y tile + X tile + out tile."""
    return 4 * (by * d + bx * d + by * bx)
