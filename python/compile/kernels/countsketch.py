"""Pallas kernel: CountSketch along the feature axis.

CountSketch is a scatter-add on GPU (each input column lands in bucket
h[j] with sign s[j]). Scatter is MXU-hostile on TPU, so we use the
matmul formulation (DESIGN.md §Hardware-Adaptation): for a column block
J, the sketch matrix tile S[J, :] = s[J]·onehot(h[J]) is materialized
on the fly in VMEM and the output tile accumulates X[:, J] @ S[J, :] —
a (bn×bm)·(bm×t) MXU matmul per grid step, revisiting the output block
across the m-axis of the grid (sequential grid ⇒ safe accumulation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cs_kernel(x_ref, h_ref, s_ref, o_ref, *, t):
    """Accumulate one (bn, t) output tile from one (bn, bm) input tile."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...]  # [bm] int32 buckets
    s = s_ref[...]  # [bm] ±1 signs
    onehot = (h[:, None] == jnp.arange(t, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )
    o_ref[...] += jnp.dot(
        x_ref[...], s[:, None] * onehot, preferred_element_type=jnp.float32
    )


def countsketch(x, h, s, t, *, block_n=128, block_m=128):
    """Pallas CountSketch: x [n,m], h,s [m] -> [n,t]. Shapes must tile."""
    n, m = x.shape
    bn, bm = min(block_n, n), min(block_m, m)
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        lambda xr, hr, sr, orf: _cs_kernel(xr, hr, sr, orf, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, t), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), jnp.float32),
        interpret=True,
    )(x, h.astype(jnp.int32), s.astype(jnp.float32))


def vmem_estimate_bytes(t, bn=128, bm=128):
    """VMEM residency of one grid step: X tile + onehot tile + out tile."""
    return 4 * (bn * bm + bm * t + bn * t) + 8 * bm
