"""L2 — the worker-local compute graphs of disKPCA, in JAX.

Each function here is a fixed-shape graph over one *column block* of a
worker's local data (rust loops blocks and pads, see
``rust/src/runtime``). They call the Pallas L1 kernels and are lowered
once by ``aot.py`` to HLO text artifacts.

Dynamic-parameter conventions (so artifacts stay static-shape):
- Gaussian γ is baked to 1.0 — rust pre-scales data by √γ (distances
  scale: ‖√γx − √γy‖² = γ‖x−y‖²), and draws Ω already scaled by 1/σ.
- polynomial is the paper's homogeneous κ = ⟨x,y⟩^q with q static per
  artifact; an inhomogeneous kernel is obtained by appending a √c
  constant coordinate on the rust side.
- arc-cos degree is static per artifact.
"""

import jax.numpy as jnp

from .kernels import countsketch as cs_k
from .kernels import gram as gram_k
from .kernels import rff as rff_k
from .kernels import tensorsketch as ts_k


# ------------------------------------------------ kernel space embeds ----
def embed_rff(x, omega, b, h, s, *, t):
    """E-block for shift-invariant kernels: CountSketch(RFF(x)).

    x: [n, d], omega: [d, m], b: [m], h/s: [m]  ->  [n, t]
    (paper §5.1: S(φ(x)) = T·R(φ(x)) with T = CountSketch.)
    """
    z = rff_k.rff_features(x, omega, b)
    return cs_k.countsketch(z, h, s, t)


def embed_arccos(x, omega, h, s, *, t, degree):
    """E-block for arc-cosine kernels: CountSketch(relu-features(x))."""
    z = rff_k.arccos_features(x, omega, degree)
    return cs_k.countsketch(z, h, s, t)


def embed_poly(x, hs, ss, g):
    """E-block for polynomial kernels: TensorSketch then Gaussian sketch.

    x: [n, d], hs/ss: [q, d], g: [t2, t]  ->  [n, t]
    (paper Lemma 4: TENSORSKETCH to O(3^q k²) dims, then dense Gaussian
    down to t = O(k/ε).)
    """
    t2 = g.shape[0]
    ts = ts_k.tensorsketch(x, hs, ss, t2)
    return jnp.dot(ts, g, preferred_element_type=jnp.float32)


# ----------------------------------------------------------- gram ops ----
def gram_gauss(y, x):
    """K(Y, X) Gaussian block, γ baked to 1 (rust pre-scales)."""
    return gram_k.gram_block(y, x, "gauss", gamma=1.0)


def gram_poly(y, x, *, q):
    """K(Y, X) homogeneous polynomial block ⟨y,x⟩^q."""
    return gram_k.gram_block(y, x, "poly", c=0.0, q=q)


def gram_arccos(y, x, *, degree):
    """K(Y, X) arc-cosine block."""
    return gram_k.gram_block(y, x, "arccos", degree=degree)


# ------------------------------------------------ protocol-side math ----
def leverage_norms(zinv_t, e):
    """disLS step 3 (paper Alg. 1): ℓⱼ = ‖((Zᵀ)⁻¹E)_{:j}‖²."""
    u = jnp.dot(zinv_t, e, preferred_element_type=jnp.float32)
    return jnp.sum(u * u, axis=0)


def project_residual(rinv_t, k_ya, diag_a):
    """Appendix A kernel-trick projection: Π = R⁻ᵀK(Y,A), residuals.

    Returns (Π: [y, n], res: [n]) with res_j = κ(a_j,a_j) − ‖Π_{:j}‖².
    """
    pi = jnp.dot(rinv_t, k_ya, preferred_element_type=jnp.float32)
    res = jnp.maximum(diag_a - jnp.sum(pi * pi, axis=0), 0.0)
    return pi, res
