"""AOT: lower every L2 graph over the shape grid to HLO-text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs ``artifacts/<name>.hlo.txt`` plus ``artifacts/manifest.json``
(consumed by ``rust/src/runtime/manifest.rs``). Python runs only here —
never on the request path.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static shape grid (DESIGN.md §5). Rust pads inputs to these shapes.
D_GRID = [32, 128, 512, 1024]
BLOCK_N = 256  # data-column block
M_RFF = 512  # random features per block
T_EMBED = 64  # kernel-subspace-embedding dim t = O(k)
T2_TS = 512  # TensorSketch dim before Gaussian down-projection
Y_PAD = 512  # padded |Y| for gram/projection artifacts
POLY_Q = 4  # paper's experiment setting
ARCCOS_DEG = 2  # paper's experiment setting


def f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def build_grid():
    """(name, fn, [arg specs]) for every artifact."""
    arts = []
    for d in D_GRID:
        arts.append(
            (
                f"embed_rff_d{d}",
                functools.partial(model.embed_rff, t=T_EMBED),
                [
                    ("x", f32(BLOCK_N, d)),
                    ("omega", f32(d, M_RFF)),
                    ("b", f32(M_RFF)),
                    ("h", i32(M_RFF)),
                    ("s", f32(M_RFF)),
                ],
            )
        )
        arts.append(
            (
                f"embed_arccos_d{d}",
                functools.partial(
                    model.embed_arccos, t=T_EMBED, degree=ARCCOS_DEG
                ),
                [
                    ("x", f32(BLOCK_N, d)),
                    ("omega", f32(d, M_RFF)),
                    ("h", i32(M_RFF)),
                    ("s", f32(M_RFF)),
                ],
            )
        )
        arts.append(
            (
                f"embed_poly_d{d}",
                model.embed_poly,
                [
                    ("x", f32(BLOCK_N, d)),
                    ("hs", i32(POLY_Q, d)),
                    ("ss", f32(POLY_Q, d)),
                    ("g", f32(T2_TS, T_EMBED)),
                ],
            )
        )
        arts.append(
            (
                f"gram_gauss_d{d}",
                model.gram_gauss,
                [("y", f32(Y_PAD, d)), ("x", f32(BLOCK_N, d))],
            )
        )
        arts.append(
            (
                f"gram_poly_d{d}",
                functools.partial(model.gram_poly, q=POLY_Q),
                [("y", f32(Y_PAD, d)), ("x", f32(BLOCK_N, d))],
            )
        )
        arts.append(
            (
                f"gram_arccos_d{d}",
                functools.partial(model.gram_arccos, degree=ARCCOS_DEG),
                [("y", f32(Y_PAD, d)), ("x", f32(BLOCK_N, d))],
            )
        )
    arts.append(
        (
            "leverage_norms",
            model.leverage_norms,
            [("zinv_t", f32(T_EMBED, T_EMBED)), ("e", f32(T_EMBED, BLOCK_N))],
        )
    )
    arts.append(
        (
            "project_residual",
            model.project_residual,
            [
                ("rinv_t", f32(Y_PAD, Y_PAD)),
                ("k_ya", f32(Y_PAD, BLOCK_N)),
                ("diag_a", f32(BLOCK_N)),
            ],
        )
    )
    return arts


def to_hlo_text(lowered):
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(name, spec):
    return {
        "name": name,
        "shape": list(spec.shape),
        "dtype": str(spec.dtype),
    }


def out_specs(fn, specs):
    outs = jax.eval_shape(fn, *specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "static": {
            "block_n": BLOCK_N,
            "m_rff": M_RFF,
            "t_embed": T_EMBED,
            "t2_ts": T2_TS,
            "y_pad": Y_PAD,
            "poly_q": POLY_Q,
            "arccos_deg": ARCCOS_DEG,
            "d_grid": D_GRID,
        },
        "artifacts": [],
    }
    for name, fn, specs in build_grid():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [spec_json(n, s) for n, s in specs],
                "outputs": out_specs(fn, [s for _, s in specs]),
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
        )
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
