//! Kernel column subset selection + downstream applications.
//!
//! The paper's §5.3 subroutine solves distributed kernel CSS with
//! O(k log k + k/ε) selected columns. This example exercises it as a
//! first-class API and feeds the selected columns into two downstream
//! consumers the paper motivates:
//!
//!   1. the span-residual certificate (how much kernel mass the
//!      selected columns capture vs uniform selection),
//!   2. distributed kernel ridge regression restricted to the selected
//!      columns (Nyström-style normal equations, O(s|Y|²) words),
//!
//! and finishes with the Theorem-1 repetition boost.
//!
//!     cargo run --release --example css_downstream

use std::sync::Arc;

use diskpca::comm::request;
use diskpca::coordinator::{
    baselines::dis_uniform_sample, dis_css, dis_kpca_boosted, dis_krr, reps_for_confidence,
    run_cluster, Params,
};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn main() {
    // Imbalanced data — the regime where non-uniform sampling earns
    // its keep: 540 points in 2 bulk clusters plus 6 rare clusters of
    // 10 points each, far away. Uniform selection keeps missing the
    // rare clusters; leverage + adaptive sampling hunts them down.
    let mut rng = Rng::seed_from(13);
    let bulk = clusters(12, 540, 2, 0.2, &mut rng);
    let mut rare = clusters(12, 60, 6, 0.05, &mut rng);
    rare.scale(6.0);
    let data = Data::Dense(bulk.hcat(&rare));
    let gamma = median_trick_gamma(&data, 0.2, 200, &mut rng);
    let kernel = Kernel::Gauss { gamma };
    // a tight budget (|Y| ≈ 30 for 8 clusters) is where selection
    // quality matters most
    let params = Params { k: 8, n_lev: 10, n_adapt: 20, ..Params::default() };

    // ---- 1. CSS vs uniform column selection -------------------------
    let shards = partition_power_law(&data, 4, 3);
    let ((css, uni_residual), stats) = run_cluster(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        move |cluster| {
            let css = dis_css(cluster, kernel, &params).expect("worker failure");
            // uniform selection of the same size, certified the same way
            let uni = dis_uniform_sample(cluster, css.y.len(), 99).expect("worker failure");
            let uni_residual: f64 = cluster
                .broadcast(request::Residuals { pts: uni })
                .expect("worker failure")
                .into_iter()
                .sum();
            (css, uni_residual)
        },
    );
    println!("== kernel column subset selection ==");
    println!("selected columns |Y|   = {}", css.y.len());
    println!("css residual fraction  = {:.4}", css.residual_fraction());
    println!("uniform residual frac. = {:.4}", uni_residual / css.trace);
    println!("communication          = {} words", stats.total_words());

    // ---- 2. distributed KRR on the selected columns -----------------
    let shards = partition_power_law(&data, 4, 3);
    let (model, krr_stats) = run_cluster(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        move |cluster| {
            let css = dis_css(cluster, kernel, &params).expect("worker failure");
            dis_krr(cluster, kernel, &css.y, 1e-3, 2026).expect("worker failure")
        },
    );
    println!("\n== downstream: kernel ridge regression on Y ==");
    println!("train MSE    = {:.5} (target power {:.4})", model.train_mse, model.target_power);
    println!("R²           = {:.4}", model.r_squared());
    println!("KRR comm     = {} words (O(s·|Y|²))", krr_stats.round_words("9-krr"));

    // ---- 3. Theorem-1 repetition boosting ---------------------------
    let delta = 1e-4;
    let reps = reps_for_confidence(delta);
    let shards = partition_power_law(&data, 4, 3);
    let (run, _) = run_cluster(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        move |cluster| dis_kpca_boosted(cluster, kernel, &params, reps).expect("worker failure"),
    );
    println!("\n== boosted disKPCA (δ = {delta}, {reps} repetitions) ==");
    for (i, e) in run.errors.iter().enumerate() {
        let mark = if i == run.winner { "  <- winner" } else { "" };
        println!("attempt {i}: err/tr = {:.4}{mark}", e / run.trace);
    }
    assert!(run.errors[run.winner] <= run.errors.iter().cloned().fold(f64::INFINITY, f64::min));
}
