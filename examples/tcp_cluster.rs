//! disKPCA over a real TCP star — every protocol message serialized
//! through the wire codec on loopback sockets. Proves the coordinator
//! never relies on shared memory, and cross-checks the word
//! accounting against actual bytes on the wire.
//!
//!     cargo run --release --example tcp_cluster

use std::sync::Arc;

use diskpca::comm::{tcp, Cluster, CommStats};
use diskpca::coordinator::{dis_eval, dis_kpca, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from(17);
    let data = Data::Dense(clusters(12, 600, 4, 0.2, &mut rng));
    let s = 5;
    let shards = partition_power_law(&data, s, 8);
    let kernel = Kernel::Gauss { gamma: 0.6 };

    // TCP star on loopback.
    let (star, endpoints) = tcp::star(s)?;
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let backend = Arc::new(NativeBackend::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = backend.clone();
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();

    let params = Params { k: 6, n_lev: 20, n_adapt: 60, ..Params::default() };
    let sol = dis_kpca(&cluster, kernel, &params)?;
    let (err, trace) = dis_eval(&cluster)?;
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    println!("disKPCA over TCP loopback: s={s}, |Y|={}", sol.num_points());
    println!("relative error = {:.4}", err / trace);
    println!("\nper-round words (counted at the accounting layer):");
    for (round, up, down) in stats.table() {
        println!("  {round:<14} up {up:>9}  down {down:>9}");
    }
    println!("total = {} words ≈ {} KiB on the wire", stats.total_words(), stats.total_words() * 8 / 1024);
    Ok(())
}
