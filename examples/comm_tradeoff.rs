//! Communication–accuracy tradeoff on a *sparse* workload — the
//! regime (paper Fig. 4, bow dataset) where disKPCA's nnz-dependent
//! communication shines: sampled points ship as (index, value) pairs,
//! so informed sampling buys more accuracy per word.
//!
//!     cargo run --release --example comm_tradeoff


use diskpca::coordinator::Params;
use diskpca::config::Config;
use diskpca::experiments::{run_method, Ctx, Method};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    cfg.set("scale", "0.25");
    cfg.set("workers", "16");
    let ctx = Ctx::from_config(&cfg)?;
    let spec = ctx.dataset("bow_like")?;
    let data = spec.generate(ctx.seed);
    let kernel = ctx.kernel("poly", &data);
    println!(
        "bow_like: n={} d={} ρ={:.1} (sparse), kernel {}",
        data.len(),
        data.dim(),
        data.avg_nnz_per_point(),
        kernel.name()
    );
    println!(
        "\n{:<20} {:>8} {:>6} {:>12} {:>12}",
        "method", "n_adapt", "|Y|", "comm(words)", "err/n"
    );
    for n_adapt in [50usize, 100, 200, 400] {
        for method in [Method::DisKpca, Method::UniformDisLr] {
            let params = Params { n_adapt, ..ctx.cfg.params() };
            let r = run_method(&ctx, &spec, &data, kernel, &params, method)?;
            println!(
                "{:<20} {:>8} {:>6} {:>12} {:>12.5}",
                r.method, n_adapt, r.num_points, r.comm_words, r.err_per_point
            );
        }
    }
    println!("\nexpected shape (paper Fig. 4a): error falls with communication;");
    println!("disKPCA dominates uniform at equal words on sparse data.");
    Ok(())
}
