//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer
//! system on a realistic small workload.
//!
//! - loads the AOT artifacts (L1 Pallas kernels inside L2 JAX graphs,
//!   compiled to HLO text by `make artifacts`) into the PJRT runtime;
//! - generates the mnist8m-like workload (784-dim, cluster-structured)
//!   plus the sparse bow-like workload (4096-dim, Zipf);
//! - runs disKPCA and both uniform baselines at matched |Y| over the
//!   power-law partition, with the Gaussian and polynomial kernels;
//! - reports the paper's headline metric — low-rank approximation
//!   error vs communication — plus the per-round word accounting.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use std::sync::Arc;

use diskpca::config::Config;
use diskpca::experiments::{run_method, Ctx, Method};
use diskpca::runtime::XlaBackend;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    cfg.set("scale", &std::env::var("E2E_SCALE").unwrap_or_else(|_| "0.25".into()));
    cfg.set("workers", "8");
    cfg.set("n_lev", "50");
    let xla = Arc::new(XlaBackend::load("artifacts")?);
    let ctx = Ctx::with_backend(&cfg, xla.clone(), "xla".into())?;

    println!("=== diskpca end-to-end pipeline (backend: xla/PJRT) ===\n");
    for (dataset, family) in [("mnist8m_like", "gauss"), ("bow_like", "poly")] {
        let spec = ctx.dataset(dataset)?;
        let data = spec.generate(ctx.seed);
        let kernel = ctx.kernel(family, &data);
        println!(
            "--- {dataset}: n={} d={} s={} ρ={:.0} kernel={} ---",
            data.len(),
            data.dim(),
            spec.s,
            data.avg_nnz_per_point(),
            kernel.name()
        );
        println!(
            "{:<20} {:>6} {:>12} {:>12} {:>9}",
            "method", "|Y|", "comm(words)", "err/n", "wall(s)"
        );
        for n_adapt in [100usize, 200] {
            let mut params = ctx.cfg.params();
            params.n_adapt = n_adapt;
            for method in Method::all() {
                let r = run_method(&ctx, &spec, &data, kernel, &params, method)?;
                println!(
                    "{:<20} {:>6} {:>12} {:>12.5} {:>9.2}",
                    format!("{} (Ŷ={n_adapt})", r.method),
                    r.num_points,
                    r.comm_words,
                    r.err_per_point,
                    r.wall_secs
                );
            }
        }
        println!();
    }

    // Surface the runtime's own accounting: every heavy op should have
    // gone through XLA, not the native fallback.
    use std::sync::atomic::Ordering;
    println!(
        "XLA runtime: {} artifact calls, {} compiles, {} native fallbacks",
        xla.stats.calls.load(Ordering::Relaxed),
        xla.stats.compiles.load(Ordering::Relaxed),
        xla.stats.fallbacks.load(Ordering::Relaxed),
    );
    println!("see EXPERIMENTS.md §E2E for the recorded run");
    Ok(())
}
