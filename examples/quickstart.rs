//! # Quickstart — distributed kernel PCA, end to end
//!
//! A runnable tour of the whole system in five steps:
//!
//! 1. **Data.** Generate a clustered synthetic dataset (the paper's
//!    experiments use Table-1 datasets; `diskpca::data::by_name` has
//!    scaled analogues — here a raw generator keeps it self-contained).
//! 2. **Kernel.** Pick the bandwidth with the paper's median trick
//!    (σ = 0.2 · median pairwise distance, γ = 1/(2σ²)).
//! 3. **Partition.** Split the points across 4 workers with power-law
//!    shard sizes, like the paper's arbitrary-partition model.
//! 4. **disKPCA.** `run_cluster` spawns one thread per worker over the
//!    in-memory star transport and runs Alg. 4: embed → disLS →
//!    RepSample → disLR. Every word that crosses a link is counted —
//!    the printed total is the paper's x-axis.
//! 5. **Evaluate.** Compare the distributed solution's residual error
//!    against the single-machine batch optimum at the same rank.
//!
//! Run it:
//!
//! ```text
//! cargo run --release --example quickstart
//! DISKPCA_THREADS=4 cargo run --release --example quickstart   # same bits, faster
//! ```
//!
//! The thread count only changes wall time: the compute pool never
//! reorders a floating-point reduction, so the solution, the error,
//! and the word counts below are bit-identical for every setting
//! (`rust/tests/par_engine.rs` enforces this).

use std::sync::Arc;

use diskpca::coordinator::{batch_kpca, dis_eval, dis_kpca, run_cluster, Params};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn main() {
    // ---- 1. A dataset: 800 points in R^16, 5 latent clusters -------
    let mut rng = Rng::seed_from(7);
    let data = Data::Dense(clusters(16, 800, 5, 0.25, &mut rng));

    // ---- 2. Kernel bandwidth by the paper's median trick -----------
    let gamma = median_trick_gamma(&data, 0.2, 200, &mut rng);
    let kernel = Kernel::Gauss { gamma };
    println!("kernel:  {}", kernel.name());
    println!("threads: {} (set DISKPCA_THREADS or --threads to scale)", diskpca::par::threads());

    // ---- 3. Partition over 4 workers (power-law sizes) -------------
    let shards = partition_power_law(&data, 4, 42);
    println!("shard sizes: {:?}", shards.iter().map(|s| s.len()).collect::<Vec<_>>());

    // ---- 4. disKPCA: k = 8 components from |Y| ≈ 20 + 60 samples ---
    // Params mirror the paper's §6.2 defaults, scaled down; `threads`
    // is 0 here, meaning "inherit the process-wide pool setting".
    let params = Params { k: 8, n_lev: 20, n_adapt: 60, ..Params::default() };
    let t0 = std::time::Instant::now();
    let ((solution, err, trace), stats) = run_cluster(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        move |cluster| {
            let sol = dis_kpca(cluster, kernel, &params).expect("worker failure");
            let (err, trace) = dis_eval(cluster).expect("worker failure");
            (sol, err, trace)
        },
    );
    let wall = t0.elapsed();

    // ---- 5. Compare with the single-machine optimum ----------------
    let batch = batch_kpca(&data.to_dense(), kernel, 8, false, 1);
    println!("\nrepresentative points |Y| = {}", solution.num_points());
    println!("communication          = {} words", stats.total_words());
    println!("wall time              = {wall:.2?}");
    println!("disKPCA error          = {:.4} ({:.1}% of tr K)", err, 100.0 * err / trace);
    println!("batch optimum          = {:.4}", batch.opt_error);
    println!("relative approximation = {:.3}×", err / batch.opt_error.max(1e-12));
    assert!(err >= batch.opt_error - 1e-6, "impossible: beat the optimum");

    // Per-round word table — the communication profile of Fig 4–6.
    println!("\nper-round words (up = worker→master):");
    for (round, up, down) in stats.table() {
        println!("  {round:<14} up {up:>8}  down {down:>8}");
    }

    // The solution is (Y, C) with L = φ(Y)·C: project new points via
    // LᵀΦ(x) = Cᵀ·K(Y, x) without ever materializing φ.
    println!("\nproject new points: solution.project(&data) -> {}×n matrix", solution.k());
}
