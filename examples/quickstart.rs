//! Quickstart: distributed kernel PCA in ~40 lines.
//!
//! Generates a clustered synthetic dataset, partitions it over 4
//! workers (power law, like the paper), runs disKPCA with a Gaussian
//! kernel, and compares the achieved low-rank error against the batch
//! optimum computed on one machine.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use diskpca::coordinator::{batch_kpca, dis_eval, dis_kpca, run_cluster, Params};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn main() {
    // 1. A dataset: 800 points in R^16, 5 latent clusters.
    let mut rng = Rng::seed_from(7);
    let data = Data::Dense(clusters(16, 800, 5, 0.25, &mut rng));

    // 2. Kernel bandwidth by the paper's median trick (σ = 0.2·median).
    let gamma = median_trick_gamma(&data, 0.2, 200, &mut rng);
    let kernel = Kernel::Gauss { gamma };
    println!("kernel: {}", kernel.name());

    // 3. Partition over 4 workers (power-law sizes, exponent 2).
    let shards = partition_power_law(&data, 4, 42);
    println!("shard sizes: {:?}", shards.iter().map(|s| s.len()).collect::<Vec<_>>());

    // 4. disKPCA: k = 8 components from |Y| ≈ 20 + 60 sampled points.
    let params = Params { k: 8, n_lev: 20, n_adapt: 60, ..Params::default() };
    let ((solution, err, trace), stats) = run_cluster(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        move |cluster| {
            let sol = dis_kpca(cluster, kernel, &params);
            let (err, trace) = dis_eval(cluster);
            (sol, err, trace)
        },
    );

    // 5. Compare with the single-machine optimum.
    let batch = batch_kpca(&data.to_dense(), kernel, 8, false, 1);
    println!("\nrepresentative points |Y| = {}", solution.num_points());
    println!("communication          = {} words", stats.total_words());
    println!("disKPCA error          = {:.4} ({:.1}% of tr K)", err, 100.0 * err / trace);
    println!("batch optimum          = {:.4}", batch.opt_error);
    println!("relative approximation = {:.3}×", err / batch.opt_error.max(1e-12));
    assert!(err >= batch.opt_error - 1e-6, "impossible: beat the optimum");
    println!("\nproject new points: solution.project(&data) -> {}×n matrix", solution.k());
}
