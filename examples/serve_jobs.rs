//! Multi-job serving session walkthrough (`cargo run --release
//! --example serve_jobs`) — also the CI smoke for the serve layer.
//!
//! One persistent in-process cluster runs four jobs and a query batch:
//!
//! 1. a cold disKPCA fit (pays the `1-embed` round),
//! 2. a warm fit with an identical `EmbedSpec` (zero `1-embed` words
//!    and a bit-identical solution — both asserted),
//! 3. a cold fit under a different seed (new spec ⇒ re-embed),
//! 4. a CSS job (warm against job 3's spec) + a KRR job on its columns,
//!
//! then projects fresh points through the installed solution with
//! `Service::transform` and cross-checks against the master-side
//! `KpcaSolution::project`.

use std::sync::Arc;

use diskpca::coordinator::Params;
use diskpca::data::{by_name, partition_power_law, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::Service;

fn main() {
    let scale = 0.05;
    let spec = by_name("susy_like", scale).expect("registry dataset");
    let data = spec.generate(11);
    let mut rng = Rng::seed_from(13);
    let gamma = median_trick_gamma(&data, 0.2, 128, &mut rng);
    let kernel = Kernel::Gauss { gamma };
    let shards = partition_power_law(&data, 4, 17);
    let params = Params {
        k: 6,
        t: 32,
        p: 64,
        n_lev: 16,
        n_adapt: 40,
        m_rff: 256,
        t2: 128,
        seed: 5,
        ..Params::default()
    };

    println!("== serve session: 4 workers, susy_like ×{scale}, gauss γ={gamma:.3} ==\n");
    let mut svc = Service::builder(kernel)
        .shards(shards)
        .backend(Arc::new(NativeBackend::new()))
        .build();

    // ---- job 0: cold fit ----
    let cold = svc.run_kpca(&params).unwrap();
    let cold_words = cold.job.stats.total_words();
    let cold_embed = cold.job.stats.round_words("1-embed");
    println!(
        "job0 (cold kpca):  |Y|={:<3} words={:<7} 1-embed={}",
        cold.output.num_points(),
        cold_words,
        cold_embed
    );
    assert!(!cold.embed_reused);
    assert!(cold_embed > 0);

    // ---- job 1: warm fit — identical spec, 1-embed skipped ----
    let warm = svc.run_kpca(&params).unwrap();
    let warm_words = warm.job.stats.total_words();
    println!(
        "job1 (warm kpca):  |Y|={:<3} words={:<7} 1-embed={} (skipped: same EmbedSpec)",
        warm.output.num_points(),
        warm_words,
        warm.job.stats.round_words("1-embed")
    );
    assert!(warm.embed_reused, "identical spec must reuse the installed embedding");
    assert_eq!(
        warm.job.stats.round_words("1-embed"),
        0,
        "warm job performed 1-embed communication"
    );
    // the acceptance invariant: the skip is invisible in the solution
    assert!(warm.output.y.data() == cold.output.y.data());
    assert!(warm.output.coeffs.data() == cold.output.coeffs.data());

    // ---- job 2: different seed ⇒ different spec ⇒ cold again ----
    let other = svc.run_kpca(&Params { seed: 6, ..params }).unwrap();
    println!(
        "job2 (cold kpca):  |Y|={:<3} words={:<7} 1-embed={} (new spec: seed changed)",
        other.output.num_points(),
        other.job.stats.total_words(),
        other.job.stats.round_words("1-embed")
    );
    assert!(!other.embed_reused);
    assert!(other.job.stats.round_words("1-embed") > 0);

    // ---- jobs 3–4: CSS + KRR downstream on the same cluster ----
    let css = svc.run_css(&Params { seed: 6, ..params }).unwrap();
    println!(
        "job3 (warm css):   |Y|={:<3} words={:<7} residual_frac={:.4}",
        css.output.y.len(),
        css.job.stats.total_words(),
        css.output.residual_fraction()
    );
    assert!(css.embed_reused, "css after job2 shares seed-6 warm state");
    let krr = svc.run_krr(&css.output.y, 1e-3, 99).unwrap();
    println!(
        "job4 (krr):        |α|={:<3} words={:<7} R²={:.4}",
        krr.output.alpha.len(),
        krr.job.stats.total_words(),
        krr.output.r_squared()
    );

    // ---- query serving: fresh points through the live solution ----
    // CSS and KRR install no projection solution, so the one serving
    // queries is still job2's disLR output.
    let n_query = 512;
    let batch = Mat::from_fn(data.dim(), n_query, |_, _| rng.normal());
    let t0 = std::time::Instant::now();
    let served = svc.transform(&batch).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let local = other.output.project(&Data::Dense(batch));
    let diff = served.max_abs_diff(&local);
    println!(
        "\ntransform: {n_query} points → {}×{} in {:.1} ms ({:.0} points/s), \
         max|served − local| = {diff:.2e}",
        served.rows(),
        served.cols(),
        dt * 1e3,
        n_query as f64 / dt.max(1e-9)
    );
    assert!(diff < 1e-6, "served projection diverged from the solution: {diff}");

    // ---- the economics ----
    println!("\nwarm-state economics (same-spec fit): {cold_words} → {warm_words} words");
    assert!(warm_words < cold_words, "warm job must ship fewer words than the cold one");
    println!("\nlifetime table (jobs namespaced, queries under svc:):");
    for (round, up, down) in svc.stats().table() {
        println!("  {round:<22} up {up:>9}  down {down:>9}");
    }
    svc.shutdown();
    println!("\nok");
}
