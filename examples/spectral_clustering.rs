//! Spectral clustering (paper §6.6): disKPCA to k components, then
//! distributed k-means over the projections — compared against the
//! uniform-sampling baseline at equal communication-shape.
//!
//!     cargo run --release --example spectral_clustering

use std::sync::Arc;

use diskpca::coordinator::{
    dis_kpca, dis_set_solution, kmeans::distributed_kmeans, run_cluster, uniform_dis_lr, Params,
};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn main() {
    // 6 well-separated clusters in 20 dims — ground truth = 6 groups.
    let mut rng = Rng::seed_from(99);
    let n = 1200;
    let data = Data::Dense(clusters(20, n, 6, 0.15, &mut rng));
    let gamma = median_trick_gamma(&data, 0.2, 300, &mut rng);
    let kernel = Kernel::Gauss { gamma };
    println!("spectral clustering with {} over {n} points, 6 true clusters", kernel.name());
    println!(
        "\n{:<16} {:>12} {:>14} {:>14} {:>7}",
        "method", "comm(words)", "kmeans obj", "kpca resid", "iters"
    );

    for use_diskpca in [true, false] {
        let shards = partition_power_law(&data, 6, 3);
        let params = Params { k: 6, n_lev: 24, n_adapt: 96, ..Params::default() };
        let total = params.n_lev + params.n_adapt;
        let (result, stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = if use_diskpca {
                    dis_kpca(cluster, kernel, &params).expect("worker failure")
                } else {
                    uniform_dis_lr(cluster, kernel, &params, total).expect("worker failure")
                };
                dis_set_solution(cluster, &sol).expect("worker failure");
                distributed_kmeans(cluster, 6, 40, 123).expect("worker failure")
            },
        );
        println!(
            "{:<16} {:>12} {:>14.4} {:>14.4} {:>7}",
            if use_diskpca { "disKPCA" } else { "uniform+disLR" },
            stats.total_words(),
            result.feature_space_obj(n),
            result.residual / n as f64,
            result.iters
        );
    }
    println!("\n(feature-space objective = kpca residual + projected k-means cost, per point)");
}
