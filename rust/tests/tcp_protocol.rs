//! Integration: the full protocol over real TCP sockets must produce
//! *bit-identical* decisions to the in-memory transport (the protocol
//! is deterministic given the seed; the transport must be invisible).

use std::sync::Arc;

use diskpca::comm::{memory, tcp, Cluster, CommStats};
use diskpca::coordinator::{
    dis_css, dis_eval, dis_kpca, dis_krr, kmeans::distributed_kmeans, GatherMode, Params, Worker,
};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn workload() -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(4);
    let data = Data::Dense(clusters(10, 220, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, 4, 6);
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 10,
        n_adapt: 20,
        w: 0,
        m_rff: 256,
        t2: 64,
        seed: 12,
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    };
    (shards, kernel, params)
}

fn run_memory() -> (f64, f64, usize, usize) {
    let (shards, kernel, params) = workload();
    let (star, endpoints) = memory::star(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &params).unwrap();
    let (err, trace) = dis_eval(&cluster).unwrap();
    let words = cluster.stats.total_words();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    (err, trace, sol.num_points(), words)
}

fn run_tcp() -> (f64, f64, usize, usize) {
    let (shards, kernel, params) = workload();
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &params).unwrap();
    let (err, trace) = dis_eval(&cluster).unwrap();
    let words = cluster.stats.total_words();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    (err, trace, sol.num_points(), words)
}

#[test]
fn tcp_and_memory_transports_agree_exactly() {
    let (err_m, trace_m, ny_m, words_m) = run_memory();
    let (err_t, trace_t, ny_t, words_t) = run_tcp();
    assert_eq!(ny_m, ny_t, "different |Y| across transports");
    assert_eq!(words_m, words_t, "different word accounting");
    assert!((trace_m - trace_t).abs() < 1e-12);
    // codec roundtrips through f64 bits ⇒ identical numerics
    assert!(
        (err_m - err_t).abs() < 1e-9 * trace_m,
        "errors diverge: {err_m} vs {err_t}"
    );
}

/// The extension messages (ReqKrrStats/ReqKrrEval/ReqScoresVec) must
/// serialize identically too: run CSS + KRR over both transports.
#[test]
fn css_and_krr_over_tcp_match_memory() {
    fn body(
        cluster: &Cluster,
        kernel: Kernel,
        params: &Params,
    ) -> (f64, f64, Vec<f64>) {
        let css = dis_css(cluster, kernel, params).unwrap();
        let model = dis_krr(cluster, kernel, &css.y, 1e-3, 77).unwrap();
        (css.residual, model.train_mse, model.alpha)
    }
    fn spawn_and_run<E: diskpca::comm::Endpoint + Send + 'static>(
        shards: Vec<Data>,
        kernel: Kernel,
        params: &Params,
        star: diskpca::comm::Star,
        endpoints: Vec<E>,
    ) -> (f64, f64, Vec<f64>) {
        let cluster = Cluster::new(star, CommStats::new());
        let handles: Vec<_> = shards
            .into_iter()
            .zip(endpoints)
            .map(|(shard, ep)| {
                let be = Arc::new(NativeBackend::new());
                std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
            })
            .collect();
        let out = body(&cluster, kernel, params);
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
        out
    }
    let (shards, kernel, params) = workload();
    let (star, endpoints) = memory::star(shards.len());
    let (res_m, mse_m, alpha_m) = spawn_and_run(shards, kernel, &params, star, endpoints);
    let (shards, kernel, params) = workload();
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let (res_t, mse_t, alpha_t) = spawn_and_run(shards, kernel, &params, star, endpoints);
    assert!((res_m - res_t).abs() < 1e-9 * res_m.abs().max(1.0));
    assert!((mse_m - mse_t).abs() < 1e-9 * mse_m.abs().max(1.0));
    assert_eq!(alpha_m.len(), alpha_t.len());
    for (a, b) in alpha_m.iter().zip(&alpha_t) {
        assert!((a - b).abs() < 1e-9 * a.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn kmeans_over_tcp() {
    let (shards, kernel, params) = workload();
    let n: usize = shards.iter().map(|s| s.len()).sum();
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let _ = dis_kpca(&cluster, kernel, &params).unwrap();
    let res = distributed_kmeans(&cluster, 3, 20, 7).unwrap();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    assert!(res.iters >= 1);
    assert!(res.feature_space_obj(n).is_finite());
    assert!(res.projected_obj >= 0.0);
}
