//! Integration: XLA backend (AOT HLO artifacts ← L2 JAX ← L1 Pallas)
//! vs the native rust oracle, and the full protocol on the XLA path.
//!
//! Requires `make artifacts` (skips politely otherwise).

use std::sync::Arc;

use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster, GatherMode, Params};
use diskpca::data::{partition_power_law, Data};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::{chol_psd, qr_thin, Mat};
use diskpca::rng::Rng;
use diskpca::runtime::{Backend, NativeBackend, XlaBackend};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn xla() -> Option<XlaBackend> {
    if !std::path::Path::new(&artifacts_dir()).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaBackend::load(&artifacts_dir()).expect("backend load"))
}

fn rel_frob(a: &Mat, b: &Mat) -> f64 {
    a.sub(b).frob_norm() / b.frob_norm().max(1e-12)
}

#[test]
fn embed_parity_all_kernels() {
    let Some(xla) = xla() else { return };
    let native = NativeBackend::new();
    let mut rng = Rng::seed_from(1);
    // d=28 pads to 32; n=300 forces a ragged last block (300 = 256+44)
    let x = Data::Dense(Mat::from_fn(28, 300, |_, _| rng.normal()));
    for (kernel, name) in [
        (Kernel::Gauss { gamma: 0.7 }, "gauss"),
        (Kernel::Poly { q: 4 }, "poly"),
        (Kernel::ArcCos { degree: 2 }, "arccos"),
    ] {
        let spec = EmbedSpec { kernel, m: 512, t2: 512, t: 64, seed: 33 };
        let en = native.embed(&spec, &x);
        let ex = xla.embed(&spec, &x);
        assert_eq!((ex.rows(), ex.cols()), (64, 300));
        let err = rel_frob(&ex, &en);
        assert!(err < 2e-4, "{name} embed parity: rel err {err}");
    }
    assert_eq!(xla.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn embed_sparse_input_parity() {
    let Some(xla) = xla() else { return };
    let native = NativeBackend::new();
    let mut rng = Rng::seed_from(5);
    let x = Data::Sparse(diskpca::data::zipf_sparse(100, 80, 12, &mut rng));
    let spec = EmbedSpec { kernel: Kernel::Gauss { gamma: 0.3 }, m: 512, t2: 512, t: 64, seed: 9 };
    let en = native.embed(&spec, &x);
    let ex = xla.embed(&spec, &x);
    assert!(rel_frob(&ex, &en) < 2e-4);
}

#[test]
fn gram_parity_all_kernels() {
    let Some(xla) = xla() else { return };
    let native = NativeBackend::new();
    let mut rng = Rng::seed_from(2);
    let y = Mat::from_fn(90, 37, |_, _| rng.normal() * 0.4);
    let x = Data::Dense(Mat::from_fn(90, 270, |_, _| rng.normal() * 0.4));
    for kernel in [
        Kernel::Gauss { gamma: 1.3 },
        Kernel::Poly { q: 4 },
        Kernel::ArcCos { degree: 2 },
    ] {
        let gn = native.gram(kernel, &y, &x);
        let gx = xla.gram(kernel, &y, &x);
        assert_eq!((gx.rows(), gx.cols()), (37, 270));
        let err = rel_frob(&gx, &gn);
        assert!(err < 5e-5, "{} gram parity: rel err {err}", kernel.name());
    }
}

#[test]
fn gram_fallback_for_unsupported_degree() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::seed_from(3);
    let y = Mat::from_fn(10, 4, |_, _| rng.normal());
    let x = Data::Dense(Mat::from_fn(10, 8, |_, _| rng.normal()));
    let before = xla.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed);
    // poly q=3 isn't in the artifact grid (q=4 baked) ⇒ native fallback
    let g = xla.gram(Kernel::Poly { q: 3 }, &y, &x);
    assert_eq!(g.rows(), 4);
    let after = xla.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(after, before + 1);
}

#[test]
fn leverage_and_projection_parity() {
    let Some(xla) = xla() else { return };
    let native = NativeBackend::new();
    let mut rng = Rng::seed_from(4);
    // leverage: t = 64 (the artifact's t_embed)
    let a = Mat::from_fn(200, 64, |_, _| rng.normal());
    let (_, z) = qr_thin(&a);
    let e = Mat::from_fn(64, 300, |_, _| rng.normal());
    let ln = native.leverage_norms(&z, &e);
    let lx = xla.leverage_norms(&z, &e);
    for (i, (g, w)) in lx.iter().zip(&ln).enumerate() {
        assert!((g - w).abs() < 1e-3 * w.max(1.0), "score {i}: {g} vs {w}");
    }
    // projection: |Y| = 50 pads to 512
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let y = Mat::from_fn(12, 50, |_, _| rng.normal());
    let kyy = diskpca::kernels::gram_sym(kernel, &y);
    let (r, _) = chol_psd(&kyy);
    let x = Data::Dense(Mat::from_fn(12, 120, |_, _| rng.normal()));
    let kyx = diskpca::kernels::gram(kernel, &y, &x);
    let diag = diskpca::kernels::diag(kernel, &x);
    let (pin, resn) = native.project_residual(&r, &kyx, &diag);
    let (pix, resx) = xla.project_residual(&r, &kyx, &diag);
    assert!(rel_frob(&pix, &pin) < 1e-4, "pi parity {}", rel_frob(&pix, &pin));
    for (a, b) in resx.iter().zip(&resn) {
        assert!((a - b).abs() < 1e-4 * b.max(1.0), "{a} vs {b}");
    }
}

#[test]
fn diskpca_end_to_end_on_xla_backend() {
    let Some(xla) = xla() else { return };
    let backend: Arc<dyn Backend> = Arc::new(xla);
    let mut rng = Rng::seed_from(11);
    let data = Data::Dense(diskpca::data::clusters(28, 400, 4, 0.15, &mut rng));
    let shards = partition_power_law(&data, 4, 7);
    let kernel = Kernel::Gauss { gamma: 0.8 };
    let params = Params {
        k: 4,
        t: 64,
        p: 96,
        n_lev: 16,
        n_adapt: 40,
        w: 0,
        m_rff: 512,
        t2: 512,
        seed: 21,
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    };
    let ((sol, err, trace), _stats) = run_cluster(shards, kernel, backend, move |cluster| {
        let sol = dis_kpca(cluster, kernel, &params).unwrap();
        let (err, trace) = dis_eval(cluster).unwrap();
        (sol, err, trace)
    });
    assert_eq!(sol.k(), 4);
    assert!(err / trace < 0.35, "xla-path relative error {}", err / trace);
    // exact single-machine eval of the same solution agrees (f32 slop)
    let local = sol.eval_error(&data);
    assert!((err - local).abs() < 1e-3 * trace, "dis {err} vs local {local}");
}
