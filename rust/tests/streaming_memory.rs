//! The out-of-core memory claim, pinned: under `--chunk-rows` a
//! worker's peak resident **matrix** allocation is bounded by the
//! chunk size (and n-independent table/reply dims), not the shard
//! size.
//!
//! This lives in its own integration binary on purpose: the
//! allocation high-water mark (`linalg::peak_mat_elems`) is
//! process-global, and any sibling test allocating shard-sized
//! matrices on a parallel test thread would pollute the reading.

use std::sync::Arc;

use diskpca::comm::Message;
use diskpca::coordinator::Worker;
use diskpca::data::Data;
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::{peak_mat_elems, reset_peak_mat_elems, Mat};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn mat(m: Message) -> Mat {
    match m {
        Message::RespMat(v) => v,
        other => panic!("{other:?}"),
    }
}

fn scalar(m: Message) -> f64 {
    match m {
        Message::RespScalar(v) => v,
        other => panic!("{other:?}"),
    }
}

#[test]
fn worker_peak_matrix_allocation_bounded_by_chunk_not_shard() {
    // n ≫ chunk: drive one worker through the full per-point protocol
    // and watch the allocation high-water mark. The resident path
    // must materialize E (t×n); the streamed path must stay bounded
    // by dims independent of n.
    let n = 600;
    let (t, p, w_cols, chunk) = (16usize, 24usize, 24usize, 8usize);
    let mut rng = Rng::seed_from(6);
    let shard = Data::Dense(Mat::from_fn(6, n, |_, _| rng.normal()));
    let kernel = Kernel::Gauss { gamma: 0.5 };
    let spec = EmbedSpec { kernel, m: 128, t2: 64, t, seed: 3 };

    let drive = |w: &mut Worker| -> usize {
        reset_peak_mat_elems();
        w.handle(Message::ReqEmbed { spec });
        let et = mat(w.handle(Message::ReqSketchEmbed { p, seed: 5 }));
        let z = diskpca::linalg::qr_r_only(&et.transpose());
        scalar(w.handle(Message::ReqScores { z }));
        let pts = match w.handle(Message::ReqSampleLeverage { count: 8, seed: 7 }) {
            Message::RespPoints(v) => v,
            other => panic!("{other:?}"),
        };
        scalar(w.handle(Message::ReqResiduals { pts: pts.clone() }));
        let ny = pts.len();
        mat(w.handle(Message::ReqProjectSketch { pts, w: w_cols, seed: 11 }));
        let coeffs = Mat::from_fn(ny, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        w.handle(Message::ReqFinal { coeffs });
        scalar(w.handle(Message::ReqEvalError));
        scalar(w.handle(Message::ReqEvalTrace));
        peak_mat_elems()
    };

    let mut resident = Worker::new(shard.clone(), kernel, Arc::new(NativeBackend::new()));
    let resident_peak = drive(&mut resident);
    assert!(
        resident_peak >= t * n,
        "resident worker should materialize E (t·n = {}), saw peak {resident_peak}",
        t * n
    );

    let mut streamed =
        Worker::new_chunked(shard.clone(), kernel, Arc::new(NativeBackend::new()), chunk);
    let streamed_peak = drive(&mut streamed);
    // Biggest legitimate streamed allocations: the per-chunk RFF
    // feature block (m×chunk), the Ω table (d×m), the t×p sketch
    // reply, and |Y|-sized blocks — all independent of n. Assert a
    // hard ceiling well below the resident t×n / m×n footprints.
    let ceiling = 128 * chunk + 6 * 128 + t * p + 256;
    assert!(
        streamed_peak <= ceiling,
        "streamed peak {streamed_peak} exceeds chunk-bounded ceiling {ceiling}"
    );
    assert!(
        streamed_peak * 4 < resident_peak,
        "streamed peak {streamed_peak} not meaningfully below resident {resident_peak}"
    );

    // and the streamed worker still agrees with the resident one
    let a = scalar(resident.handle(Message::ReqEvalError));
    let b = scalar(streamed.handle(Message::ReqEvalError));
    assert_eq!(a.to_bits(), b.to_bits());
}
