//! Fault injection: kill one worker mid-round on both transports and
//! pin the failure contract of the typed session core —
//!
//! 1. `dis_kpca` returns `Err(CommError)` (no panic),
//! 2. the error names the dead worker and the round it died in,
//! 3. the master does not hang (bounded by the reply timeout, but the
//!    hang-up markers fire long before it),
//! 4. the surviving workers receive `Quit` and shut down cleanly.
//!
//! Plus the elastic kill matrix: a worker killed after every request
//! count (≈ every round boundary) × {memory, TCP} × {resident,
//! streaming} recovers through [`diskpca::recovery`] and produces a
//! solution, eval, and per-round word table **bitwise identical** to
//! the fault-free run.
//!
//! Plus the never-rejoins cells: the same kill but the host *refuses*
//! to revive the slot. With rebalancing on, the dead slot's shard is
//! adopted by a survivor and the job re-runs on s−1 workers — bitwise
//! identical (word table included) to a fresh cold fit over the
//! post-rebalance shard layout. With rebalancing off, the run fails
//! with the typed [`CommError::Degraded`] naming the lost slot.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use diskpca::comm::{
    memory, tcp, Cluster, CommError, CommStats, Endpoint, Message, ReplyEvent, Star, WorkerLink,
};
use diskpca::coordinator::{dis_eval, dis_kpca, KpcaSolution, Params, SamplingMode, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::recovery::{
    dis_eval_recovering, dis_kpca_recovering, with_rebalance, AdoptSource, LocalHost, Recovery,
    ReviveHost, Transport,
};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn workload(s: usize) -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(11);
    let data = Data::Dense(clusters(8, 150, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, s, 2);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 5,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// Serve `die_after` requests, then exit without replying to the
/// next one — a worker dying mid-round with a request in hand.
fn doomed_worker(
    mut endpoint: impl Endpoint,
    shard: Data,
    kernel: Kernel,
    die_after: usize,
) {
    doomed_worker_chunked(&mut endpoint, shard, kernel, 0, die_after)
}

/// [`doomed_worker`] with a streaming chunk width (`0` = resident).
fn doomed_worker_chunked(
    endpoint: &mut impl Endpoint,
    shard: Data,
    kernel: Kernel,
    chunk_rows: usize,
    die_after: usize,
) {
    let mut worker = Worker::new_chunked(shard, kernel, Arc::new(NativeBackend::new()), chunk_rows);
    let mut served = 0usize;
    loop {
        let req = match endpoint.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        if served == die_after {
            return; // die holding an unanswered request
        }
        let resp = worker.handle(req);
        if endpoint.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// Requests each worker sees under dis_kpca: ReqEmbed (round
/// "1-embed"), ReqSketchEmbed + ReqScores ("2-disLS"), … — dying
/// after 2 served requests drops the worker inside round "2-disLS".
const DIE_AFTER: usize = 2;
const DEAD_WORKER: usize = 1;
const EXPECT_ROUND: &str = "2-disLS";

fn assert_names_worker_and_round(err: &CommError) {
    assert_eq!(
        err.worker(),
        Some(DEAD_WORKER),
        "error must name the dead worker: {err}"
    );
    assert_eq!(err.round(), EXPECT_ROUND, "error must name the round: {err}");
    assert!(matches!(err, CommError::Link { .. }), "expected a link failure, got {err:?}");
    // the rendered message carries both, for logs/exit paths
    let text = err.to_string();
    assert!(text.contains("worker 1"), "{text}");
    assert!(text.contains(EXPECT_ROUND), "{text}");
}

#[test]
fn memory_worker_death_mid_round_aborts_with_context() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = memory::star(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    // a genuine deadlock would otherwise stall the test binary
    cluster.set_reply_timeout(Duration::from_secs(60));
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
    assert_names_worker_and_round(&err);
    // survivors shut down cleanly on Quit — join() would hang forever
    // if the protocol left them blocked mid-round
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

#[test]
fn tcp_worker_death_mid_round_aborts_with_context() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(60));
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
    assert_names_worker_and_round(&err);
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

/// The drop guard alone must release TCP workers after an aborted
/// round — no explicit `shutdown()` call.
#[test]
fn drop_guard_releases_workers_after_abort() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    {
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_reply_timeout(Duration::from_secs(60));
        let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
        assert_eq!(err.worker(), Some(DEAD_WORKER));
        // cluster dropped here → drop guard sends Quit to survivors
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

// ---------------------------------------------------------------------------
// Elastic kill matrix: recovery must be invisible in the results.
// ---------------------------------------------------------------------------

type RunResult = (KpcaSolution, (f64, f64), Vec<(String, usize, usize)>);

/// Fault-free cold fit over an explicit shard layout (memory star,
/// normal workers).
fn cold_run(shards: Vec<Data>, kernel: Kernel, params: &Params, chunk_rows: usize) -> RunResult {
    let (star, endpoints) = memory::star(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            std::thread::spawn(move || {
                Worker::new_chunked(shard, kernel, Arc::new(NativeBackend::new()), chunk_rows)
                    .run(ep)
            })
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, params).unwrap();
    let ev = dis_eval(&cluster).unwrap();
    let table = cluster.stats.table();
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker thread panicked");
    }
    (sol, ev, table)
}

/// Fault-free reference run (memory star, normal workers).
fn baseline(chunk_rows: usize) -> RunResult {
    let (shards, kernel, params) = workload(3);
    cold_run(shards, kernel, &params, chunk_rows)
}

/// Elastic run with worker [`DEAD_WORKER`] killed after `die_after`
/// served requests; returns the result plus the revive count.
#[allow(clippy::too_many_arguments)]
fn drive_elastic<E: Endpoint + Send + 'static>(
    star: Star,
    endpoints: Vec<E>,
    reply_tx: Sender<ReplyEvent>,
    shards: Vec<Data>,
    kernel: Kernel,
    params: &Params,
    transport: Transport,
    chunk_rows: usize,
    die_after: usize,
) -> (RunResult, usize) {
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(120));
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, mut ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker_chunked(&mut ep, shard, kernel, chunk_rows, die_after);
                } else {
                    Worker::new_chunked(shard, kernel, Arc::new(NativeBackend::new()), chunk_rows)
                        .run(ep);
                }
            })
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        chunk_rows,
        reply_tx,
        transport,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    let sol =
        dis_kpca_recovering(&cluster, &mut rec, kernel, params, SamplingMode::Full, false)
            .unwrap_or_else(|e| panic!("{transport:?} chunk={chunk_rows} die={die_after}: {e}"));
    let ev = dis_eval_recovering(&cluster, &mut rec)
        .unwrap_or_else(|e| panic!("{transport:?} chunk={chunk_rows} die={die_after} eval: {e}"));
    let table = cluster.stats.table();
    let recoveries = rec.recoveries();
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
    ((sol, ev, table), recoveries)
}

fn elastic_run(transport: Transport, chunk_rows: usize, die_after: usize) -> (RunResult, usize) {
    let (shards, kernel, params) = workload(3);
    match transport {
        Transport::Memory => {
            let (star, eps, tx) = memory::star_elastic(shards.len());
            drive_elastic(star, eps, tx, shards, kernel, &params, transport, chunk_rows, die_after)
        }
        Transport::Tcp => {
            let (star, eps, tx) = tcp::star_elastic(shards.len()).unwrap();
            drive_elastic(star, eps, tx, shards, kernel, &params, transport, chunk_rows, die_after)
        }
    }
}

fn assert_bit_identical(ctx: &str, got: &RunResult, want: &RunResult) {
    let (sol, ev, table) = got;
    let (bsol, bev, btable) = want;
    assert!(sol.y.data() == bsol.y.data(), "{ctx}: representative points differ");
    assert!(sol.coeffs.data() == bsol.coeffs.data(), "{ctx}: coefficients differ");
    assert_eq!(ev.0.to_bits(), bev.0.to_bits(), "{ctx}: eval error differs");
    assert_eq!(ev.1.to_bits(), bev.1.to_bits(), "{ctx}: eval trace differs");
    assert_eq!(table, btable, "{ctx}: per-round word table differs");
}

/// The matrix: kill worker 1 after every request count from the first
/// request (mid `1-embed`) through the late rounds, on both transports
/// and both worker modes. Every cell must recover and reproduce the
/// fault-free run bit for bit — words table included.
#[test]
fn kill_matrix_recovers_bit_identically() {
    for &chunk_rows in &[0usize, 16] {
        let want = baseline(chunk_rows);
        for transport in [Transport::Memory, Transport::Tcp] {
            for die_after in [0usize, 1, 2, 3, 4, 6, 8] {
                let ctx = format!("{transport:?} chunk={chunk_rows} die_after={die_after}");
                let (got, recoveries) = elastic_run(transport, chunk_rows, die_after);
                assert!(recoveries >= 1, "{ctx}: no recovery happened — kill not injected?");
                assert_bit_identical(&ctx, &got, &want);
            }
        }
    }
}

/// A second worker dying *during* the recovery settle window is also
/// revived (the settle loop feeds newly surfaced markers back in).
#[test]
fn double_death_in_one_round_recovers() {
    let (shards, kernel, params) = workload(3);
    let want = baseline(0);
    let (star, endpoints, reply_tx) = memory::star_elastic(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(120));
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, mut ep))| {
            std::thread::spawn(move || match i {
                0 => doomed_worker_chunked(&mut ep, shard, kernel, 0, 3),
                1 => doomed_worker_chunked(&mut ep, shard, kernel, 0, 3),
                _ => Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep),
            })
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    let sol = dis_kpca_recovering(&cluster, &mut rec, kernel, &params, SamplingMode::Full, false)
        .unwrap();
    let ev = dis_eval_recovering(&cluster, &mut rec).unwrap();
    assert!(rec.recoveries() >= 2, "both deaths must be recovered ({})", rec.recoveries());
    let got = (sol, ev, cluster.stats.table());
    assert_bit_identical("double death", &got, &want);
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
}

// ---------------------------------------------------------------------------
// Never-rejoins cells: permanent loss → rebalance onto survivors.
// ---------------------------------------------------------------------------

/// A [`ReviveHost`] whose `refuse` slot never comes back — every other
/// capability (shard adoption included) delegates to the wrapped
/// [`LocalHost`].
struct NoRejoin {
    inner: LocalHost,
    refuse: usize,
}

impl ReviveHost for NoRejoin {
    fn revive(&mut self, slot: usize) -> Result<Box<dyn WorkerLink>, String> {
        if slot == self.refuse {
            return Err(format!("slot {slot} never rejoins"));
        }
        self.inner.revive(slot)
    }

    fn shard_path(&self, slot: usize) -> Option<(String, usize)> {
        self.inner.shard_path(slot)
    }

    fn adopt_source(&mut self, slot: usize) -> Result<AdoptSource, String> {
        self.inner.adopt_source(slot)
    }

    fn rebalanced(&mut self, dead: usize, adopter: usize) {
        self.inner.rebalanced(dead, adopter)
    }

    fn join(&mut self) {
        self.inner.join()
    }
}

/// Column-wise concatenation of two dense shards — the layout a
/// survivor holds after adopting a dead slot's columns (own first).
fn concat_dense(own: &Data, adopted: &Data) -> Data {
    let (a, b) = match (own, adopted) {
        (Data::Dense(a), Data::Dense(b)) => (a, b),
        _ => panic!("dense shards expected"),
    };
    let m = Mat::from_fn(a.rows(), a.cols() + b.cols(), |i, j| {
        if j < a.cols() {
            a[(i, j)]
        } else {
            b[(i, j - a.cols())]
        }
    });
    Data::Dense(m)
}

/// The layout after worker [`DEAD_WORKER`] (slot 1 of 3) is lost for
/// good: the first live survivor after it — slot 2 — adopts its
/// columns (own-first order), then renumbers down to slot 1.
fn survivor_baseline(chunk_rows: usize) -> RunResult {
    let (shards, kernel, params) = workload(3);
    let survivors = vec![shards[0].clone(), concat_dense(&shards[2], &shards[1])];
    cold_run(survivors, kernel, &params, chunk_rows)
}

#[allow(clippy::too_many_arguments)]
fn drive_never_rejoins<E: Endpoint + Send + 'static>(
    star: Star,
    endpoints: Vec<E>,
    reply_tx: Sender<ReplyEvent>,
    shards: Vec<Data>,
    kernel: Kernel,
    params: &Params,
    transport: Transport,
    chunk_rows: usize,
    rebalance: bool,
) -> Result<RunResult, CommError> {
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(120));
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, mut ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker_chunked(&mut ep, shard, kernel, chunk_rows, DIE_AFTER);
                } else {
                    Worker::new_chunked(shard, kernel, Arc::new(NativeBackend::new()), chunk_rows)
                        .run(ep);
                }
            })
        })
        .collect();
    let inner = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        chunk_rows,
        reply_tx,
        transport,
    );
    let mut rec = Recovery::new(Box::new(NoRejoin { inner, refuse: DEAD_WORKER }));
    rec.set_grace(Duration::from_millis(50));
    rec.set_rebalance(rebalance);
    let res = with_rebalance(&cluster, &mut rec, |cluster, rec| {
        let sol = dis_kpca_recovering(cluster, rec, kernel, params, SamplingMode::Full, false)?;
        let ev = dis_eval_recovering(cluster, rec)?;
        Ok((sol, ev, cluster.stats.table()))
    });
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
    res
}

fn never_rejoins_run(
    transport: Transport,
    chunk_rows: usize,
    rebalance: bool,
) -> Result<RunResult, CommError> {
    let (shards, kernel, params) = workload(3);
    match transport {
        Transport::Memory => {
            let (star, eps, tx) = memory::star_elastic(shards.len());
            drive_never_rejoins(
                star, eps, tx, shards, kernel, &params, transport, chunk_rows, rebalance,
            )
        }
        Transport::Tcp => {
            let (star, eps, tx) = tcp::star_elastic(shards.len()).unwrap();
            drive_never_rejoins(
                star, eps, tx, shards, kernel, &params, transport, chunk_rows, rebalance,
            )
        }
    }
}

/// Worker 1 dies mid `2-disLS` and never rejoins. With rebalancing
/// on, survivor 2 adopts its shard, the cluster shrinks to two slots,
/// and the re-run — solution, eval, and per-round word table — is
/// bitwise identical to a fresh cold fit over the survivor layout.
#[test]
fn never_rejoins_rebalances_bit_identically_to_survivor_cold_fit() {
    for &chunk_rows in &[0usize, 16] {
        let want = survivor_baseline(chunk_rows);
        for transport in [Transport::Memory, Transport::Tcp] {
            let ctx = format!("never-rejoins {transport:?} chunk={chunk_rows}");
            let got = never_rejoins_run(transport, chunk_rows, true)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert_bit_identical(&ctx, &got, &want);
        }
    }
}

/// With rebalancing off (the default), permanent loss is a *typed*
/// degraded error naming the lost slot — not a generic protocol
/// failure.
#[test]
fn never_rejoins_without_rebalance_is_a_typed_degraded_error() {
    let err = never_rejoins_run(Transport::Memory, 0, false).unwrap_err();
    match &err {
        CommError::Degraded { slot, .. } => assert_eq!(*slot, DEAD_WORKER),
        other => panic!("expected CommError::Degraded, got {other:?}"),
    }
    let text = err.to_string();
    assert!(text.contains("degraded") && text.contains("worker 1"), "{text}");
}
