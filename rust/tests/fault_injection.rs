//! Fault injection: kill one worker mid-round on both transports and
//! pin the failure contract of the typed session core —
//!
//! 1. `dis_kpca` returns `Err(CommError)` (no panic),
//! 2. the error names the dead worker and the round it died in,
//! 3. the master does not hang (bounded by the reply timeout, but the
//!    hang-up markers fire long before it),
//! 4. the surviving workers receive `Quit` and shut down cleanly.

use std::sync::Arc;
use std::time::Duration;

use diskpca::comm::{memory, tcp, Cluster, CommError, CommStats, Endpoint, Message};
use diskpca::coordinator::{dis_kpca, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn workload(s: usize) -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(11);
    let data = Data::Dense(clusters(8, 150, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, s, 2);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 5,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// Serve `die_after` requests, then exit without replying to the
/// next one — a worker dying mid-round with a request in hand.
fn doomed_worker(
    mut endpoint: impl Endpoint,
    shard: Data,
    kernel: Kernel,
    die_after: usize,
) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    let mut served = 0usize;
    loop {
        let req = match endpoint.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        if served == die_after {
            return; // die holding an unanswered request
        }
        let resp = worker.handle(req);
        if endpoint.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// Requests each worker sees under dis_kpca: ReqEmbed (round
/// "1-embed"), ReqSketchEmbed + ReqScores ("2-disLS"), … — dying
/// after 2 served requests drops the worker inside round "2-disLS".
const DIE_AFTER: usize = 2;
const DEAD_WORKER: usize = 1;
const EXPECT_ROUND: &str = "2-disLS";

fn assert_names_worker_and_round(err: &CommError) {
    assert_eq!(
        err.worker(),
        Some(DEAD_WORKER),
        "error must name the dead worker: {err}"
    );
    assert_eq!(err.round(), EXPECT_ROUND, "error must name the round: {err}");
    assert!(matches!(err, CommError::Link { .. }), "expected a link failure, got {err:?}");
    // the rendered message carries both, for logs/exit paths
    let text = err.to_string();
    assert!(text.contains("worker 1"), "{text}");
    assert!(text.contains(EXPECT_ROUND), "{text}");
}

#[test]
fn memory_worker_death_mid_round_aborts_with_context() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = memory::star(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    // a genuine deadlock would otherwise stall the test binary
    cluster.set_reply_timeout(Duration::from_secs(60));
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
    assert_names_worker_and_round(&err);
    // survivors shut down cleanly on Quit — join() would hang forever
    // if the protocol left them blocked mid-round
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

#[test]
fn tcp_worker_death_mid_round_aborts_with_context() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(60));
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
    assert_names_worker_and_round(&err);
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

/// The drop guard alone must release TCP workers after an aborted
/// round — no explicit `shutdown()` call.
#[test]
fn drop_guard_releases_workers_after_abort() {
    let (shards, kernel, params) = workload(3);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD_WORKER {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    {
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_reply_timeout(Duration::from_secs(60));
        let err = dis_kpca(&cluster, kernel, &params).unwrap_err();
        assert_eq!(err.worker(), Some(DEAD_WORKER));
        // cluster dropped here → drop guard sends Quit to survivors
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}
