//! Scheduler correctness: concurrency must be invisible in results.
//!
//! Three contracts, one per test:
//! 1. A mixed job sequence (KPCA fit, KRR, transform batches, eval)
//!    run with `max_inflight > 1` is bitwise equal — solutions AND
//!    per-job word tables — to the same sequence on the sequential
//!    (`max_inflight: 1`) scheduler.
//! 2. A full admission queue returns a typed [`Rejected::QueueFull`]
//!    immediately — never a hang — and the rejection bridges to the
//!    `RespError` wire form the TCP front end sends.
//! 3. A worker dying mid-flight under `max_inflight > 1` is revived
//!    through the PR-6 elastic path (replay-free `revive_only` +
//!    job rerun): every job still completes with results bitwise
//!    equal to a fault-free run.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use diskpca::comm::{memory, Cluster, CommStats, Endpoint, Message, PointSet};
use diskpca::coordinator::{Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::recovery::{LocalHost, Recovery, Transport};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::{JobOutput, JobSpec, Rejected, ServeConfig, Service};

const S: usize = 3;

fn workload() -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(31);
    let data = Data::Dense(clusters(8, 150, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, S, 4);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 17,
        ..Params::default()
    };
    (shards, kernel, params)
}

fn service(shards: Vec<Data>, kernel: Kernel, max_inflight: usize) -> Service {
    Service::builder(kernel)
        .shards(shards)
        .backend(Arc::new(NativeBackend::new()))
        .config(ServeConfig { max_inflight, ..ServeConfig::default() })
        .build()
}

/// Everything the mixed sequence produces, bit-comparable.
struct MixTrace {
    kpca_y: Vec<u64>,
    kpca_coeffs: Vec<u64>,
    kpca_table: Vec<(String, usize, usize)>,
    krr_alpha: Vec<u64>,
    krr_table: Vec<(String, usize, usize)>,
    t1: Vec<u64>,
    t2: Vec<u64>,
    eval: (u64, u64),
    eval_table: Vec<(String, usize, usize)>,
}

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// The mixed sequence: one fit, then a KRR job + two transform
/// batches + an eval. On the concurrent service the last four are
/// submitted together and genuinely share the cluster (KRR has no
/// worker-state footprint; transforms and eval only read the
/// installed solution).
fn run_mix(svc: &mut Service, params: &Params, concurrent: bool) -> MixTrace {
    let fit = svc.run_kpca(params).unwrap();
    let y = PointSet::Dense(fit.output.y.clone());
    let mut rng = Rng::seed_from(77);
    let b1 = Mat::from_fn(8, 9, |_, _| rng.normal());
    let b2 = Mat::from_fn(8, 23, |_, _| rng.normal());
    svc.set_transform_chunk(4); // force multi-chunk pipelined dispatch

    let (krr, t1, t2, eval) = if concurrent {
        let hk = svc
            .submit(JobSpec::Krr { y: y.clone(), lambda: 1e-3, teacher_seed: 7 })
            .unwrap();
        let h1 = svc.submit(JobSpec::Transform { batch: b1.clone() }).unwrap();
        let h2 = svc.submit(JobSpec::Transform { batch: b2.clone() }).unwrap();
        let he = svc.submit(JobSpec::Eval).unwrap();
        let krr = match hk.wait().unwrap() {
            JobOutput::Krr(r) => r,
            other => panic!("expected krr, got {other:?}"),
        };
        let t1 = match h1.wait().unwrap() {
            JobOutput::Transform(m) => m,
            other => panic!("expected transform, got {other:?}"),
        };
        let t2 = match h2.wait().unwrap() {
            JobOutput::Transform(m) => m,
            other => panic!("expected transform, got {other:?}"),
        };
        let eval = match he.wait().unwrap() {
            JobOutput::Eval(r) => r,
            other => panic!("expected eval, got {other:?}"),
        };
        (krr, t1, t2, eval)
    } else {
        let krr = svc.run_krr(&y, 1e-3, 7).unwrap();
        let t1 = svc.transform(&b1).unwrap();
        let t2 = svc.transform(&b2).unwrap();
        let eval = svc.run_eval().unwrap();
        (krr, t1, t2, eval)
    };
    MixTrace {
        kpca_y: bits(&fit.output.y),
        kpca_coeffs: bits(&fit.output.coeffs),
        kpca_table: fit.job.stats.table(),
        krr_alpha: krr.output.alpha.iter().map(|v| v.to_bits()).collect(),
        krr_table: krr.job.stats.table(),
        t1: bits(&t1),
        t2: bits(&t2),
        eval: (eval.output.0.to_bits(), eval.output.1.to_bits()),
        eval_table: eval.job.stats.table(),
    }
}

fn assert_mix_eq(got: &MixTrace, want: &MixTrace) {
    assert_eq!(got.kpca_y, want.kpca_y, "kpca representative points differ");
    assert_eq!(got.kpca_coeffs, want.kpca_coeffs, "kpca coefficients differ");
    assert_eq!(got.kpca_table, want.kpca_table, "kpca per-job word table differs");
    assert_eq!(got.krr_alpha, want.krr_alpha, "krr weights differ");
    assert_eq!(got.krr_table, want.krr_table, "krr per-job word table differs");
    assert_eq!(got.t1, want.t1, "transform batch 1 differs");
    assert_eq!(got.t2, want.t2, "transform batch 2 differs");
    assert_eq!(got.eval, want.eval, "eval differs");
    assert_eq!(got.eval_table, want.eval_table, "eval per-job word table differs");
}

/// Contract 1: interleaved == sequential, bit for bit.
#[test]
fn concurrent_mix_is_bitwise_equal_to_sequential() {
    let (shards, kernel, params) = workload();
    let mut seq = service(shards.clone(), kernel, 1);
    let want = run_mix(&mut seq, &params, false);
    seq.shutdown();

    let mut conc = service(shards, kernel, 3);
    let got = run_mix(&mut conc, &params, true);
    // the concurrent lifetime table still namespaces every job
    assert!(conc.stats().round_words("job0:1-embed") > 0);
    assert!(conc.stats().round_words("job1:9-krr") > 0);
    assert!(conc.stats().round_words("job2:6-eval") > 0);
    assert!(conc.stats().round_words("svc:10-transform") > 0);
    conc.shutdown();

    assert_mix_eq(&got, &want);
}

/// A worker that parks on a shared gate before handling each request
/// (so in-flight jobs stall deterministically until the gate opens).
fn gated_worker(
    mut ep: impl Endpoint,
    shard: Data,
    kernel: Kernel,
    gate: Arc<(Mutex<bool>, Condvar)>,
) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    loop {
        let req = match ep.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        let (open, cv) = &*gate;
        let mut o = open.lock().unwrap();
        while !*o {
            o = cv.wait(o).unwrap();
        }
        drop(o);
        if ep.send_resp(worker.handle(req)).is_err() {
            return;
        }
    }
}

/// Contract 2: a full queue is a typed rejection, never a hang.
#[test]
fn full_admission_queue_rejects_typed_and_promptly() {
    let (shards, kernel, _) = workload();
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let (star, endpoints) = memory::star(S);
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let gate = gate.clone();
            std::thread::spawn(move || gated_worker(ep, shard, kernel, gate))
        })
        .collect();
    let svc = Service::builder(kernel)
        .cluster(Cluster::new(star, CommStats::new()))
        .config(ServeConfig { max_inflight: 1, queue_depth: 1, ..ServeConfig::default() })
        .build();

    let y = PointSet::Dense(Mat::from_fn(8, 4, |i, j| (i * 4 + j) as f64 * 0.1));
    // job A dispatches onto the (gated) cluster and stalls in flight
    let ha = svc.submit(JobSpec::Krr { y: y.clone(), lambda: 1e-2, teacher_seed: 1 }).unwrap();
    let t0 = std::time::Instant::now();
    while svc.jobs_run() < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "job A never dispatched");
        std::thread::sleep(Duration::from_millis(1));
    }
    // job B fills the depth-1 admission queue
    let hb = svc.submit(JobSpec::Krr { y: y.clone(), lambda: 1e-2, teacher_seed: 2 }).unwrap();
    // job C must be rejected typed — and immediately, while A is still
    // stalled in flight (the never-a-hang half of the contract)
    let t1 = std::time::Instant::now();
    let rej = svc
        .submit(JobSpec::Krr { y, lambda: 1e-2, teacher_seed: 3 })
        .expect_err("queue full: submission must be rejected");
    assert!(t1.elapsed() < Duration::from_secs(1), "rejection must not block");
    assert_eq!(rej, Rejected::QueueFull { depth: 1 });
    match rej.to_resp_error() {
        Message::RespError(detail) => {
            assert!(detail.starts_with("rejected: "), "wire form: {detail}")
        }
        other => panic!("expected RespError wire form, got {other:?}"),
    }

    // open the gate: both admitted jobs complete normally
    {
        let (open, cv) = &*gate;
        *open.lock().unwrap() = true;
        cv.notify_all();
    }
    assert!(matches!(ha.wait().unwrap(), JobOutput::Krr(_)));
    assert!(matches!(hb.wait().unwrap(), JobOutput::Krr(_)));
    svc.shutdown();
    for h in handles {
        h.join().unwrap();
    }
}

/// Serve `die_after` requests, then exit holding the next one
/// (same shape as the elastic_soak mortal worker).
fn mortal_worker(mut ep: impl Endpoint, shard: Data, kernel: Kernel, die_after: usize) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    let mut served = 0usize;
    loop {
        let req = match ep.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        if served == die_after {
            return;
        }
        let resp = worker.handle(req);
        if ep.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// Contract 3: a mid-flight death under `max_inflight > 1` heals via
/// the PR-6 revive path (replay-free `revive_only` + job rerun) and
/// the sequence still matches a fault-free run bit for bit.
#[test]
fn worker_death_under_concurrency_recovers_bitwise() {
    let (shards, kernel, params) = workload();

    // fault-free sequential reference
    let mut ideal = service(shards.clone(), kernel, 1);
    let want = run_mix(&mut ideal, &params, false);
    ideal.shutdown();

    // mortal cluster: worker 1 dies mid-fit; max_inflight 2
    let die_afters = [usize::MAX, 3, usize::MAX];
    let (star, endpoints, reply_tx) = memory::star_elastic(S);
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .zip(die_afters)
        .map(|((shard, ep), die_after)| {
            std::thread::spawn(move || mortal_worker(ep, shard, kernel, die_after))
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    let mut svc = Service::builder(kernel)
        .cluster(Cluster::new(star, CommStats::new()))
        .config(ServeConfig { max_inflight: 2, ..ServeConfig::default() })
        .build();
    svc.set_recovery(rec);

    let got = run_mix(&mut svc, &params, true);
    assert!(
        svc.recoveries() >= 1,
        "the mortal worker should have died and been revived (got {})",
        svc.recoveries()
    );
    svc.shutdown();
    for h in handles {
        let _ = h.join();
    }

    assert_mix_eq(&got, &want);
}
