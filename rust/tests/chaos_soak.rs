//! Seeded chaos soak: the same multi-job [`diskpca::serve::Service`]
//! sequence as `elastic_soak.rs`, but the faults come from the seeded
//! chaos transport ([`diskpca::comm::chaos`]) instead of mortal
//! workers — every master→worker link is wrapped in a [`ChaosLink`]
//! that deterministically severs links and delays sends per a fixed
//! seed. The workers themselves are immortal: when a chaos roll
//! severs a link, the master sees a link failure, recovery revives
//! the slot over a fresh raw link (replacing the chaos wrapper), and
//! the job replays. At a fixed seed the fault schedule is identical
//! on every run, and every job must complete with outputs and
//! per-job word tables bitwise identical to a fault-free service.
//!
//! [`ChaosLink`]: diskpca::comm::chaos::ChaosLink

use std::sync::Arc;
use std::time::Duration;

use diskpca::comm::{chaos, memory, Cluster, CommStats};
use diskpca::coordinator::{Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::recovery::{LocalHost, Recovery, Transport};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::{ServeConfig, Service};

const S: usize = 3;

/// The chaos schedule: fixed, so the soak replays the same severs and
/// delays on every run.
const CHAOS_SEED: u64 = 0xc4a0_5eed;

fn workload() -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(23);
    let data = Data::Dense(clusters(7, 130, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, S, 4);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 9,
        ..Params::default()
    };
    (shards, kernel, params)
}

struct JobTrace {
    y: Vec<f64>,
    coeffs: Vec<f64>,
    table: Vec<(String, usize, usize)>,
    embed_words: usize,
    reused: bool,
}

/// Three KPCA fits (cold + two warm) and a final eval — the same
/// sequence `elastic_soak.rs` runs.
fn run_jobs(svc: &mut Service, params: &Params) -> (Vec<JobTrace>, (f64, f64)) {
    let mut traces = Vec::new();
    for _ in 0..3 {
        let report = svc.run_kpca(params).unwrap();
        traces.push(JobTrace {
            y: report.output.y.data().to_vec(),
            coeffs: report.output.coeffs.data().to_vec(),
            table: report.job.stats.table(),
            embed_words: report.job.stats.round_words("1-embed"),
            reused: report.embed_reused,
        });
    }
    let ev = svc.run_eval().unwrap().output;
    (traces, ev)
}

#[test]
fn chaos_soak_at_fixed_seed_completes_every_job_bit_identically() {
    let (shards, kernel, params) = workload();

    // fault-free reference service
    let mut ideal = Service::builder(kernel)
        .shards(shards.clone())
        .backend(Arc::new(NativeBackend::new()))
        .build();
    let (want, want_ev) = run_jobs(&mut ideal, &params);
    ideal.shutdown();

    // chaos service: immortal workers behind seeded fault-injection
    // links; severed links are healed by revival (which swaps the
    // chaos wrapper for a fresh raw link)
    let (star, endpoints, reply_tx) = memory::star_elastic(S);
    let star = chaos::wrap_star(star, CHAOS_SEED);
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .map(|(shard, ep)| {
            std::thread::spawn(move || {
                Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep)
            })
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    // chaos keeps rolling for the whole sequence — don't let the
    // per-driver revive cap end the soak early
    rec.set_max_recoveries(64);
    let cfg = ServeConfig { comm_retries: 2, ..ServeConfig::default() };
    let mut svc = Service::builder(kernel)
        .cluster(Cluster::new(star, CommStats::new()))
        .config(cfg)
        .build();
    svc.set_recovery(rec);

    let (got, got_ev) = run_jobs(&mut svc, &params);

    assert!(
        svc.recoveries() >= 1,
        "the fixed chaos seed should sever at least one link over the sequence"
    );
    assert_eq!(got_ev.0.to_bits(), want_ev.0.to_bits(), "eval error differs");
    assert_eq!(got_ev.1.to_bits(), want_ev.1.to_bits(), "eval trace differs");
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(g.y == w.y, "job {j}: representative points differ");
        assert!(g.coeffs == w.coeffs, "job {j}: coefficients differ");
        assert_eq!(g.table, w.table, "job {j}: per-job word table differs");
        assert_eq!(g.reused, w.reused, "job {j}: warm-reuse flag differs");
        if j > 0 {
            assert!(g.reused, "job {j} must reuse the warm embedding");
            assert_eq!(g.embed_words, 0, "warm job {j} must skip 1-embed entirely");
        }
    }

    svc.shutdown();
    for h in handles {
        let _ = h.join();
    }
}
