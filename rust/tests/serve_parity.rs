//! Serve-layer parity: a persistent multi-job [`Service`] must be
//! *invisible* in the results. N jobs run sequentially on one serve
//! cluster must be bit-identical — solution bits and per-job word
//! tables, row for row — to N fresh single-job clusters, on both the
//! in-memory and TCP transports. And a warm job (identical
//! `EmbedSpec`) must skip the `1-embed` round with zero words while
//! still producing the cold cluster's exact solution — the
//! acceptance invariant of the serving layer.

use std::sync::Arc;

use diskpca::comm::{memory, tcp, Cluster, CommStats, Endpoint, Star};
use diskpca::coordinator::{dis_kpca, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::{ServeConfig, Service};

/// In-process service pinned to the sequential scheduler
/// (`max_inflight: 1` — the configuration this whole suite certifies
/// as bit-identical to fresh single-job clusters).
fn mem_service(shards: Vec<Data>, kernel: Kernel) -> Service {
    Service::builder(kernel)
        .shards(shards)
        .backend(Arc::new(NativeBackend::new()))
        .config(ServeConfig { max_inflight: 1, ..ServeConfig::default() })
        .build()
}

fn workload(s: usize) -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(6);
    let data = Data::Dense(clusters(9, 160, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, s, 4);
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 14,
        m_rff: 128,
        t2: 64,
        seed: 21,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// What parity compares per job: solution bits + the word table.
#[derive(Debug, PartialEq)]
struct JobOutcome {
    y_bits: Vec<u64>,
    coeff_bits: Vec<u64>,
    table: Vec<(String, usize, usize)>,
}

fn outcome(
    sol: &diskpca::coordinator::KpcaSolution,
    table: Vec<(String, usize, usize)>,
) -> JobOutcome {
    JobOutcome {
        y_bits: sol.y.data().iter().map(|v| v.to_bits()).collect(),
        coeff_bits: sol.coeffs.data().iter().map(|v| v.to_bits()).collect(),
        table,
    }
}

/// One fresh single-job cluster: spawn, fit, snapshot the table
/// *before* shutdown (so the Quit words don't skew the comparison),
/// tear down.
fn fresh_run<E: Endpoint + Send + 'static>(
    shards: Vec<Data>,
    kernel: Kernel,
    params: Params,
    star: Star,
    endpoints: Vec<E>,
) -> JobOutcome {
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &params).unwrap();
    let table = stats.table();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    outcome(&sol, table)
}

fn fresh_memory(s: usize, params: Params) -> JobOutcome {
    let (shards, kernel, _) = workload(s);
    let (star, endpoints) = memory::star(shards.len());
    fresh_run(shards, kernel, params, star, endpoints)
}

fn fresh_tcp(s: usize, params: Params) -> JobOutcome {
    let (shards, kernel, _) = workload(s);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    fresh_run(shards, kernel, params, star, endpoints)
}

/// A serve cluster over TCP loopback: worker threads on real sockets.
fn tcp_service(
    shards: Vec<Data>,
    kernel: Kernel,
) -> (Service, Vec<std::thread::JoinHandle<()>>) {
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    (Service::new(Cluster::new(star, CommStats::new()), kernel), handles)
}

/// N sequential jobs (distinct seeds ⇒ each pays its own embed round)
/// on one serve cluster == N fresh clusters, bit for bit, table row
/// for table row.
fn multi_job_parity(tcp_transport: bool) {
    let s = 4;
    let (shards, kernel, base) = workload(s);
    let seeds = [21u64, 22, 23];

    let (mut svc, handles) = if tcp_transport {
        tcp_service(shards, kernel)
    } else {
        (mem_service(shards, kernel), Vec::new())
    };
    let served: Vec<JobOutcome> = seeds
        .iter()
        .map(|&seed| {
            let report = svc.run_kpca(&Params { seed, ..base }).unwrap();
            assert!(!report.embed_reused, "distinct seeds must not reuse embeds");
            outcome(&report.output, report.job.stats.table())
        })
        .collect();
    // the lifetime stats kept every job apart by namespace
    for (j, _) in seeds.iter().enumerate() {
        assert!(
            svc.stats().round_words(&format!("job{j}:1-embed")) > 0,
            "job{j} missing from the namespaced lifetime table"
        );
    }
    svc.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    for (i, (&seed, got)) in seeds.iter().zip(&served).enumerate() {
        let fresh = if tcp_transport {
            fresh_tcp(s, Params { seed, ..base })
        } else {
            fresh_memory(s, Params { seed, ..base })
        };
        assert_eq!(
            got, &fresh,
            "job {i} (seed {seed}) differs from a fresh single-job cluster"
        );
    }
}

#[test]
fn multi_job_parity_memory() {
    multi_job_parity(false);
}

#[test]
fn multi_job_parity_tcp() {
    multi_job_parity(true);
}

/// The acceptance invariant: a second job with an identical
/// `EmbedSpec` on a warm cluster performs **zero** `1-embed`
/// communication (asserted on its per-job `CommStats`) while its
/// solution stays bit-identical to a cold-cluster run.
fn warm_reuse(tcp_transport: bool) {
    let s = 4;
    let (shards, kernel, params) = workload(s);
    let (mut svc, handles) = if tcp_transport {
        tcp_service(shards, kernel)
    } else {
        (mem_service(shards, kernel), Vec::new())
    };
    let cold = svc.run_kpca(&params).unwrap();
    let warm = svc.run_kpca(&params).unwrap();
    assert!(!cold.embed_reused && warm.embed_reused);
    assert!(cold.job.stats.round_words("1-embed") > 0);
    assert_eq!(
        warm.job.stats.round_words("1-embed"),
        0,
        "warm job performed 1-embed communication"
    );
    assert!(
        warm.job.stats.total_words() < cold.job.stats.total_words(),
        "warm job must ship strictly fewer words"
    );
    let served_cold = outcome(&cold.output, cold.job.stats.table());
    let served_warm_bits = outcome(&warm.output, Vec::new());
    svc.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    // both jobs equal a fresh cold cluster's solution bit for bit
    let fresh = if tcp_transport {
        fresh_tcp(s, params)
    } else {
        fresh_memory(s, params)
    };
    assert_eq!(served_cold.y_bits, fresh.y_bits);
    assert_eq!(served_cold.coeff_bits, fresh.coeff_bits);
    assert_eq!(served_cold.table, fresh.table, "cold job table differs from fresh");
    assert_eq!(
        served_warm_bits.y_bits, fresh.y_bits,
        "warm solution diverged from the cold cluster's"
    );
    assert_eq!(served_warm_bits.coeff_bits, fresh.coeff_bits);
}

#[test]
fn warm_reuse_zero_embed_words_memory() {
    warm_reuse(false);
}

#[test]
fn warm_reuse_zero_embed_words_tcp() {
    warm_reuse(true);
}

/// Query serving over both transports: transform answers match the
/// returned solution's own projection, independent of transport and
/// batch chunking.
#[test]
fn transform_parity_across_transports() {
    let s = 3;
    let (shards, kernel, params) = workload(s);
    let mut rng = Rng::seed_from(123);
    let batch = Mat::from_fn(9, 40, |_, _| rng.normal());

    let mut mem_svc = mem_service(shards.clone(), kernel);
    let sol = mem_svc.run_kpca(&params).unwrap().output;
    let mem_proj = mem_svc.transform(&batch).unwrap();
    mem_svc.shutdown();

    let (mut tcp_svc, handles) = tcp_service(shards, kernel);
    tcp_svc.run_kpca(&params).unwrap();
    tcp_svc.set_transform_chunk(7); // chunked dispatch must not matter
    let tcp_proj = tcp_svc.transform(&batch).unwrap();
    tcp_svc.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    assert!(mem_proj.data() == tcp_proj.data(), "transform differs across transports");
    let local = sol.project(&Data::Dense(batch));
    assert!(
        mem_proj.max_abs_diff(&local) < 1e-6,
        "served projection diverged: {}",
        mem_proj.max_abs_diff(&local)
    );
}
