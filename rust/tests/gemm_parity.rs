//! GEMM engine parity suite.
//!
//! The packed register-tiled engine (`diskpca::linalg::gemm`) promises
//! results **bit-identical** to the retained reference loops for every
//! shape, tile raggedness, input pattern (including explicit zeros,
//! NaN and ±∞ — the zero-skip semantics pinned in `Mat::matmul`'s
//! docs) and thread count. This suite sweeps all of it and finishes
//! with the same protocol-level determinism check `par_engine.rs`
//! pins, now running on top of the packed engine.

use std::sync::Arc;

use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster, Params};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::linalg::{dot, gemm, Mat};
use diskpca::par;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

/// Bitwise equality — NaN payloads included (`==` on f64 would treat
/// NaN ≠ NaN and -0.0 == 0.0, both wrong for this contract).
fn bits_equal(a: &Mat, b: &Mat) -> bool {
    (a.rows(), a.cols()) == (b.rows(), b.cols())
        && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Sparse-ish test matrix: every third entry an explicit 0.0 so the
/// zero-skip path fires throughout.
fn testmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |i, j| if (i * n + j) % 3 == 0 { 0.0 } else { rng.normal() })
}

/// Ragged-shape property sweep: the packed engine vs. the reference
/// loops, bit for bit, over every combination of dimensions around
/// the MR/NR tile boundaries (plus empty and wide).
#[test]
fn packed_engine_matches_reference_over_ragged_shapes() {
    let mut dims = vec![
        0,
        1,
        gemm::MR - 1,
        gemm::MR,
        gemm::MR + 1,
        gemm::NR - 1,
        gemm::NR,
        gemm::NR + 1,
        3 * gemm::NR + 2,
    ];
    dims.dedup();
    let mut rng = Rng::seed_from(1);
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let a = testmat(&mut rng, m, k);
                let b = testmat(&mut rng, k, n);
                let got = gemm::with_thread_scratch(|s| gemm::matmul_with(&a, &b, s));
                let want = gemm::reference::matmul(&a, &b);
                assert!(bits_equal(&got, &want), "matmul {m}x{k}x{n}");
                // dispatch path (may pick either implementation) must
                // agree too
                assert!(bits_equal(&a.matmul(&b), &want), "matmul dispatch {m}x{k}x{n}");

                let at = testmat(&mut rng, k, m);
                let got = gemm::with_thread_scratch(|s| gemm::matmul_at_b_with(&at, &b, s));
                let want = gemm::reference::matmul_at_b(&at, &b);
                assert!(bits_equal(&got, &want), "matmul_at_b {m}x{k}x{n}");
                assert!(bits_equal(&at.matmul_at_b(&b), &want), "at_b dispatch {m}x{k}x{n}");

                let bt = testmat(&mut rng, n, k);
                let want = gemm::reference::matmul_a_bt(&a, &bt);
                assert!(bits_equal(&a.matmul_a_bt(&bt), &want), "matmul_a_bt {m}x{k}x{n}");
            }
        }
    }
}

#[test]
fn gram_self_matches_reference_over_ragged_shapes() {
    let mut rng = Rng::seed_from(2);
    for &(m, k) in &[(0, 4), (1, 1), (3, 9), (5, 17), (16, 1024), (17, 1025), (33, 40)] {
        let a = testmat(&mut rng, m, k);
        let want = gemm::reference::gram_self(&a);
        assert!(bits_equal(&a.gram_self(), &want), "gram_self {m}x{k}");
    }
}

/// The engine's parallel split must not change a single bit, for any
/// pool size — same invariant `par_engine.rs` pins, now over the
/// packed paths (shapes big enough to engage packing and the pool).
#[test]
fn packed_engine_thread_invariant() {
    let mut rng = Rng::seed_from(3);
    let a = testmat(&mut rng, 90, 80);
    let b = testmat(&mut rng, 80, 70);
    let want_ab = gemm::reference::matmul(&a, &b);
    let at = testmat(&mut rng, 80, 90);
    let want_atb = gemm::reference::matmul_at_b(&at, &b);
    let w1 = testmat(&mut rng, 60, 300);
    let w2 = testmat(&mut rng, 50, 300);
    let want_abt = gemm::reference::matmul_a_bt(&w1, &w2);
    let g = testmat(&mut rng, 70, 200);
    let want_g = gemm::reference::gram_self(&g);
    for threads in [1usize, 4] {
        par::set_threads(threads);
        assert!(bits_equal(&a.matmul(&b), &want_ab), "matmul threads={threads}");
        assert!(bits_equal(&at.matmul_at_b(&b), &want_atb), "at_b threads={threads}");
        assert!(bits_equal(&w1.matmul_a_bt(&w2), &want_abt), "a_bt threads={threads}");
        assert!(bits_equal(&g.gram_self(), &want_g), "gram threads={threads}");
    }
    par::set_threads(1);
}

/// Regression for the pinned zero-skip semantics: on NaN/±∞ inputs the
/// packed engine must agree with the reference loops **bitwise** — a
/// true GEMM (no skip) would differ, because 0·∞ = NaN.
#[test]
fn nonfinite_inputs_agree_bitwise_with_reference() {
    let mut rng = Rng::seed_from(4);
    let (m, k, n) = (13, 19, 11);
    let mut a = testmat(&mut rng, m, k);
    let mut b = testmat(&mut rng, k, n);
    let specials = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 0.0];
    for (idx, &v) in specials.iter().enumerate() {
        a[(idx, idx)] = v;
        b[(idx + 1, idx)] = v;
    }
    // a zero row in A against an all-∞ row in B: the skip keeps the
    // output row exactly 0.0 where a true GEMM would produce NaN
    for j in 0..k {
        a[(5, j)] = 0.0;
    }
    for j in 0..n {
        b[(3, j)] = f64::INFINITY;
    }

    let packed = gemm::with_thread_scratch(|s| gemm::matmul_with(&a, &b, s));
    let reference = gemm::reference::matmul(&a, &b);
    assert!(bits_equal(&packed, &reference), "matmul NaN/inf parity");
    for j in 0..n {
        assert_eq!(packed[(5, j)].to_bits(), 0.0f64.to_bits(), "zero-skip row poisoned at {j}");
    }
    // NaN actually propagated somewhere (the test would be vacuous if
    // the specials all landed on skipped terms)
    assert!(packed.data().iter().any(|v| v.is_nan()));

    let at = a.transpose();
    let packed = gemm::with_thread_scratch(|s| gemm::matmul_at_b_with(&at, &b, s));
    let reference = gemm::reference::matmul_at_b(&at, &b);
    assert!(bits_equal(&packed, &reference), "matmul_at_b NaN/inf parity");

    let bt = b.transpose();
    let want = gemm::reference::matmul_a_bt(&a, &bt);
    assert!(bits_equal(&a.matmul_a_bt(&bt), &want), "matmul_a_bt NaN/inf parity");

    let mut g = testmat(&mut rng, 9, 21);
    g[(2, 3)] = f64::NAN;
    g[(7, 0)] = f64::NEG_INFINITY;
    let want = gemm::reference::gram_self(&g);
    assert!(bits_equal(&g.gram_self(), &want), "gram_self NaN/inf parity");
}

/// `dot4` is the other microkernel: per-element arithmetic identical
/// to `dot`, for every length class (4-lane body + ragged tail).
#[test]
fn dot4_matches_dot_bitwise_including_nonfinite() {
    let mut rng = Rng::seed_from(5);
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 65, 200] {
        let mut a = testmat(&mut rng, 1, n);
        let mut b = testmat(&mut rng, 4, n);
        if n >= 3 {
            a[(0, 1)] = f64::NAN;
            b[(2, 2)] = f64::INFINITY;
        }
        let got = gemm::dot4(a.row(0), [b.row(0), b.row(1), b.row(2), b.row(3)]);
        for j in 0..4 {
            let want = dot(a.row(0), b.row(j));
            assert_eq!(got[j].to_bits(), want.to_bits(), "n={n} j={j}");
        }
    }
}

/// End-to-end determinism on top of the packed engine — mirrors
/// `par_engine.rs::dis_kpca_identical_across_thread_counts`: the full
/// protocol (whose every round now runs through the microkernel) must
/// produce identical solutions, eval numbers and per-round comm words
/// for every thread count.
#[test]
fn dis_kpca_identical_across_thread_counts_on_packed_engine() {
    let mut rng = Rng::seed_from(42);
    let data = Data::Dense(clusters(8, 160, 4, 0.2, &mut rng));
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let params = Params {
        k: 4,
        t: 16,
        p: 40,
        n_lev: 12,
        n_adapt: 24,
        m_rff: 256,
        t2: 128,
        seed: 7,
        ..Params::default()
    };
    let mut runs = Vec::new();
    for threads in [1usize, 4] {
        par::set_threads(threads);
        let shards = partition_power_law(&data, 3, 1);
        let ((sol, err, trace), stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = dis_kpca(cluster, kernel, &params).unwrap();
                let (err, trace) = dis_eval(cluster).unwrap();
                (sol, err, trace)
            },
        );
        runs.push((sol, err, trace, stats.total_words()));
    }
    par::set_threads(1);
    let (s1, e1, t1, w1) = &runs[0];
    let (s4, e4, t4, w4) = &runs[1];
    assert!(s1.y.data() == s4.y.data(), "representative points differ across thread counts");
    assert!(s1.coeffs.data() == s4.coeffs.data(), "coefficients differ across thread counts");
    assert!(e1 == e4 && t1 == t4, "eval differs: {e1}/{t1} vs {e4}/{t4}");
    assert_eq!(w1, w4, "communication words must not depend on threads");
}
