//! Streaming-worker parity tests — the out-of-core tentpole
//! invariant: for every chunk size (and for disk-backed shard
//! stores), worker results are **bit-identical** to the resident
//! path, per-round communication word counts included, from single
//! sketch applies up to full `dis_kpca` over the TCP launcher. A
//! final test pins the memory claim itself: under chunking a worker's
//! peak matrix allocation tracks the chunk size, not the shard size.

use std::sync::Arc;

use diskpca::comm::Message;
use diskpca::config::Config;
use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster_chunked, Params, Worker};
use diskpca::data::{clusters, partition_power_law, zipf_sparse, Data, ShardSource, ShardStore};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn params() -> Params {
    Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 10,
        n_adapt: 20,
        m_rff: 256,
        t2: 64,
        seed: 12,
        ..Params::default()
    }
}

fn mat(m: Message) -> Mat {
    match m {
        Message::RespMat(v) => v,
        other => panic!("{other:?}"),
    }
}

/// Run dis_kpca + eval and return everything parity cares about:
/// solution bits, eval bits, and the per-round word table.
fn run_once(
    shards: Vec<Data>,
    chunk_rows: usize,
) -> (Mat, Mat, f64, f64, Vec<(String, usize, usize)>) {
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let p = params();
    let ((sol, err, trace), stats) = run_cluster_chunked(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        chunk_rows,
        move |cluster| {
            let sol = dis_kpca(cluster, kernel, &p).unwrap();
            let (err, trace) = dis_eval(cluster).unwrap();
            (sol, err, trace)
        },
    );
    (sol.y, sol.coeffs, err, trace, stats.table())
}

#[test]
fn dis_kpca_bit_identical_across_chunk_sizes_dense() {
    let mut rng = Rng::seed_from(4);
    let data = Data::Dense(clusters(10, 220, 3, 0.2, &mut rng));
    let n = data.len();
    let shards = partition_power_law(&data, 4, 6);
    let (y0, c0, err0, trace0, table0) = run_once(shards.clone(), 0);
    // the ISSUE's chunk grid: mid-size, larger-than-most-shards, n+1
    for chunk in [64, 1000, n + 1] {
        let (y, c, err, trace, table) = run_once(shards.clone(), chunk);
        assert!(y.data() == y0.data(), "solution points differ at chunk={chunk}");
        assert!(c.data() == c0.data(), "coefficients differ at chunk={chunk}");
        assert_eq!(err.to_bits(), err0.to_bits(), "eval error differs at chunk={chunk}");
        assert_eq!(trace.to_bits(), trace0.to_bits());
        assert_eq!(table, table0, "per-round comm words differ at chunk={chunk}");
    }
}

#[test]
fn dis_kpca_bit_identical_sparse_shards() {
    let mut rng = Rng::seed_from(9);
    let data = Data::Sparse(zipf_sparse(300, 150, 20, &mut rng));
    let shards = partition_power_law(&data, 3, 3);
    let (y0, c0, err0, _, table0) = run_once(shards.clone(), 0);
    for chunk in [1, 33] {
        let (y, c, err, _, table) = run_once(shards.clone(), chunk);
        assert!(y.data() == y0.data(), "sparse solution differs at chunk={chunk}");
        assert!(c.data() == c0.data());
        assert_eq!(err.to_bits(), err0.to_bits());
        assert_eq!(table, table0, "sparse comm words differ at chunk={chunk}");
    }
}

#[test]
fn poly_kernel_streaming_parity() {
    // TensorSketch + Gaussian embedding path (Poly goes through a
    // different sketch pipeline than RFF kernels)
    let mut rng = Rng::seed_from(5);
    let data = Data::Dense(clusters(8, 120, 3, 0.25, &mut rng));
    let kernel = Kernel::Poly { q: 2 };
    let p = params();
    let run = |chunk: usize| {
        let shards = partition_power_law(&data, 3, 2);
        run_cluster_chunked(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            chunk,
            move |cluster| {
                let sol = dis_kpca(cluster, kernel, &p).unwrap();
                let (err, trace) = dis_eval(cluster).unwrap();
                (sol.y, sol.coeffs, err, trace)
            },
        )
        .0
    };
    let (y0, c0, e0, t0) = run(0);
    let (y1, c1, e1, t1) = run(17);
    assert!(y0.data() == y1.data());
    assert!(c0.data() == c1.data());
    assert_eq!(e0.to_bits(), e1.to_bits());
    assert_eq!(t0.to_bits(), t1.to_bits());
}

#[test]
fn disk_backed_store_matches_resident_end_to_end() {
    // workers running straight off .dkps files must equal the
    // all-in-memory run bit for bit
    let mut rng = Rng::seed_from(7);
    let data = Data::Dense(clusters(9, 180, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, 3, 8);
    let (y0, c0, err0, trace0, table0) = run_once(shards.clone(), 0);

    let kernel = Kernel::Gauss { gamma: 0.7 };
    let p = params();
    let dir = std::env::temp_dir().join("diskpca_parity_stores");
    std::fs::create_dir_all(&dir).unwrap();
    let sources: Vec<ShardSource> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let path = dir.join(format!("shard_{i}.dkps"));
            diskpca::data::shard_store::write(sh, &path, 16).unwrap();
            ShardSource::Store(ShardStore::open(&path).unwrap())
        })
        .collect();
    let (star, endpoints) = diskpca::comm::memory::star(sources.len());
    let stats = diskpca::comm::CommStats::new();
    let cluster = diskpca::comm::Cluster::new(star, stats.clone());
    let handles: Vec<_> = sources
        .into_iter()
        .zip(endpoints)
        .map(|(src, ep)| {
            std::thread::spawn(move || {
                Worker::with_source(src, kernel, Arc::new(NativeBackend::new()), 0).run(ep)
            })
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &p).unwrap();
    let (err, trace) = dis_eval(&cluster).unwrap();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    assert!(sol.y.data() == y0.data(), "disk-backed solution differs");
    assert!(sol.coeffs.data() == c0.data());
    assert_eq!(err.to_bits(), err0.to_bits());
    assert_eq!(trace.to_bits(), trace0.to_bits());
    assert_eq!(stats.table(), table0, "disk-backed comm words differ");
}

#[test]
fn tcp_launcher_selftest_chunked_parity() {
    // full dis_kpca through real sockets: resident vs --chunk-rows
    let mk = |chunk: Option<&str>| {
        let mut cfg = Config::new();
        cfg.set("workers", "3");
        cfg.set("kernel", "gauss");
        cfg.set("gamma", "0.6");
        cfg.set("k", "3");
        cfg.set("t", "16");
        cfg.set("p", "32");
        cfg.set("n_lev", "8");
        cfg.set("n_adapt", "12");
        cfg.set("m_rff", "128");
        cfg.set("t2", "64");
        if let Some(c) = chunk {
            cfg.set("chunk-rows", c);
        }
        cfg
    };
    let (err0, trace0) = diskpca::launcher::selftest(&mk(None)).unwrap();
    for chunk in ["64", "1000"] {
        let (err, trace) = diskpca::launcher::selftest(&mk(Some(chunk))).unwrap();
        assert_eq!(err0.to_bits(), err.to_bits(), "tcp parity broke at chunk-rows={chunk}");
        assert_eq!(trace0.to_bits(), trace.to_bits());
    }
}

#[test]
fn single_sketch_apply_parity_over_store() {
    // the smallest end of the pinned spectrum: one ReqEmbed +
    // ReqSketchEmbed against resident, in-memory-chunked, and
    // disk-backed workers
    let mut rng = Rng::seed_from(2);
    let shard = Data::Dense(Mat::from_fn(6, 47, |_, _| rng.normal()));
    let path = std::env::temp_dir().join("diskpca_parity_single.dkps");
    diskpca::data::shard_store::write(&shard, &path, 9).unwrap();
    let kernel = Kernel::Gauss { gamma: 0.5 };
    let spec = EmbedSpec { kernel, m: 128, t2: 64, t: 8, seed: 3 };
    let be = || Arc::new(NativeBackend::new());
    let mut variants: Vec<(String, Worker)> = vec![
        ("resident".into(), Worker::new(shard.clone(), kernel, be())),
        ("chunk5".into(), Worker::new_chunked(shard.clone(), kernel, be(), 5)),
        (
            "store".into(),
            Worker::with_source(ShardSource::Store(ShardStore::open(&path).unwrap()), kernel, be(), 0),
        ),
        (
            "store+chunk7".into(),
            Worker::with_source(ShardSource::Store(ShardStore::open(&path).unwrap()), kernel, be(), 7),
        ),
    ];
    let mut reference: Option<Mat> = None;
    for (name, w) in &mut variants {
        w.handle(Message::ReqEmbed { spec });
        let sk = mat(w.handle(Message::ReqSketchEmbed { p: 16, seed: 5 }));
        match &reference {
            None => reference = Some(sk),
            Some(r) => assert!(sk.data() == r.data(), "{name} sketch bits differ"),
        }
    }
}

// NOTE: the companion memory-bound test (peak matrix allocation under
// chunking tracks the chunk size, not the shard size) lives in its own
// integration binary, `streaming_memory.rs` — the allocation gauge is
// process-global, and this binary's parity tests allocate shard-sized
// matrices on parallel test threads.
