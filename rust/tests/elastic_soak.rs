//! Seeded soak: a multi-job [`diskpca::serve::Service`] over the
//! elastic memory transport where **every** worker thread is mortal —
//! each dies after a deterministic-seed randomized request count,
//! spread across the job sequence. Every job must still complete, the
//! outputs and per-job word tables must be bitwise identical to a
//! fault-free service running the same sequence, and warm-spec reuse
//! must keep holding after rejoins (a revived worker has the embedding
//! replayed into it, so later warm jobs still skip `1-embed`).

use std::sync::Arc;
use std::time::Duration;

use diskpca::comm::{memory, Cluster, CommStats, Endpoint, Message};
use diskpca::coordinator::{Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::recovery::{LocalHost, Recovery, Transport};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::Service;

const S: usize = 3;

fn workload() -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(23);
    let data = Data::Dense(clusters(7, 130, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, S, 4);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 9,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// Serve `die_after` requests, then exit holding the next one.
fn mortal_worker(mut ep: impl Endpoint, shard: Data, kernel: Kernel, die_after: usize) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    let mut served = 0usize;
    loop {
        let req = match ep.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        if served == die_after {
            return;
        }
        let resp = worker.handle(req);
        if ep.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// The job sequence both services run: three KPCA fits (cold + two
/// warm) and a final eval. Returns per-job (y bits, coeffs bits,
/// table, embed words, reused flag) plus the eval pair.
fn run_jobs(svc: &mut Service, params: &Params) -> (Vec<JobTrace>, (f64, f64)) {
    let mut traces = Vec::new();
    for _ in 0..3 {
        let report = svc.run_kpca(params).unwrap();
        traces.push(JobTrace {
            y: report.output.y.data().to_vec(),
            coeffs: report.output.coeffs.data().to_vec(),
            table: report.job.stats.table(),
            embed_words: report.job.stats.round_words("1-embed"),
            reused: report.embed_reused,
        });
    }
    let ev = svc.run_eval().unwrap().output;
    (traces, ev)
}

struct JobTrace {
    y: Vec<f64>,
    coeffs: Vec<f64>,
    table: Vec<(String, usize, usize)>,
    embed_words: usize,
    reused: bool,
}

#[test]
fn seeded_soak_every_job_completes_and_warm_reuse_survives_rejoin() {
    let (shards, kernel, params) = workload();

    // fault-free reference service
    let mut ideal = Service::builder(kernel)
        .shards(shards.clone())
        .backend(Arc::new(NativeBackend::new()))
        .build();
    let (want, want_ev) = run_jobs(&mut ideal, &params);
    ideal.shutdown();

    // mortal service: every worker dies after a seeded request count,
    // staggered so deaths land in different jobs of the sequence
    let mut seed_rng = Rng::seed_from(0x50a7);
    let die_afters: Vec<usize> = (0..S).map(|i| 3 + i * 8 + seed_rng.below(5)).collect();
    let (star, endpoints, reply_tx) = memory::star_elastic(S);
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .zip(die_afters.iter().copied())
        .map(|((shard, ep), die_after)| {
            std::thread::spawn(move || mortal_worker(ep, shard, kernel, die_after))
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    let mut svc = Service::new(Cluster::new(star, CommStats::new()), kernel);
    svc.set_recovery(rec);

    let (got, got_ev) = run_jobs(&mut svc, &params);

    assert!(
        svc.recoveries() >= S,
        "all {S} mortal workers should have died and been revived (got {})",
        svc.recoveries()
    );
    assert_eq!(got_ev.0.to_bits(), want_ev.0.to_bits(), "eval error differs");
    assert_eq!(got_ev.1.to_bits(), want_ev.1.to_bits(), "eval trace differs");
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(g.y == w.y, "job {j}: representative points differ");
        assert!(g.coeffs == w.coeffs, "job {j}: coefficients differ");
        assert_eq!(g.table, w.table, "job {j}: per-job word table differs");
        assert_eq!(g.reused, w.reused, "job {j}: warm-reuse flag differs");
        if j > 0 {
            assert!(g.reused, "job {j} must reuse the warm embedding");
            assert_eq!(g.embed_words, 0, "warm job {j} must skip 1-embed entirely");
        }
    }

    svc.shutdown();
    for h in handles {
        let _ = h.join();
    }
}
