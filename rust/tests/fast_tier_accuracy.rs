//! Accuracy gate for the opt-in explicit-SIMD fast compute tier
//! (`--compute-tier fast`, [`diskpca::linalg::simd`]).
//!
//! The exact tier's bit-identity contract is pinned elsewhere
//! (`gemm_parity`, `par_engine`, `protocol_parity`); this suite pins
//! what the *fast* tier is allowed to do instead:
//!
//! | kernel | bound vs exact |
//! |---|---|
//! | packed GEMM / dot products | relative Frobenius ≤ 1e-13 |
//! | RFF cos map (post-projection) | per-entry abs ≤ 1e-14 |
//! | arc-cos map (post-projection) | value-identical (zero-sign aside) |
//! | Gauss / Laplace gram exp map | per-entry relative ≤ 1e-12 |
//! | FWHT butterflies | **bit-identical** |
//! | end-to-end dis_kpca rel-err | within 0.1 of the exact run |
//!
//! The tier is process-global state, so every test takes [`TierGuard`]
//! — a mutex hold that flips to the fast tier and restores the exact
//! tier (and the SIMD dispatch) on drop, even across panics. This
//! binary is declared as its own `[[test]]` target so no other suite
//! shares the process.

use std::sync::{Arc, Mutex, MutexGuard};

use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster, Params};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::{arccos_features, gram_sym, rff_features, rff_params, Kernel};
use diskpca::linalg::fft::fwht_inplace;
use diskpca::linalg::simd::{
    dispatch_name, set_compute_tier, set_force_portable, ComputeTier,
};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::sketch::Srht;

static LOCK: Mutex<()> = Mutex::new(());

/// Hold the suite-wide lock with the fast tier installed; drop
/// restores the exact tier and clears any forced-portable dispatch.
struct TierGuard {
    _lock: MutexGuard<'static, ()>,
}

impl TierGuard {
    fn fast() -> Self {
        let lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_compute_tier(ComputeTier::Fast);
        Self { _lock: lock }
    }

    /// Evaluate `f` under the exact tier, then return to fast — for
    /// computing the reference halves of the comparisons below.
    fn exactly<T>(&self, f: impl FnOnce() -> T) -> T {
        set_compute_tier(ComputeTier::Exact);
        let out = f();
        set_compute_tier(ComputeTier::Fast);
        out
    }
}

impl Drop for TierGuard {
    fn drop(&mut self) {
        set_force_portable(false);
        set_compute_tier(ComputeTier::Exact);
    }
}

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

/// ‖a − b‖_F / ‖a‖_F.
fn rel_fro(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let d = a[(i, j)] - b[(i, j)];
            num += d * d;
            den += a[(i, j)] * a[(i, j)];
        }
    }
    (num / den.max(1e-300)).sqrt()
}

fn assert_gemm_paths_close(g: &TierGuard, rng: &mut Rng, tol: f64) {
    // (m, k, n) with m·n·k ≥ PACKED_MIN_MNK so the microkernel runs,
    // with remainder tiles (non-multiples of MR=4 / NR=8) included
    for &(m, k, n) in &[(48usize, 40usize, 72usize), (33, 129, 45), (64, 256, 64)] {
        let a = randmat(rng, m, k);
        let b = randmat(rng, k, n);
        let at = randmat(rng, k, m);
        let bt = randmat(rng, n, k);
        let (e1, e2, e3, e4) = g.exactly(|| {
            (a.matmul(&b), at.matmul_at_b(&b), a.matmul_a_bt(&bt), a.gram_self())
        });
        assert!(rel_fro(&e1, &a.matmul(&b)) <= tol, "matmul {m}x{k}x{n}");
        assert!(rel_fro(&e2, &at.matmul_at_b(&b)) <= tol, "matmul_at_b {m}x{k}x{n}");
        assert!(rel_fro(&e3, &a.matmul_a_bt(&bt)) <= tol, "matmul_a_bt {m}x{k}x{n}");
        assert!(rel_fro(&e4, &a.gram_self()) <= tol, "gram_self {m}x{k}");
    }
}

#[test]
fn gemm_within_relative_frobenius_bound() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(11);
    assert_gemm_paths_close(&g, &mut rng, 1e-13);
}

#[test]
fn small_gemm_below_packed_threshold_is_bit_identical() {
    // under the dispatch floor both tiers take the reference loops
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(12);
    let a = randmat(&mut rng, 7, 9);
    let b = randmat(&mut rng, 9, 5);
    let exact = g.exactly(|| a.matmul(&b));
    let fast = a.matmul(&b);
    for i in 0..7 {
        for j in 0..5 {
            assert_eq!(exact[(i, j)].to_bits(), fast[(i, j)].to_bits(), "({i},{j})");
        }
    }
}

#[test]
fn rff_features_within_per_entry_bound() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(13);
    // d·m·n below the packed-GEMM floor, so the Ωᵀx projection is
    // bit-identical in both tiers and only the cos map differs —
    // bounded by the documented |cos_fast − cos| ≤ 5e-15 times the
    // √(2/m) scale
    let d = 4;
    let m = 16;
    let params = rff_params(d, m, 0.7, &mut rng);
    let x = Data::Dense(randmat(&mut rng, d, 20));
    let exact = g.exactly(|| rff_features(&params, &x));
    let fast = rff_features(&params, &x);
    for i in 0..m {
        for j in 0..20 {
            let diff = (exact[(i, j)] - fast[(i, j)]).abs();
            assert!(diff <= 1e-14, "({i},{j}): {diff:e}");
        }
    }
    // full pipeline (projection over the packed floor): still tight
    let params = rff_params(12, 128, 0.7, &mut rng);
    let x = Data::Dense(randmat(&mut rng, 12, 64));
    let exact = g.exactly(|| rff_features(&params, &x));
    let fast = rff_features(&params, &x);
    assert!(rel_fro(&exact, &fast) <= 1e-12);
}

#[test]
fn arccos_features_value_identical_after_identical_projection() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(14);
    let d = 4;
    let m = 16;
    let omega = randmat(&mut rng, d, m);
    let x = Data::Dense(randmat(&mut rng, d, 20));
    for degree in [0u32, 1, 2, 3] {
        let exact = g.exactly(|| arccos_features(&omega, degree, &x));
        let fast = arccos_features(&omega, degree, &x);
        for i in 0..m {
            for j in 0..20 {
                // == on f64: value-identical, tolerating -0.0 vs 0.0
                // (f64::max may return either sign of zero)
                assert!(exact[(i, j)] == fast[(i, j)], "deg {degree} ({i},{j})");
            }
        }
    }
}

#[test]
fn gauss_and_laplace_gram_within_per_entry_relative_bound() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(15);
    let y = randmat(&mut rng, 6, 18);
    for kernel in [Kernel::Gauss { gamma: 0.4 }, Kernel::Laplace { gamma: 0.4 }] {
        let exact = g.exactly(|| gram_sym(kernel, &y));
        let fast = gram_sym(kernel, &y);
        for i in 0..18 {
            for j in 0..18 {
                let (e, f) = (exact[(i, j)], fast[(i, j)]);
                assert!(e > 0.0 && e <= 1.0, "{kernel:?} ({i},{j}): {e}");
                assert!(((e - f) / e).abs() <= 1e-12, "{kernel:?} ({i},{j}): {e} vs {f}");
            }
        }
    }
}

#[test]
fn fwht_and_srht_are_bit_identical_across_tiers() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(16);
    // the lane-wise butterfly is pairwise a+b / a−b with no
    // reassociation — the one fast-tier kernel with a stronger-than-
    // bound guarantee
    for &n in &[4usize, 8, 64, 512, 1024] {
        let orig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut exact = orig.clone();
        g.exactly(|| fwht_inplace(&mut exact));
        let mut fast = orig;
        fwht_inplace(&mut fast);
        for i in 0..n {
            assert_eq!(exact[i].to_bits(), fast[i].to_bits(), "n={n} i={i}");
        }
    }
    // …so a full SRHT sketch is bit-identical too
    let s = Srht::new(100, 32, &mut rng);
    let a = randmat(&mut rng, 100, 9);
    let exact = g.exactly(|| s.apply_feature_axis(&a));
    let fast = s.apply_feature_axis(&a);
    for i in 0..32 {
        for j in 0..9 {
            assert_eq!(exact[(i, j)].to_bits(), fast[(i, j)].to_bits(), "({i},{j})");
        }
    }
}

#[test]
fn portable_fallback_passes_the_same_bounds() {
    // force the portable (non-intrinsics) lanes: the dispatch smoke —
    // machines without AVX2 must satisfy the identical contract
    let g = TierGuard::fast();
    set_force_portable(true);
    assert_eq!(dispatch_name(), "portable");
    let mut rng = Rng::seed_from(17);
    assert_gemm_paths_close(&g, &mut rng, 1e-13);
    let orig: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
    let mut exact = orig.clone();
    g.exactly(|| fwht_inplace(&mut exact));
    let mut fast = orig;
    fwht_inplace(&mut fast);
    for i in 0..256 {
        assert_eq!(exact[i].to_bits(), fast[i].to_bits(), "i={i}");
    }
    set_force_portable(false);
}

#[test]
fn fast_tier_is_self_deterministic_across_thread_counts() {
    // the fast tier may differ from exact, but must not differ from
    // itself: same packing, tiling and chunk partitioning for every
    // pool size
    let _g = TierGuard::fast();
    let mut rng = Rng::seed_from(18);
    let a = randmat(&mut rng, 96, 128);
    let b = randmat(&mut rng, 128, 80);
    let params = rff_params(24, 256, 0.5, &mut rng);
    let x = Data::Dense(randmat(&mut rng, 24, 200));
    let mut runs: Vec<Vec<u64>> = Vec::new();
    for threads in [1usize, 4] {
        diskpca::par::set_threads(threads);
        let mut bits = Vec::new();
        let c = a.matmul(&b);
        let gm = a.gram_self();
        let f = rff_features(&params, &x);
        for m in [&c, &gm, &f] {
            for i in 0..m.rows() {
                for j in 0..m.cols() {
                    bits.push(m[(i, j)].to_bits());
                }
            }
        }
        runs.push(bits);
    }
    diskpca::par::set_threads(1);
    assert_eq!(runs[0], runs[1], "fast tier must be thread-count invariant");
}

#[test]
fn end_to_end_dis_kpca_error_matches_exact_within_tolerance() {
    let g = TierGuard::fast();
    let mut rng = Rng::seed_from(19);
    let data = Data::Dense(clusters(8, 160, 3, 0.2, &mut rng));
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 10,
        n_adapt: 20,
        m_rff: 128,
        t2: 64,
        seed: 7,
        ..Params::default()
    };
    let run = || {
        let shards = partition_power_law(&data, 3, 1);
        let ((err, trace), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        (err, trace)
    };
    let (err_e, trace_e) = g.exactly(run);
    let (err_f, trace_f) = run();
    assert!(err_e >= 0.0 && err_e < trace_e, "exact run sane: {err_e} vs {trace_e}");
    assert!(err_f >= 0.0 && err_f < trace_f, "fast run sane: {err_f} vs {trace_f}");
    // the per-kernel bounds are ~1e-12, but a perturbed leverage score
    // can flip a sampled point, so the end-to-end gate is coarser: the
    // two relative errors must tell the same story
    let (r_e, r_f) = (err_e / trace_e, err_f / trace_f);
    assert!((r_e - r_f).abs() <= 0.1, "rel-err drifted: exact {r_e} vs fast {r_f}");
}
