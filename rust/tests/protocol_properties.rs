//! Property-based tests over coordinator invariants (hand-rolled
//! random-input harness — proptest is unavailable offline; DESIGN.md
//! §3). Each property runs across a sweep of random configurations
//! derived from a fixed master seed, so failures are reproducible.

use std::sync::Arc;

use diskpca::comm::{codec, Message, PointSet};
use diskpca::coordinator::{
    batch_kpca, dis_css, dis_eval, dis_kpca, dis_kpca_boosted, run_cluster, GatherMode, Params,
    Worker,
};
use diskpca::data::{clusters, partition_power_law, zipf_sparse, Data};
use diskpca::kernels::{gram, Kernel};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn random_kernel(rng: &mut Rng) -> Kernel {
    match rng.below(4) {
        0 => Kernel::Gauss { gamma: rng.uniform(0.1, 2.0) },
        1 => Kernel::Poly { q: 2 + rng.below(3) as u32 },
        2 => Kernel::ArcCos { degree: rng.below(3) as u32 },
        _ => Kernel::Laplace { gamma: rng.uniform(0.1, 1.5) },
    }
}

fn random_data(rng: &mut Rng) -> Data {
    let d = 4 + rng.below(12);
    let n = 60 + rng.below(120);
    if rng.below(4) == 0 {
        Data::Sparse(zipf_sparse(d * 8, n, 1 + d / 2, rng))
    } else {
        let k = 2 + rng.below(4);
        Data::Dense(clusters(d, n, k, rng.uniform(0.1, 0.6), rng))
    }
}

fn random_params(rng: &mut Rng) -> Params {
    Params {
        k: 2 + rng.below(4),
        t: 8 + 8 * rng.below(3),
        p: 24 + rng.below(40),
        n_lev: 6 + rng.below(10),
        n_adapt: 10 + rng.below(30),
        w: 0,
        m_rff: 128,
        t2: 64,
        seed: rng.next_u64(),
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    }
}

/// Property: for any config, the solution is orthonormal, its error
/// is within [optimum, trace], and distributed eval == local eval.
#[test]
fn prop_solution_sound_across_configs() {
    let mut rng = Rng::seed_from(0xfeed);
    for trial in 0..8 {
        let data = random_data(&mut rng);
        let kernel = random_kernel(&mut rng);
        let params = random_params(&mut rng);
        let s = 2 + rng.below(4);
        let shards = partition_power_law(&data, s, rng.next_u64());
        let ((sol, err, trace), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |c| {
                let sol = dis_kpca(c, kernel, &params).unwrap();
                let (e, t) = dis_eval(c).unwrap();
                (sol, e, t)
            },
        );
        // orthonormal
        let kyy = gram(kernel, &sol.y, &Data::Dense(sol.y.clone()));
        let ltl = sol.coeffs.matmul_at_b(&kyy.matmul(&sol.coeffs));
        let err_orth = ltl.max_abs_diff(&Mat::identity(sol.k()));
        assert!(err_orth < 1e-3, "trial {trial}: LᵀL err {err_orth}");
        // error bounds
        assert!(err >= -1e-6 && err <= trace * (1.0 + 1e-9), "trial {trial}: {err} vs {trace}");
        // distributed == local
        let local = sol.eval_error(&data);
        assert!(
            (err - local).abs() <= 1e-6 * trace.max(1.0),
            "trial {trial}: dis {err} local {local}"
        );
        // never beats the batch optimum
        let opt = batch_kpca(&data.to_dense(), kernel, params.k, false, 3).opt_error;
        assert!(err >= opt - 1e-6 * trace.max(1.0), "trial {trial}: {err} < opt {opt}");
    }
}

/// Property: residual masses decrease monotonically as the broadcast
/// set P grows (more span ⇒ smaller distances).
#[test]
fn prop_residuals_monotone_in_p() {
    let mut rng = Rng::seed_from(0xbeef);
    for _trial in 0..6 {
        let data = random_data(&mut rng);
        let kernel = random_kernel(&mut rng);
        let mut worker = Worker::new(
            data.clone(),
            kernel,
            Arc::new(NativeBackend::new()),
        );
        let n = data.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut last = f64::INFINITY;
        for take in [2usize, 8, 24] {
            let take = take.min(n);
            let pts = PointSet::from_data(&data, &idx[..take]);
            let mass = match worker.handle(Message::ReqResiduals { pts }) {
                Message::RespScalar(v) => v,
                other => panic!("{other:?}"),
            };
            assert!(mass <= last + 1e-6, "residual grew: {mass} > {last}");
            last = mass;
        }
    }
}

/// Property: every message survives a codec roundtrip with identical
/// word count and tag (random payloads).
#[test]
fn prop_codec_roundtrip_random_messages() {
    let mut rng = Rng::seed_from(0xc0dec);
    for _ in 0..50 {
        let r = 1 + rng.below(20);
        let c = 1 + rng.below(20);
        let m = Mat::from_fn(r, c, |_, _| rng.normal());
        let sparse_cols: Vec<Vec<(u32, f64)>> = (0..rng.below(6))
            .map(|_| (0..rng.below(5)).map(|_| (rng.below(50) as u32, rng.normal())).collect())
            .collect();
        let msgs = vec![
            Message::RespMat(m.clone()),
            Message::ReqScores { z: m.clone() },
            Message::ReqFinal { coeffs: m.clone() },
            Message::ReqKmeansStep { centers: m.clone() },
            Message::ReqResiduals {
                pts: PointSet::Sparse { d: 50, cols: sparse_cols.clone() },
            },
            Message::ReqSetSolution {
                pts: PointSet::Dense(m.clone()),
                coeffs: m.clone(),
            },
            Message::RespKmeans {
                sums: m.clone(),
                counts: (0..c).map(|_| rng.below(100)).collect(),
                obj: rng.normal(),
            },
        ];
        for msg in msgs {
            let back = codec::decode(&codec::encode(&msg)).unwrap();
            assert_eq!(back.tag(), msg.tag());
            assert_eq!(back.words(), msg.words());
        }
    }
}

/// Property: partitioning preserves the multiset of points for any
/// (n, s, seed).
#[test]
fn prop_partition_preserves_points() {
    let mut rng = Rng::seed_from(0x9a27);
    for _ in 0..10 {
        let data = random_data(&mut rng);
        let s = 1 + rng.below(8);
        let shards = partition_power_law(&data, s, rng.next_u64());
        assert_eq!(shards.len(), s);
        assert_eq!(shards.iter().map(|x| x.len()).sum::<usize>(), data.len());
        let total_nnz: usize = shards.iter().map(|x| x.nnz()).sum();
        assert_eq!(total_nnz, data.nnz());
    }
}

/// Property: the CSS certificate is sound for any config — the
/// residual equals the single-machine kernel-trick recomputation, and
/// the fraction lies in [0, 1].
#[test]
fn prop_css_certificate_sound() {
    let mut rng = Rng::seed_from(0xc550);
    for trial in 0..6 {
        let data = random_data(&mut rng);
        let kernel = random_kernel(&mut rng);
        let params = random_params(&mut rng);
        let s = 2 + rng.below(3);
        let shards = partition_power_law(&data, s, rng.next_u64());
        let (sol, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |c| dis_css(c, kernel, &params).unwrap(),
        );
        let frac = sol.residual_fraction();
        assert!((0.0..=1.0).contains(&frac), "trial {trial}: frac {frac}");
        // recompute single-machine
        let y = sol.y.to_mat();
        let kyy = gram(kernel, &y, &Data::Dense(y.clone()));
        let (r, _) = diskpca::linalg::chol_psd(&kyy);
        let kya = gram(kernel, &y, &data);
        let pi = diskpca::linalg::solve_upper_transpose_mat(&r, &kya);
        let norms = pi.col_norms_sq();
        let local: f64 = diskpca::kernels::diag(kernel, &data)
            .iter()
            .zip(&norms)
            .map(|(&d, &n)| (d - n).max(0.0))
            .sum();
        assert!(
            (sol.residual - local).abs() <= 1e-4 * sol.trace.max(1.0),
            "trial {trial}: dis {} vs local {local}",
            sol.residual
        );
    }
}

/// Property: boosting returns the argmin attempt and installs it.
#[test]
fn prop_boost_returns_min_attempt() {
    let mut rng = Rng::seed_from(0xb057);
    for _trial in 0..4 {
        let data = random_data(&mut rng);
        let kernel = random_kernel(&mut rng);
        let params = random_params(&mut rng);
        let shards = partition_power_law(&data, 2 + rng.below(3), rng.next_u64());
        let ((run, installed), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |c| {
                let run = dis_kpca_boosted(c, kernel, &params, 3).unwrap();
                let (err, _) = dis_eval(c).unwrap();
                (run, err)
            },
        );
        let min = run.errors.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(run.errors[run.winner], min);
        assert!((installed - min).abs() <= 1e-5 * run.trace.max(1.0));
    }
}

/// Property: degenerate shards — identical points, zero matrices, a
/// single point — never panic and keep errors within bounds.
#[test]
fn prop_degenerate_data_survives() {
    let mut rng = Rng::seed_from(0xdead);
    let degenerates: Vec<Data> = vec![
        // all points identical
        Data::Dense(Mat::from_fn(5, 40, |i, _| (i as f64) * 0.3)),
        // all zeros
        Data::Dense(Mat::zeros(4, 30)),
        // rank-1 data
        {
            let v: Vec<f64> = (0..6).map(|i| (i as f64 + 1.0) * 0.2).collect();
            Data::Dense(Mat::from_fn(6, 50, |i, j| v[i] * ((j as f64) - 25.0) * 0.1))
        },
    ];
    for data in degenerates {
        for kernel in [
            Kernel::Gauss { gamma: 0.5 },
            Kernel::Poly { q: 2 },
            Kernel::Laplace { gamma: 0.5 },
        ] {
            let params = Params {
                k: 3,
                t: 8,
                p: 20,
                n_lev: 5,
                n_adapt: 8,
                w: 0,
                m_rff: 64,
                t2: 32,
                seed: rng.next_u64(),
                threads: 0,
                chunk_rows: 0,
                gather: GatherMode::Flat,
            };
            let shards = partition_power_law(&data, 3, rng.next_u64());
            let ((err, trace), _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |c| {
                    let _ = dis_kpca(c, kernel, &params).unwrap();
                    dis_eval(c).unwrap()
                },
            );
            assert!(err >= -1e-6, "err {err}");
            assert!(err <= trace * (1.0 + 1e-6) + 1e-6, "err {err} trace {trace}");
        }
    }
}

/// Property: the word accounting is exact — total words equal the sum
/// of the per-message `words()` on both directions (cross-checked by
/// replaying the same run and summing by hand is impossible from
/// outside, so we check internal consistency: table sums = total).
#[test]
fn prop_comm_table_sums_to_total() {
    let mut rng = Rng::seed_from(0xacc1);
    for _ in 0..4 {
        let data = random_data(&mut rng);
        let kernel = random_kernel(&mut rng);
        let params = random_params(&mut rng);
        let shards = partition_power_law(&data, 3, rng.next_u64());
        let (_, stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |c| {
                let _ = dis_kpca(c, kernel, &params).unwrap();
                dis_eval(c).unwrap()
            },
        );
        let table_total: usize = stats.table().iter().map(|(_, u, d)| u + d).sum();
        assert_eq!(table_total, stats.total_words());
        assert!(stats.message_count() > 0);
    }
}

/// Compile-time exhaustive index over `Message` variants: adding a
/// variant without extending `canonical_messages` below breaks this
/// match, which is the point — the codec coverage test can then never
/// silently miss a frame.
fn variant_index(m: &Message) -> usize {
    use Message::*;
    match m {
        ReqEmbed { .. } => 0,
        ReqSketchEmbed { .. } => 1,
        ReqScores { .. } => 2,
        ReqSampleLeverage { .. } => 3,
        ReqResiduals { .. } => 4,
        ReqSampleAdaptive { .. } => 5,
        ReqProjectSketch { .. } => 6,
        ReqFinal { .. } => 7,
        ReqEvalError => 8,
        ReqEvalTrace => 9,
        ReqSampleUniform { .. } => 10,
        ReqKmeansStep { .. } => 11,
        ReqCount => 12,
        Quit => 13,
        RespMat(_) => 14,
        RespScalar(_) => 15,
        RespCount(_) => 16,
        RespPoints(_) => 17,
        RespKmeans { .. } => 18,
        Ack => 19,
        ReqSetSolution { .. } => 20,
        ReqSampleProjected { .. } => 21,
        ReqBusyTime => 22,
        ReqScoresVec => 23,
        ReqKrrStats { .. } => 24,
        RespKrr { .. } => 25,
        ReqKrrEval { .. } => 26,
        RespError(_) => 27,
        ReqProjectPoints { .. } => 28,
        ReqSketchEmbedR { .. } => 29,
        ReqProjectSketchR { .. } => 30,
        ReqLoadShard { .. } => 31,
        ReqRefreshShard { .. } => 32,
        ReqDeltaSketch { .. } => 33,
        ReqAdoptShard { .. } => 34,
    }
}

/// One canonical instance of every `Message` variant, with both dense
/// and sparse point payloads represented.
fn canonical_messages() -> Vec<Message> {
    let mut rng = Rng::seed_from(0xa11);
    let m = Mat::from_fn(3, 4, |_, _| rng.normal());
    let tall = Mat::from_fn(5, 2, |_, _| rng.normal());
    let dense = PointSet::Dense(Mat::from_fn(4, 3, |_, _| rng.normal()));
    let sparse = PointSet::Sparse {
        d: 40,
        cols: vec![vec![(0, 1.5), (7, -2.0)], vec![], vec![(39, 0.25)]],
    };
    let spec = diskpca::embed::EmbedSpec {
        kernel: diskpca::kernels::Kernel::Laplace { gamma: 0.4 },
        m: 256,
        t2: 128,
        t: 32,
        seed: 77,
    };
    vec![
        Message::ReqEmbed { spec },
        Message::ReqSketchEmbed { p: 9, seed: 2 },
        Message::ReqScores { z: m.clone() },
        Message::ReqSampleLeverage { count: 3, seed: 4 },
        Message::ReqResiduals { pts: sparse.clone() },
        Message::ReqSampleAdaptive { count: 5, seed: 6 },
        Message::ReqProjectSketch { pts: dense.clone(), w: 7, seed: 8 },
        Message::ReqFinal { coeffs: tall.clone() },
        Message::ReqEvalError,
        Message::ReqEvalTrace,
        Message::ReqSampleUniform { count: 9, seed: 10 },
        Message::ReqKmeansStep { centers: m.clone() },
        Message::ReqCount,
        Message::Quit,
        Message::RespMat(m.clone()),
        Message::RespScalar(-0.5),
        Message::RespCount(11),
        Message::RespPoints(sparse),
        Message::RespKmeans { sums: m.clone(), counts: vec![2, 0, 5, 1], obj: 3.25 },
        Message::Ack,
        Message::ReqSetSolution { pts: dense, coeffs: tall.clone() },
        Message::ReqSampleProjected { count: 12, seed: 13 },
        Message::ReqBusyTime,
        Message::ReqScoresVec,
        Message::ReqKrrStats {
            pts: PointSet::Dense(Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f64)),
            teacher_seed: 14,
        },
        Message::RespKrr { g: m.clone(), b: tall, tnorm: 6.5 },
        Message::ReqKrrEval { alpha: Mat::from_fn(4, 1, |i, _| i as f64 * 0.1) },
        Message::RespError("block 3 unreadable".into()),
        Message::ReqProjectPoints {
            pts: PointSet::Dense(Mat::from_fn(3, 5, |i, j| (i + j) as f64)),
        },
        Message::ReqSketchEmbedR { p: 15, seed: 16 },
        Message::ReqProjectSketchR {
            pts: PointSet::Dense(Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64)),
            w: 17,
            seed: 18,
        },
        Message::ReqLoadShard { path: "shards/susy_like_002.dkps".into(), chunk_rows: 64 },
        Message::ReqRefreshShard { epoch: 3 },
        Message::ReqDeltaSketch { p: 19, seed: 20 },
        Message::ReqAdoptShard {
            path: "shards/susy_like_001.dkps".into(),
            pts: PointSet::Dense(Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f64)),
            chunk_rows: 32,
        },
    ]
}

/// Property: EVERY wire frame variant — requests, responses,
/// `RespError` included — round-trips the codec with an identical
/// byte encoding (payload equality without needing `PartialEq`) and
/// an invariant word count across encode/decode.
#[test]
fn codec_roundtrip_covers_every_variant() {
    let msgs = canonical_messages();
    // exhaustiveness: one of each variant, none forgotten
    let mut seen: Vec<usize> = msgs.iter().map(variant_index).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen, (0..35).collect::<Vec<_>>(), "canonical list must cover all 35 variants");
    for msg in msgs {
        let bytes = codec::encode(&msg);
        let back = codec::decode(&bytes).unwrap_or_else(|e| panic!("{}: {e:?}", msg.tag()));
        assert_eq!(back.tag(), msg.tag(), "variant changed across the wire");
        assert_eq!(back.words(), msg.words(), "{}: words() not invariant", msg.tag());
        assert_eq!(variant_index(&back), variant_index(&msg));
        // re-encoding the decoded message must reproduce the exact
        // bytes — i.e. every payload field survived bit-for-bit.
        assert_eq!(codec::encode(&back), bytes, "{}: lossy roundtrip", msg.tag());
    }
}

/// Property: truncating a valid frame at any byte boundary yields a
/// decode error (never a panic or a bogus message) for every variant.
#[test]
fn codec_rejects_truncation_of_every_variant() {
    for msg in canonical_messages() {
        let bytes = codec::encode(&msg);
        for cut in [0, 1, bytes.len().saturating_sub(1)] {
            if cut >= bytes.len() {
                continue;
            }
            assert!(
                codec::decode(&bytes[..cut]).is_err(),
                "{}: truncation at {cut}/{} decoded",
                msg.tag(),
                bytes.len()
            );
        }
    }
}
