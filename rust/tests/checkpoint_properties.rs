//! Checkpoint codec properties, mirroring `protocol_properties.rs`:
//! every decodable buffer re-encodes to the exact same bytes, every
//! truncation of a valid checkpoint is rejected (never panics, never
//! mis-decodes), and a solution restored from a decoded checkpoint
//! replays into a revived worker bit-identically to an uninterrupted
//! run.

use std::sync::Arc;
use std::time::Duration;

use diskpca::comm::codec::CodecError;
use diskpca::comm::{memory, Cluster, CommStats, Endpoint, Message, PointSet};
use diskpca::coordinator::{dis_eval, dis_kpca, dis_set_solution, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::recovery::{
    dis_eval_recovering, Checkpoint, LocalHost, Recovery, Transport, CHECKPOINT_VERSION,
};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

/// A spread of checkpoints over the codec's value space: empty,
/// partially filled, dense and sparse point sets, degenerate shapes.
fn varied_checkpoints() -> Vec<Checkpoint> {
    let spec = EmbedSpec {
        kernel: Kernel::Gauss { gamma: 0.75 },
        m: 128,
        t2: 64,
        t: 16,
        seed: 7 ^ 0xeb3d,
    };
    vec![
        Checkpoint::new(0),
        Checkpoint { round: "2-disLS".into(), spec: Some(spec), ..Checkpoint::new(7) },
        Checkpoint {
            round: "5-disLR".into(),
            w_cols: 33,
            spec: Some(spec),
            z: Some(Mat::from_fn(4, 4, |i, j| 1.0 / (1.0 + (i + j) as f64))),
            y: Some(PointSet::Dense(Mat::from_fn(3, 6, |i, j| (i * 6 + j) as f64 - 8.5))),
            final_w: Some(Mat::from_fn(6, 2, |i, j| (i as f64).powi(j as i32 + 1))),
            ..Checkpoint::new(7)
        },
        // sparse representative set, including an all-zero column
        Checkpoint {
            round: "recover".into(),
            y: Some(PointSet::Sparse {
                d: 5,
                cols: vec![vec![(0, 1.5), (3, -2.0)], vec![], vec![(4, 0.25)]],
            }),
            solution: Some((
                PointSet::Sparse { d: 2, cols: vec![vec![(1, -0.5)]] },
                Mat::from_fn(1, 1, |_, _| f64::MIN_POSITIVE),
            )),
            ..Checkpoint::new(u64::MAX)
        },
        // degenerate 0×0 matrices must survive the trip too
        Checkpoint {
            round: String::new(),
            z: Some(Mat::zeros(0, 0)),
            final_w: Some(Mat::zeros(0, 3)),
            ..Checkpoint::new(1)
        },
    ]
}

#[test]
fn every_checkpoint_reencodes_to_identical_bytes() {
    for (i, cp) in varied_checkpoints().into_iter().enumerate() {
        let bytes = cp.encode();
        let back = Checkpoint::decode(&bytes).unwrap_or_else(|e| panic!("checkpoint {i}: {e:?}"));
        assert_eq!(back.encode(), bytes, "checkpoint {i}: decode∘encode is not the identity");
        assert_eq!(back.round, cp.round, "checkpoint {i}");
        assert_eq!(back.seed, cp.seed, "checkpoint {i}");
        assert_eq!(back.w_cols, cp.w_cols, "checkpoint {i}");
        assert_eq!(back.spec, cp.spec, "checkpoint {i}");
    }
}

#[test]
fn every_truncation_is_rejected() {
    for (i, cp) in varied_checkpoints().into_iter().enumerate() {
        let bytes = cp.encode();
        for len in 0..bytes.len() {
            assert!(
                Checkpoint::decode(&bytes[..len]).is_err(),
                "checkpoint {i}: {len}-byte prefix of {} decoded",
                bytes.len()
            );
        }
    }
}

#[test]
fn version_flag_and_trailing_corruption_are_rejected() {
    let bytes = varied_checkpoints().pop().unwrap().encode();

    let mut wrong_version = bytes.clone();
    wrong_version[0] = CHECKPOINT_VERSION + 3;
    assert!(matches!(
        Checkpoint::decode(&wrong_version),
        Err(CodecError::BadTag(v)) if v == CHECKPOINT_VERSION + 3
    ));

    // the last field flag sits at the tail of every checkpoint whose
    // final option is None — force it to a non-boolean byte
    let mut bad_flag = Checkpoint::new(5).encode();
    let last = bad_flag.len() - 1;
    bad_flag[last] = 9;
    assert!(matches!(Checkpoint::decode(&bad_flag), Err(CodecError::BadTag(9))));

    let mut trailing = bytes;
    trailing.push(0);
    assert!(matches!(Checkpoint::decode(&trailing), Err(CodecError::Trailing)));
}

/// A worker that serves `die_after` requests then exits holding the
/// next one (duplicated from `fault_injection.rs` — test crates are
/// separate binaries).
fn mortal_worker(mut ep: impl Endpoint, shard: Data, kernel: Kernel, die_after: usize) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    let mut served = 0usize;
    loop {
        let req = match ep.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) || served == die_after {
            return;
        }
        let resp = worker.handle(req);
        if ep.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// The end-to-end property: a checkpoint that went through
/// encode→decode drives a replay whose eval is bit-identical to the
/// uninterrupted cluster's.
#[test]
fn replay_from_decoded_checkpoint_matches_uninterrupted_run() {
    let s = 3;
    let mut rng = Rng::seed_from(31);
    let data = Data::Dense(clusters(6, 110, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, s, 2);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 13,
        ..Params::default()
    };

    // uninterrupted reference: fit + eval on a plain memory star
    let (star, endpoints) = memory::star(s);
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .map(|(shard, ep)| {
            std::thread::spawn(move || {
                Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep)
            })
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &params).unwrap();
    let want = dis_eval(&cluster).unwrap();
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }

    // serialize the solution as a checkpoint and round-trip it
    let cp = Checkpoint {
        round: "6-eval".into(),
        solution: Some((PointSet::Dense(sol.y.clone()), sol.coeffs.clone())),
        ..Checkpoint::new(params.seed)
    };
    let decoded = Checkpoint::decode(&cp.encode()).unwrap();

    // elastic cluster: worker 1 answers the solution install, then
    // dies holding its first eval request
    let (star, endpoints, reply_tx) = memory::star_elastic(s);
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(60));
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            let die_after = if i == 1 { 1 } else { usize::MAX };
            std::thread::spawn(move || mortal_worker(ep, shard, kernel, die_after))
        })
        .collect();
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));

    dis_set_solution(&cluster, &sol).unwrap();
    // resume from the serialized state, as a restarted master would
    rec.checkpoint = decoded;
    let got = dis_eval_recovering(&cluster, &mut rec).unwrap();

    assert!(rec.recoveries() >= 1, "worker 1's death must have forced a revival");
    assert_eq!(got.0.to_bits(), want.0.to_bits(), "eval error differs after replay");
    assert_eq!(got.1.to_bits(), want.1.to_bits(), "eval trace differs after replay");

    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
}
