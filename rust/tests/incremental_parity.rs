//! Incremental-refit parity tests — the epoch-aware tentpole
//! invariant: a warm [`dis_kpca_refit`] over appended shard stores is
//! **bit-identical** to a cold [`dis_kpca`] over the same stores —
//! solution points, coefficients, and the per-round communication
//! word table for every shared round — while shipping **zero**
//! `1-embed` words and strictly fewer total words. Pinned across
//! chunk sizes and across the memory and TCP transports.

use std::sync::Arc;

use diskpca::comm::{memory, tcp, Cluster, CommStats};
use diskpca::coordinator::{dis_kpca, dis_kpca_refit, Params, RefitReport, Worker};
use diskpca::data::{clusters, partition_power_law, Data, ShardSource, ShardStore};
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn kernel() -> Kernel {
    Kernel::Gauss { gamma: 0.7 }
}

fn params() -> Params {
    Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 10,
        n_adapt: 20,
        m_rff: 256,
        t2: 64,
        seed: 12,
        ..Params::default()
    }
}

/// The refit gate is effectively disabled here: these tests pin
/// bit-identity of the *warm* path, and the gate's own behavior is
/// covered by the serve and master unit tests.
const NO_GATE: f64 = 1e-6;

fn base_shards(seed: u64) -> Vec<Data> {
    let mut rng = Rng::seed_from(seed);
    let data = Data::Dense(clusters(8, 150, 3, 0.2, &mut rng));
    partition_power_law(&data, 3, 6)
}

/// Deterministic per-shard append payload (shard `i` gets `3 + i`
/// columns), identical across chunk sizes and transports so warm
/// solutions are comparable between sweeps.
fn delta_for(i: usize) -> Data {
    let mut rng = Rng::seed_from(100 + i as u64);
    Data::Dense(Mat::from_fn(8, 3 + i, |_, _| rng.normal()))
}

fn write_stores(tag: &str, shards: &[Data], block_points: usize) -> Vec<std::path::PathBuf> {
    let dir = std::env::temp_dir().join(format!("diskpca_incremental_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let path = dir.join(format!("shard_{i}.dkps"));
            diskpca::data::shard_store::write(sh, &path, block_points).unwrap();
            path
        })
        .collect()
}

/// Spawn store-backed workers on the memory transport, run `body`
/// against the cluster (with live access to its stats for mid-run
/// table snapshots), shut down, and join.
fn with_store_cluster<T>(
    paths: &[std::path::PathBuf],
    chunk_rows: usize,
    body: impl FnOnce(&Cluster, &CommStats) -> T,
) -> T {
    let sources: Vec<ShardSource> = paths
        .iter()
        .map(|p| ShardSource::Store(ShardStore::open(p).unwrap()))
        .collect();
    let (star, endpoints) = memory::star(sources.len());
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let handles: Vec<_> = sources
        .into_iter()
        .zip(endpoints)
        .map(|(src, ep)| {
            let k = kernel();
            std::thread::spawn(move || {
                Worker::with_source(src, k, Arc::new(NativeBackend::new()), chunk_rows).run(ep)
            })
        })
        .collect();
    let out = body(&cluster, &stats);
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    out
}

type Table = Vec<(String, usize, usize)>;

/// Per-round word growth between two cumulative snapshots — the
/// contribution of whatever ran in between (rounds that did not move
/// are dropped).
fn table_diff(before: &Table, after: &Table) -> Table {
    after
        .iter()
        .map(|(round, up, down)| {
            let (bu, bd) = before
                .iter()
                .find(|(r, _, _)| r == round)
                .map(|(_, u, d)| (*u, *d))
                .unwrap_or((0, 0));
            (round.clone(), up - bu, down - bd)
        })
        .filter(|(_, u, d)| *u > 0 || *d > 0)
        .collect()
}

fn words(t: &Table, round: &str) -> (usize, usize) {
    t.iter()
        .find(|(r, _, _)| r == round)
        .map(|(_, u, d)| (*u, *d))
        .unwrap_or((0, 0))
}

fn total(t: &Table) -> usize {
    t.iter().map(|(_, u, d)| u + d).sum()
}

/// The word-table contract of one refit against its cold reference:
/// no `1-embed` words at all, a (tiny) `0-refresh` round the cold fit
/// doesn't have, every shared round identical word for word, and
/// strictly fewer words in total.
fn assert_refit_words(refit: &Table, cold: &Table, ctx: &str) {
    assert_eq!(words(refit, "1-embed"), (0, 0), "{ctx}: refit must ship zero 1-embed words");
    assert!(words(refit, "0-refresh") != (0, 0), "{ctx}: refit must run the refresh round");
    assert_eq!(words(cold, "0-refresh"), (0, 0), "{ctx}: cold fit has no refresh round");
    for (round, up, down) in cold {
        if round == "1-embed" {
            continue;
        }
        assert_eq!(
            words(refit, round),
            (*up, *down),
            "{ctx}: shared round {round} must cost identical words"
        );
    }
    assert!(
        total(refit) < total(cold),
        "{ctx}: refit must be strictly cheaper ({} vs {} words)",
        total(refit),
        total(cold)
    );
}

#[test]
fn refit_without_appends_is_bit_identical_and_strictly_cheaper() {
    let shards = base_shards(4);
    for chunk in [0usize, 5] {
        let paths = write_stores(&format!("noappend_c{chunk}"), &shards, 5);
        let (y0, c0, report, fit_table, refit_table) =
            with_store_cluster(&paths, chunk, |cluster, stats| {
                let p = params();
                let cold = dis_kpca(cluster, kernel(), &p).unwrap();
                let fit_table = stats.table();
                let report = dis_kpca_refit(cluster, kernel(), &p, 0, NO_GATE).unwrap();
                let refit_table = table_diff(&fit_table, &stats.table());
                (cold.y, cold.coeffs, report, fit_table, refit_table)
            });
        assert!(!report.fell_back, "chunk={chunk}");
        assert_eq!(report.epoch, 0, "nothing was appended");
        assert_eq!(report.delta_cols, 0);
        assert!(
            report.solution.y.data() == y0.data(),
            "chunk={chunk}: refit solution points differ from the cold fit"
        );
        assert!(report.solution.coeffs.data() == c0.data(), "chunk={chunk}");
        assert_refit_words(&refit_table, &fit_table, &format!("chunk={chunk}"));
    }
}

#[test]
fn refit_after_append_matches_fresh_cold_fit_bit_for_bit() {
    let shards = base_shards(9);
    let total_delta: usize = (0..shards.len()).map(|i| delta_for(i).len()).sum();
    let mut warm_bits: Option<Vec<u64>> = None;
    for chunk in [0usize, 6] {
        let paths = write_stores(&format!("append_c{chunk}"), &shards, 5);
        // one persistent cluster: fit at epoch 0, commit appends
        // through separate writer handles (the workers' own handles
        // stay stale until the refresh round), then refit warm
        let (report, refit_table) = with_store_cluster(&paths, chunk, |cluster, stats| {
            let p = params();
            let _ = dis_kpca(cluster, kernel(), &p).unwrap();
            for (i, path) in paths.iter().enumerate() {
                let mut writer = ShardStore::open(path).unwrap();
                writer.append(&delta_for(i)).unwrap();
            }
            let before = stats.table();
            let report = dis_kpca_refit(cluster, kernel(), &p, 0, NO_GATE).unwrap();
            (report, table_diff(&before, &stats.table()))
        });
        assert!(!report.fell_back, "chunk={chunk}");
        assert_eq!(report.epoch, 1, "one append per shard commits one epoch");
        assert_eq!(report.delta_cols, total_delta, "chunk={chunk}");

        // the reference: a fresh cold fit over the appended stores
        let (y_cold, c_cold, cold_table) = with_store_cluster(&paths, chunk, |cluster, stats| {
            let sol = dis_kpca(cluster, kernel(), &params()).unwrap();
            (sol.y, sol.coeffs, stats.table())
        });
        assert!(
            report.solution.y.data() == y_cold.data(),
            "chunk={chunk}: warm refit differs from a cold fit over the appended data"
        );
        assert!(report.solution.coeffs.data() == c_cold.data(), "chunk={chunk}");
        assert_refit_words(&refit_table, &cold_table, &format!("chunk={chunk}"));

        // and the warm solution itself is chunk-invariant
        let bits: Vec<u64> = report.solution.y.data().iter().map(|v| v.to_bits()).collect();
        match &warm_bits {
            None => warm_bits = Some(bits),
            Some(b) => assert!(*b == bits, "warm solution differs across chunk sizes"),
        }
    }
}

#[test]
fn refit_after_append_parity_over_tcp() {
    // the same fit → append → refit flow through real sockets, then a
    // cold memory-transport fit over the appended stores as the
    // reference — pinning both transport-independence and parity
    let shards = base_shards(21);
    let s = shards.len();
    let paths = write_stores("tcp", &shards, 5);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // free the port for `listen` (race-free enough on loopback)

    let master_paths = paths.clone();
    let master_addr = addr.clone();
    let master = std::thread::spawn(move || -> (RefitReport, Table) {
        let star = tcp::listen(&master_addr, s).unwrap();
        let stats = CommStats::new();
        let cluster = Cluster::new(star, stats.clone());
        let p = params();
        let _ = dis_kpca(&cluster, kernel(), &p).unwrap();
        for (i, path) in master_paths.iter().enumerate() {
            let mut writer = ShardStore::open(path).unwrap();
            writer.append(&delta_for(i)).unwrap();
        }
        let before = stats.table();
        let report = dis_kpca_refit(&cluster, kernel(), &p, 0, NO_GATE).unwrap();
        let refit_table = table_diff(&before, &stats.table());
        cluster.shutdown();
        (report, refit_table)
    });
    std::thread::sleep(std::time::Duration::from_millis(100));
    let workers: Vec<_> = paths
        .iter()
        .map(|path| {
            let path = path.clone();
            let addr = addr.clone();
            std::thread::spawn(move || {
                let src = ShardSource::Store(ShardStore::open(&path).unwrap());
                let ep = tcp::connect(&addr).unwrap();
                Worker::with_source(src, kernel(), Arc::new(NativeBackend::new()), 4).run(ep)
            })
        })
        .collect();
    let (report, refit_table) = master.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    let (y_cold, c_cold, cold_table) = with_store_cluster(&paths, 4, |cluster, stats| {
        let sol = dis_kpca(cluster, kernel(), &params()).unwrap();
        (sol.y, sol.coeffs, stats.table())
    });
    assert_eq!(report.epoch, 1);
    assert!(!report.fell_back);
    assert!(
        report.solution.y.data() == y_cold.data(),
        "tcp warm refit differs from the memory-transport cold fit"
    );
    assert!(report.solution.coeffs.data() == c_cold.data());
    assert_refit_words(&refit_table, &cold_table, "tcp");
}
