//! Parallel-engine integration tests.
//!
//! The `diskpca::par` pool promises *bit-identical* results for every
//! thread count — parallelism only ever splits independent output
//! elements, never reassociates a floating-point reduction. These
//! tests pin that promise on every parallelized hot path, all the way
//! up to the full `dis_kpca` protocol, plus panic propagation.
//!
//! Note on the global pool: the thread count is process-wide and these
//! tests run concurrently under `cargo test`. The bit-identity tests
//! are safe *because* of the property under test — results do not
//! depend on the pool size, so a racing `set_threads` cannot change
//! any asserted value — and the panic test triggers on the chunk
//! holding the final row, which exists under every partition.

use std::sync::Arc;

use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster, GatherMode, Params};
use diskpca::data::{clusters, partition_power_law, zipf_sparse, Data};
use diskpca::kernels::{self, Kernel};
use diskpca::linalg::{qr_r_only, qr_thin, Mat};
use diskpca::par;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::sketch::{CountSketch, Srht, TensorSketch};
use diskpca::sparse::Csc;

/// Evaluate `f` under a 1-thread pool and a 4-thread pool and assert
/// the two matrices agree to the last bit.
fn assert_threads_invariant(name: &str, f: impl Fn() -> Mat) {
    par::set_threads(1);
    let serial = f();
    par::set_threads(4);
    let parallel = f();
    par::set_threads(1);
    assert_eq!(
        (serial.rows(), serial.cols()),
        (parallel.rows(), parallel.cols()),
        "{name}: shape mismatch"
    );
    assert!(serial.data() == parallel.data(), "{name}: bits differ between 1 and 4 threads");
}

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

#[test]
fn matmul_family_thread_invariant() {
    let mut rng = Rng::seed_from(101);
    let a = randmat(&mut rng, 90, 80);
    let b = randmat(&mut rng, 80, 70);
    assert_threads_invariant("matmul", || a.matmul(&b));

    let tall = randmat(&mut rng, 600, 48);
    let tall2 = randmat(&mut rng, 600, 52);
    assert_threads_invariant("matmul_at_b", || tall.matmul_at_b(&tall2));

    let wide1 = randmat(&mut rng, 120, 300);
    let wide2 = randmat(&mut rng, 90, 300);
    assert_threads_invariant("matmul_a_bt", || wide1.matmul_a_bt(&wide2));

    let g = randmat(&mut rng, 150, 400);
    assert_threads_invariant("gram_self", || g.gram_self());
}

#[test]
fn gram_blocks_thread_invariant_and_match_serial_reference() {
    let mut rng = Rng::seed_from(102);
    let d = 6;
    let y = randmat(&mut rng, d, 48);
    let dense = randmat(&mut rng, d, 600);
    let sparse = Csc::from_dense(&Mat::from_fn(d, 600, |i, j| {
        if (i + j) % 3 == 0 {
            rng.normal()
        } else {
            0.0
        }
    }));
    for kernel in [
        Kernel::Gauss { gamma: 0.4 },
        Kernel::Poly { q: 3 },
        Kernel::ArcCos { degree: 2 },
        Kernel::Laplace { gamma: 0.3 },
    ] {
        let xd = Data::Dense(dense.clone());
        let yd = y.clone();
        assert_threads_invariant(&format!("gram dense {}", kernel.name()), move || {
            kernels::gram(kernel, &yd, &xd)
        });
        let xs = Data::Sparse(sparse.clone());
        let ys = y.clone();
        assert_threads_invariant(&format!("gram sparse {}", kernel.name()), move || {
            kernels::gram(kernel, &ys, &xs)
        });
    }
    // parallel gram entries must equal the scalar κ(x, y) reference
    par::set_threads(4);
    let k = Kernel::Gauss { gamma: 0.4 };
    let g = kernels::gram(k, &y, &Data::Dense(dense.clone()));
    for i in [0usize, 13, 47] {
        for j in [0usize, 99, 599] {
            let want = k.eval(&y.col(i), &dense.col(j));
            assert!((g[(i, j)] - want).abs() < 1e-12, "entry ({i},{j})");
        }
    }
    par::set_threads(1);
}

#[test]
fn feature_maps_thread_invariant() {
    let mut rng = Rng::seed_from(103);
    let d = 10;
    let x = Data::Dense(randmat(&mut rng, d, 128));
    let rff = kernels::rff_params(d, 512, 0.5, &mut rng);
    assert_threads_invariant("rff_features", || kernels::rff_features(&rff, &x));

    let omega = kernels::arccos_params(d, 512, &mut rng);
    assert_threads_invariant("arccos_features", || kernels::arccos_features(&omega, 2, &x));

    let xs = Data::Sparse(zipf_sparse(512, 200, 30, &mut rng));
    let omega_sp = kernels::arccos_params(512, 256, &mut rng);
    assert_threads_invariant("arccos_features sparse", || {
        kernels::arccos_features(&omega_sp, 1, &xs)
    });
}

#[test]
fn sketches_thread_invariant() {
    let mut rng = Rng::seed_from(104);
    let e = randmat(&mut rng, 64, 4096);
    let cs_point = CountSketch::new(4096, 256, &mut rng);
    assert_threads_invariant("countsketch point_axis", || cs_point.apply_point_axis(&e));

    let z = randmat(&mut rng, 512, 256);
    let cs_feat = CountSketch::new(512, 64, &mut rng);
    assert_threads_invariant("countsketch feature_axis", || cs_feat.apply_feature_axis(&z));

    let sp = zipf_sparse(512, 300, 40, &mut rng);
    let cs_sp = CountSketch::new(512, 64, &mut rng);
    assert_threads_invariant("countsketch sparse", || cs_sp.apply_feature_axis_sparse(&sp));

    let ts = TensorSketch::new(96, 128, 3, &mut rng);
    let xd = randmat(&mut rng, 96, 40);
    assert_threads_invariant("tensorsketch dense", || ts.apply_feature_axis(&xd));
    let xsp = Csc::from_dense(&Mat::from_fn(96, 40, |i, j| {
        if (i * 5 + j) % 7 == 0 {
            1.0 + (i + j) as f64 * 0.01
        } else {
            0.0
        }
    }));
    assert_threads_invariant("tensorsketch sparse", || ts.apply_feature_axis_sparse(&xsp));

    let srht = Srht::new(200, 64, &mut rng);
    let xr = randmat(&mut rng, 200, 48);
    assert_threads_invariant("srht feature_axis", || srht.apply_feature_axis(&xr));
}

#[test]
fn qr_thread_invariant() {
    let mut rng = Rng::seed_from(105);
    let a = randmat(&mut rng, 500, 150);
    assert_threads_invariant("qr_thin Q", || qr_thin(&a).0);
    assert_threads_invariant("qr_thin R", || qr_thin(&a).1);
    // tall path (CholeskyQR via matmul_at_b)
    let tall = randmat(&mut rng, 4000, 64);
    assert_threads_invariant("qr_r_only tall", || qr_r_only(&tall));
    // Householder path (m <= 4n) with panels above the parallel cutoff
    let mid = randmat(&mut rng, 500, 140);
    assert_threads_invariant("qr_r_only householder", || qr_r_only(&mid));
}

#[test]
fn par_chunks_propagates_worker_panics() {
    par::set_threads(4);
    let caught = std::panic::catch_unwind(|| {
        let mut buf = vec![0.0f64; 32 * 8];
        // panic in whichever chunk holds the final row — fires exactly
        // once under every partition (serial included), so the test is
        // immune to concurrent set_threads calls from sibling tests
        par::par_chunks(&mut buf, 8, |row0, chunk| {
            if row0 + chunk.len() / 8 == 32 {
                panic!("deliberate failure in chunk starting at row {row0}");
            }
        });
    });
    assert!(caught.is_err(), "panic inside par_chunks must reach the caller");
    par::set_threads(1);
    // the pool must remain fully usable after a propagated panic
    par::set_threads(2);
    let sums = par::par_join((0..8).map(|i| move || i + 1).collect::<Vec<_>>());
    assert_eq!(sums, (1..=8).collect::<Vec<_>>());
    par::set_threads(1);
}

#[test]
fn dis_kpca_identical_across_thread_counts() {
    let mut rng = Rng::seed_from(42);
    let data = Data::Dense(clusters(8, 240, 4, 0.2, &mut rng));
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let params = Params {
        k: 4,
        t: 16,
        p: 40,
        n_lev: 12,
        n_adapt: 24,
        w: 0,
        m_rff: 256,
        t2: 128,
        seed: 7,
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    };
    let mut runs = Vec::new();
    for threads in [1usize, 4] {
        par::set_threads(threads);
        let shards = partition_power_law(&data, 4, 1);
        let ((sol, err, trace), stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = dis_kpca(cluster, kernel, &params).unwrap();
                let (err, trace) = dis_eval(cluster).unwrap();
                (sol, err, trace)
            },
        );
        runs.push((sol, err, trace, stats.total_words()));
    }
    par::set_threads(1);
    let (s1, e1, t1, w1) = &runs[0];
    let (s4, e4, t4, w4) = &runs[1];
    assert!(s1.y.data() == s4.y.data(), "representative points differ across thread counts");
    assert!(s1.coeffs.data() == s4.coeffs.data(), "coefficients differ across thread counts");
    assert!(e1 == e4 && t1 == t4, "eval differs: {e1}/{t1} vs {e4}/{t4}");
    assert_eq!(w1, w4, "communication words must not depend on threads");
}
