//! Tree (TSQR) gather mode: the pairwise R-factor reduction must
//! preserve the stacked Gram exactly in theory (to roundoff in f64),
//! and an end-to-end `--gather tree` fit must produce a solution of
//! the same quality as the flat gather while shipping strictly fewer
//! words in the sketch-aggregation round whenever `p > t`.

use std::sync::Arc;

use diskpca::comm::{memory, Cluster, CommStats};
use diskpca::coordinator::{dis_eval, dis_kpca, tsqr_merge, GatherMode, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::linalg::{qr_r_only, Mat};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

#[test]
fn tsqr_merge_preserves_the_stacked_gram() {
    let t = 12;
    let mut rng = Rng::seed_from(4);
    // every fan-in shape: single factor, even, odd (carry), power of
    // two, and a tree deep enough to carry across levels
    for s in [1usize, 2, 3, 5, 8, 32] {
        let blocks: Vec<Mat> = (0..s)
            .map(|_| {
                let rows = t + rng.below(20);
                Mat::from_fn(rows, t, |_, _| rng.normal())
            })
            .collect();
        let rs: Vec<Mat> = blocks.iter().map(qr_r_only).collect();
        let merged = tsqr_merge(rs);
        assert_eq!((merged.rows(), merged.cols()), (t, t), "s={s}: R must be t×t");
        let got = merged.matmul_at_b(&merged);
        let want = {
            let stacked = Mat::vcat_all(&blocks);
            stacked.matmul_at_b(&stacked)
        };
        let scale = (0..t).map(|i| want[(i, i)]).fold(0.0f64, f64::max);
        assert!(
            got.max_abs_diff(&want) < 1e-9 * scale,
            "s={s}: merged Gram drifts by {} (scale {scale})",
            got.max_abs_diff(&want)
        );
    }
}

/// Fit + eval under one gather mode; returns the eval pair and the
/// word counts of the two rounds tree mode compresses.
fn fit(gather: GatherMode, shards: &[Data], kernel: Kernel, params: &Params) -> ((f64, f64), usize, usize) {
    let params = Params { gather, ..*params };
    let (star, endpoints) = memory::star(shards.len());
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .map(|(shard, ep)| {
            std::thread::spawn(move || {
                Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep)
            })
        })
        .collect();
    dis_kpca(&cluster, kernel, &params).unwrap();
    let ev = dis_eval(&cluster).unwrap();
    let disls = cluster.stats.round_words("2-disLS");
    let dislr = cluster.stats.round_words("5-disLR");
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    (ev, disls, dislr)
}

#[test]
fn tree_gather_matches_flat_quality_with_fewer_sketch_words() {
    let mut rng = Rng::seed_from(17);
    let data = Data::Dense(clusters(6, 150, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, 3, 6);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    // p ≫ t so the flat gather's t×p replies dwarf tree's t×t factors
    let params = Params {
        k: 3,
        t: 16,
        p: 64,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 11,
        ..Params::default()
    };

    let ((flat_err, flat_trace), flat_disls, _) = fit(GatherMode::Flat, &shards, kernel, &params);
    let ((tree_err, tree_trace), tree_disls, _) = fit(GatherMode::Tree, &shards, kernel, &params);

    assert!(flat_err.is_finite() && flat_err >= 0.0 && flat_err <= flat_trace);
    assert!(tree_err.is_finite() && tree_err >= 0.0 && tree_err <= tree_trace);
    // same Gram in exact arithmetic ⇒ the solutions agree to roundoff
    assert_eq!(tree_trace.to_bits(), flat_trace.to_bits(), "trace is gather-independent");
    assert!(
        (tree_err - flat_err).abs() <= 1e-6 * flat_trace.max(1.0),
        "tree err {tree_err} vs flat err {flat_err} (trace {flat_trace})"
    );
    assert!(
        tree_disls < flat_disls,
        "tree 2-disLS words ({tree_disls}) must undercut flat ({flat_disls}) at p > t"
    );
}
