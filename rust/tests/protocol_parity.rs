//! Transport/refactor parity: the typed session core (encode-once
//! broadcast, completion-order gather) must leave protocol *outputs*
//! untouched. For s ∈ {1, 4} and both transports (in-memory star,
//! TCP loopback), `dis_kpca`, `dis_css` and `dis_krr` must produce
//! bit-identical results and identical per-round `CommStats` word
//! tables — the protocol is deterministic given the seed, and neither
//! the transport nor the gather order may be observable.

use std::sync::Arc;

use diskpca::comm::{memory, tcp, Cluster, CommStats, Endpoint, Star};
use diskpca::coordinator::{dis_css, dis_eval, dis_kpca, dis_krr, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn workload(s: usize) -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(6);
    let data = Data::Dense(clusters(9, 160, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, s, 4);
    let kernel = Kernel::Gauss { gamma: 0.7 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 14,
        m_rff: 128,
        t2: 64,
        seed: 21,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// Everything parity compares: solution bits, eval bits, CSS and KRR
/// outputs, and the full per-round word table.
struct Outcome {
    y_bits: Vec<u64>,
    coeff_bits: Vec<u64>,
    err: u64,
    trace: u64,
    css_residual: u64,
    krr_alpha_bits: Vec<u64>,
    table: Vec<(String, usize, usize)>,
}

fn drive<E: Endpoint + Send + 'static>(
    shards: Vec<Data>,
    kernel: Kernel,
    params: Params,
    star: Star,
    endpoints: Vec<E>,
) -> Outcome {
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let sol = dis_kpca(&cluster, kernel, &params).unwrap();
    let (err, trace) = dis_eval(&cluster).unwrap();
    let css = dis_css(&cluster, kernel, &params).unwrap();
    let krr = dis_krr(&cluster, kernel, &css.y, 1e-3, 99).unwrap();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    Outcome {
        y_bits: sol.y.data().iter().map(|v| v.to_bits()).collect(),
        coeff_bits: sol.coeffs.data().iter().map(|v| v.to_bits()).collect(),
        err: err.to_bits(),
        trace: trace.to_bits(),
        css_residual: css.residual.to_bits(),
        krr_alpha_bits: krr.alpha.iter().map(|v| v.to_bits()).collect(),
        table: stats.table(),
    }
}

fn run_memory(s: usize) -> Outcome {
    let (shards, kernel, params) = workload(s);
    let (star, endpoints) = memory::star(shards.len());
    drive(shards, kernel, params, star, endpoints)
}

fn run_tcp(s: usize) -> Outcome {
    let (shards, kernel, params) = workload(s);
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    drive(shards, kernel, params, star, endpoints)
}

fn assert_outcomes_identical(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.y_bits, b.y_bits, "{label}: representative points differ");
    assert_eq!(a.coeff_bits, b.coeff_bits, "{label}: coefficients differ");
    assert_eq!(a.err, b.err, "{label}: eval error differs");
    assert_eq!(a.trace, b.trace, "{label}: trace differs");
    assert_eq!(a.css_residual, b.css_residual, "{label}: CSS certificate differs");
    assert_eq!(a.krr_alpha_bits, b.krr_alpha_bits, "{label}: KRR coefficients differ");
    assert_eq!(a.table, b.table, "{label}: per-round word tables differ");
}

#[test]
fn transports_bit_identical_s4() {
    let mem = run_memory(4);
    let tcp_run = run_tcp(4);
    assert_outcomes_identical(&mem, &tcp_run, "s=4 memory vs tcp");
    // and deterministic across repeat runs of the same transport
    let mem2 = run_memory(4);
    assert_outcomes_identical(&mem, &mem2, "s=4 memory repeat");
}

#[test]
fn transports_bit_identical_s1() {
    let mem = run_memory(1);
    let tcp_run = run_tcp(1);
    assert_outcomes_identical(&mem, &tcp_run, "s=1 memory vs tcp");
    let tcp2 = run_tcp(1);
    assert_outcomes_identical(&tcp_run, &tcp2, "s=1 tcp repeat");
}

/// The word tables must also be invariant in *content*: every
/// protocol round shows up with nonzero traffic in both directions
/// where the algorithm sends any.
#[test]
fn word_table_covers_all_rounds() {
    let out = run_memory(4);
    let rounds: Vec<&str> = out.table.iter().map(|(r, _, _)| r.as_str()).collect();
    for expect in [
        "1-embed", "2-disLS", "3-levSample", "4-adaptive", "5-disLR", "6-eval", "7-cssCert",
        "9-krr",
    ] {
        assert!(rounds.contains(&expect), "round {expect} missing from {rounds:?}");
    }
}
