//! Regression tests for the environment-knob parsing. These knobs
//! used to fall back to their defaults on unparsable values — a typo
//! like `DISKPCA_COMM_TIMEOUT_SECS=5s` silently disabled the timeout.
//! Every knob the serving stack reads now funnels through one typed
//! entry point, [`ServeConfig::parse`]: a malformed value is an error
//! naming the variable and echoing the offending value, and the use
//! sites panic with a `config ...` message instead of proceeding with
//! a default the operator never chose.

use std::time::Duration;

use diskpca::serve::ServeConfig;

/// Lookup closure over an inline list of (name, value) pairs.
fn env(pairs: &[(&str, &str)]) -> impl Fn(&str) -> Option<String> + '_ {
    move |name| pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| v.to_string())
}

#[test]
fn empty_environment_yields_the_documented_defaults() {
    let cfg = ServeConfig::parse(|_| None).unwrap();
    assert_eq!(cfg.comm_timeout, None, "unset keeps no timeout");
    assert_eq!(cfg.embed_cache_mb, 64, "unset keeps the 64 MiB default");
    assert_eq!(cfg.table_cache_mb, 128, "unset keeps the 128 MiB default");
    assert_eq!(cfg.max_inflight, 1, "sequential scheduling by default");
    assert_eq!(cfg.queue_depth, 32);
    assert_eq!(cfg.pipeline_depth, 2);
    assert_eq!(cfg.variance_frac, 0.95, "unset keeps the 0.95 refit gate");
    assert_eq!(cfg.comm_retries, 0, "unset keeps the fail-fast no-retry default");
    assert_eq!(cfg.chaos_seed, None, "unset keeps chaos injection off");
    assert_eq!(cfg, ServeConfig::default());
}

#[test]
fn comm_retries_parses_and_rejects_garbage() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_COMM_RETRIES", v)]));
    assert_eq!(at("0").unwrap().comm_retries, 0, "0 keeps the fail-fast path");
    assert_eq!(at("3").unwrap().comm_retries, 3);
    assert_eq!(at(" 5 ").unwrap().comm_retries, 5, "surrounding whitespace is tolerated");
    for bad in ["many", "", "-7", "1.5", "3s"] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_COMM_RETRIES"), "error must name the variable: {err}");
        assert!(
            err.contains(bad.trim()) || bad.trim().is_empty(),
            "error must echo the value: {err}"
        );
    }
}

#[test]
fn chaos_seed_parses_and_rejects_garbage() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_CHAOS_SEED", v)]));
    assert_eq!(at("42").unwrap().chaos_seed, Some(42));
    // seed 0 is a schedule like any other — unset is the only "off"
    assert_eq!(at("0").unwrap().chaos_seed, Some(0), "0 arms chaos with seed 0");
    assert_eq!(at(" 7 ").unwrap().chaos_seed, Some(7), "surrounding whitespace is tolerated");
    for bad in ["coin", "", "-1", "0.5", "0x2a"] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_CHAOS_SEED"), "error must name the variable: {err}");
        assert!(
            err.contains(bad.trim()) || bad.trim().is_empty(),
            "error must echo the value: {err}"
        );
    }
}

#[test]
fn comm_timeout_accepts_whole_seconds_and_zero_disables() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_COMM_TIMEOUT_SECS", v)]));
    assert_eq!(at("0").unwrap().comm_timeout, None, "0 disables");
    assert_eq!(at("5").unwrap().comm_timeout, Some(Duration::from_secs(5)));
    assert_eq!(
        at(" 7 ").unwrap().comm_timeout,
        Some(Duration::from_secs(7)),
        "surrounding whitespace is tolerated"
    );
}

#[test]
fn comm_timeout_rejects_garbage_with_named_variable() {
    for bad in ["5s", "abc", "", "1.5", "-3", "0x10"] {
        let err = ServeConfig::parse(env(&[("DISKPCA_COMM_TIMEOUT_SECS", bad)])).unwrap_err();
        assert!(
            err.contains("DISKPCA_COMM_TIMEOUT_SECS"),
            "error must name the variable: {err}"
        );
        assert!(
            err.contains(bad.trim()) || bad.trim().is_empty(),
            "error must echo the value: {err}"
        );
    }
}

#[test]
fn embed_cache_mb_parses_and_rejects_garbage() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_EMBED_CACHE_MB", v)]));
    assert_eq!(at("0").unwrap().embed_cache_mb, 0, "0 disables the cache");
    assert_eq!(at(" 256 ").unwrap().embed_cache_mb, 256);
    assert_eq!(at("256").unwrap().embed_cache_bytes(), 256 << 20);
    for bad in ["64MB", "", "-1", "2.5"] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_EMBED_CACHE_MB"), "error must name the variable: {err}");
    }
}

#[test]
fn table_cache_mb_parses_and_rejects_garbage() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_TABLE_CACHE_MB", v)]));
    assert_eq!(at("0").unwrap().table_cache_mb, 0, "0 disables the cache");
    assert_eq!(at(" 512 ").unwrap().table_cache_mb, 512);
    for bad in ["lots", "", "-8", "1e3"] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_TABLE_CACHE_MB"), "error must name the variable: {err}");
    }
}

#[test]
fn scheduler_knobs_parse_and_reject_zero_or_garbage() {
    let cfg = ServeConfig::parse(env(&[
        ("DISKPCA_MAX_INFLIGHT", "4"),
        ("DISKPCA_QUEUE_DEPTH", " 8 "),
        ("DISKPCA_PIPELINE_DEPTH", "3"),
    ]))
    .unwrap();
    assert_eq!((cfg.max_inflight, cfg.queue_depth, cfg.pipeline_depth), (4, 8, 3));
    // zero runners / zero-deep queues are misconfigurations, not modes
    for var in ["DISKPCA_MAX_INFLIGHT", "DISKPCA_QUEUE_DEPTH", "DISKPCA_PIPELINE_DEPTH"] {
        let err = ServeConfig::parse(env(&[(var, "0")])).unwrap_err();
        assert!(err.contains(var) && err.contains("at least 1"), "{err}");
        for bad in ["two", "", "-1", "1.5"] {
            let err = ServeConfig::parse(env(&[(var, bad)])).unwrap_err();
            assert!(err.contains(var), "error must name the variable: {err}");
        }
    }
}

#[test]
fn compute_tier_parses_and_rejects_unknown_names() {
    use diskpca::linalg::simd::ComputeTier;
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_COMPUTE_TIER", v)]));
    assert_eq!(
        ServeConfig::parse(|_| None).unwrap().compute_tier,
        ComputeTier::Exact,
        "unset keeps the bit-reproducible exact tier"
    );
    assert_eq!(at("exact").unwrap().compute_tier, ComputeTier::Exact);
    assert_eq!(at("fast").unwrap().compute_tier, ComputeTier::Fast);
    assert_eq!(
        at(" fast ").unwrap().compute_tier,
        ComputeTier::Fast,
        "surrounding whitespace is tolerated"
    );
    for bad in ["turbo", "", "Fast?", "exactly", "1"] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_COMPUTE_TIER"), "error must name the variable: {err}");
        assert!(
            err.contains(bad.trim()) || bad.trim().is_empty(),
            "error must echo the value: {err}"
        );
        assert!(err.contains("expected exact|fast"), "error must list the accepted names: {err}");
        // ServeConfig::from_env wraps this as panic!("config {err}") —
        // the same hard-error convention as every other knob here
        assert!(format!("config {err}").starts_with("config DISKPCA_COMPUTE_TIER="));
    }
}

#[test]
fn variance_frac_parses_and_rejects_out_of_range_or_garbage() {
    let at = |v: &str| ServeConfig::parse(env(&[("DISKPCA_VARIANCE_FRAC", v)]));
    assert_eq!(at("0.5").unwrap().variance_frac, 0.5);
    assert_eq!(at("1").unwrap().variance_frac, 1.0, "1.0 demands the full spectrum");
    assert_eq!(
        at(" 0.99 ").unwrap().variance_frac,
        0.99,
        "surrounding whitespace is tolerated"
    );
    // 0 would accept any refit, > 1 would reject every one: both are
    // misconfigurations, not modes
    for bad in ["0", "0.0", "-0.5", "1.01", "95%", "most", ""] {
        let err = at(bad).unwrap_err();
        assert!(err.contains("DISKPCA_VARIANCE_FRAC"), "error must name the variable: {err}");
        assert!(
            err.contains(bad.trim()) || bad.trim().is_empty(),
            "error must echo the value: {err}"
        );
    }
}

#[test]
fn first_offending_variable_aborts_the_whole_parse() {
    let err = ServeConfig::parse(env(&[
        ("DISKPCA_COMM_TIMEOUT_SECS", "10"),
        ("DISKPCA_QUEUE_DEPTH", "bogus"),
    ]))
    .unwrap_err();
    assert!(err.contains("DISKPCA_QUEUE_DEPTH") && err.contains("bogus"), "{err}");
}
