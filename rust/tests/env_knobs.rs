//! Regression tests for the environment-knob parsers. These knobs
//! used to fall back to their defaults on unparsable values — a typo
//! like `DISKPCA_COMM_TIMEOUT_SECS=5s` silently disabled the timeout.
//! Every parser now returns a clear error naming the variable and the
//! offending value, and the use sites panic with a `config ...`
//! message instead of proceeding with a default the operator never
//! chose.

use std::time::Duration;

use diskpca::comm::parse_comm_timeout;
use diskpca::coordinator::worker::parse_embed_cache_mb;
use diskpca::runtime::parse_table_cache_mb;

#[test]
fn comm_timeout_accepts_whole_seconds_and_zero_disables() {
    assert_eq!(parse_comm_timeout(None), Ok(None), "unset keeps no timeout");
    assert_eq!(parse_comm_timeout(Some("0")), Ok(None), "0 disables");
    assert_eq!(parse_comm_timeout(Some("5")), Ok(Some(Duration::from_secs(5))));
    assert_eq!(
        parse_comm_timeout(Some(" 7 ")),
        Ok(Some(Duration::from_secs(7))),
        "surrounding whitespace is tolerated"
    );
}

#[test]
fn comm_timeout_rejects_garbage_with_named_variable() {
    for bad in ["5s", "abc", "", "1.5", "-3", "0x10"] {
        let err = parse_comm_timeout(Some(bad)).unwrap_err();
        assert!(
            err.contains("DISKPCA_COMM_TIMEOUT_SECS"),
            "error must name the variable: {err}"
        );
        assert!(err.contains(bad.trim()) || bad.trim().is_empty(), "error must echo the value: {err}");
    }
}

#[test]
fn embed_cache_mb_defaults_and_rejects_garbage() {
    assert_eq!(parse_embed_cache_mb(None), Ok(64), "unset keeps the 64 MiB default");
    assert_eq!(parse_embed_cache_mb(Some("0")), Ok(0), "0 disables the cache");
    assert_eq!(parse_embed_cache_mb(Some(" 256 ")), Ok(256));
    for bad in ["64MB", "", "-1", "2.5"] {
        let err = parse_embed_cache_mb(Some(bad)).unwrap_err();
        assert!(err.contains("DISKPCA_EMBED_CACHE_MB"), "error must name the variable: {err}");
    }
}

#[test]
fn table_cache_mb_defaults_and_rejects_garbage() {
    assert_eq!(parse_table_cache_mb(None), Ok(128), "unset keeps the 128 MiB default");
    assert_eq!(parse_table_cache_mb(Some("0")), Ok(0), "0 disables the cache");
    assert_eq!(parse_table_cache_mb(Some(" 512 ")), Ok(512));
    for bad in ["lots", "", "-8", "1e3"] {
        let err = parse_table_cache_mb(Some(bad)).unwrap_err();
        assert!(err.contains("DISKPCA_TABLE_CACHE_MB"), "error must name the variable: {err}");
    }
}
