//! TCP framing / codec edge cases and worker-error surfacing.
//!
//! The happy path is covered by `tcp_protocol.rs`; these tests pin the
//! failure modes that used to be `expect(...)`-only: truncated frames,
//! absurd length prefixes (which must error out instead of attempting
//! a multi-GiB allocation), codec garbage inside a well-framed
//! payload, and worker-side failures crossing the wire as
//! `RespError` with context instead of a dead socket.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use diskpca::comm::tcp::{self, MAX_FRAME_BYTES};
use diskpca::comm::Message;
use diskpca::coordinator::Worker;
use diskpca::data::Data;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

/// Write raw bytes to a fresh loopback connection, return the
/// server-side stream to read the frame from.
fn pair_with_payload(payload: &[u8]) -> TcpStream {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut client = TcpStream::connect(addr).unwrap();
    let (server, _) = listener.accept().unwrap();
    client.write_all(payload).unwrap();
    drop(client); // close so reads past the payload hit EOF, not a hang
    server
}

#[test]
fn truncated_frame_is_an_error_not_a_hang_or_panic() {
    // promise 64 bytes, deliver 10
    let mut bytes = 64u64.to_le_bytes().to_vec();
    bytes.extend_from_slice(&[1u8; 10]);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("truncated frame must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
}

#[test]
fn truncated_length_prefix_is_an_error() {
    let mut server = pair_with_payload(&[1, 2, 3]); // 3 of 8 prefix bytes
    assert!(tcp::read_frame(&mut server).is_err());
}

#[test]
fn oversized_length_prefix_rejected_without_allocating() {
    for n in [MAX_FRAME_BYTES + 1, u64::MAX] {
        let mut server = pair_with_payload(&n.to_le_bytes());
        let err = tcp::read_frame(&mut server).expect_err("oversized prefix must fail");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "unhelpful error: {err}");
    }
}

#[test]
fn codec_garbage_in_wellformed_frame_propagates_decode_error() {
    // valid framing, nonsense payload: tag 200 does not exist
    let payload = [200u8, 1, 2, 3];
    let mut bytes = (payload.len() as u64).to_le_bytes().to_vec();
    bytes.extend_from_slice(&payload);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("bad tag must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("BadTag"), "decode error lost: {err}");

    // truncated *payload* (valid tag, missing matrix body) — the
    // codec's Truncated error must propagate the same way
    let payload = [2u8, 9]; // ReqScores with a mangled Mat header
    let mut bytes = (payload.len() as u64).to_le_bytes().to_vec();
    bytes.extend_from_slice(&payload);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("truncated payload must fail");
    assert!(err.to_string().contains("Truncated"), "decode error lost: {err}");
}

#[test]
fn worker_error_crosses_the_wire_with_context() {
    let (links, endpoints) = tcp::star(1).unwrap();
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|ep| {
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(1);
                let shard = Data::Dense(Mat::from_fn(4, 12, |_, _| rng.normal()));
                let be = Arc::new(NativeBackend::new());
                Worker::new(shard, Kernel::Gauss { gamma: 0.5 }, be).run(ep);
            })
        })
        .collect();
    // protocol misuse: scores before embed. The worker must answer
    // with RespError (and survive), not die and strand the master.
    links[0].send(Message::ReqScores { z: Mat::identity(4) });
    match links[0].recv() {
        Message::RespError(msg) => {
            assert!(msg.contains("ReqEmbed first"), "context lost: {msg}");
            assert!(msg.contains("ReqScores"), "failing request not named: {msg}");
        }
        other => panic!("expected RespError over TCP, got {other:?}"),
    }
    // worker still serves afterwards
    links[0].send(Message::ReqCount);
    assert!(matches!(links[0].recv(), Message::RespCount(12)));
    links[0].send(Message::Quit);
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn roundtrip_over_sockets_preserves_error_payload() {
    let (links, endpoints) = tcp::star(1).unwrap();
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|mut ep| {
            std::thread::spawn(move || loop {
                match ep.recv() {
                    Message::Quit => break,
                    _ => ep.send(Message::RespError("shard store: block 3 unreadable".into())),
                }
            })
        })
        .collect();
    links[0].send(Message::ReqCount);
    match links[0].recv() {
        Message::RespError(msg) => assert_eq!(msg, "shard store: block 3 unreadable"),
        other => panic!("{other:?}"),
    }
    links[0].send(Message::Quit);
    for h in handles {
        h.join().unwrap();
    }
}
