//! TCP framing / codec edge cases and worker-error surfacing.
//!
//! The happy path is covered by `tcp_protocol.rs`; these tests pin the
//! failure modes that used to be `expect(...)`-only: truncated frames,
//! absurd length prefixes (which must error out instead of attempting
//! a multi-GiB allocation), codec garbage inside a well-framed
//! payload, and worker-side failures crossing the wire as
//! `RespError` with context instead of a dead socket.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use diskpca::comm::tcp::{self, MAX_FRAME_BYTES};
use diskpca::comm::{request, Cluster, CommError, CommStats, Message};
use diskpca::coordinator::Worker;
use diskpca::data::Data;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

/// Write raw bytes to a fresh loopback connection, return the
/// server-side stream to read the frame from.
fn pair_with_payload(payload: &[u8]) -> TcpStream {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut client = TcpStream::connect(addr).unwrap();
    let (server, _) = listener.accept().unwrap();
    client.write_all(payload).unwrap();
    drop(client); // close so reads past the payload hit EOF, not a hang
    server
}

#[test]
fn truncated_frame_is_an_error_not_a_hang_or_panic() {
    // promise 64 bytes, deliver 10
    let mut bytes = 64u64.to_le_bytes().to_vec();
    bytes.extend_from_slice(&[1u8; 10]);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("truncated frame must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
}

#[test]
fn truncated_length_prefix_is_an_error() {
    let mut server = pair_with_payload(&[1, 2, 3]); // 3 of 8 prefix bytes
    assert!(tcp::read_frame(&mut server).is_err());
}

#[test]
fn oversized_length_prefix_rejected_without_allocating() {
    for n in [MAX_FRAME_BYTES + 1, u64::MAX] {
        let mut server = pair_with_payload(&n.to_le_bytes());
        let err = tcp::read_frame(&mut server).expect_err("oversized prefix must fail");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "unhelpful error: {err}");
    }
}

#[test]
fn codec_garbage_in_wellformed_frame_propagates_decode_error() {
    // valid framing, nonsense payload: tag 200 does not exist
    let payload = [200u8, 1, 2, 3];
    let mut bytes = (payload.len() as u64).to_le_bytes().to_vec();
    bytes.extend_from_slice(&payload);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("bad tag must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("BadTag"), "decode error lost: {err}");

    // truncated *payload* (valid tag, missing matrix body) — the
    // codec's Truncated error must propagate the same way
    let payload = [2u8, 9]; // ReqScores with a mangled Mat header
    let mut bytes = (payload.len() as u64).to_le_bytes().to_vec();
    bytes.extend_from_slice(&payload);
    let mut server = pair_with_payload(&bytes);
    let err = tcp::read_frame(&mut server).expect_err("truncated payload must fail");
    assert!(err.to_string().contains("Truncated"), "decode error lost: {err}");
}

#[test]
fn worker_error_crosses_the_wire_with_context() {
    let (star, endpoints) = tcp::star(1).unwrap();
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|ep| {
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(1);
                let shard = Data::Dense(Mat::from_fn(4, 12, |_, _| rng.normal()));
                let be = Arc::new(NativeBackend::new());
                Worker::new(shard, Kernel::Gauss { gamma: 0.5 }, be).run(ep);
            })
        })
        .collect();
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_round("2-disLS");
    // protocol misuse: scores before embed. The worker must answer
    // with RespError (and survive), surfaced as a typed Worker error
    // naming the worker and round — not a dead socket or a panic.
    let err = cluster.call(0, request::Scores { z: Mat::identity(4) }).unwrap_err();
    match &err {
        CommError::Worker { worker: 0, round, detail } => {
            assert_eq!(round, "2-disLS");
            assert!(detail.contains("ReqEmbed first"), "context lost: {detail}");
            assert!(detail.contains("ReqScores"), "failing request not named: {detail}");
        }
        other => panic!("expected Worker error over TCP, got {other:?}"),
    }
    // worker still serves afterwards
    assert_eq!(cluster.call(0, request::Count).unwrap(), 12);
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn roundtrip_over_sockets_preserves_error_payload() {
    let (star, endpoints) = tcp::star(1).unwrap();
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|mut ep| {
            std::thread::spawn(move || loop {
                match ep.try_recv() {
                    Ok(Message::Quit) | Err(_) => break,
                    Ok(_) => ep
                        .try_send(&Message::RespError("shard store: block 3 unreadable".into()))
                        .unwrap(),
                }
            })
        })
        .collect();
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_round("io");
    let err = cluster.call(0, request::Count).unwrap_err();
    match err {
        CommError::Worker { worker: 0, detail, .. } => {
            assert_eq!(detail, "shard store: block 3 unreadable")
        }
        other => panic!("{other:?}"),
    }
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
}
