//! # diskpca — Communication-Efficient Distributed Kernel PCA
//!
//! Production-quality reproduction of Balcan, Liang, Song, Woodruff,
//! Xie, *"Communication Efficient Distributed Kernel Principal
//! Component Analysis"* (KDD 2016), as a three-layer rust + JAX +
//! Pallas stack:
//!
//! - **L3 (this crate)**: the paper's master–worker protocol — kernel
//!   subspace embeddings, distributed leverage scores, representative
//!   point sampling, distributed low-rank approximation — with exact
//!   per-word communication accounting, plus every substrate it needs
//!   (dense/sparse linear algebra, sketches, PRNG, transports,
//!   dataset generators, evaluation).
//! - **L2/L1**: JAX compute graphs with Pallas kernels, AOT-lowered to
//!   HLO-text artifacts (`make artifacts`) and executed from rust via
//!   PJRT ([`runtime`]). Python never runs on the request path.
//!
//! `docs/ARCHITECTURE.md` maps every paper algorithm and figure to the
//! modules below and draws the master↔worker dataflow.
//!
//! ## Module map
//!
//! | Layer | Module | Role (paper reference) |
//! |---|---|---|
//! | protocol | [`coordinator`] | Algs. 1–4 drivers, worker state machine, baselines, k-means/KRR/CSS extensions |
//! | protocol | [`comm`] | star transports (in-memory, TCP) + per-word accounting (§4 cost model) |
//! | protocol | [`serve`] | multi-job sessions on a persistent cluster: warm-state reuse, per-job accounting, batched projection serving |
//! | protocol | [`recovery`] | elastic fault tolerance: slot revival, checkpointed round replay, bit-identical retry |
//! | protocol | [`embed`] | kernel subspace embeddings `E = S(φ(A))` (§5.1, Lemmas 4–5) |
//! | compute | [`kernels`] | κ(x,y), Gram blocks, random-feature expansions (§3) |
//! | compute | [`sketch`] | CountSketch / Gaussian / SRHT / TensorSketch (Lemma 1) |
//! | compute | [`linalg`] | packed register-tiled GEMM engine ([`linalg::gemm`]), dense QR/Cholesky/SVD/eig + leverage scores |
//! | compute | [`sparse`] | CSC shards, `O(nnz)` paths (§4's ρ-dependence) |
//! | compute | [`par`] | shared thread pool — deterministic parallel Gram/sketch/matmul hot paths |
//! | compute | [`runtime`] | [`runtime::Backend`]: native f64 vs XLA/PJRT artifacts |
//! | harness | [`data`] | Table-1 dataset analogues, partitioners, disk I/O, out-of-core shard stores ([`data::shard_store`]) |
//! | harness | [`experiments`] | one driver per paper table/figure (§6) |
//! | harness | [`rng`] | xoshiro PRNG, alias tables, shared-seed sampling |
//! | harness | [`config`] / [`cli`] / [`launcher`] | flags, `key = value` configs, multi-process deployment |
//! | harness | [`bench_harness`] / [`json`] | offline micro-bench runner, minimal JSON |
//!
//! ## Quick start
//!
//! Run the end-to-end tour (`cargo run --release --example quickstart`)
//! or, in code:
//!
//! ```
//! use std::sync::Arc;
//! use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster, Params};
//! use diskpca::data::{clusters, partition_power_law, Data};
//! use diskpca::kernels::Kernel;
//! use diskpca::rng::Rng;
//! use diskpca::runtime::NativeBackend;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = Data::Dense(clusters(8, 120, 3, 0.2, &mut rng));
//! let shards = partition_power_law(&data, 3, 42);
//! let kernel = Kernel::Gauss { gamma: 0.5 };
//! let params = Params { k: 3, t: 16, p: 32, n_lev: 8, n_adapt: 16, ..Params::default() };
//! let ((sol, err, trace), stats) = run_cluster(
//!     shards,
//!     kernel,
//!     Arc::new(NativeBackend::new()),
//!     move |cluster| {
//!         let sol = dis_kpca(cluster, kernel, &params).unwrap();
//!         let (err, trace) = dis_eval(cluster).unwrap();
//!         (sol, err, trace)
//!     },
//! );
//! assert_eq!(sol.k(), 3);
//! assert!(err >= 0.0 && err <= trace);
//! assert!(stats.total_words() > 0);
//! ```
//!
//! Start at [`coordinator`] for the headline algorithm; [`par`] for
//! the `--threads` scaling knob; [`data::shard_store`] +
//! [`coordinator::worker`] for the `--chunk-rows` out-of-core
//! streaming path (bit-identical to resident for every chunk size);
//! [`serve`] for multi-job sessions with warm-state reuse and the
//! batched projection/query path (`diskpca serve`).

#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench_harness;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod experiments;
pub mod json;
pub mod kernels;
pub mod launcher;
pub mod linalg;
pub mod par;
pub mod recovery;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod sparse;
