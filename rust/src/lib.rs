//! # diskpca — Communication-Efficient Distributed Kernel PCA
//!
//! Production-quality reproduction of Balcan, Liang, Song, Woodruff,
//! Xie, *"Communication Efficient Distributed Kernel Principal
//! Component Analysis"* (KDD 2016), as a three-layer rust + JAX +
//! Pallas stack:
//!
//! - **L3 (this crate)**: the paper's master–worker protocol — kernel
//!   subspace embeddings, distributed leverage scores, representative
//!   point sampling, distributed low-rank approximation — with exact
//!   per-word communication accounting, plus every substrate it needs
//!   (dense/sparse linear algebra, sketches, PRNG, transports,
//!   dataset generators, evaluation).
//! - **L2/L1**: JAX compute graphs with Pallas kernels, AOT-lowered to
//!   HLO-text artifacts (`make artifacts`) and executed from rust via
//!   PJRT ([`runtime`]). Python never runs on the request path.
//!
//! Start at [`coordinator`] for the headline algorithm, or
//! `examples/quickstart.rs` for a runnable tour.

pub mod bench_harness;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod experiments;
pub mod json;
pub mod kernels;
pub mod launcher;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod sparse;
