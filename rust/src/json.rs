//! Minimal JSON substrate (no serde offline): parser for the artifact
//! manifest + writer for experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), at: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.at != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.at, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.at).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.at += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.at += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.at..].starts_with(s.as_bytes()) {
            self.at += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.at += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.at += 1;
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.at += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.at..self.at + 4)
                                .ok_or_else(|| self.err("eof in \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.at += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // copy a run of plain bytes
                    let start = self.at;
                    while !matches!(self.peek(), Some(b'"' | b'\\') | None) {
                        self.at += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.at])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.at += 1;
        }
        std::str::from_utf8(&self.b[start..self.at])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b']') => {
                    self.at += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b'}') => {
                    self.at += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Serialize a [`Value`] compactly.
pub fn write(v: &Value) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Value::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders for result writing.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(vs: Vec<Value>) -> Value {
    Value::Arr(vs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
 "version": 1,
 "static": {"block_n": 256, "d_grid": [32, 128]},
 "artifacts": [
  {"name": "embed_rff_d32", "file": "embed_rff_d32.hlo.txt",
   "inputs": [{"name": "x", "shape": [256, 32], "dtype": "float32"}],
   "outputs": [{"shape": [256, 64], "dtype": "float32"}]}
 ]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let grid = v.get("static").unwrap().get("d_grid").unwrap().as_arr().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[1].as_usize(), Some(128));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("embed_rff_d32"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
    }

    #[test]
    fn roundtrip_write_parse() {
        let v = obj(vec![
            ("a", num(1.5)),
            ("b", s("hi \"there\"\n")),
            ("c", arr(vec![Value::Bool(true), Value::Null, num(-3.0)])),
            ("d", obj(vec![("nested", num(42.0))])),
        ]);
        let text = write(&v);
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn numbers_and_escapes() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse(r#""Ab""#).unwrap().as_str(), Some("Ab"));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }
}
