//! Tiny CLI argument parser (no clap offline): subcommand + `--key
//! value` flags + positionals, feeding [`crate::config::Config`].

use crate::config::Config;

#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    pub positionals: Vec<String>,
    pub config: Config,
}

pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cli, String> {
    let mut it = args.into_iter().peekable();
    let command = it.next().unwrap_or_else(|| "help".to_string());
    let mut config = Config::new();
    let mut positionals = Vec::new();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            if key.is_empty() {
                return Err("bare -- not supported".into());
            }
            // --flag=value or --flag value or boolean --flag
            if let Some((k, v)) = key.split_once('=') {
                config.set(k, v);
            } else if it
                .peek()
                .map(|n| !n.starts_with("--"))
                .unwrap_or(false)
            {
                let v = it.next().unwrap();
                config.set(key, &v);
            } else {
                config.set(key, "true");
            }
        } else {
            positionals.push(arg);
        }
    }
    // --config file.conf loads a file underneath the flag overrides
    if let Some(path) = config.get("config").map(str::to_string) {
        let mut base = Config::load(&path)?;
        base.merge(&config);
        config = base;
    }
    Ok(Cli { command, positionals, config })
}

pub const USAGE: &str = "diskpca — communication-efficient distributed kernel PCA (KDD'16)

USAGE: diskpca <command> [dataset] [--key value ...]

COMMANDS
  run        run disKPCA on a dataset        diskpca run har_like --kernel gauss --n_adapt 200
  table1     print the dataset registry (Table 1 analogues)
  fig2..fig8 regenerate the paper's figures  diskpca fig4 --scale 0.1
  figL       extension: Laplacian-kernel comm/error tradeoff
  css        extension: kernel column subset selection + KRR downstream
  bench-comm print the per-round communication table for one run
  ablation   sampling-stage ablation (full / leverage-only / adaptive-only)
  shard      write power-law shards of a dataset to disk
  master     multi-process master:  diskpca master --listen 0.0.0.0:7700 --workers 4 --kernel gauss --gamma 0.5
  worker     multi-process worker:  diskpca worker --connect host:7700 --data shard.bin --kernel gauss --gamma 0.5
  serve      persistent multi-job session: run --jobs N fits on one cluster
             (warm EmbedSpec reuse skips the 1-embed round), then serve a
             --transform-point projection batch. In-process by default;
             with --listen/--workers it drives external `diskpca worker`s:
             diskpca serve susy_like --jobs 4 --transform 1024
  help       this message

COMMON FLAGS
  --kernel gauss|poly|arccos|laplace   kernel family (default gauss)
  --backend native|xla         worker compute backend (default native)
  --scale F                    dataset size multiplier (default 0.1)
  --k N --t N --p N --n_lev N --n_adapt N --m_rff N --t2 N --seed N
  --threads N                  compute-pool threads per process (default 1;
                               results are bit-identical for every N)
  --chunk-rows N               stream worker passes over N-point chunks so
                               worker memory tracks N, not the shard size
                               (default 0 = resident; results are
                               bit-identical for every N). `shard` writes
                               chunked .dkps stores when set; `worker` maps
                               .dkps shards out-of-core
  --gather flat|tree           sketch-aggregation topology (default flat):
                               tree merges worker R factors pairwise (TSQR),
                               cutting the master's per-round gather cost from
                               O(s·t·p) to O(t²) words per merge level
  --compute-tier exact|fast    numeric kernel tier (default exact, env
                               DISKPCA_COMPUTE_TIER): exact is bit-reproducible
                               scalar code; fast opts into explicit-SIMD
                               (AVX2/FMA) GEMM, RFF/cos, FWHT and Gram loops —
                               results differ from exact only within the
                               documented accuracy bounds (tests/
                               fast_tier_accuracy.rs) and stay deterministic
                               for every thread count within the tier
  --elastic                    master: survive worker deaths — keep listening,
                               attach the next rejoining worker to the dead
                               slot, replay its round state, retry the round;
                               results stay bit-identical to a fault-free run
  --shards p0,p1,...           master --elastic: slot-ordered shard paths to
                               re-ship (ReqLoadShard) to rejoining workers
                               that started without --data
  --rejoin-wait SECS           master --elastic: how long to wait for a
                               replacement worker to connect (default 60)
  --rebalance                  master --elastic: when a dead slot's revival
                               budget runs out, adopt its shard onto a
                               survivor, shrink the cluster, and re-run the
                               job cold on s-1 workers (bit-identical to a
                               fresh fit over the post-rebalance layout).
                               Off by default: permanent loss then exits 4
  --comm-retries N             reply-timeout retry budget (default 0, env
                               DISKPCA_COMM_RETRIES): each expiry doubles
                               the bound and retries, up to N times, before
                               the timeout poisons the cluster — waits out
                               slow-but-alive workers
  --chaos-seed S               master --elastic: wrap every worker link in
                               the seeded deterministic fault-injection
                               transport (delays + severed links; env
                               DISKPCA_CHAOS_SEED). Same seed, same fault
                               schedule — healed runs stay bit-identical
  --workers N                  override the dataset's worker count
  --jobs N                     serve: fits to run on the session (default 3)
  --transform N                serve: query points to project (default 256)
  --refit                      serve: close the session with an incremental
                               warm refit (epoch-aware, no 1-embed round)
  --max-inflight N             serve: concurrent job lanes on the scheduler
                               (default 1 = bit-identical sequential path;
                               env DISKPCA_MAX_INFLIGHT). Independent jobs —
                               KRR fits, transform batches — interleave their
                               rounds; conflicting jobs serialize FIFO
  --queue-depth N              serve: admission-queue bound (default 32, env
                               DISKPCA_QUEUE_DEPTH); a full queue rejects
                               submissions with a typed error instead of
                               stalling the front end
  --pipeline-depth N           serve: transform super-chunks kept in flight
                               per query batch (default 2, env
                               DISKPCA_PIPELINE_DEPTH; results are bitwise
                               identical for every depth)
  --embed-cache-mb N           worker/serve: embed warm-cache byte budget in
                               MiB (default 64, env DISKPCA_EMBED_CACHE_MB;
                               0 disables caching)
  --variance-frac F            serve: refit acceptance gate in (0, 1]
                               (default 0.95, env DISKPCA_VARIANCE_FRAC).
                               An incremental warm refit whose top-k solution
                               preserves less than F of the sketched spectrum
                               re-runs as a full cold fit
  --config FILE                load key=value config file
  --out DIR                    results directory (default results)

EXIT CODES (master / worker deployment subcommands)
  0  success
  1  environment error (flags, data files, bind/connect)
  2  usage error (unknown command)
  3  protocol failure — a worker died, reported an error, or replied
     garbage mid-round; the error names the worker and the round, and
     the master releases surviving workers before exiting
  4  degraded — a worker slot is permanently lost (revival budget
     exhausted or no rejoin within --rejoin-wait) and --rebalance was
     off or impossible; the error names the lost slot. Re-shard, or
     rerun with --rebalance to continue on the survivors
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positionals() {
        let cli = parse(sv(&["run", "har_like", "--k", "10", "--kernel=poly", "--verbose"]))
            .unwrap();
        assert_eq!(cli.command, "run");
        assert_eq!(cli.positionals, vec!["har_like"]);
        assert_eq!(cli.config.usize_or("k", 0), 10);
        assert_eq!(cli.config.str_or("kernel", ""), "poly");
        assert!(cli.config.bool_or("verbose", false));
    }

    #[test]
    fn empty_args_give_help() {
        let cli = parse(sv(&[])).unwrap();
        assert_eq!(cli.command, "help");
    }

    #[test]
    fn flag_at_end_is_boolean() {
        let cli = parse(sv(&["run", "--fast"])).unwrap();
        assert!(cli.config.bool_or("fast", false));
    }

    #[test]
    fn negative_numbers_are_values() {
        let cli = parse(sv(&["run", "--offset", "-3"])).unwrap();
        assert_eq!(cli.config.str_or("offset", ""), "-3");
    }
}
