//! Worker-side protocol state machine.
//!
//! A worker owns one shard, answers the master's requests, and keeps
//! the between-round state the paper's algorithms rely on (its E^i,
//! its leverage scores, its residual distances, its Π^i, and finally
//! its projected coordinates). All heavy math is dispatched through
//! the [`Backend`] so the same worker runs native or XLA.

use std::sync::Arc;

use crate::comm::{Message, PointSet};
use crate::data::Data;
use crate::kernels::{diag as kernel_diag, Kernel};
use crate::linalg::{chol_psd, Mat};
use crate::rng::{AliasTable, Rng};
use crate::runtime::Backend;
use crate::sketch::CountSketch;

/// Per-thread CPU time — the Fig-7 "computation time" metric. Wall
/// clocks inflate under core contention when many worker threads
/// share one core (the whole point of the scaling study is to watch
/// per-worker compute shrink, so contention must not leak in).
#[cfg(target_os = "linux")]
pub fn thread_cpu_time() -> std::time::Duration {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: CLOCK_THREAD_CPUTIME_ID with a valid out-pointer.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    std::time::Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Non-Linux fallback: monotonic wall clock (scaling studies then
/// require an otherwise-idle machine).
#[cfg(not(target_os = "linux"))]
pub fn thread_cpu_time() -> std::time::Duration {
    use std::time::Instant;
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed()
}

pub struct Worker {
    shard: Data,
    kernel: Kernel,
    backend: Arc<dyn Backend>,
    // ---- protocol state ----
    /// E^i = S(φ(Aⁱ)) — t×nᵢ (Alg. 4 step 1).
    embedded: Option<Mat>,
    /// generalized leverage scores of the local columns (Alg. 1).
    scores: Option<Vec<f64>>,
    /// squared residual distances to span φ(P) (Alg. 2).
    residuals: Option<Vec<f64>>,
    /// Π^i = Qᵀφ(Aⁱ) — |Y|×nᵢ (Alg. 3 step 1).
    pi: Option<Mat>,
    /// LᵀΦ(Aⁱ) — k×nᵢ once a solution is installed.
    projected: Option<Mat>,
    /// KRR state: (K(Y,Aⁱ), teacher targets) from ReqKrrStats.
    krr: Option<(Mat, Vec<f64>)>,
    /// cumulative compute time (Fig-7 critical-path metric).
    busy: std::time::Duration,
}

impl Worker {
    pub fn new(shard: Data, kernel: Kernel, backend: Arc<dyn Backend>) -> Self {
        Self {
            shard,
            kernel,
            backend,
            embedded: None,
            scores: None,
            residuals: None,
            pi: None,
            projected: None,
            krr: None,
            busy: std::time::Duration::ZERO,
        }
    }

    /// Serve requests until `Quit` — works over any transport.
    pub fn run(mut self, mut endpoint: impl crate::comm::Endpoint) {
        loop {
            let req = endpoint.recv_req();
            if matches!(req, Message::Quit) {
                break;
            }
            endpoint.send_resp(self.handle(req));
        }
    }

    /// Handle one request (public for tcp workers + unit tests).
    pub fn handle(&mut self, req: Message) -> Message {
        let t0 = thread_cpu_time();
        let resp = self.dispatch(req);
        self.busy += thread_cpu_time().saturating_sub(t0);
        resp
    }

    fn dispatch(&mut self, req: Message) -> Message {
        match req {
            Message::ReqCount => Message::RespCount(self.shard.len()),
            Message::ReqBusyTime => Message::RespScalar(self.busy.as_secs_f64()),
            Message::ReqEmbed { spec } => {
                self.embedded = Some(self.backend.embed(&spec, &self.shard));
                Message::Ack
            }
            Message::ReqSketchEmbed { p, seed } => {
                let e = self.embedded.as_ref().expect("ReqEmbed first");
                let mut rng = Rng::seed_from(seed);
                let cs = CountSketch::new(e.cols(), p, &mut rng);
                Message::RespMat(cs.apply_point_axis(e))
            }
            Message::ReqScores { z } => {
                let e = self.embedded.as_ref().expect("ReqEmbed first");
                let scores = self.backend.leverage_norms(&z, e);
                let total = scores.iter().sum();
                self.scores = Some(scores);
                Message::RespScalar(total)
            }
            Message::ReqScoresVec => {
                let scores = self.scores.as_ref().expect("ReqScores first");
                let mut m = Mat::zeros(1, scores.len());
                for (j, &v) in scores.iter().enumerate() {
                    m[(0, j)] = v;
                }
                Message::RespMat(m)
            }
            Message::ReqKrrStats { pts, teacher_seed } => {
                let y = pts.to_mat();
                let k_ya = self.backend.gram(self.kernel, &y, &self.shard);
                let targets = self.teacher_targets(teacher_seed);
                // g = K_YA·K_AY (|Y|×|Y|), b = K_YA·t (|Y|×1)
                let g = k_ya.matmul_a_bt(&k_ya);
                let mut b = Mat::zeros(y.cols(), 1);
                for i in 0..y.cols() {
                    let row = k_ya.row(i);
                    b[(i, 0)] = row.iter().zip(&targets).map(|(&k, &t)| k * t).sum();
                }
                let tnorm = targets.iter().map(|&t| t * t).sum();
                self.krr = Some((k_ya, targets));
                Message::RespKrr { g, b, tnorm }
            }
            Message::ReqKrrEval { alpha } => {
                let (k_ya, targets) = self.krr.as_ref().expect("ReqKrrStats first");
                // pred = αᵀ·K_YA (1×nᵢ)
                let pred = alpha.matmul_at_b(k_ya);
                let err: f64 = (0..targets.len())
                    .map(|j| {
                        let e = pred[(0, j)] - targets[j];
                        e * e
                    })
                    .sum();
                Message::RespScalar(err)
            }
            Message::ReqSampleLeverage { count, seed } => {
                let scores = self.scores.clone().expect("ReqScores first");
                self.sample_weighted(&scores, count, seed)
            }
            Message::ReqResiduals { pts } => {
                let res = self.compute_residuals(&pts.to_mat());
                let total = res.iter().sum();
                self.residuals = Some(res);
                Message::RespScalar(total)
            }
            Message::ReqSampleAdaptive { count, seed } => {
                let res = self.residuals.clone().expect("ReqResiduals first");
                self.sample_weighted(&res, count, seed)
            }
            Message::ReqProjectSketch { pts, w, seed } => {
                let y = pts.to_mat();
                let pi = self.project(&y).0;
                let mut rng = Rng::seed_from(seed);
                let cs = CountSketch::new(pi.cols(), w, &mut rng);
                let sketched = cs.apply_point_axis(&pi);
                self.pi = Some(pi);
                Message::RespMat(sketched)
            }
            Message::ReqFinal { coeffs } => {
                // L = Q·W ⇒ Lᵀφ(A) = Wᵀ·Π (Π cached from ReqProjectSketch)
                let pi = self.pi.as_ref().expect("ReqProjectSketch first");
                self.projected = Some(coeffs.matmul_at_b(pi));
                Message::Ack
            }
            Message::ReqSetSolution { pts, coeffs } => {
                // L = φ(Y)·C ⇒ Lᵀφ(A) = Cᵀ·K(Y, A)
                let y = pts.to_mat();
                let k_ya = self.backend.gram(self.kernel, &y, &self.shard);
                self.projected = Some(coeffs.matmul_at_b(&k_ya));
                Message::Ack
            }
            Message::ReqEvalError => {
                let proj = self.projected.as_ref().expect("no solution installed");
                let diag = kernel_diag(self.kernel, &self.shard);
                let norms = proj.col_norms_sq();
                let err: f64 = diag
                    .iter()
                    .zip(&norms)
                    .map(|(&d, &n)| (d - n).max(0.0))
                    .sum();
                Message::RespScalar(err)
            }
            Message::ReqEvalTrace => {
                Message::RespScalar(kernel_diag(self.kernel, &self.shard).iter().sum())
            }
            Message::ReqSampleUniform { count, seed } => {
                let n = self.shard.len();
                let mut rng = Rng::seed_from(seed);
                let idx: Vec<usize> = if count >= n {
                    (0..n).collect()
                } else {
                    rng.sample_without_replacement(n, count)
                };
                Message::RespPoints(PointSet::from_data(&self.shard, &idx))
            }
            Message::ReqSampleProjected { count, seed } => {
                let proj = self.projected.as_ref().expect("no solution installed");
                let n = proj.cols();
                let mut rng = Rng::seed_from(seed);
                let idx: Vec<usize> = (0..count.min(n)).map(|_| rng.below(n)).collect();
                Message::RespMat(proj.select_cols(&idx))
            }
            Message::ReqKmeansStep { centers } => {
                let proj = self.projected.as_ref().expect("no solution installed");
                let (kdim, c) = (centers.rows(), centers.cols());
                assert_eq!(proj.rows(), kdim);
                let mut sums = Mat::zeros(kdim, c);
                let mut counts = vec![0usize; c];
                let mut obj = 0.0;
                for j in 0..proj.cols() {
                    let mut best = (f64::INFINITY, 0usize);
                    for ci in 0..c {
                        let mut d2 = 0.0;
                        for r in 0..kdim {
                            let d = proj[(r, j)] - centers[(r, ci)];
                            d2 += d * d;
                        }
                        if d2 < best.0 {
                            best = (d2, ci);
                        }
                    }
                    obj += best.0;
                    counts[best.1] += 1;
                    for r in 0..kdim {
                        sums[(r, best.1)] += proj[(r, j)];
                    }
                }
                Message::RespKmeans { sums, counts, obj }
            }
            Message::Quit => Message::Ack,
            other => panic!("worker got unexpected {other:?}"),
        }
    }

    /// Weighted sample of local points (with replacement, then
    /// deduplicated — duplicates add nothing to span φ(Y) but would
    /// cost words), returned in the shard's natural encoding.
    fn sample_weighted(&mut self, weights: &[f64], count: usize, seed: u64) -> Message {
        if weights.is_empty() || count == 0 {
            return Message::RespPoints(PointSet::from_data(&self.shard, &[]));
        }
        let mut rng = Rng::seed_from(seed);
        let table = AliasTable::new(weights);
        let mut idx = table.draw_many(&mut rng, count);
        idx.sort_unstable();
        idx.dedup();
        Message::RespPoints(PointSet::from_data(&self.shard, &idx))
    }

    /// Π = R⁻ᵀK(Y, Aⁱ) and residuals, via kernel trick + implicit
    /// Gram–Schmidt (paper Appendix A).
    fn project(&self, y: &Mat) -> (Mat, Vec<f64>) {
        let k_yy = crate::kernels::gram(self.kernel, y, &Data::Dense(y.clone()));
        let (r, _jitter) = chol_psd(&k_yy);
        let k_ya = self.backend.gram(self.kernel, y, &self.shard);
        let diag = kernel_diag(self.kernel, &self.shard);
        self.backend.project_residual(&r, &k_ya, &diag)
    }

    fn compute_residuals(&self, p: &Mat) -> Vec<f64> {
        self.project(p).1
    }

    /// Synthetic teacher targets tⱼ = cos(vᵀxⱼ), v ~ N(0, I/√d) from
    /// the shared seed — a fixed nonlinear function every worker can
    /// evaluate locally, so KRR has ground truth without label
    /// plumbing.
    fn teacher_targets(&self, seed: u64) -> Vec<f64> {
        let d = self.shard.dim();
        let mut rng = Rng::seed_from(seed);
        let scale = 1.0 / (d as f64).sqrt();
        let v: Vec<f64> = (0..d).map(|_| rng.normal() * scale).collect();
        (0..self.shard.len())
            .map(|j| {
                let mut a = 0.0;
                match &self.shard {
                    Data::Dense(m) => {
                        let c = m.col(j);
                        for r in 0..d {
                            a += v[r] * c[r];
                        }
                    }
                    Data::Sparse(s) => {
                        for (r, val) in s.col_iter(j) {
                            a += v[r] * val;
                        }
                    }
                }
                a.cos()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::EmbedSpec;
    use crate::runtime::NativeBackend;

    fn mk_worker(n: usize) -> Worker {
        let mut rng = Rng::seed_from(1);
        let shard = Data::Dense(Mat::from_fn(6, n, |_, _| rng.normal()));
        Worker::new(
            shard,
            Kernel::Gauss { gamma: 0.5 },
            Arc::new(NativeBackend::new()),
        )
    }

    #[test]
    fn protocol_happy_path() {
        let mut w = mk_worker(30);
        assert!(matches!(w.handle(Message::ReqCount), Message::RespCount(30)));
        let spec = EmbedSpec {
            kernel: Kernel::Gauss { gamma: 0.5 },
            m: 256,
            t2: 64,
            t: 16,
            seed: 3,
        };
        assert!(matches!(w.handle(Message::ReqEmbed { spec }), Message::Ack));
        let et = match w.handle(Message::ReqSketchEmbed { p: 20, seed: 5 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((et.rows(), et.cols()), (16, 20));
        // Z from the sketch (as the master would)
        let z = crate::linalg::qr_r_only(&et.transpose());
        let mass = match w.handle(Message::ReqScores { z }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(mass > 0.0);
        let pts = match w.handle(Message::ReqSampleLeverage { count: 5, seed: 7 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        // 5 draws with replacement, deduplicated
        assert!((1..=5).contains(&pts.len()), "{}", pts.len());
        let resid_mass = match w.handle(Message::ReqResiduals { pts: pts.clone() }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(resid_mass >= 0.0);
        let extra = match w.handle(Message::ReqSampleAdaptive { count: 4, seed: 9 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let y = PointSet::concat(&[pts, extra]);
        let ny = y.len();
        let pit = match w.handle(Message::ReqProjectSketch { pts: y.clone(), w: 12, seed: 11 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((pit.rows(), pit.cols()), (ny, 12));
        // fake top-k coefficients: identity on first 3 dims
        let wmat = Mat::from_fn(ny, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matches!(w.handle(Message::ReqFinal { coeffs: wmat }), Message::Ack));
        let err = match w.handle(Message::ReqEvalError) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        let trace = match w.handle(Message::ReqEvalTrace) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(err >= 0.0 && err <= trace + 1e-9, "err {err} trace {trace}");
        assert!((trace - 30.0).abs() < 1e-9); // gauss diag = 1 each
    }

    #[test]
    fn residuals_zero_when_sampled_points_cover_shard() {
        let mut w = mk_worker(8);
        // P = the entire shard ⇒ all residuals ≈ 0
        let all: Vec<usize> = (0..8).collect();
        let pts = PointSet::from_data(&w.shard, &all);
        let mass = match w.handle(Message::ReqResiduals { pts }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(mass < 1e-5, "mass {mass}");
    }

    #[test]
    fn set_solution_then_kmeans() {
        let mut w = mk_worker(20);
        // random 4-point solution, orthonormalized coefficients not
        // required for exercising the code path
        let y = match w.handle(Message::ReqSampleUniform { count: 4, seed: 1 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let coeffs = Mat::from_fn(4, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matches!(
            w.handle(Message::ReqSetSolution { pts: y, coeffs }),
            Message::Ack
        ));
        let sample = match w.handle(Message::ReqSampleProjected { count: 3, seed: 2 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((sample.rows(), sample.cols()), (2, 3));
        match w.handle(Message::ReqKmeansStep { centers: sample }) {
            Message::RespKmeans { sums, counts, obj } => {
                assert_eq!(sums.rows(), 2);
                assert_eq!(counts.iter().sum::<usize>(), 20);
                assert!(obj >= 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scores_vec_returns_per_point_scores() {
        let mut w = mk_worker(12);
        let spec = EmbedSpec {
            kernel: Kernel::Gauss { gamma: 0.5 },
            m: 128,
            t2: 64,
            t: 8,
            seed: 3,
        };
        w.handle(Message::ReqEmbed { spec });
        let et = match w.handle(Message::ReqSketchEmbed { p: 16, seed: 5 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        let z = crate::linalg::qr_r_only(&et.transpose());
        let total = match w.handle(Message::ReqScores { z }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        let vec = match w.handle(Message::ReqScoresVec) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((vec.rows(), vec.cols()), (1, 12));
        let sum: f64 = vec.row(0).iter().sum();
        assert!((sum - total).abs() < 1e-9 * total.max(1.0));
        assert!(vec.row(0).iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn krr_stats_then_eval() {
        let mut w = mk_worker(25);
        let y = match w.handle(Message::ReqSampleUniform { count: 6, seed: 4 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let ny = y.len();
        let (g, b, tnorm) = match w.handle(Message::ReqKrrStats { pts: y, teacher_seed: 9 }) {
            Message::RespKrr { g, b, tnorm } => (g, b, tnorm),
            other => panic!("{other:?}"),
        };
        assert_eq!((g.rows(), g.cols()), (ny, ny));
        assert_eq!((b.rows(), b.cols()), (ny, 1));
        // G = K_YA·K_AY is PSD ⇒ nonneg diagonal; targets are cos(·) ⇒
        // ‖t‖² ≤ n
        for i in 0..ny {
            assert!(g[(i, i)] >= -1e-12);
        }
        assert!(tnorm >= 0.0 && tnorm <= 25.0 + 1e-9);
        // evaluating α = 0 must give SSE = ‖t‖²
        let zero = Mat::zeros(ny, 1);
        let sse = match w.handle(Message::ReqKrrEval { alpha: zero }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!((sse - tnorm).abs() < 1e-9 * tnorm.max(1.0), "{sse} vs {tnorm}");
    }

    #[test]
    fn uniform_sample_capped_at_shard_size() {
        let mut w = mk_worker(5);
        let pts = match w.handle(Message::ReqSampleUniform { count: 50, seed: 3 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        assert_eq!(pts.len(), 5);
    }
}
