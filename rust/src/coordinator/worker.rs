//! Worker-side protocol state machine.
//!
//! A worker owns one shard, answers the master's requests, and keeps
//! the between-round state the paper's algorithms rely on (its E^i,
//! its leverage scores, its residual distances, its Π^i, and finally
//! its projected coordinates). All heavy math is dispatched through
//! the [`Backend`] so the same worker runs native or XLA.
//!
//! # Handler registration
//!
//! Each protocol request registers exactly one handler: an
//! `impl Handle<R> for Worker` (the typed trait from
//! [`crate::comm::request`]), whose return type is pinned to the
//! request's response type — a handler replying with the wrong
//! variant is a compile error. The resident and the streaming
//! execution paths live *inside* each handler (one
//! `if self.streaming()` branch), so the two paths share a single
//! registration point and cannot drift apart. [`Worker::handle`]
//! lowers an incoming [`Message`] to its typed request and wraps the
//! typed response back into the wire message.
//!
//! # Resident vs streaming execution
//!
//! With `chunk_rows == 0` over an in-memory shard the worker runs the
//! historical **resident** path: E^i (t×nᵢ) and Π^i (|Y|×nᵢ) are
//! materialized once and cached between rounds. With `chunk_rows > 0`
//! (or a disk-backed [`ShardSource::Store`]) it runs the **streaming**
//! path: every per-point pass — sketch application, Gram blocks
//! against Y, leverage and residual scans, evaluation — *folds over
//! ascending column chunks*, so peak matrix memory is bounded by the
//! chunk size rather than the shard size. Only O(nᵢ) vectors (scores,
//! residuals, KRR targets) stay resident.
//!
//! With the native backend the two paths are **bit-identical** for
//! everything `dis_kpca` touches: every chunked operation is
//! per-column independent, and every cross-point reduction
//! (point-axis CountSketch accumulation, scalar masses, eval sums) is
//! folded element-by-element in the same ascending point order as the
//! resident code, so no floating-point sum is ever reassociated.
//! `tests/streaming_parity.rs` pins this from single sketch applies
//! up to full `dis_kpca` over TCP. Two documented caveats: the KRR
//! normal-equation matrix `g`, whose resident path uses a
//! differently-associated blocked matmul (the streamed `g` is still
//! deterministic and chunk-size-invariant); and the XLA backend,
//! which streaming dispatches per chunk — its static-shape padding
//! means f32 rounding may vary with the chunk size (native, the
//! parity oracle, does not).

use std::sync::Arc;

use crate::comm::request as rq;
use crate::comm::{Handle, KmeansPart, KrrPart, Message, PointSet, Request};
use crate::data::{Data, ShardSource};
use crate::embed::EmbedSpec;
use crate::kernels::{diag as kernel_diag, diag_into as kernel_diag_into, Kernel};
use crate::linalg::{chol_psd, Mat};
use crate::rng::{AliasTable, Rng};
use crate::runtime::Backend;
use crate::sketch::CountSketch;

/// Per-thread CPU time — the Fig-7 "computation time" metric. Wall
/// clocks inflate under core contention when many worker threads
/// share one core (the whole point of the scaling study is to watch
/// per-worker compute shrink, so contention must not leak in).
#[cfg(target_os = "linux")]
pub fn thread_cpu_time() -> std::time::Duration {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: CLOCK_THREAD_CPUTIME_ID with a valid out-pointer.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    std::time::Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Non-Linux fallback: monotonic wall clock (scaling studies then
/// require an otherwise-idle machine).
#[cfg(not(target_os = "linux"))]
pub fn thread_cpu_time() -> std::time::Duration {
    use std::sync::OnceLock;
    use std::time::Instant;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed()
}

/// Reusable buffers threaded through one streaming pass's chunk loop —
/// allocated once per pass, not once per chunk. This is the worker's
/// slice of the allocation-free streaming story; the heavyweight
/// per-chunk reuse lives below it: [`crate::runtime::NativeBackend`]
/// keeps a warm embed-table cache (tables built once per pass, not
/// per chunk), the sketches hold their inverted bucket indexes /
/// FFT buffers across applies, and the packed GEMM engine
/// ([`crate::linalg::gemm`]) reuses its per-thread pack arenas — a
/// chunk loop runs on one thread, so every chunk hits the same warm
/// arena.
#[derive(Default)]
struct ChunkScratch {
    /// κ(xⱼ,xⱼ) of the current chunk.
    diag: Vec<f64>,
}

/// How a streaming worker reconstructs LᵀΦ(chunk) on demand instead
/// of caching the full k×nᵢ projection.
enum StreamSolution {
    /// disLR output L = Q·W: LᵀΦ(x) = Wᵀ·R⁻ᵀ·K(Y, x).
    Factored { y: Mat, r_upper: Mat, coeffs: Mat },
    /// Directly installed L = φ(Y)·C: LᵀΦ(x) = Cᵀ·K(Y, x).
    Direct { y: Mat, coeffs: Mat },
}

/// KRR round state — resident caches the full K(Y, Aⁱ); streaming
/// keeps only Y and the O(nᵢ) target vector.
enum KrrState {
    Resident { k_ya: Mat, targets: Vec<f64> },
    Streamed { y: Mat, targets: Vec<f64> },
}

/// Retained disLS sketch accumulator — the worker's half of the
/// incremental-refit contract. [`rq::SketchEmbed`] records the t×p
/// point-axis CountSketch it returned (keyed by the `(p, seed)` the
/// master drew it under, plus the column count it covered);
/// [`rq::DeltaSketch`] then folds only columns `[cols, n)` of an
/// appended shard on top of `out`, which is bit-identical to a cold
/// full-shard sketch because the sketch tables come from
/// [`CountSketch::new_extendable`] (prefix-stable in the column count)
/// and the point-axis fold adds columns in ascending order either way.
/// A worker with no (or a mismatched) accumulator — e.g. one revived
/// by the elastic runtime after a crash — silently folds from column
/// 0 instead: same bits, just no savings.
struct SketchAcc {
    p: usize,
    seed: u64,
    /// Columns `[0, cols)` are already folded into `out`.
    cols: usize,
    out: Mat,
}

/// Warm-state cache of resident embeddings E^i = S(φ(Aⁱ)), keyed by
/// the [`EmbedSpec`] (hash key for lookup, full equality re-checked on
/// every hit). Jobs on a persistent serve cluster that alternate
/// between a few specs skip the embedding recompute entirely; eviction
/// is least-recently-used, bounded by a byte budget
/// (`DISKPCA_EMBED_CACHE_MB`). The default is deliberately modest
/// (64 MiB): the cache also sees one-shot multi-spec runs (boosting
/// sweeps a fresh spec per attempt and never revisits one), where
/// retained entries are dead weight — serve deployments that want
/// more warmth raise the budget explicitly (`--embed-cache-mb`).
///
/// The entries are `Arc`s shared with the worker's installed
/// embedding, so a cached-and-installed embedding costs its bytes
/// once.
struct EmbedCache {
    /// (key, spec, embedding, last-use tick)
    entries: Vec<(u64, EmbedSpec, Arc<Mat>, u64)>,
    budget_bytes: usize,
    tick: u64,
    hits: usize,
    misses: usize,
}

impl EmbedCache {
    fn new(budget_bytes: usize) -> Self {
        Self { entries: Vec::new(), budget_bytes, tick: 0, hits: 0, misses: 0 }
    }

    fn bytes(&self) -> usize {
        self.entries.iter().map(|(_, _, e, _)| e.rows() * e.cols() * 8).sum()
    }

    fn get(&mut self, spec: &EmbedSpec) -> Option<Arc<Mat>> {
        let key = spec.cache_key();
        self.tick += 1;
        for (k, s, e, used) in self.entries.iter_mut() {
            if *k == key && s == spec {
                *used = self.tick;
                self.hits += 1;
                return Some(Arc::clone(e));
            }
        }
        self.misses += 1;
        None
    }

    fn put(&mut self, spec: EmbedSpec, e: Arc<Mat>) {
        let bytes = e.rows() * e.cols() * 8;
        if bytes > self.budget_bytes {
            return; // a single over-budget entry is never cached
        }
        self.tick += 1;
        self.entries.push((spec.cache_key(), spec, e, self.tick));
        self.evict_to_budget();
    }

    /// Drop least-recently-used entries until within the byte budget.
    fn evict_to_budget(&mut self) {
        while self.bytes() > self.budget_bytes {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, _, _, used))| *used)
                .map(|(i, _)| i)
                .expect("nonempty while over budget");
            self.entries.remove(lru);
        }
    }
}

/// Parse a `DISKPCA_EMBED_CACHE_MB` value (`None` = unset ⇒ the 64 MiB
/// default). A set-but-unparsable value is a configuration error, not
/// silently the default — the knob only exists because someone set it.
pub fn parse_embed_cache_mb(raw: Option<&str>) -> Result<usize, String> {
    match raw {
        None => Ok(64),
        Some(v) => v
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("DISKPCA_EMBED_CACHE_MB={v}: not a whole number of MiB")),
    }
}

fn embed_cache_budget_from_env() -> usize {
    let raw = std::env::var("DISKPCA_EMBED_CACHE_MB").ok();
    let mb = match parse_embed_cache_mb(raw.as_deref()) {
        Ok(mb) => mb,
        Err(msg) => panic!("config {msg}"),
    };
    mb.saturating_mul(1 << 20)
}

pub struct Worker {
    source: ShardSource,
    /// Streaming chunk width in points; `0` over a resident shard
    /// selects the resident path. Disk-backed sources always stream
    /// (`0` ⇒ one chunk per stored block).
    chunk_rows: usize,
    kernel: Kernel,
    backend: Arc<dyn Backend>,
    // ---- resident-path caches ----
    /// E^i = S(φ(Aⁱ)) — t×nᵢ (Alg. 4 step 1). Shared with
    /// `embed_cache` so multi-job reinstalls are free.
    embedded: Option<Arc<Mat>>,
    /// Warm-state cache of embeddings across jobs (resident path; the
    /// streaming path never materializes E^i so has nothing to cache).
    embed_cache: EmbedCache,
    /// Π^i = Qᵀφ(Aⁱ) — |Y|×nᵢ (Alg. 3 step 1).
    pi: Option<Mat>,
    /// LᵀΦ(Aⁱ) — k×nᵢ once a solution is installed.
    projected: Option<Mat>,
    // ---- streaming-path state (all O(chunk) or O(|Y|)) ----
    /// Embedding spec cached by ReqEmbed; the embedding is recomputed
    /// per chunk through [`Backend::embed`] (Alg. 4 step 1), so the
    /// XLA backend stays on its hot path under streaming too.
    embed_spec: Option<EmbedSpec>,
    /// (Y, chol factor of K(Y,Y)) cached by ReqProjectSketch — on
    /// *both* paths since the serve layer landed: resident workers
    /// need it to install a queryable `StreamSolution` too.
    stream_basis: Option<(Mat, Mat)>,
    /// The installed solution in new-point-projectable form — the
    /// state ReqProjectPoints queries (both paths).
    stream_solution: Option<StreamSolution>,
    // ---- O(nᵢ) state shared by both paths ----
    /// generalized leverage scores of the local columns (Alg. 1).
    scores: Option<Vec<f64>>,
    /// squared residual distances to span φ(P) (Alg. 2).
    residuals: Option<Vec<f64>>,
    /// KRR state from ReqKrrStats.
    krr: Option<KrrState>,
    /// Retained disLS sketch for incremental refit (both paths).
    disls_acc: Option<SketchAcc>,
    /// cumulative compute time (Fig-7 critical-path metric).
    busy: std::time::Duration,
}

impl Worker {
    /// Resident worker over an in-memory shard (the historical path).
    pub fn new(shard: Data, kernel: Kernel, backend: Arc<dyn Backend>) -> Self {
        Self::with_source(ShardSource::Resident(shard), kernel, backend, 0)
    }

    /// In-memory shard, streamed in `chunk_rows`-point chunks
    /// (`0` = resident).
    pub fn new_chunked(
        shard: Data,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
    ) -> Self {
        Self::with_source(ShardSource::Resident(shard), kernel, backend, chunk_rows)
    }

    /// Worker over any [`ShardSource`] — the out-of-core entry point.
    pub fn with_source(
        source: ShardSource,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
    ) -> Self {
        Self {
            source,
            chunk_rows,
            kernel,
            backend,
            embedded: None,
            embed_cache: EmbedCache::new(embed_cache_budget_from_env()),
            pi: None,
            projected: None,
            embed_spec: None,
            stream_basis: None,
            stream_solution: None,
            scores: None,
            residuals: None,
            krr: None,
            disls_acc: None,
            busy: std::time::Duration::ZERO,
        }
    }

    fn streaming(&self) -> bool {
        self.chunk_rows > 0 || matches!(self.source, ShardSource::Store(_))
    }

    /// Bound the embed warm-cache (bytes). `0` disables caching.
    /// Overrides the `DISKPCA_EMBED_CACHE_MB` default.
    pub fn set_embed_cache_budget(&mut self, bytes: usize) {
        self.embed_cache.budget_bytes = bytes;
        self.embed_cache.evict_to_budget();
    }

    /// (entries, bytes, hits, misses) of the embed warm cache — for
    /// eviction tests and serve-mode introspection.
    pub fn embed_cache_stats(&self) -> (usize, usize, usize, usize) {
        let c = &self.embed_cache;
        (c.entries.len(), c.bytes(), c.hits, c.misses)
    }

    /// The in-memory shard (resident path only).
    fn shard(&self) -> &Data {
        self.source.resident().expect("resident path requires an in-memory shard")
    }

    /// Serve requests until `Quit` — works over any transport. A lost
    /// master ends the loop cleanly (the transport surfaced it as an
    /// `Err`); the multi-process launcher runs its own loop to attach
    /// richer context before exiting.
    pub fn run(mut self, mut endpoint: impl crate::comm::Endpoint) {
        loop {
            let req = match endpoint.recv_req() {
                Ok(req) => req,
                Err(_) => return, // master hung up: stop serving
            };
            if matches!(req, Message::Quit) {
                return;
            }
            let resp = self.handle(req);
            if endpoint.send_resp(resp).is_err() {
                return; // master hung up mid-reply
            }
        }
    }

    /// Handle one request (public for tcp workers + unit tests). A
    /// panicking handler (protocol misuse, shard-store IO failure) is
    /// caught and surfaced to the master as [`Message::RespError`]
    /// instead of killing the worker with no context.
    pub fn handle(&mut self, req: Message) -> Message {
        let t0 = thread_cpu_time();
        let tag = req.tag();
        let resp =
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.dispatch(req))) {
                Ok(resp) => resp,
                Err(payload) => {
                    let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                        (*s).to_string()
                    } else if let Some(s) = payload.downcast_ref::<String>() {
                        s.clone()
                    } else {
                        "non-string panic payload".to_string()
                    };
                    Message::RespError(format!("worker failed handling {tag}: {msg}"))
                }
            };
        self.busy += thread_cpu_time().saturating_sub(t0);
        resp
    }

    /// Run the registered [`Handle`] impl for a typed request and wrap
    /// its (type-checked) response for the wire.
    fn respond<R: Request>(&mut self, req: R) -> Message
    where
        Worker: Handle<R>,
    {
        R::encode_response(self.handle_req(req))
    }

    /// Lower the wire message to its typed request — the single
    /// registration point shared by the resident and streaming paths
    /// (each handler branches internally).
    fn dispatch(&mut self, req: Message) -> Message {
        match req {
            Message::ReqEmbed { spec } => self.respond(rq::Embed { spec }),
            Message::ReqSketchEmbed { p, seed } => self.respond(rq::SketchEmbed { p, seed }),
            Message::ReqScores { z } => self.respond(rq::Scores { z }),
            Message::ReqSampleLeverage { count, seed } => {
                self.respond(rq::SampleLeverage { count, seed })
            }
            Message::ReqResiduals { pts } => self.respond(rq::Residuals { pts }),
            Message::ReqSampleAdaptive { count, seed } => {
                self.respond(rq::SampleAdaptive { count, seed })
            }
            Message::ReqProjectSketch { pts, w, seed } => {
                self.respond(rq::ProjectSketch { pts, w, seed })
            }
            Message::ReqFinal { coeffs } => self.respond(rq::Final { coeffs }),
            Message::ReqSetSolution { pts, coeffs } => {
                self.respond(rq::SetSolution { pts, coeffs })
            }
            Message::ReqSampleProjected { count, seed } => {
                self.respond(rq::SampleProjected { count, seed })
            }
            Message::ReqEvalError => self.respond(rq::EvalError),
            Message::ReqEvalTrace => self.respond(rq::EvalTrace),
            Message::ReqSampleUniform { count, seed } => {
                self.respond(rq::SampleUniform { count, seed })
            }
            Message::ReqKmeansStep { centers } => self.respond(rq::KmeansStep { centers }),
            Message::ReqScoresVec => self.respond(rq::ScoresVec),
            Message::ReqKrrStats { pts, teacher_seed } => {
                self.respond(rq::KrrStats { pts, teacher_seed })
            }
            Message::ReqKrrEval { alpha } => self.respond(rq::KrrEval { alpha }),
            Message::ReqProjectPoints { pts } => self.respond(rq::ProjectPoints { pts }),
            Message::ReqCount => self.respond(rq::Count),
            Message::ReqBusyTime => self.respond(rq::BusyTime),
            Message::ReqSketchEmbedR { p, seed } => self.respond(rq::SketchEmbedR { p, seed }),
            Message::ReqProjectSketchR { pts, w, seed } => {
                self.respond(rq::ProjectSketchR { pts, w, seed })
            }
            Message::ReqLoadShard { path, chunk_rows } => {
                self.respond(rq::LoadShard { path, chunk_rows })
            }
            Message::ReqRefreshShard { epoch } => self.respond(rq::RefreshShard { epoch }),
            Message::ReqDeltaSketch { p, seed } => self.respond(rq::DeltaSketch { p, seed }),
            Message::ReqAdoptShard { path, pts, chunk_rows } => {
                self.respond(rq::AdoptShard { path, pts, chunk_rows })
            }
            Message::Quit => Message::Ack,
            other => panic!("worker got unexpected {other:?}"),
        }
    }

    /// Weighted sample of local points (with replacement, then
    /// deduplicated — duplicates add nothing to span φ(Y) but would
    /// cost words), returned in the shard's natural encoding.
    fn sample_weighted(&mut self, weights: &[f64], count: usize, seed: u64) -> PointSet {
        if weights.is_empty() || count == 0 {
            return self.source.point_set(&[]);
        }
        let mut rng = Rng::seed_from(seed);
        let table = AliasTable::new(weights);
        let mut idx = table.draw_many(&mut rng, count);
        idx.sort_unstable();
        idx.dedup();
        self.source.point_set(&idx)
    }

    /// Upper-triangular Cholesky factor of K(Y, Y) — the shared first
    /// step of both the resident `project` and every streamed
    /// projection pass (identical construction, so factors agree
    /// bit-for-bit).
    fn chol_basis(&self, y: &Mat) -> Mat {
        let k_yy = crate::kernels::gram(self.kernel, y, &Data::Dense(y.clone()));
        chol_psd(&k_yy).0
    }

    /// Π = R⁻ᵀK(Y, Aⁱ) and residuals, via kernel trick + implicit
    /// Gram–Schmidt (paper Appendix A). Resident path only. Also
    /// returns the basis factor R so callers can retain (Y, R) for
    /// later new-point projection ([`rq::ProjectPoints`]).
    fn project(&self, y: &Mat) -> (Mat, Vec<f64>, Mat) {
        let r = self.chol_basis(y);
        let k_ya = self.backend.gram(self.kernel, y, self.shard());
        let diag = kernel_diag(self.kernel, self.shard());
        let (pi, res) = self.backend.project_residual(&r, &k_ya, &diag);
        (pi, res, r)
    }

    fn compute_residuals(&self, p: &Mat) -> Vec<f64> {
        self.project(p).1
    }
}

// ---- path-independent handlers ------------------------------------

impl Handle<rq::Count> for Worker {
    fn handle_req(&mut self, _req: rq::Count) -> usize {
        self.source.len()
    }
}

impl Handle<rq::BusyTime> for Worker {
    fn handle_req(&mut self, _req: rq::BusyTime) -> f64 {
        self.busy.as_secs_f64()
    }
}

impl Handle<rq::ScoresVec> for Worker {
    fn handle_req(&mut self, _req: rq::ScoresVec) -> Mat {
        let scores = self.scores.as_ref().expect("ReqScores first");
        let mut m = Mat::zeros(1, scores.len());
        for (j, &v) in scores.iter().enumerate() {
            m[(0, j)] = v;
        }
        m
    }
}

impl Handle<rq::SampleLeverage> for Worker {
    fn handle_req(&mut self, req: rq::SampleLeverage) -> PointSet {
        let scores = self.scores.clone().expect("ReqScores first");
        self.sample_weighted(&scores, req.count, req.seed)
    }
}

impl Handle<rq::SampleAdaptive> for Worker {
    fn handle_req(&mut self, req: rq::SampleAdaptive) -> PointSet {
        let res = self.residuals.clone().expect("ReqResiduals first");
        self.sample_weighted(&res, req.count, req.seed)
    }
}

impl Handle<rq::SampleUniform> for Worker {
    fn handle_req(&mut self, req: rq::SampleUniform) -> PointSet {
        let n = self.source.len();
        let mut rng = Rng::seed_from(req.seed);
        let idx: Vec<usize> = if req.count >= n {
            (0..n).collect()
        } else {
            rng.sample_without_replacement(n, req.count)
        };
        self.source.point_set(&idx)
    }
}

// ---- per-point passes: each handler holds its resident twin and its
// streaming fold side by side (see the module docs for the
// bit-identity argument; every streamed arm mirrors the resident
// per-column operations and fold order exactly) -----------------------

impl Handle<rq::Embed> for Worker {
    fn handle_req(&mut self, req: rq::Embed) {
        if self.streaming() {
            // Only the spec is cached; the embedding is recomputed
            // chunk-by-chunk through the backend on demand and never
            // materialized whole. Tables re-derive from the spec's
            // seed, so per-chunk columns equal the resident
            // embedding's columns.
            self.embed_spec = Some(req.spec);
        } else {
            // Warm-state reuse: a spec seen before (jobs alternating
            // between a few specs on a persistent cluster) skips the
            // recompute. Bit-safe — the embedding is a deterministic
            // function of (spec, shard) and the shard never changes.
            let e = match self.embed_cache.get(&req.spec) {
                Some(e) => e,
                None => {
                    let e = Arc::new(self.backend.embed(&req.spec, self.shard()));
                    self.embed_cache.put(req.spec, Arc::clone(&e));
                    e
                }
            };
            self.embedded = Some(e);
        }
    }
}

impl Handle<rq::SketchEmbed> for Worker {
    /// Sketch tables come from [`CountSketch::new_extendable`] (not
    /// `new`), so the same `(p, seed)` over an appended shard extends
    /// — rather than reshuffles — the column hashing, which is what
    /// lets [`rq::DeltaSketch`] fold only the appended columns onto
    /// the retained accumulator and still match a cold sketch
    /// bit-for-bit.
    fn handle_req(&mut self, rq::SketchEmbed { p, seed }: rq::SketchEmbed) -> Mat {
        let out = if self.streaming() {
            let spec = *self.embed_spec.as_ref().expect("ReqEmbed first");
            let backend = &self.backend;
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new_extendable(self.source.len(), p, &mut rng);
            let mut out = Mat::zeros(spec.t, p);
            self.source.for_each_chunk(self.chunk_rows, |j0, chunk| {
                cs.accumulate_point_axis(&backend.embed(&spec, chunk), j0, &mut out);
            });
            out
        } else {
            let e = Arc::clone(self.embedded.as_ref().expect("ReqEmbed first"));
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new_extendable(e.cols(), p, &mut rng);
            cs.apply_point_axis(&e)
        };
        let cols = self.source.len();
        self.disls_acc = Some(SketchAcc { p, seed, cols, out: out.clone() });
        out
    }
}

impl Handle<rq::Scores> for Worker {
    fn handle_req(&mut self, rq::Scores { z }: rq::Scores) -> f64 {
        let scores = if self.streaming() {
            let spec = self.embed_spec.as_ref().expect("ReqEmbed first");
            let backend = &self.backend;
            let mut scores = Vec::with_capacity(self.source.len());
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                scores.extend(backend.leverage_norms(&z, &backend.embed(spec, chunk)));
            });
            scores
        } else {
            let e: &Mat = self.embedded.as_ref().expect("ReqEmbed first");
            self.backend.leverage_norms(&z, e)
        };
        let total = scores.iter().sum();
        self.scores = Some(scores);
        total
    }
}

impl Handle<rq::Residuals> for Worker {
    fn handle_req(&mut self, rq::Residuals { pts }: rq::Residuals) -> f64 {
        let res = if self.streaming() {
            let y = pts.to_mat();
            let r = self.chol_basis(&y);
            let backend = &self.backend;
            let kernel = self.kernel;
            let mut res = Vec::with_capacity(self.source.len());
            let mut scratch = ChunkScratch::default();
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                let k_ya = backend.gram(kernel, &y, chunk);
                kernel_diag_into(kernel, chunk, &mut scratch.diag);
                res.extend(backend.project_residual(&r, &k_ya, &scratch.diag).1);
            });
            res
        } else {
            self.compute_residuals(&pts.to_mat())
        };
        let total = res.iter().sum();
        self.residuals = Some(res);
        total
    }
}

impl Handle<rq::ProjectSketch> for Worker {
    fn handle_req(&mut self, rq::ProjectSketch { pts, w, seed }: rq::ProjectSketch) -> Mat {
        if self.streaming() {
            let y = pts.to_mat();
            let r = self.chol_basis(&y);
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new(self.source.len(), w, &mut rng);
            let mut out = Mat::zeros(y.cols(), w);
            {
                let backend = &self.backend;
                let kernel = self.kernel;
                let mut scratch = ChunkScratch::default();
                self.source.for_each_chunk(self.chunk_rows, |j0, chunk| {
                    let k_ya = backend.gram(kernel, &y, chunk);
                    kernel_diag_into(kernel, chunk, &mut scratch.diag);
                    let (pi, _) = backend.project_residual(&r, &k_ya, &scratch.diag);
                    cs.accumulate_point_axis(&pi, j0, &mut out);
                });
            }
            self.stream_basis = Some((y, r));
            out
        } else {
            let y = pts.to_mat();
            let (pi, _res, r) = self.project(&y);
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new(pi.cols(), w, &mut rng);
            let sketched = cs.apply_point_axis(&pi);
            self.pi = Some(pi);
            // retain (Y, R) so ReqFinal can install a queryable
            // solution on the resident path too (serving new points)
            self.stream_basis = Some((y, r));
            sketched
        }
    }
}

impl Handle<rq::SketchEmbedR> for Worker {
    /// Tree-gather twin of [`rq::SketchEmbed`]: same sketch compute
    /// (and cache effects), but the reply is the p×t sketch compressed
    /// to its t×t R factor (`RᵀR = sketch·sketchᵀ`), so tree-merged
    /// aggregation preserves the Gram the master needs while each hop
    /// carries O(t²) words instead of O(t·p).
    fn handle_req(&mut self, rq::SketchEmbedR { p, seed }: rq::SketchEmbedR) -> Mat {
        let sketch = <Self as Handle<rq::SketchEmbed>>::handle_req(self, rq::SketchEmbed { p, seed });
        crate::linalg::qr_r_only(&sketch.transpose())
    }
}

impl Handle<rq::ProjectSketchR> for Worker {
    /// Tree-gather twin of [`rq::ProjectSketch`]: identical compute and
    /// state effects (Π / (Y, R) retained for `ReqFinal`), reply
    /// compressed to the |Y|×|Y| R factor of the sketched matrix.
    fn handle_req(&mut self, rq::ProjectSketchR { pts, w, seed }: rq::ProjectSketchR) -> Mat {
        let sketched =
            <Self as Handle<rq::ProjectSketch>>::handle_req(self, rq::ProjectSketch { pts, w, seed });
        crate::linalg::qr_r_only(&sketched.transpose())
    }
}

impl Handle<rq::LoadShard> for Worker {
    /// Elastic shard (re-)assignment: rebuild this worker around a
    /// disk-backed shard, dropping every piece of between-round state
    /// (the recovery layer replays the rounds that rebuild it). The
    /// embed-cache budget survives — it is deployment config, not
    /// round state. IO failure panics and reaches the master as a
    /// typed [`Message::RespError`] via [`Worker::handle`]'s catch.
    fn handle_req(&mut self, rq::LoadShard { path, chunk_rows }: rq::LoadShard) {
        let store = crate::data::ShardStore::open(&path)
            .unwrap_or_else(|e| panic!("LoadShard {path}: {e}"));
        let budget = self.embed_cache.budget_bytes;
        let busy = self.busy;
        *self = Worker::with_source(
            ShardSource::Store(store),
            self.kernel,
            Arc::clone(&self.backend),
            chunk_rows,
        );
        self.embed_cache.budget_bytes = budget;
        self.busy = busy;
    }
}

impl Handle<rq::AdoptShard> for Worker {
    /// Degraded-mode rebalance: append a permanently lost slot's
    /// columns *after* this worker's own and rebuild around the
    /// combined resident shard, dropping every piece of between-round
    /// state like [`rq::LoadShard`] (the re-run rebuilds it). The
    /// own-then-adopted order is load-bearing: it makes the combined
    /// shard equal to the concatenation a fresh cold fit over the
    /// post-rebalance assignment would start from, which is what keeps
    /// the healed solution bit-identical. A non-empty `path` names a
    /// `.dkps` store whose columns are read here (only the path
    /// crossed the wire); otherwise `pts` carries them inline. IO
    /// failure panics and reaches the master as a typed
    /// [`Message::RespError`] via [`Worker::handle`]'s catch.
    fn handle_req(&mut self, rq::AdoptShard { path, pts, chunk_rows }: rq::AdoptShard) {
        let n = self.source.len();
        let own_idx: Vec<usize> = (0..n).collect();
        let own = self.source.point_set(&own_idx);
        let adopted = if path.is_empty() {
            pts
        } else {
            let store = crate::data::ShardStore::open(&path)
                .unwrap_or_else(|e| panic!("AdoptShard {path}: {e}"));
            let source = ShardSource::Store(store);
            let idx: Vec<usize> = (0..source.len()).collect();
            source.point_set(&idx)
        };
        let combined = PointSet::concat(&[own, adopted]);
        let data = match combined {
            PointSet::Dense(m) => Data::Dense(m),
            PointSet::Sparse { d, cols } => {
                Data::Sparse(crate::sparse::Csc::from_columns(d, cols))
            }
        };
        let budget = self.embed_cache.budget_bytes;
        let busy = self.busy;
        *self = Worker::with_source(
            ShardSource::Resident(data),
            self.kernel,
            Arc::clone(&self.backend),
            chunk_rows,
        );
        self.embed_cache.budget_bytes = budget;
        self.busy = busy;
    }
}

impl Handle<rq::RefreshShard> for Worker {
    /// Re-open a disk-backed shard so appends committed since the last
    /// fit become visible, and report the delta relative to the
    /// master's installed epoch (`req.epoch`) as a 1×3 row
    /// `[shard_epoch, delta_cols, n]` — exact small integers, so the
    /// f64 wire encoding is lossless. Resident shards are immutable:
    /// the reply is always `[0, 0, n]`. IO failure panics and reaches
    /// the master as a typed [`Message::RespError`].
    fn handle_req(&mut self, rq::RefreshShard { epoch }: rq::RefreshShard) -> Mat {
        if let ShardSource::Store(store) = &mut self.source {
            store
                .refresh()
                .unwrap_or_else(|e| panic!("RefreshShard {}: {e}", store.path().display()));
        }
        let n = self.source.len();
        let (shard_epoch, delta) = match &self.source {
            ShardSource::Store(store) => {
                let r = store.delta_range(epoch);
                (store.epoch(), r.end - r.start)
            }
            _ => (0, 0),
        };
        let mut m = Mat::zeros(1, 3);
        m[(0, 0)] = shard_epoch as f64;
        m[(0, 1)] = delta as f64;
        m[(0, 2)] = n as f64;
        m
    }
}

impl Handle<rq::DeltaSketch> for Worker {
    /// Incremental twin of [`rq::SketchEmbed`]: return the same full
    /// t×p point-axis sketch of S(φ(Aⁱ)), but fold only the columns
    /// the retained [`SketchAcc`] has not seen. With a matching
    /// accumulator the per-request work is O(delta columns); without
    /// one (fresh or revived worker, or a different `(p, seed)`) the
    /// fold silently restarts from column 0 — the reply is
    /// bit-identical either way, so the master never needs to know
    /// which case it hit. Deliberately the same wire cost as
    /// `ReqSketchEmbed`, so refit and cold-fit word tables line up
    /// row for row.
    fn handle_req(&mut self, rq::DeltaSketch { p, seed }: rq::DeltaSketch) -> Mat {
        let n = self.source.len();
        let out = if self.streaming() {
            let spec = *self.embed_spec.as_ref().expect("ReqEmbed first");
            let (start, mut out) = match self.disls_acc.take() {
                Some(acc) if acc.p == p && acc.seed == seed && acc.cols <= n => {
                    (acc.cols, acc.out)
                }
                _ => (0, Mat::zeros(spec.t, p)),
            };
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new_extendable(n, p, &mut rng);
            {
                let backend = &self.backend;
                self.source.for_each_chunk_from(self.chunk_rows, start, |j0, chunk| {
                    cs.accumulate_point_axis(&backend.embed(&spec, chunk), j0, &mut out);
                });
            }
            out
        } else {
            // Resident shards never grow, but the handler still works
            // there (serving the no-delta and fallback cases) so both
            // paths share one registration point.
            let e = Arc::clone(self.embedded.as_ref().expect("ReqEmbed first"));
            let (start, mut out) = match self.disls_acc.take() {
                Some(acc) if acc.p == p && acc.seed == seed && acc.cols <= n => {
                    (acc.cols, acc.out)
                }
                _ => (0, Mat::zeros(e.rows(), p)),
            };
            let mut rng = Rng::seed_from(seed);
            let cs = CountSketch::new_extendable(n, p, &mut rng);
            if start < n {
                let tail: Vec<usize> = (start..n).collect();
                cs.accumulate_point_axis(&e.select_cols(&tail), start, &mut out);
            }
            out
        };
        self.disls_acc = Some(SketchAcc { p, seed, cols: n, out: out.clone() });
        out
    }
}

impl Handle<rq::Final> for Worker {
    fn handle_req(&mut self, rq::Final { coeffs }: rq::Final) {
        if !self.streaming() {
            // L = Q·W ⇒ Lᵀφ(A) = Wᵀ·Π (Π cached from ReqProjectSketch)
            let pi = self.pi.as_ref().expect("ReqProjectSketch first");
            self.projected = Some(coeffs.matmul_at_b(pi));
        }
        // both paths: install the factored form so ReqProjectPoints
        // can project *new* points through the solution
        let (y, r) = self.stream_basis.clone().expect("ReqProjectSketch first");
        self.stream_solution = Some(StreamSolution::Factored { y, r_upper: r, coeffs });
    }
}

impl Handle<rq::SetSolution> for Worker {
    fn handle_req(&mut self, rq::SetSolution { pts, coeffs }: rq::SetSolution) {
        if self.streaming() {
            self.stream_solution = Some(StreamSolution::Direct { y: pts.to_mat(), coeffs });
        } else {
            // L = φ(Y)·C ⇒ Lᵀφ(A) = Cᵀ·K(Y, A)
            let y = pts.to_mat();
            let k_ya = self.backend.gram(self.kernel, &y, self.shard());
            self.projected = Some(coeffs.matmul_at_b(&k_ya));
            self.stream_solution = Some(StreamSolution::Direct { y, coeffs });
        }
    }
}

impl Handle<rq::ProjectPoints> for Worker {
    /// Serving-path query: LᵀΦ(batch) for a batch of *new* points,
    /// independent of the local shard. Streaming workers fold the
    /// batch over `chunk_rows`-column slices (the PR-2 fold, applied
    /// to the query instead of the shard), so worker memory tracks the
    /// chunk, not the batch; per-column operations are identical, so
    /// results are bit-identical for every chunk size. The master may
    /// pipeline these requests
    /// ([`crate::coordinator::dis_project_points`]): since the worker
    /// loop is strictly recv→handle→send, the next batch sits in the
    /// transport buffer while this one folds through its chunks, so
    /// the chunk I/O of consecutive batches overlaps the master-side
    /// assembly without any worker-side change.
    fn handle_req(&mut self, rq::ProjectPoints { pts }: rq::ProjectPoints) -> Mat {
        let sol = self.stream_solution.as_ref().expect("no solution installed");
        let k = match sol {
            StreamSolution::Factored { coeffs, .. } | StreamSolution::Direct { coeffs, .. } => {
                coeffs.cols()
            }
        };
        let batch = Data::Dense(pts.to_mat());
        let n = batch.len();
        let step = if self.chunk_rows > 0 { self.chunk_rows } else { n.max(1) };
        let mut out = Mat::zeros(k, n);
        let mut scratch = ChunkScratch::default();
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + step).min(n);
            let chunk = batch.slice_cols(j0, j1);
            let proj = projected_chunk(self.backend.as_ref(), self.kernel, sol, &chunk, &mut scratch);
            for j in j0..j1 {
                for i in 0..k {
                    out[(i, j)] = proj[(i, j - j0)];
                }
            }
            j0 = j1;
        }
        out
    }
}

impl Handle<rq::EvalError> for Worker {
    fn handle_req(&mut self, _req: rq::EvalError) -> f64 {
        if self.streaming() {
            let sol = self.stream_solution.as_ref().expect("no solution installed");
            let backend = &self.backend;
            let kernel = self.kernel;
            let mut err = 0.0;
            let mut scratch = ChunkScratch::default();
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                let proj = projected_chunk(backend.as_ref(), kernel, sol, chunk, &mut scratch);
                let norms = proj.col_norms_sq();
                // projected_chunk's contract: scratch.diag now holds
                // this chunk's κ(x,x), whatever the solution variant
                for (&d, &n) in scratch.diag.iter().zip(&norms) {
                    err += (d - n).max(0.0);
                }
            });
            err
        } else {
            let proj = self.projected.as_ref().expect("no solution installed");
            let diag = kernel_diag(self.kernel, self.shard());
            let norms = proj.col_norms_sq();
            diag.iter()
                .zip(&norms)
                .map(|(&d, &n)| (d - n).max(0.0))
                .sum()
        }
    }
}

impl Handle<rq::EvalTrace> for Worker {
    fn handle_req(&mut self, _req: rq::EvalTrace) -> f64 {
        if self.streaming() {
            let kernel = self.kernel;
            let mut trace = 0.0;
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                for v in kernel_diag(kernel, chunk) {
                    trace += v;
                }
            });
            trace
        } else {
            crate::kernels::diag_sum(self.kernel, self.shard())
        }
    }
}

impl Handle<rq::SampleProjected> for Worker {
    fn handle_req(&mut self, rq::SampleProjected { count, seed }: rq::SampleProjected) -> Mat {
        if self.streaming() {
            let sol = self.stream_solution.as_ref().expect("no solution installed");
            let n = self.source.len();
            let mut rng = Rng::seed_from(seed);
            let idx: Vec<usize> = (0..count.min(n)).map(|_| rng.below(n)).collect();
            let sel = self.source.select(&idx);
            let mut scratch = ChunkScratch::default();
            projected_chunk(self.backend.as_ref(), self.kernel, sol, &sel, &mut scratch)
        } else {
            let proj = self.projected.as_ref().expect("no solution installed");
            let n = proj.cols();
            let mut rng = Rng::seed_from(seed);
            let idx: Vec<usize> = (0..count.min(n)).map(|_| rng.below(n)).collect();
            proj.select_cols(&idx)
        }
    }
}

impl Handle<rq::KmeansStep> for Worker {
    fn handle_req(&mut self, rq::KmeansStep { centers }: rq::KmeansStep) -> KmeansPart {
        let (kdim, c) = (centers.rows(), centers.cols());
        let mut sums = Mat::zeros(kdim, c);
        let mut counts = vec![0usize; c];
        let mut obj = 0.0;
        if self.streaming() {
            let sol = self.stream_solution.as_ref().expect("no solution installed");
            let backend = &self.backend;
            let kernel = self.kernel;
            let mut scratch = ChunkScratch::default();
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                let proj = projected_chunk(backend.as_ref(), kernel, sol, chunk, &mut scratch);
                assert_eq!(proj.rows(), kdim);
                kmeans_fold(&proj, &centers, &mut sums, &mut counts, &mut obj);
            });
        } else {
            let proj = self.projected.as_ref().expect("no solution installed");
            assert_eq!(proj.rows(), kdim);
            kmeans_fold(proj, &centers, &mut sums, &mut counts, &mut obj);
        }
        KmeansPart { sums, counts, obj }
    }
}

impl Handle<rq::KrrStats> for Worker {
    fn handle_req(&mut self, rq::KrrStats { pts, teacher_seed }: rq::KrrStats) -> KrrPart {
        let y = pts.to_mat();
        if self.streaming() {
            let ny = y.cols();
            let v = teacher_vector(self.source.dim(), teacher_seed);
            let backend = &self.backend;
            let kernel = self.kernel;
            let mut g = Mat::zeros(ny, ny);
            let mut b = Mat::zeros(ny, 1);
            let mut tnorm = 0.0;
            let mut targets = Vec::with_capacity(self.source.len());
            self.source.for_each_chunk(self.chunk_rows, |_, chunk| {
                let k_ya = backend.gram(kernel, &y, chunk);
                let t_chunk = teacher_targets_chunk(chunk, &v);
                // Per-point rank-1 accumulation in ascending global
                // point order: deterministic and chunk-size
                // invariant. `b`/`tnorm` fold in exactly the
                // resident order; `g` is the one quantity whose
                // resident twin (a blocked matmul) associates its
                // sums differently — see the module docs.
                for (j, &t) in t_chunk.iter().enumerate() {
                    for i in 0..ny {
                        let kij = k_ya[(i, j)];
                        for i2 in 0..ny {
                            g[(i, i2)] += kij * k_ya[(i2, j)];
                        }
                        b[(i, 0)] += kij * t;
                    }
                    tnorm += t * t;
                }
                targets.extend(t_chunk);
            });
            self.krr = Some(KrrState::Streamed { y, targets });
            KrrPart { g, b, tnorm }
        } else {
            let k_ya = self.backend.gram(self.kernel, &y, self.shard());
            let v = teacher_vector(self.source.dim(), teacher_seed);
            let targets = teacher_targets_chunk(self.shard(), &v);
            // g = K_YA·K_AY (|Y|×|Y|), b = K_YA·t (|Y|×1)
            let g = k_ya.matmul_a_bt(&k_ya);
            let mut b = Mat::zeros(y.cols(), 1);
            for i in 0..y.cols() {
                let row = k_ya.row(i);
                b[(i, 0)] = row.iter().zip(&targets).map(|(&k, &t)| k * t).sum();
            }
            let tnorm = targets.iter().map(|&t| t * t).sum();
            self.krr = Some(KrrState::Resident { k_ya, targets });
            KrrPart { g, b, tnorm }
        }
    }
}

impl Handle<rq::KrrEval> for Worker {
    fn handle_req(&mut self, rq::KrrEval { alpha }: rq::KrrEval) -> f64 {
        match self.krr.as_ref().expect("ReqKrrStats first") {
            KrrState::Resident { k_ya, targets } => {
                // pred = αᵀ·K_YA (1×nᵢ)
                let pred = alpha.matmul_at_b(k_ya);
                (0..targets.len())
                    .map(|j| {
                        let e = pred[(0, j)] - targets[j];
                        e * e
                    })
                    .sum()
            }
            KrrState::Streamed { y, targets } => {
                let backend = &self.backend;
                let kernel = self.kernel;
                let mut err = 0.0;
                self.source.for_each_chunk(self.chunk_rows, |j0, chunk| {
                    let k_ya = backend.gram(kernel, y, chunk);
                    let pred = alpha.matmul_at_b(&k_ya);
                    for j in 0..chunk.len() {
                        let e = pred[(0, j)] - targets[j0 + j];
                        err += e * e;
                    }
                });
                err
            }
        }
    }
}

/// LᵀΦ(x) for a column chunk under a streamed solution. Per-column
/// identical to the resident path's cached projection; `scratch` is
/// the pass-scoped [`ChunkScratch`], reused across chunks.
///
/// Contract: on return `scratch.diag` holds κ(xⱼ,xⱼ) for **this**
/// chunk, for every solution variant — callers (the eval fold) may
/// rely on it without knowing which arm ran.
fn projected_chunk(
    backend: &dyn Backend,
    kernel: Kernel,
    sol: &StreamSolution,
    x: &Data,
    scratch: &mut ChunkScratch,
) -> Mat {
    kernel_diag_into(kernel, x, &mut scratch.diag);
    match sol {
        StreamSolution::Factored { y, r_upper, coeffs } => {
            let k_ya = backend.gram(kernel, y, x);
            let (pi, _) = backend.project_residual(r_upper, &k_ya, &scratch.diag);
            coeffs.matmul_at_b(&pi)
        }
        StreamSolution::Direct { y, coeffs } => {
            let k_ya = backend.gram(kernel, y, x);
            coeffs.matmul_at_b(&k_ya)
        }
    }
}

/// One k-means assignment pass over projected columns, folding into
/// shared accumulators — the same per-point operations in the same
/// ascending order whether called once (resident) or per chunk.
fn kmeans_fold(proj: &Mat, centers: &Mat, sums: &mut Mat, counts: &mut [usize], obj: &mut f64) {
    let (kdim, c) = (centers.rows(), centers.cols());
    for j in 0..proj.cols() {
        let mut best = (f64::INFINITY, 0usize);
        for ci in 0..c {
            let mut d2 = 0.0;
            for r in 0..kdim {
                let d = proj[(r, j)] - centers[(r, ci)];
                d2 += d * d;
            }
            if d2 < best.0 {
                best = (d2, ci);
            }
        }
        *obj += best.0;
        counts[best.1] += 1;
        for r in 0..kdim {
            sums[(r, best.1)] += proj[(r, j)];
        }
    }
}

/// The teacher direction v ~ N(0, I/√d) from the shared seed.
fn teacher_vector(d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed);
    let scale = 1.0 / (d as f64).sqrt();
    (0..d).map(|_| rng.normal() * scale).collect()
}

/// Synthetic teacher targets tⱼ = cos(vᵀxⱼ) for a column chunk — a
/// fixed nonlinear function every worker can evaluate locally, so KRR
/// has ground truth without label plumbing. Per-column, so chunked
/// evaluation matches the whole-shard pass bit-for-bit.
fn teacher_targets_chunk(x: &Data, v: &[f64]) -> Vec<f64> {
    let d = x.dim();
    (0..x.len())
        .map(|j| {
            let mut a = 0.0;
            match x {
                Data::Dense(m) => {
                    let c = m.col(j);
                    for r in 0..d {
                        a += v[r] * c[r];
                    }
                }
                Data::Sparse(s) => {
                    for (r, val) in s.col_iter(j) {
                        a += v[r] * val;
                    }
                }
            }
            a.cos()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::PointSet;
    use crate::runtime::NativeBackend;

    fn mk_worker(n: usize) -> Worker {
        mk_worker_chunked(n, 0)
    }

    fn mk_worker_chunked(n: usize, chunk_rows: usize) -> Worker {
        let mut rng = Rng::seed_from(1);
        let shard = Data::Dense(Mat::from_fn(6, n, |_, _| rng.normal()));
        Worker::new_chunked(
            shard,
            Kernel::Gauss { gamma: 0.5 },
            Arc::new(NativeBackend::new()),
            chunk_rows,
        )
    }

    #[test]
    fn protocol_happy_path() {
        let mut w = mk_worker(30);
        assert!(matches!(w.handle(Message::ReqCount), Message::RespCount(30)));
        let spec = EmbedSpec {
            kernel: Kernel::Gauss { gamma: 0.5 },
            m: 256,
            t2: 64,
            t: 16,
            seed: 3,
        };
        assert!(matches!(w.handle(Message::ReqEmbed { spec }), Message::Ack));
        let et = match w.handle(Message::ReqSketchEmbed { p: 20, seed: 5 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((et.rows(), et.cols()), (16, 20));
        // Z from the sketch (as the master would)
        let z = crate::linalg::qr_r_only(&et.transpose());
        let mass = match w.handle(Message::ReqScores { z }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(mass > 0.0);
        let pts = match w.handle(Message::ReqSampleLeverage { count: 5, seed: 7 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        // 5 draws with replacement, deduplicated
        assert!((1..=5).contains(&pts.len()), "{}", pts.len());
        let resid_mass = match w.handle(Message::ReqResiduals { pts: pts.clone() }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(resid_mass >= 0.0);
        let extra = match w.handle(Message::ReqSampleAdaptive { count: 4, seed: 9 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let y = PointSet::concat(&[pts, extra]);
        let ny = y.len();
        let pit = match w.handle(Message::ReqProjectSketch { pts: y.clone(), w: 12, seed: 11 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((pit.rows(), pit.cols()), (ny, 12));
        // fake top-k coefficients: identity on first 3 dims
        let wmat = Mat::from_fn(ny, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matches!(w.handle(Message::ReqFinal { coeffs: wmat }), Message::Ack));
        let err = match w.handle(Message::ReqEvalError) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        let trace = match w.handle(Message::ReqEvalTrace) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(err >= 0.0 && err <= trace + 1e-9, "err {err} trace {trace}");
        assert!((trace - 30.0).abs() < 1e-9); // gauss diag = 1 each
    }

    /// Resident vs streamed: the full request sequence must produce
    /// bit-identical replies for every chunk size (the tentpole
    /// invariant; `tests/streaming_parity.rs` extends this to full
    /// `dis_kpca` over both transports).
    #[test]
    fn streaming_replies_bit_identical_to_resident() {
        let n = 30;
        for chunk in [1, 7, 30, 64] {
            let mut res = mk_worker(n);
            let mut stream = mk_worker_chunked(n, chunk);
            assert!(stream.streaming() && !res.streaming());
            let spec = EmbedSpec {
                kernel: Kernel::Gauss { gamma: 0.5 },
                m: 256,
                t2: 64,
                t: 16,
                seed: 3,
            };
            let mut lockstep = |req: Message| -> (Message, Message) {
                let a = res.handle(req.clone());
                let b = stream.handle(req);
                (a, b)
            };
            lockstep(Message::ReqEmbed { spec });
            let (a, b) = lockstep(Message::ReqSketchEmbed { p: 20, seed: 5 });
            let et = match (a, b) {
                (Message::RespMat(x), Message::RespMat(y)) => {
                    assert!(x.data() == y.data(), "sketch-embed bits differ (chunk={chunk})");
                    x
                }
                other => panic!("{other:?}"),
            };
            let z = crate::linalg::qr_r_only(&et.transpose());
            let (a, b) = lockstep(Message::ReqScores { z });
            match (a, b) {
                (Message::RespScalar(x), Message::RespScalar(y)) => {
                    assert_eq!(x.to_bits(), y.to_bits(), "score mass differs (chunk={chunk})")
                }
                other => panic!("{other:?}"),
            }
            let (a, b) = lockstep(Message::ReqScoresVec);
            match (a, b) {
                (Message::RespMat(x), Message::RespMat(y)) => assert!(x.data() == y.data()),
                other => panic!("{other:?}"),
            }
            let (a, b) = lockstep(Message::ReqSampleLeverage { count: 6, seed: 7 });
            let pts = match (a, b) {
                (Message::RespPoints(x), Message::RespPoints(y)) => {
                    assert!(x.to_mat().data() == y.to_mat().data());
                    x
                }
                other => panic!("{other:?}"),
            };
            let (a, b) = lockstep(Message::ReqResiduals { pts: pts.clone() });
            match (a, b) {
                (Message::RespScalar(x), Message::RespScalar(y)) => {
                    assert_eq!(x.to_bits(), y.to_bits(), "residual mass differs (chunk={chunk})")
                }
                other => panic!("{other:?}"),
            }
            let ny = pts.len();
            let (a, b) = lockstep(Message::ReqProjectSketch { pts, w: 12, seed: 11 });
            match (a, b) {
                (Message::RespMat(x), Message::RespMat(y)) => assert!(x.data() == y.data()),
                other => panic!("{other:?}"),
            }
            let wmat = Mat::from_fn(ny, 2, |i, j| if i == j { 1.0 } else { 0.0 });
            lockstep(Message::ReqFinal { coeffs: wmat });
            for req in [Message::ReqEvalError, Message::ReqEvalTrace] {
                let (a, b) = lockstep(req);
                match (a, b) {
                    (Message::RespScalar(x), Message::RespScalar(y)) => {
                        assert_eq!(x.to_bits(), y.to_bits(), "eval differs (chunk={chunk})")
                    }
                    other => panic!("{other:?}"),
                }
            }
            let (a, b) = lockstep(Message::ReqSampleProjected { count: 4, seed: 2 });
            match (a, b) {
                (Message::RespMat(x), Message::RespMat(y)) => assert!(x.data() == y.data()),
                other => panic!("{other:?}"),
            }
            let (a, b) = lockstep(Message::ReqKmeansStep {
                centers: Mat::from_fn(2, 3, |i, j| (i + j) as f64 * 0.1),
            });
            match (a, b) {
                (
                    Message::RespKmeans { sums: s1, counts: c1, obj: o1 },
                    Message::RespKmeans { sums: s2, counts: c2, obj: o2 },
                ) => {
                    assert!(s1.data() == s2.data());
                    assert_eq!(c1, c2);
                    assert_eq!(o1.to_bits(), o2.to_bits());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn protocol_misuse_surfaces_error_instead_of_killing_worker() {
        let mut w = mk_worker(10);
        // ReqScores before ReqEmbed used to panic the worker thread
        match w.handle(Message::ReqScores { z: Mat::identity(4) }) {
            Message::RespError(msg) => {
                assert!(msg.contains("ReqEmbed first"), "unhelpful error: {msg}")
            }
            other => panic!("expected RespError, got {other:?}"),
        }
        // the worker survives and keeps serving
        assert!(matches!(w.handle(Message::ReqCount), Message::RespCount(10)));
    }

    #[test]
    fn residuals_zero_when_sampled_points_cover_shard() {
        let mut w = mk_worker(8);
        // P = the entire shard ⇒ all residuals ≈ 0
        let all: Vec<usize> = (0..8).collect();
        let pts = match w.handle(Message::ReqSampleUniform { count: 8, seed: 1 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        assert_eq!(pts.len(), all.len());
        let mass = match w.handle(Message::ReqResiduals { pts }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!(mass < 1e-5, "mass {mass}");
    }

    #[test]
    fn set_solution_then_kmeans() {
        let mut w = mk_worker(20);
        // random 4-point solution, orthonormalized coefficients not
        // required for exercising the code path
        let y = match w.handle(Message::ReqSampleUniform { count: 4, seed: 1 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let coeffs = Mat::from_fn(4, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matches!(
            w.handle(Message::ReqSetSolution { pts: y, coeffs }),
            Message::Ack
        ));
        let sample = match w.handle(Message::ReqSampleProjected { count: 3, seed: 2 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((sample.rows(), sample.cols()), (2, 3));
        match w.handle(Message::ReqKmeansStep { centers: sample }) {
            Message::RespKmeans { sums, counts, obj } => {
                assert_eq!(sums.rows(), 2);
                assert_eq!(counts.iter().sum::<usize>(), 20);
                assert!(obj >= 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scores_vec_returns_per_point_scores() {
        let mut w = mk_worker(12);
        let spec = EmbedSpec {
            kernel: Kernel::Gauss { gamma: 0.5 },
            m: 128,
            t2: 64,
            t: 8,
            seed: 3,
        };
        w.handle(Message::ReqEmbed { spec });
        let et = match w.handle(Message::ReqSketchEmbed { p: 16, seed: 5 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        let z = crate::linalg::qr_r_only(&et.transpose());
        let total = match w.handle(Message::ReqScores { z }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        let vec = match w.handle(Message::ReqScoresVec) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((vec.rows(), vec.cols()), (1, 12));
        let sum: f64 = vec.row(0).iter().sum();
        assert!((sum - total).abs() < 1e-9 * total.max(1.0));
        assert!(vec.row(0).iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn krr_stats_then_eval() {
        let mut w = mk_worker(25);
        let y = match w.handle(Message::ReqSampleUniform { count: 6, seed: 4 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let ny = y.len();
        let (g, b, tnorm) = match w.handle(Message::ReqKrrStats { pts: y, teacher_seed: 9 }) {
            Message::RespKrr { g, b, tnorm } => (g, b, tnorm),
            other => panic!("{other:?}"),
        };
        assert_eq!((g.rows(), g.cols()), (ny, ny));
        assert_eq!((b.rows(), b.cols()), (ny, 1));
        // G = K_YA·K_AY is PSD ⇒ nonneg diagonal; targets are cos(·) ⇒
        // ‖t‖² ≤ n
        for i in 0..ny {
            assert!(g[(i, i)] >= -1e-12);
        }
        assert!(tnorm >= 0.0 && tnorm <= 25.0 + 1e-9);
        // evaluating α = 0 must give SSE = ‖t‖²
        let zero = Mat::zeros(ny, 1);
        let sse = match w.handle(Message::ReqKrrEval { alpha: zero }) {
            Message::RespScalar(v) => v,
            other => panic!("{other:?}"),
        };
        assert!((sse - tnorm).abs() < 1e-9 * tnorm.max(1.0), "{sse} vs {tnorm}");
    }

    /// Streamed KRR agrees with resident to FP tolerance (exactly for
    /// b/tnorm/eval; `g` only reassociates) and is chunk-invariant.
    #[test]
    fn krr_streamed_matches_resident_and_chunk_invariant() {
        let run = |chunk: usize| {
            let mut w = mk_worker_chunked(25, chunk);
            let y = match w.handle(Message::ReqSampleUniform { count: 6, seed: 4 }) {
                Message::RespPoints(p) => p,
                other => panic!("{other:?}"),
            };
            let ny = y.len();
            let (g, b, tnorm) = match w.handle(Message::ReqKrrStats { pts: y, teacher_seed: 9 }) {
                Message::RespKrr { g, b, tnorm } => (g, b, tnorm),
                other => panic!("{other:?}"),
            };
            let sse = match w.handle(Message::ReqKrrEval { alpha: Mat::zeros(ny, 1) }) {
                Message::RespScalar(v) => v,
                other => panic!("{other:?}"),
            };
            (g, b, tnorm, sse)
        };
        let (g0, b0, t0, s0) = run(0);
        let (g7, b7, t7, s7) = run(7);
        let (g99, b99, ..) = run(99);
        // streamed-vs-streamed: bit-identical for every chunk size
        assert!(g7.data() == g99.data(), "streamed g must be chunk-invariant");
        assert!(b7.data() == b99.data());
        // streamed-vs-resident: b/tnorm/sse bitwise, g to tolerance
        assert!(b0.data() == b7.data(), "b must match resident bitwise");
        assert_eq!(t0.to_bits(), t7.to_bits());
        assert_eq!(s0.to_bits(), s7.to_bits());
        assert!(g0.max_abs_diff(&g7) < 1e-9 * (1.0 + g0.frob_norm()));
    }

    #[test]
    fn embed_cache_reuses_and_evicts_lru_by_byte_budget() {
        let mut w = mk_worker(20);
        let spec1 =
            EmbedSpec { kernel: Kernel::Gauss { gamma: 0.5 }, m: 128, t2: 64, t: 8, seed: 1 };
        let spec2 = EmbedSpec { seed: 2, ..spec1 };
        let entry_bytes = 8 * 20 * 8; // t×nᵢ f64s
        w.handle(Message::ReqEmbed { spec: spec1 });
        w.handle(Message::ReqEmbed { spec: spec2 });
        let (len, bytes, hits, misses) = w.embed_cache_stats();
        assert_eq!((len, bytes, hits, misses), (2, 2 * entry_bytes, 0, 2));
        // re-install spec1: a warm hit, embedding bit-identical to the
        // first build (shared Arc — not merely equal)
        w.handle(Message::ReqEmbed { spec: spec1 });
        assert_eq!(w.embed_cache_stats().2, 1, "second install must hit the cache");
        // shrinking the budget to one entry evicts the LRU (spec2)
        w.set_embed_cache_budget(entry_bytes);
        assert_eq!(w.embed_cache_stats().0, 1);
        w.handle(Message::ReqEmbed { spec: spec2 });
        let (len, _, _, misses) = w.embed_cache_stats();
        assert_eq!((len, misses), (1, 3), "evicted spec must re-miss");
        // the cache never held more than the budget
        assert!(w.embed_cache_stats().1 <= entry_bytes);
        // zero budget disables caching entirely
        w.set_embed_cache_budget(0);
        w.handle(Message::ReqEmbed { spec: spec1 });
        assert_eq!(w.embed_cache_stats().0, 0);
        // worker still serves with an uncached embedding installed
        assert!(matches!(
            w.handle(Message::ReqSketchEmbed { p: 12, seed: 5 }),
            Message::RespMat(_)
        ));
    }

    /// The serving query path: new points project identically whether
    /// the worker is resident or streams the batch in chunks, and the
    /// result matches the solution's own projection identity.
    #[test]
    fn project_points_resident_and_chunked_bit_identical() {
        let run = |chunk: usize| {
            let mut w = mk_worker_chunked(30, chunk);
            let spec = EmbedSpec {
                kernel: Kernel::Gauss { gamma: 0.5 },
                m: 256,
                t2: 64,
                t: 16,
                seed: 3,
            };
            w.handle(Message::ReqEmbed { spec });
            let et = match w.handle(Message::ReqSketchEmbed { p: 20, seed: 5 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            let z = crate::linalg::qr_r_only(&et.transpose());
            w.handle(Message::ReqScores { z });
            let pts = match w.handle(Message::ReqSampleLeverage { count: 6, seed: 7 }) {
                Message::RespPoints(p) => p,
                other => panic!("{other:?}"),
            };
            let ny = pts.len();
            w.handle(Message::ReqProjectSketch { pts, w: 12, seed: 11 });
            let wmat = Mat::from_fn(ny, 2, |i, j| if i == j { 1.0 } else { 0.0 });
            w.handle(Message::ReqFinal { coeffs: wmat });
            // fresh query points, never seen by the protocol
            let mut rng = Rng::seed_from(77);
            let batch = PointSet::Dense(Mat::from_fn(6, 9, |_, _| rng.normal()));
            match w.handle(Message::ReqProjectPoints { pts: batch }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            }
        };
        let resident = run(0);
        assert_eq!((resident.rows(), resident.cols()), (2, 9));
        for chunk in [1, 4, 9, 64] {
            let streamed = run(chunk);
            assert!(
                resident.data() == streamed.data(),
                "ProjectPoints differs at chunk={chunk}"
            );
        }
    }

    #[test]
    fn project_points_works_after_set_solution() {
        let mut w = mk_worker(20);
        let y = match w.handle(Message::ReqSampleUniform { count: 4, seed: 1 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        let ny = y.len();
        let coeffs = Mat::from_fn(ny, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        w.handle(Message::ReqSetSolution { pts: y, coeffs });
        let mut rng = Rng::seed_from(5);
        let batch = PointSet::Dense(Mat::from_fn(6, 3, |_, _| rng.normal()));
        let proj = match w.handle(Message::ReqProjectPoints { pts: batch }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((proj.rows(), proj.cols()), (2, 3));
        assert!(proj.data().iter().all(|v| v.is_finite()));
        // empty batch → k×0, not an error
        let empty = match w.handle(Message::ReqProjectPoints {
            pts: PointSet::Dense(Mat::zeros(6, 0)),
        }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!((empty.rows(), empty.cols()), (2, 0));
    }

    /// DeltaSketch with no delta (and with a mismatched key) replies
    /// bit-identically to SketchEmbed on both paths — the master can
    /// swap one for the other without touching the numbers.
    #[test]
    fn delta_sketch_matches_full_sketch_and_survives_key_mismatch() {
        for chunk in [0usize, 7] {
            let mut w = mk_worker_chunked(26, chunk);
            let spec = EmbedSpec {
                kernel: Kernel::Gauss { gamma: 0.5 },
                m: 256,
                t2: 64,
                t: 16,
                seed: 3,
            };
            w.handle(Message::ReqEmbed { spec });
            let full = match w.handle(Message::ReqSketchEmbed { p: 20, seed: 5 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            // matching (p, seed): zero-delta fold off the accumulator
            let delta = match w.handle(Message::ReqDeltaSketch { p: 20, seed: 5 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            assert!(full.data() == delta.data(), "no-delta refit differs (chunk={chunk})");
            // mismatched seed: silent full re-fold, not an error, and
            // it matches what SketchEmbed would have returned
            let refold = match w.handle(Message::ReqDeltaSketch { p: 20, seed: 6 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            let mut fresh = mk_worker_chunked(26, chunk);
            fresh.handle(Message::ReqEmbed { spec });
            let expect = match fresh.handle(Message::ReqSketchEmbed { p: 20, seed: 6 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            assert!(refold.data() == expect.data(), "mismatch fallback differs (chunk={chunk})");
        }
    }

    /// The incremental contract end to end at the worker level: sketch
    /// a store-backed shard, append columns through a second handle,
    /// refresh, and the delta fold must be bit-identical to a cold
    /// worker sketching the appended store from scratch.
    #[test]
    fn delta_sketch_after_append_bit_identical_to_cold() {
        let path = std::env::temp_dir().join("diskpca_worker_delta.dkps");
        let mut rng = Rng::seed_from(42);
        let base = Data::Dense(Mat::from_fn(6, 21, |_, _| rng.normal()));
        let extra = Data::Dense(Mat::from_fn(6, 4, |_, _| rng.normal()));
        crate::data::shard_store::write(&base, &path, 8).unwrap();
        let mk = |chunk: usize| {
            Worker::with_source(
                ShardSource::Store(crate::data::ShardStore::open(&path).unwrap()),
                Kernel::Gauss { gamma: 0.5 },
                Arc::new(NativeBackend::new()),
                chunk,
            )
        };
        let spec =
            EmbedSpec { kernel: Kernel::Gauss { gamma: 0.5 }, m: 256, t2: 64, t: 16, seed: 3 };
        let mut warm = mk(5);
        warm.handle(Message::ReqEmbed { spec });
        warm.handle(Message::ReqSketchEmbed { p: 20, seed: 5 });
        // append through a second handle, as a producer process would
        let mut producer = crate::data::ShardStore::open(&path).unwrap();
        producer.append(&extra).unwrap();
        // refresh reports the new epoch and the delta vs epoch 0
        let report = match warm.handle(Message::ReqRefreshShard { epoch: 0 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!(
            (report[(0, 0)], report[(0, 1)], report[(0, 2)]),
            (1.0, 4.0, 25.0),
            "refresh report wrong"
        );
        assert!(matches!(warm.handle(Message::ReqCount), Message::RespCount(25)));
        let warm_sketch = match warm.handle(Message::ReqDeltaSketch { p: 20, seed: 5 }) {
            Message::RespMat(m) => m,
            other => panic!("{other:?}"),
        };
        // cold worker over the appended store, full sketch — and a
        // second chunk size, since the fold must be chunk-invariant
        for chunk in [5usize, 3] {
            let mut cold = mk(chunk);
            cold.handle(Message::ReqEmbed { spec });
            let cold_sketch = match cold.handle(Message::ReqSketchEmbed { p: 20, seed: 5 }) {
                Message::RespMat(m) => m,
                other => panic!("{other:?}"),
            };
            assert!(
                warm_sketch.data() == cold_sketch.data(),
                "delta fold differs from cold sketch (chunk={chunk})"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn uniform_sample_capped_at_shard_size() {
        let mut w = mk_worker(5);
        let pts = match w.handle(Message::ReqSampleUniform { count: 50, seed: 3 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        assert_eq!(pts.len(), 5);
    }
}
