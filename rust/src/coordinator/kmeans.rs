//! Distributed k-means over the KPCA projection — the paper's
//! spectral-clustering downstream application (§6.6, Fig. 8).
//!
//! Workers hold LᵀΦ(xⱼ) ∈ R^k (installed by disKPCA's ReqFinal or a
//! baseline's ReqSetSolution); the master seeds centers from a
//! projected sample and runs Lloyd iterations where each round costs
//! O(s·k·c) words (centers down, sums/counts up).
//!
//! The reported objective is the exact feature-space k-means cost
//! restricted to centers in span(L):
//!   ‖φ(x) − L·c‖² = (κ(x,x) − ‖LᵀΦ(x)‖²) + ‖LᵀΦ(x) − c‖²
//! i.e. `kpca residual + projected k-means objective` — both terms are
//! computed distributedly.

use crate::comm::request as rq;
use crate::comm::{Cluster, CommError};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Result of a distributed k-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// kdim×c final centers (projected space).
    pub centers: Mat,
    /// Σⱼ minᶜ ‖zⱼ − c‖² (projected space).
    pub projected_obj: f64,
    /// Σⱼ κ(xⱼ,xⱼ) − ‖zⱼ‖² (the KPCA residual term).
    pub residual: f64,
    /// iterations actually run.
    pub iters: usize,
}

impl KmeansResult {
    /// Exact feature-space objective (see module docs), averaged.
    pub fn feature_space_obj(&self, n: usize) -> f64 {
        (self.projected_obj + self.residual) / n as f64
    }
}

/// Lloyd's algorithm over the cluster. A solution must already be
/// installed on the workers.
pub fn distributed_kmeans(
    cluster: &Cluster,
    c: usize,
    max_iters: usize,
    seed: u64,
) -> Result<KmeansResult, CommError> {
    let sx = cluster.session("7-kmeans");
    let mut rng = Rng::seed_from(seed ^ 0x4a3a);
    // ---- seeding: oversample projected points, pick c spread ones ----
    let over = (3 * c).max(c + 2);
    let s = sx.num_workers();
    let parts: Vec<Mat> = sx.scatter(
        (0..s)
            .map(|i| rq::SampleProjected {
                count: over.div_ceil(s),
                seed: seed ^ (0x5eed + i as u64),
            })
            .collect(),
    )?;
    let mut pool: Option<Mat> = None;
    for part in parts {
        if part.cols() == 0 {
            continue;
        }
        pool = Some(match pool {
            None => part,
            Some(acc) => acc.hcat(&part),
        });
    }
    let pool = pool.ok_or_else(|| CommError::Protocol {
        round: "7-kmeans".into(),
        detail: "every worker returned an empty projected sample (no data to seed centers)".into(),
    })?;
    // greedy farthest-point from the pool (k-means++ flavoured, exact
    // distances over the small pool)
    let mut chosen = vec![rng.below(pool.cols())];
    while chosen.len() < c.min(pool.cols()) {
        let mut best = (f64::NEG_INFINITY, 0);
        for j in 0..pool.cols() {
            let mut dmin = f64::INFINITY;
            for &ci in &chosen {
                let mut d2 = 0.0;
                for r in 0..pool.rows() {
                    let d = pool[(r, j)] - pool[(r, ci)];
                    d2 += d * d;
                }
                dmin = dmin.min(d2);
            }
            if dmin > best.0 {
                best = (dmin, j);
            }
        }
        chosen.push(best.1);
    }
    let mut centers = pool.select_cols(&chosen);

    // ---- Lloyd iterations ----
    let mut last_obj = f64::INFINITY;
    let mut obj = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iters {
        let replies = sx.broadcast(rq::KmeansStep { centers: centers.clone() })?;
        let kdim = centers.rows();
        let mut sums = Mat::zeros(kdim, centers.cols());
        let mut counts = vec![0usize; centers.cols()];
        obj = 0.0;
        for part in replies {
            sums.add_assign(&part.sums);
            for (a, b) in counts.iter_mut().zip(&part.counts) {
                *a += b;
            }
            obj += part.obj;
        }
        for ci in 0..centers.cols() {
            if counts[ci] > 0 {
                for r in 0..kdim {
                    centers[(r, ci)] = sums[(r, ci)] / counts[ci] as f64;
                }
            }
        }
        iters = it + 1;
        if last_obj - obj < 1e-9 * obj.abs().max(1e-12) {
            break;
        }
        last_obj = obj;
    }

    // residual term via the standard eval round
    let residual = sx.broadcast(rq::EvalError)?.into_iter().sum();

    Ok(KmeansResult { centers, projected_obj: obj, residual, iters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{dis_kpca, run_cluster, Params};
    use crate::data::{partition_power_law, Data};
    use crate::kernels::Kernel;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    #[test]
    fn spectral_clustering_on_separated_clusters() {
        let mut rng = Rng::seed_from(21);
        let data = Data::Dense(crate::data::clusters(10, 240, 3, 0.08, &mut rng));
        let n = data.len();
        let shards = partition_power_law(&data, 4, 2);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let params = Params {
            k: 3,
            t: 16,
            p: 40,
            n_lev: 12,
            n_adapt: 30,
            m_rff: 512,
            t2: 128,
            w: 0,
            seed: 23,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        };
        let (result, stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _sol = dis_kpca(cluster, kernel, &params).unwrap();
                distributed_kmeans(cluster, 3, 25, 31).unwrap()
            },
        );
        assert!(result.iters >= 1);
        assert_eq!(result.centers.cols(), 3);
        // well-separated clusters ⇒ tiny within-cluster variance in
        // the projected space relative to the total mass
        let avg = result.feature_space_obj(n);
        assert!(avg < 0.5, "avg feature-space objective {avg}");
        assert!(stats.round_words("7-kmeans") > 0);
    }

    #[test]
    fn kmeans_objective_monotone_nonincreasing() {
        let mut rng = Rng::seed_from(33);
        let data = Data::Dense(crate::data::clusters(8, 160, 4, 0.3, &mut rng));
        let shards = partition_power_law(&data, 3, 5);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let params = Params {
            k: 4,
            t: 16,
            p: 40,
            n_lev: 10,
            n_adapt: 20,
            m_rff: 256,
            t2: 128,
            w: 0,
            seed: 3,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        };
        // run twice with different iteration caps — more Lloyd steps
        // can't increase the (deterministic) objective
        let mut objs = Vec::new();
        for iters in [1usize, 20] {
            let shards = shards.clone();
            let (res, _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| {
                    let _ = dis_kpca(cluster, kernel, &params).unwrap();
                    distributed_kmeans(cluster, 4, iters, 77).unwrap()
                },
            );
            objs.push(res.projected_obj);
        }
        assert!(objs[1] <= objs[0] + 1e-9, "{objs:?}");
    }
}
