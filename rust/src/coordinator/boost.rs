//! Success-probability boosting by repetition (paper Theorem 1
//! remark: "The constant success probability can be boosted up to any
//! high probability 1−δ by repetition, which adds only an extra
//! O(log 1/δ) term to communication and computation.")
//!
//! [`dis_kpca_boosted`] runs disKPCA `reps` times with independent
//! derived seeds, evaluates each candidate with the exact distributed
//! error round, and keeps the best. The communication multiplies by
//! `reps` — the accounting picks this up automatically because every
//! repetition's rounds go through the same [`crate::comm::CommStats`].

use crate::comm::{Cluster, CommError};
use crate::kernels::Kernel;

use super::master::{dis_eval, dis_kpca, dis_set_solution};
use super::{KpcaSolution, Params};

/// Number of repetitions for failure probability ≤ δ given the base
/// algorithm's 0.99 success rate: each repetition independently fails
/// with probability ≤ 0.01, and we can *verify* candidates exactly via
/// `dis_eval`, so r = ⌈log(δ)/log(0.01)⌉ repetitions suffice.
pub fn reps_for_confidence(delta: f64) -> usize {
    assert!(delta > 0.0 && delta < 1.0);
    (delta.ln() / 0.01f64.ln()).ceil().max(1.0) as usize
}

/// Outcome of a boosted run: the winning solution plus the per-attempt
/// errors (useful for reporting the boost's effect).
#[derive(Clone, Debug)]
pub struct BoostedRun {
    pub solution: KpcaSolution,
    /// ‖φ(A) − LLᵀφ(A)‖² of each attempt, in attempt order.
    pub errors: Vec<f64>,
    /// index into `errors` of the winner (minimum error).
    pub winner: usize,
    /// tr K (shared across attempts — same data).
    pub trace: f64,
}

/// Run disKPCA `reps` times with independent seeds; return the
/// attempt with the smallest exact approximation error.
pub fn dis_kpca_boosted(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    reps: usize,
) -> Result<BoostedRun, CommError> {
    assert!(reps >= 1);
    let mut best: Option<(f64, KpcaSolution)> = None;
    let mut errors = Vec::with_capacity(reps);
    let mut trace = 0.0;
    for r in 0..reps {
        // splitmix-style seed derivation keeps attempts independent
        let attempt = Params {
            seed: params.seed.wrapping_add(0x9e3779b97f4a7c15u64.wrapping_mul(r as u64 + 1)),
            ..*params
        };
        let sol = dis_kpca(cluster, kernel, &attempt)?;
        let (err, tr) = dis_eval(cluster)?;
        errors.push(err);
        trace = tr;
        if best.as_ref().map_or(true, |(b, _)| err < *b) {
            best = Some((err, sol));
        }
    }
    let (_, solution) = best.expect("reps >= 1 attempts ran");
    // leave the winner installed on the workers (the last attempt may
    // not be the winner).
    dis_set_solution(cluster, &solution)?;
    let winner = errors
        .iter()
        .enumerate()
        // total_cmp: a NaN attempt error (degenerate shard) must not
        // panic the winner selection
        .min_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    Ok(BoostedRun { solution, errors, winner, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_cluster;
    use crate::data::{partition_power_law, Data};
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    #[test]
    fn reps_formula() {
        assert_eq!(reps_for_confidence(0.01), 1);
        assert_eq!(reps_for_confidence(1e-4), 2);
        assert_eq!(reps_for_confidence(1e-6), 3);
    }

    #[test]
    fn boosted_beats_or_ties_every_attempt() {
        let mut rng = Rng::seed_from(21);
        let data = Data::Dense(crate::data::clusters(8, 160, 4, 0.2, &mut rng));
        let shards = partition_power_law(&data, 3, 5);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let params = Params {
            k: 4,
            t: 16,
            p: 40,
            n_lev: 10,
            n_adapt: 16,
            w: 0,
            m_rff: 256,
            t2: 128,
            seed: 77,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        };
        let ((run, final_err), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let run = dis_kpca_boosted(cluster, kernel, &params, 3).unwrap();
                let (err, _) = dis_eval(cluster).unwrap();
                (run, err)
            },
        );
        assert_eq!(run.errors.len(), 3);
        let best = run.errors.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(run.errors[run.winner], best);
        // the installed solution must be the winner, not the last try
        assert!(
            (final_err - best).abs() < 1e-6 * run.trace,
            "installed {final_err} vs best {best}"
        );
        // winning error from the data's perspective too
        let local = run.solution.eval_error(&data);
        assert!((local - best).abs() < 1e-6 * run.trace);
    }

    #[test]
    fn boosting_never_hurts() {
        let mut rng = Rng::seed_from(22);
        let data = Data::Dense(crate::data::clusters(6, 120, 4, 0.25, &mut rng));
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let params = Params {
            k: 3,
            t: 12,
            p: 30,
            n_lev: 8,
            n_adapt: 10,
            w: 0,
            m_rff: 128,
            t2: 64,
            seed: 5,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        };
        // single run error
        let shards = partition_power_law(&data, 3, 6);
        let (single, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap().0
            },
        );
        // boosted (first attempt uses a derived seed, so compare via
        // min: the boosted error is the min over its own attempts)
        let shards = partition_power_law(&data, 3, 6);
        let (run, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_kpca_boosted(cluster, kernel, &params, 4).unwrap(),
        );
        let boosted = run.errors[run.winner];
        // across 4 independent attempts, the min is very unlikely to
        // be more than marginally worse than any single reference run
        assert!(boosted <= single * 1.10, "boosted {boosted} single {single}");
    }
}
