//! The paper's system contribution: communication-efficient
//! distributed kernel PCA (master–worker, arbitrary partition).
//!
//! - [`master`] — the four protocol drivers (Algs. 1–4)
//! - [`worker`] — the worker state machine
//! - [`baselines`] — uniform+disLR, uniform+batch-KPCA, batch KPCA
//! - [`kmeans`] — distributed k-means / spectral clustering (§6.6)
//! - [`run_cluster`] — spawn worker threads + run a master closure
//!
//! Every `dis_*` entry point returns
//! `Result<_, `[`crate::comm::CommError`]`>`: a worker failure aborts
//! the round with the worker index and round label attached, and the
//! cluster's drop guard releases the remaining workers.

pub mod baselines;
pub mod boost;
pub mod css;
pub mod kmeans;
pub mod krr;
pub mod master;
pub mod related;
pub mod worker;

pub use baselines::{batch_kpca, uniform_batch_kpca, uniform_dis_lr, BatchKpca};
pub use boost::{dis_kpca_boosted, reps_for_confidence, BoostedRun};
pub use css::{dis_css, dis_css_warm, CssSolution};
pub use krr::{dis_krr, KrrModel};
pub use master::{
    choose_k, dis_embed, dis_eval, dis_kpca, dis_kpca_mode, dis_kpca_refit, dis_kpca_warm,
    dis_leverage_scores, dis_leverage_scores_delta, dis_leverage_scores_eps,
    dis_leverage_scores_z, dis_leverage_vectors, dis_low_rank, dis_low_rank_frac, dis_low_rank_w,
    dis_project_points, dis_refresh_shards, dis_set_solution, embed_spec_for,
    leverage_sketch_width, rep_sample, rep_sample_mode, tsqr_merge, RefitReport, SamplingMode,
};
pub use worker::Worker;

use std::sync::Arc;

use crate::comm::{memory, Cluster, CommStats};
use crate::data::Data;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::Backend;

/// How the master aggregates the two sketch-gather rounds (disLS's
/// embedded sketches, disLR's projected sketches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherMode {
    /// Historical star gather: every worker ships its full t×p sketch
    /// and the master concatenates — O(s·t·p) master words, O(s)
    /// master merge cost.
    Flat,
    /// TSQR-style tree merge: each worker compresses its sketch to the
    /// t×t R factor of its transpose (same Gram, `RᵀR = S·Sᵀ`) and the
    /// master reduces the R factors pairwise in a binary tree —
    /// O(s·t²) words and an O(log s) critical path. Deterministic for
    /// a fixed `s`, but *not* bit-identical to [`GatherMode::Flat`]
    /// (the two associate floating-point sums differently).
    Tree,
}

impl Default for GatherMode {
    fn default() -> Self {
        GatherMode::Flat
    }
}

/// Tunables for disKPCA (paper §6.2 defaults unless noted).
#[derive(Clone, Copy, Debug)]
pub struct Params {
    /// number of principal components (paper: 10).
    pub k: usize,
    /// kernel-subspace-embedding dim t = O(k) (paper: 50; our XLA
    /// grid bakes 64).
    pub t: usize,
    /// disLS right-sketch columns p = O(t) (paper: 250).
    pub p: usize,
    /// leverage samples |P| = O(k log k) (paper: part of |Y|).
    pub n_lev: usize,
    /// adaptive samples |Ŷ| = O(k/ε) (paper sweeps 50–400).
    pub n_adapt: usize,
    /// disLR sketch columns w (0 ⇒ |Y|, the paper's setting).
    pub w: usize,
    /// random features m for shift-invariant/arc-cos kernels
    /// (paper: 2000; our XLA grid bakes 512).
    pub m_rff: usize,
    /// TensorSketch dim t₂ for polynomial kernels.
    pub t2: usize,
    /// master seed — every random choice derives from it.
    pub seed: u64,
    /// compute threads for the [`crate::par`] pool (`--threads`).
    /// 0 = leave the process-wide pool setting untouched. Results are
    /// bit-identical for every value — only wall time changes.
    pub threads: usize,
    /// worker streaming chunk width in points (`--chunk-rows`).
    /// 0 = resident path (full intermediates cached in memory); N > 0
    /// makes every worker per-point pass fold over N-point chunks, so
    /// worker matrix memory is bounded by N instead of the shard
    /// size. Results are bit-identical for every value — see
    /// [`worker`] module docs.
    pub chunk_rows: usize,
    /// sketch-aggregation topology (`--gather`): [`GatherMode::Flat`]
    /// reproduces the paper's star gather; [`GatherMode::Tree`] trades
    /// bit-compatibility with it for O(log s) master critical path.
    pub gather: GatherMode,
}

impl Default for Params {
    fn default() -> Self {
        Self {
            k: 10,
            t: 64,
            p: 250,
            n_lev: 50,
            n_adapt: 200,
            w: 0,
            m_rff: 512,
            t2: 512,
            seed: 0xd15c,
            threads: 0,
            chunk_rows: 0,
            gather: GatherMode::Flat,
        }
    }
}

impl Params {
    /// Apply this config's thread count to the global [`crate::par`]
    /// pool (no-op when `threads == 0`). Called at every protocol
    /// entry point so `--threads` flows through to worker compute.
    pub fn apply_threads(&self) {
        if self.threads > 0 {
            crate::par::set_threads(self.threads);
        }
    }
}

/// The output of disKPCA: k components L = φ(Y)·C represented by the
/// |Y| sampled points and a coefficient matrix (paper Thm 1 remark).
#[derive(Clone, Debug)]
pub struct KpcaSolution {
    pub kernel: Kernel,
    /// d×|Y| representative points.
    pub y: Mat,
    /// |Y|×k coefficients; LᵀL = I by construction.
    pub coeffs: Mat,
}

impl KpcaSolution {
    pub fn num_points(&self) -> usize {
        self.y.cols()
    }

    pub fn k(&self) -> usize {
        self.coeffs.cols()
    }

    /// Project points onto the components: LᵀΦ(x) = Cᵀ·K(Y, x) — k×n.
    pub fn project(&self, x: &Data) -> Mat {
        let k_yx = crate::kernels::gram(self.kernel, &self.y, x);
        self.coeffs.matmul_at_b(&k_yx)
    }

    /// Exact ‖φ(x) − LLᵀφ(x)‖² summed over a dataset (single-machine
    /// evaluation; the distributed path is `master::dis_eval`).
    pub fn eval_error(&self, x: &Data) -> f64 {
        let proj = self.project(x);
        let norms = proj.col_norms_sq();
        crate::kernels::diag(self.kernel, x)
            .iter()
            .zip(&norms)
            .map(|(&d, &n)| (d - n).max(0.0))
            .sum()
    }
}

/// Spawn `shards.len()` worker threads over the in-memory transport,
/// run `body` against the cluster, join, and return the body's output
/// plus the communication stats.
///
/// The master drivers fan every round out with non-blocking sends
/// before gathering replies ([`crate::comm::Cluster::broadcast`] /
/// [`crate::comm::Cluster::scatter`]), so all `s` workers execute
/// their local phase concurrently; inside each phase the heavy math
/// additionally runs on the shared [`crate::par`] pool. Round word
/// counts are independent of both kinds of parallelism.
pub fn run_cluster<T: Send + 'static>(
    shards: Vec<Data>,
    kernel: Kernel,
    backend: Arc<dyn Backend>,
    body: impl FnOnce(&Cluster) -> T,
) -> (T, CommStats) {
    run_cluster_chunked(shards, kernel, backend, 0, body)
}

/// [`run_cluster`] with streaming workers: `chunk_rows > 0` makes
/// every worker fold its per-point passes over `chunk_rows`-point
/// chunks (`Params::chunk_rows` / `--chunk-rows`). `0` is the
/// resident path; results and per-round comm words are bit-identical
/// for every value.
pub fn run_cluster_chunked<T: Send + 'static>(
    shards: Vec<Data>,
    kernel: Kernel,
    backend: Arc<dyn Backend>,
    chunk_rows: usize,
    body: impl FnOnce(&Cluster) -> T,
) -> (T, CommStats) {
    let (star, endpoints) = memory::star(shards.len());
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = backend.clone();
            std::thread::spawn(move || Worker::new_chunked(shard, kernel, be, chunk_rows).run(ep))
        })
        .collect();
    let out = body(&cluster);
    cluster.shutdown();
    for h in handles {
        h.join().expect("worker panicked");
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_power_law, Data};
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;

    fn cluster_low_rank_data(n: usize, d: usize) -> Data {
        let mut rng = Rng::seed_from(42);
        Data::Dense(crate::data::clusters(d, n, 4, 0.15, &mut rng))
    }

    fn small_params() -> Params {
        Params {
            k: 4,
            t: 16,
            p: 40,
            n_lev: 12,
            n_adapt: 24,
            w: 0,
            m_rff: 256,
            t2: 128,
            seed: 7,
            threads: 0,
            chunk_rows: 0,
            gather: GatherMode::Flat,
        }
    }

    #[test]
    fn diskpca_end_to_end_gauss() {
        let data = cluster_low_rank_data(200, 8);
        let shards = partition_power_law(&data, 4, 1);
        let kernel = Kernel::Gauss { gamma: 0.8 };
        let params = small_params();
        let ((sol, err, trace), stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = dis_kpca(cluster, kernel, &params).unwrap();
                let (err, trace) = dis_eval(cluster).unwrap();
                (sol, err, trace)
            },
        );
        assert_eq!(sol.k(), 4);
        assert!(sol.num_points() >= 12 && sol.num_points() <= 12 + 24);
        // distributed eval must match single-machine eval of the
        // returned solution
        let local_err = sol.eval_error(&data);
        assert!(
            (err - local_err).abs() < 1e-6 * trace,
            "dis {err} vs local {local_err}"
        );
        // 4 tight clusters, k=4, gaussian kernel ⇒ relative error
        // well below the trivial solution (err = trace for L = 0).
        assert!(err / trace < 0.35, "relative error {}", err / trace);
        // communication accounting: every round present
        for round in ["1-embed", "2-disLS", "3-levSample", "4-adaptive", "5-disLR", "6-eval"] {
            assert!(stats.round_words(round) > 0, "round {round} missing");
        }
    }

    #[test]
    fn diskpca_poly_kernel() {
        let data = cluster_low_rank_data(150, 6);
        let shards = partition_power_law(&data, 3, 2);
        let kernel = Kernel::Poly { q: 2 };
        let params = small_params();
        let ((err, trace), _stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _sol = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err >= 0.0 && err < trace, "err {err} trace {trace}");
        assert!(err / trace < 0.5, "poly relative error {}", err / trace);
    }

    #[test]
    fn diskpca_arccos_kernel_sparse_data() {
        let mut rng = Rng::seed_from(3);
        let data = Data::Sparse(crate::data::zipf_sparse(300, 120, 20, &mut rng));
        let shards = partition_power_law(&data, 3, 3);
        let kernel = Kernel::ArcCos { degree: 2 };
        let params = small_params();
        let ((err, trace), stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err >= -1e-6 && err < trace);
        // sparse points must be shipped sparse: the sampling rounds
        // cost ≪ dense d×|Y| words
        let sample_words = stats.round_words("3-levSample") + stats.round_words("4-adaptive");
        let dense_cost = 300 * (12 + 24) * 4; // d × |Y| × (s bcasts)
        assert!(sample_words < dense_cost, "{sample_words} vs {dense_cost}");
    }

    #[test]
    fn diskpca_laplace_kernel() {
        let data = cluster_low_rank_data(150, 6);
        let shards = partition_power_law(&data, 3, 7);
        let kernel = Kernel::Laplace { gamma: 0.5 };
        let params = small_params();
        let ((err, trace), _stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _sol = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err >= 0.0 && err < trace, "err {err} trace {trace}");
        assert!(err / trace < 0.5, "laplace relative error {}", err / trace);
    }

    #[test]
    fn eps_leverage_scores_match_exact() {
        // (1±ε) accuracy of disLS with the ε/2 embedding (§5.2 remark):
        // compare worker-held scores against exact leverage of the
        // concatenated embedded data E (reconstructible from the spec).
        let data = cluster_low_rank_data(120, 6);
        let shards = partition_power_law(&data, 3, 8);
        let shards_copy = shards.clone();
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let params = small_params();
        let eps = 0.5;
        let (vectors, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let spec = crate::embed::EmbedSpec {
                    kernel,
                    m: params.m_rff,
                    t2: params.t2,
                    t: params.t,
                    seed: params.seed ^ 0xeb3d,
                };
                dis_embed(cluster, spec).unwrap();
                let _ = master::dis_leverage_scores_eps(cluster, &params, eps).unwrap();
                master::dis_leverage_vectors(cluster).unwrap()
            },
        );
        // exact scores of E = [E¹ … Eˢ], rebuilt locally
        let spec = crate::embed::EmbedSpec {
            kernel,
            m: params.m_rff,
            t2: params.t2,
            t: params.t,
            seed: params.seed ^ 0xeb3d,
        };
        let mut e = crate::embed::embed(&spec, &shards_copy[0]);
        for sh in &shards_copy[1..] {
            e = e.hcat(&crate::embed::embed(&spec, sh));
        }
        let exact = crate::linalg::exact_leverage_scores(&e);
        let approx: Vec<f64> = vectors.into_iter().flatten().collect();
        assert_eq!(approx.len(), exact.len());
        for (j, (&a, &x)) in approx.iter().zip(&exact).enumerate() {
            if x > 1e-8 {
                let ratio = a / x;
                assert!(
                    (1.0 - eps..=1.0 + eps).contains(&ratio),
                    "col {j}: approx {a} exact {x} ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn more_adaptive_samples_reduce_error() {
        let data = cluster_low_rank_data(240, 10);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let mut errs = Vec::new();
        for n_adapt in [6, 80] {
            let shards = partition_power_law(&data, 4, 1);
            let params = Params { n_adapt, ..small_params() };
            let ((err, _), _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| {
                    let _ = dis_kpca(cluster, kernel, &params).unwrap();
                    dis_eval(cluster).unwrap()
                },
            );
            errs.push(err);
        }
        assert!(errs[1] <= errs[0] * 1.05, "{errs:?}");
    }

    #[test]
    fn solution_projection_orthonormal() {
        let data = cluster_low_rank_data(120, 6);
        let shards = partition_power_law(&data, 2, 5);
        let kernel = Kernel::Gauss { gamma: 1.0 };
        let params = small_params();
        let (sol, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_kpca(cluster, kernel, &params).unwrap(),
        );
        // LᵀL = Cᵀ K(Y,Y) C must be ≈ I
        let kyy = crate::kernels::gram(kernel, &sol.y, &Data::Dense(sol.y.clone()));
        let ltl = sol.coeffs.matmul_at_b(&kyy.matmul(&sol.coeffs));
        let eye = Mat::identity(sol.k());
        assert!(ltl.max_abs_diff(&eye) < 1e-4, "LᵀL err {}", ltl.max_abs_diff(&eye));
    }

    #[test]
    fn single_worker_cluster() {
        // s=1 degenerates to a (sketched) single-machine algorithm and
        // must still work end to end.
        let data = cluster_low_rank_data(120, 6);
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let params = small_params();
        let ((err, trace), stats) = run_cluster(
            vec![data],
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                assert_eq!(cluster.num_workers(), 1);
                let _ = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err >= 0.0 && err < trace);
        assert!(stats.total_words() > 0);
    }

    #[test]
    fn rank_one_kpca() {
        let data = cluster_low_rank_data(90, 5);
        let kernel = Kernel::Gauss { gamma: 0.4 };
        let params = Params { k: 1, ..small_params() };
        let (sol, _) = run_cluster(
            vec![data.slice_cols(0, 45), data.slice_cols(45, 90)],
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_kpca(cluster, kernel, &params).unwrap(),
        );
        assert_eq!(sol.k(), 1);
    }

    #[test]
    fn tiny_shards_survive() {
        // workers with 1–3 points each: sketches, sampling and
        // projection must tolerate n_i smaller than every sketch dim.
        let data = cluster_low_rank_data(12, 5);
        let shards: Vec<Data> = (0..4).map(|i| data.slice_cols(3 * i, 3 * i + 3)).collect();
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let params = Params { k: 2, n_lev: 4, n_adapt: 6, ..small_params() };
        let ((err, trace), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        // 12 points, |Y| can cover everything ⇒ tiny error
        assert!(err <= trace * 0.6 + 1e-9, "err {err} trace {trace}");
    }

    /// Regression: every worker holding literally identical points
    /// forces cross-worker duplicate draws — before the
    /// [`crate::comm::PointSet::concat_dedup`] fix, Y contained exact
    /// duplicate columns, K(Y,Y) was exactly singular, and disLR's
    /// triangular solve emitted junk coefficients.
    #[test]
    fn duplicate_representatives_are_deduped_and_coeffs_finite() {
        let col = [0.3, -0.1, 0.7, 0.2];
        let data = Data::Dense(Mat::from_fn(4, 24, |i, _| col[i]));
        let shards = vec![data.slice_cols(0, 12), data.slice_cols(12, 24)];
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let params = Params { k: 2, n_lev: 6, n_adapt: 8, ..small_params() };
        let ((sol, err, trace), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = dis_kpca(cluster, kernel, &params).unwrap();
                let (err, trace) = dis_eval(cluster).unwrap();
                (sol, err, trace)
            },
        );
        // all points identical ⇒ Y collapses to a single representative
        assert_eq!(sol.num_points(), 1, "duplicate columns survived in Y");
        assert!(
            sol.coeffs.data().iter().all(|v| v.is_finite()),
            "non-finite disLR coefficients from a singular K(Y,Y)"
        );
        assert!(err >= -1e-9 && err <= trace * (1.0 + 1e-9), "err {err} trace {trace}");
    }

    #[test]
    fn ablation_modes_all_run() {
        let data = cluster_low_rank_data(150, 8);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let params = small_params();
        let mut errs = Vec::new();
        for mode in [
            SamplingMode::Full,
            SamplingMode::LeverageOnly,
            SamplingMode::AdaptiveOnly,
        ] {
            let shards = partition_power_law(&data, 3, 4);
            let ((err, trace), _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| {
                    let _ = super::dis_kpca_mode(cluster, kernel, &params, mode).unwrap();
                    dis_eval(cluster).unwrap()
                },
            );
            assert!(err >= 0.0 && err <= trace);
            errs.push(err);
        }
        assert_eq!(errs.len(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = cluster_low_rank_data(100, 5);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let mut sols = Vec::new();
        for _ in 0..2 {
            let shards = partition_power_law(&data, 3, 9);
            let params = small_params();
            let (sol, _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| dis_kpca(cluster, kernel, &params).unwrap(),
            );
            sols.push(sol);
        }
        assert_eq!(sols[0].num_points(), sols[1].num_points());
        assert!(sols[0].y.max_abs_diff(&sols[1].y) < 1e-12);
        assert!(sols[0].coeffs.max_abs_diff(&sols[1].coeffs) < 1e-9);
    }
}
