//! Related-work comparators from the paper's §2 — implemented to
//! check the paper's *arguments* about them, not just cite them:
//!
//! - **random features + distributed linear PCA**: needs `m` features
//!   to ε-approximate the kernel, so its communication is `O(s·m·k)`
//!   with `m = Õ(d/ε²)` — the paper argues this is too high, and the
//!   solution lives in RFF space, not the kernel feature space.
//! - **pivoted (incomplete) Cholesky KPCA**: excellent per-pivot
//!   accuracy, but a faithful distributed version needs one
//!   communication **round per pivot** — the paper's reason to reject
//!   it. We implement the algorithm and its round/word model.
//! - **Nyström** is the paper's `uniform+batchKPCA` baseline (already
//!   in `baselines.rs`): batch KPCA restricted to span of a uniform
//!   sample is exactly the Nyström KPCA estimator.

use crate::data::Data;
use crate::kernels::{diag as kernel_diag, gram, rff_features, rff_params, Kernel};
use crate::linalg::{top_k_left_singular, Mat};
use crate::rng::Rng;
use crate::sketch::right_countsketch;

use super::KpcaSolution;

/// Random-feature distributed linear PCA (the §2 strawman).
///
/// Workers expand their shard to `m` shared random features, right-
/// sketch to `p` columns, ship to the master; the master SVDs the
/// stacked m×(s·p) matrix. Returns (top-k basis in RFF space,
/// residual error *in the RFF-approximated feature space*, trace,
/// communicated words).
pub fn rff_linear_pca(
    shards: &[Data],
    gamma: f64,
    m: usize,
    k: usize,
    p: usize,
    seed: u64,
) -> (Mat, f64, f64, usize) {
    let d = shards[0].dim();
    let mut rng = Rng::seed_from(seed);
    // shared features (seed broadcast — O(1) words)
    let params = rff_params(d, m, gamma, &mut rng);
    let mut stacked: Option<Mat> = None;
    let mut words = 0usize;
    let mut zs = Vec::new();
    for (i, sh) in shards.iter().enumerate() {
        let z = rff_features(&params, sh); // m×nᵢ
        let mut wrng = Rng::seed_from(seed ^ (0x0f + i as u64));
        let sk = right_countsketch(&z, p.min(z.cols().max(1)), &mut wrng);
        words += sk.rows() * sk.cols();
        stacked = Some(match stacked {
            None => sk.clone(),
            Some(acc) => acc.hcat(&sk),
        });
        zs.push(z);
    }
    let (u, _) = top_k_left_singular(&stacked.unwrap(), k);
    words += shards.len() * u.rows() * u.cols(); // broadcast U back
    // residual in RFF space: Σ ‖z‖² − ‖Uᵀz‖²
    let mut err = 0.0;
    let mut trace = 0.0;
    for z in &zs {
        trace += z.frob_norm_sq();
        let proj = u.matmul_at_b(z);
        err += z.frob_norm_sq() - proj.frob_norm_sq();
    }
    (u, err.max(0.0), trace, words)
}

/// Pivoted incomplete Cholesky KPCA (Bach–Jordan style): greedily pick
/// the point with the largest residual diagonal, extend the implicit
/// Cholesky factor, stop after `c` pivots. Single-machine algorithm;
/// [`cholesky_comm_model`] gives what a faithful distributed version
/// would cost.
///
/// Returns the KPCA solution spanned by the pivot points plus the
/// per-step residual trace (monotone ↓ — useful for ablation plots).
pub fn pivoted_cholesky_kpca(
    data: &Data,
    kernel: Kernel,
    c: usize,
    k: usize,
) -> (KpcaSolution, Vec<f64>) {
    let n = data.len();
    let c = c.min(n);
    let mut diag = kernel_diag(kernel, data);
    // rows of the factor restricted to chosen pivots: G[j][t] = t-th
    // coefficient of point j (n×c, built column by column)
    let mut g: Vec<Vec<f64>> = vec![Vec::with_capacity(c); n];
    let mut pivots = Vec::with_capacity(c);
    let mut residual_trace = Vec::with_capacity(c);
    for _step in 0..c {
        // best pivot = argmax residual diagonal
        let (jmax, &dmax) = diag
            .iter()
            .enumerate()
            // total_cmp: NaN residual diagonals (NaN-poisoned shard)
            // must not panic the pivot search
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        if dmax <= 1e-12 {
            break;
        }
        pivots.push(jmax);
        let piv = data.select_cols_dense(&[jmax]);
        let krow = gram(kernel, &piv, data); // 1×n kernel row
        let scale = dmax.sqrt();
        let gj: Vec<f64> = (0..n)
            .map(|j| {
                let mut v = krow[(0, j)];
                for t in 0..g[jmax].len() {
                    v -= g[j][t] * g[jmax][t];
                }
                v / scale
            })
            .collect();
        for j in 0..n {
            let upd = gj[j];
            g[j].push(upd);
            diag[j] = (diag[j] - upd * upd).max(0.0);
        }
        residual_trace.push(diag.iter().sum());
    }
    // batch KPCA in the span of the pivots
    let y = data.select_cols_dense(&pivots);
    let batch = super::baselines::batch_kpca(&y, kernel, k, y.cols() <= 300, 7);
    // …but that only orthonormalizes w.r.t. the pivots; project data
    // properly by reusing the standard machinery:
    let sol = KpcaSolution { kernel, y, coeffs: batch.solution.coeffs };
    (sol, residual_trace)
}

/// Communication a faithful distributed pivoted Cholesky would need:
/// `c` rounds, each shipping the pivot point (ρ words) to all `s`
/// workers plus gathering s candidate maxima — `c·(s·ρ + 2s)` words
/// and, critically, `c` synchronous rounds (vs disKPCA's 4).
pub fn cholesky_comm_model(c: usize, s: usize, rho: f64) -> (usize, usize) {
    let words = c * (s * rho.ceil() as usize + 2 * s);
    (words, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clusters;

    fn test_data(n: usize) -> Data {
        let mut rng = Rng::seed_from(3);
        Data::Dense(clusters(8, n, 3, 0.2, &mut rng))
    }

    #[test]
    fn rff_linear_pca_reduces_error_with_k() {
        let data = test_data(120);
        let shards = vec![data.slice_cols(0, 60), data.slice_cols(60, 120)];
        let mut errs = Vec::new();
        for k in [1usize, 8] {
            let (u, err, trace, words) = rff_linear_pca(&shards, 0.5, 256, k, 40, 5);
            assert_eq!(u.cols(), k);
            assert!(err >= 0.0 && err <= trace * 1.001);
            assert!(words > 0);
            errs.push(err / trace);
        }
        assert!(errs[1] < errs[0], "{errs:?}");
    }

    #[test]
    fn pivoted_cholesky_residual_monotone() {
        let data = test_data(80);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let (sol, res) = pivoted_cholesky_kpca(&data, kernel, 20, 4);
        assert!(sol.num_points() <= 20);
        for w in res.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "residual grew: {w:?}");
        }
        // 20 greedy pivots on 3 tight clusters ⇒ tiny residual
        let final_res = *res.last().unwrap();
        assert!(final_res < 0.2 * 80.0, "{final_res}");
    }

    #[test]
    fn pivoted_cholesky_solution_evaluates() {
        let data = test_data(60);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let (sol, _) = pivoted_cholesky_kpca(&data, kernel, 25, 4);
        let err = sol.eval_error(&data);
        let trace = 60.0;
        assert!(err >= 0.0 && err < trace, "{err}");
        // beats a 4-point solution
        let (small, _) = pivoted_cholesky_kpca(&data, kernel, 4, 4);
        assert!(err <= small.eval_error(&data) + 1e-9);
    }

    #[test]
    fn comm_model_counts_rounds() {
        let (words, rounds) = cholesky_comm_model(100, 10, 50.0);
        assert_eq!(rounds, 100);
        assert_eq!(words, 100 * (10 * 50 + 20));
    }
}
