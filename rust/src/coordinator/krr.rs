//! Distributed kernel ridge regression on the representative set —
//! a downstream application of kernel CSS (the paper: "The column
//! subset selection problem has various applications in big data
//! scenarios, so this result could be of independent interest").
//!
//! Given the CSS output Y, restrict the regression function to
//! f = Σ_{i∈Y} αᵢ κ(yᵢ, ·) and solve the Nyström-style normal
//! equations over the *whole* distributed dataset:
//! `(Σᵢ K_{YAⁱ} K_{AⁱY} + λ K_YY) α = Σᵢ K_{YAⁱ} tⁱ`.
//!
//! Each worker ships one |Y|×|Y| matrix and one |Y| vector — total
//! communication O(s|Y|²) words, independent of n. Targets are a
//! synthetic teacher tⱼ = cos(vᵀxⱼ) every worker derives from a shared
//! seed, giving ground truth without label plumbing.

use crate::comm::request as rq;
use crate::comm::{Cluster, CommError, PointSet};
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol_psd, solve_lower, solve_upper, Mat};

/// Fitted KRR model: f(x) = Σᵢ αᵢ κ(yᵢ, x).
#[derive(Clone, Debug)]
pub struct KrrModel {
    pub kernel: Kernel,
    /// d×|Y| representative points.
    pub y: Mat,
    /// |Y| coefficients.
    pub alpha: Vec<f64>,
    /// training mean squared error over the distributed dataset.
    pub train_mse: f64,
    /// Σⱼ tⱼ² / n — the trivial predictor's MSE, for reference.
    pub target_power: f64,
}

impl KrrModel {
    /// Predict on out-of-sample dense points (d×m): returns m values.
    pub fn predict(&self, x: &Mat) -> Vec<f64> {
        let k_yx = gram(self.kernel, &self.y, &crate::data::Data::Dense(x.clone()));
        (0..x.cols())
            .map(|j| (0..self.y.cols()).map(|i| self.alpha[i] * k_yx[(i, j)]).sum())
            .collect()
    }

    /// 1 − MSE/power: fraction of target variance explained (≤ 1).
    pub fn r_squared(&self) -> f64 {
        if self.target_power <= 0.0 {
            0.0
        } else {
            1.0 - self.train_mse / self.target_power
        }
    }
}

/// Fit distributed KRR on the representative set `y` with ridge λ and
/// the teacher defined by `teacher_seed`. Two rounds: normal-equation
/// aggregation, then a training-error round.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use diskpca::coordinator::{dis_css, dis_krr, run_cluster, Params};
/// use diskpca::data::{clusters, partition_power_law, Data};
/// use diskpca::kernels::Kernel;
/// use diskpca::rng::Rng;
/// use diskpca::runtime::NativeBackend;
///
/// let mut rng = Rng::seed_from(3);
/// let data = Data::Dense(clusters(5, 70, 3, 0.2, &mut rng));
/// let shards = partition_power_law(&data, 2, 9);
/// let kernel = Kernel::Gauss { gamma: 0.5 };
/// let params = Params {
///     k: 3, t: 8, p: 16, n_lev: 6, n_adapt: 8, m_rff: 128, t2: 64,
///     ..Params::default()
/// };
/// let (model, _stats) = run_cluster(
///     shards,
///     kernel,
///     Arc::new(NativeBackend::new()),
///     move |cluster| {
///         let css = dis_css(cluster, kernel, &params)?;
///         dis_krr(cluster, kernel, &css.y, 1e-3, 7)
///     },
/// );
/// let model = model.unwrap();    // a worker failure would be Err
/// assert_eq!(model.alpha.len(), model.y.cols());
/// assert!(model.r_squared() <= 1.0);
/// // predict on fresh points without any further communication
/// let preds = model.predict(&diskpca::linalg::Mat::zeros(5, 4));
/// assert_eq!(preds.len(), 4);
/// ```
pub fn dis_krr(
    cluster: &Cluster,
    kernel: Kernel,
    y: &PointSet,
    lambda: f64,
    teacher_seed: u64,
) -> Result<KrrModel, CommError> {
    let sx = cluster.session("9-krr");
    let ny = y.len();
    let mut g_sum = Mat::zeros(ny, ny);
    let mut b_sum = Mat::zeros(ny, 1);
    let mut tnorm_sum = 0.0;
    for part in sx.broadcast(rq::KrrStats { pts: y.clone(), teacher_seed })? {
        g_sum.add_assign(&part.g);
        b_sum.add_assign(&part.b);
        tnorm_sum += part.tnorm;
    }
    // (G + λ K_YY) α = b, solved via Cholesky (PSD + ridge).
    let y_mat = y.to_mat();
    let k_yy = gram(kernel, &y_mat, &crate::data::Data::Dense(y_mat.clone()));
    let mut lhs = g_sum;
    for i in 0..ny {
        for j in 0..ny {
            lhs[(i, j)] += lambda * k_yy[(i, j)];
        }
    }
    let (r, _) = chol_psd(&lhs);
    // RᵀR α = b ⇒ forward then backward substitution
    let z = solve_lower(&r.transpose(), &b_sum.col(0));
    let alpha = solve_upper(&r, &z);
    // training-error round
    let mut alpha_mat = Mat::zeros(ny, 1);
    alpha_mat.set_col(0, &alpha);
    let sse: f64 = sx.broadcast(rq::KrrEval { alpha: alpha_mat })?.into_iter().sum();
    let n: usize = sx.broadcast(rq::Count)?.into_iter().sum();
    let nf = (n as f64).max(1.0);
    Ok(KrrModel {
        kernel,
        y: y_mat,
        alpha,
        train_mse: sse / nf,
        target_power: tnorm_sum / nf,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::css::dis_css;
    use crate::coordinator::{run_cluster, GatherMode, Params};
    use crate::data::{partition_power_law, Data};
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    fn smooth_data(n: usize, d: usize, seed: u64) -> Data {
        let mut rng = Rng::seed_from(seed);
        Data::Dense(Mat::from_fn(d, n, |_, _| rng.normal()))
    }

    fn params() -> Params {
        Params { k: 6, t: 16, p: 40, n_lev: 12, n_adapt: 40, w: 0, m_rff: 256, t2: 128, seed: 31, threads: 0, chunk_rows: 0, gather: GatherMode::Flat }
    }

    #[test]
    fn krr_fits_smooth_teacher() {
        let data = smooth_data(240, 6, 1);
        let shards = partition_power_law(&data, 4, 1);
        let kernel = Kernel::Gauss { gamma: 0.3 };
        let p = params();
        let (model, stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let css = dis_css(cluster, kernel, &p).unwrap();
                dis_krr(cluster, kernel, &css.y, 1e-3, 99).unwrap()
            },
        );
        // teacher cos(vᵀx) is smooth ⇒ Gaussian KRR on ~50 centers
        // should explain most of the variance
        assert!(model.r_squared() > 0.8, "R² {}", model.r_squared());
        // comm for the KRR rounds is O(s·|Y|²), counted
        assert!(stats.round_words("9-krr") > 0);
    }

    #[test]
    fn krr_prediction_matches_teacher_out_of_sample() {
        let data = smooth_data(300, 5, 2);
        let shards = partition_power_law(&data, 3, 2);
        let kernel = Kernel::Gauss { gamma: 0.3 };
        let p = params();
        let seed = 123u64;
        let (model, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let css = dis_css(cluster, kernel, &p).unwrap();
                dis_krr(cluster, kernel, &css.y, 1e-3, seed).unwrap()
            },
        );
        // fresh points from the same distribution; teacher recomputed
        // with the worker's derivation (v ~ N(0, I/√d) from seed)
        let mut rng = Rng::seed_from(7);
        let test = Mat::from_fn(5, 40, |_, _| rng.normal());
        let mut trng = Rng::seed_from(seed);
        let scale = 1.0 / (5f64).sqrt();
        let v: Vec<f64> = (0..5).map(|_| trng.normal() * scale).collect();
        let preds = model.predict(&test);
        let mut sse = 0.0;
        let mut pow = 0.0;
        for j in 0..40 {
            let t: f64 = (0..5).map(|r| v[r] * test[(r, j)]).sum::<f64>().cos();
            sse += (preds[j] - t) * (preds[j] - t);
            pow += t * t;
        }
        assert!(sse / pow < 0.35, "oos relative err {}", sse / pow);
    }

    #[test]
    fn more_ridge_means_smaller_coefficients() {
        let data = smooth_data(150, 4, 3);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let p = params();
        let mut norms = Vec::new();
        for lambda in [1e-4, 1e2] {
            let shards = partition_power_law(&data, 3, 3);
            let (model, _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| {
                    let css = dis_css(cluster, kernel, &p).unwrap();
                    dis_krr(cluster, kernel, &css.y, lambda, 5).unwrap()
                },
            );
            norms.push(model.alpha.iter().map(|a| a * a).sum::<f64>().sqrt());
        }
        assert!(norms[1] < norms[0], "{norms:?}");
    }
}
