//! Distributed kernel Column Subset Selection (the paper's §5.3
//! subroutine, exposed as a first-class API).
//!
//! The paper: "we have also developed an algorithm for the distributed
//! Column Subset Selection (CSS) problem, which can select a set of
//! O(k/ε) points whose span contains (1+ε)-approximation, with
//! communication O(sρk/ε + sk²). … this result could be of independent
//! interest."
//!
//! [`dis_css`] runs rounds 1–3 of disKPCA (embed → leverage scores →
//! RepSample) and stops *before* the rank-k refinement: the output is
//! the selected columns Y plus a certificate — the exactly-measured
//! residual ‖φ(A) − proj_{span φ(Y)} φ(A)‖² — obtained with one extra
//! O(s) round.

use crate::comm::request as rq;
use crate::comm::{Cluster, CommError, PointSet};
use crate::kernels::Kernel;

use super::master::{dis_embed, dis_leverage_scores, rep_sample};
use super::Params;

/// Output of distributed kernel CSS.
#[derive(Clone, Debug)]
pub struct CssSolution {
    /// The selected columns (|Y| = O(k log k + k/ε) actual points, in
    /// the shards' natural dense/sparse encoding).
    pub y: PointSet,
    /// ‖φ(A) − P_{span φ(Y)} φ(A)‖² — the span's total squared
    /// residual over the entire dataset.
    pub residual: f64,
    /// tr K = Σⱼ κ(xⱼ,xⱼ); `residual / trace` is the fraction of
    /// kernel mass outside the span (1.0 for Y = ∅, 0.0 for full rank).
    pub trace: f64,
}

impl CssSolution {
    /// Fraction of total kernel mass not captured by span φ(Y).
    pub fn residual_fraction(&self) -> f64 {
        if self.trace <= 0.0 {
            0.0
        } else {
            (self.residual / self.trace).clamp(0.0, 1.0)
        }
    }
}

/// Distributed kernel column subset selection (paper §5.3): leverage
/// sampling + adaptive sampling, plus a certificate round measuring
/// the span residual.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use diskpca::coordinator::{dis_css, run_cluster, Params};
/// use diskpca::data::{clusters, partition_power_law, Data};
/// use diskpca::kernels::Kernel;
/// use diskpca::rng::Rng;
/// use diskpca::runtime::NativeBackend;
///
/// let mut rng = Rng::seed_from(2);
/// let data = Data::Dense(clusters(6, 80, 4, 0.15, &mut rng));
/// let shards = partition_power_law(&data, 2, 5);
/// let kernel = Kernel::Gauss { gamma: 0.5 };
/// let params = Params {
///     k: 3, t: 8, p: 16, n_lev: 6, n_adapt: 10, m_rff: 128, t2: 64,
///     ..Params::default()
/// };
/// let (css, _stats) = run_cluster(
///     shards,
///     kernel,
///     Arc::new(NativeBackend::new()),
///     move |cluster| dis_css(cluster, kernel, &params),
/// );
/// let css = css.unwrap();    // a worker failure would be Err
/// assert!(css.y.len() >= 1);
/// // the certificate bounds the span residual as a mass fraction
/// assert!((0.0..=1.0).contains(&css.residual_fraction()));
/// ```
pub fn dis_css(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
) -> Result<CssSolution, CommError> {
    dis_css_warm(cluster, kernel, params, false)
}

/// [`dis_css`] with an explicit warm-start flag (serve layer):
/// `embed_installed = true` skips the `1-embed` broadcast — the caller
/// asserts every worker holds E^i for exactly
/// [`super::master::embed_spec_for`]`(kernel, params)`.
pub fn dis_css_warm(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    embed_installed: bool,
) -> Result<CssSolution, CommError> {
    params.apply_threads();
    if !embed_installed {
        dis_embed(cluster, super::master::embed_spec_for(kernel, params))?;
    }
    let masses = dis_leverage_scores(cluster, params)?;
    let y = rep_sample(cluster, params, &masses)?;
    // certificate: exact residual of the full span (one scalar per
    // worker — reuses the adaptive-sampling residual machinery).
    let sx = cluster.session("7-cssCert");
    let residual: f64 = sx.broadcast(rq::Residuals { pts: y.clone() })?.into_iter().sum();
    let trace: f64 = sx.broadcast(rq::EvalTrace)?.into_iter().sum();
    Ok(CssSolution { y, residual, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_cluster;
    use crate::data::{partition_power_law, Data};
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    fn clustered(n: usize, d: usize, seed: u64) -> Data {
        let mut rng = Rng::seed_from(seed);
        Data::Dense(crate::data::clusters(d, n, 5, 0.1, &mut rng))
    }

    fn params(n_lev: usize, n_adapt: usize) -> Params {
        Params { k: 5, t: 16, p: 40, n_lev, n_adapt, m_rff: 256, t2: 128, w: 0, seed: 11, threads: 0, chunk_rows: 0, gather: crate::coordinator::GatherMode::Flat }
    }

    #[test]
    fn css_residual_certificate_matches_local_eval() {
        let data = clustered(160, 8, 1);
        let shards = partition_power_law(&data, 4, 1);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let p = params(10, 20);
        let (sol, stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_css(cluster, kernel, &p).unwrap(),
        );
        // recompute the residual single-machine via the kernel trick
        let y = sol.y.to_mat();
        let k_yy = crate::kernels::gram(kernel, &y, &Data::Dense(y.clone()));
        let (r, _) = crate::linalg::chol_psd(&k_yy);
        let k_ya = crate::kernels::gram(kernel, &y, &data);
        let pi = crate::linalg::solve_upper_transpose_mat(&r, &k_ya);
        let norms = pi.col_norms_sq();
        let local: f64 = crate::kernels::diag(kernel, &data)
            .iter()
            .zip(&norms)
            .map(|(&d, &n)| (d - n).max(0.0))
            .sum();
        assert!(
            (sol.residual - local).abs() < 1e-6 * sol.trace.max(1.0),
            "dis {} local {local}",
            sol.residual
        );
        assert!(stats.round_words("7-cssCert") > 0);
    }

    #[test]
    fn css_residual_fraction_decreases_with_more_columns() {
        let data = clustered(200, 10, 2);
        let kernel = Kernel::Gauss { gamma: 0.4 };
        let mut fracs = Vec::new();
        for n_adapt in [5, 60] {
            let shards = partition_power_law(&data, 4, 2);
            let p = params(8, n_adapt);
            let (sol, _) = run_cluster(
                shards,
                kernel,
                Arc::new(NativeBackend::new()),
                move |cluster| dis_css(cluster, kernel, &p).unwrap(),
            );
            fracs.push(sol.residual_fraction());
        }
        assert!(fracs[1] <= fracs[0] + 1e-9, "{fracs:?}");
    }

    #[test]
    fn css_full_coverage_gives_zero_residual() {
        // |Y| can cover all 12 points ⇒ residual ≈ 0
        let data = clustered(12, 6, 3);
        let shards = partition_power_law(&data, 2, 3);
        let kernel = Kernel::Gauss { gamma: 0.8 };
        let p = params(12, 40);
        let (sol, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_css(cluster, kernel, &p).unwrap(),
        );
        assert!(sol.residual_fraction() < 0.05, "{}", sol.residual_fraction());
    }

    /// Regression: the full-coverage scenario where P already spans
    /// every shard *exactly* — with identical points, κ(x,x) = κ(y,x)
    /// = 1 and every residual clamps to exactly 0.0, so the adaptive
    /// stage's total mass is zero. The allocation must fall back to a
    /// deterministic uniform split (not an undefined one), and dedup
    /// must collapse the resulting duplicate draws back to {x}.
    #[test]
    fn css_full_coverage_zero_mass_uses_uniform_fallback() {
        let data = Data::Dense(Mat::from_fn(5, 30, |i, _| (i as f64) * 0.2 - 0.4));
        let shards = partition_power_law(&data, 3, 7);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let p = params(6, 12);
        let (sol, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_css(cluster, kernel, &p).unwrap(),
        );
        assert_eq!(sol.y.len(), 1, "identical points must collapse to one representative");
        assert!(sol.residual.abs() < 1e-9, "residual {}", sol.residual);
        assert!(sol.residual_fraction() < 1e-9);
    }

    #[test]
    fn css_poly_kernel_runs_sparse() {
        let mut rng = Rng::seed_from(4);
        let data = Data::Sparse(crate::data::zipf_sparse(100, 80, 12, &mut rng));
        let shards = partition_power_law(&data, 3, 4);
        let kernel = Kernel::Poly { q: 2 };
        let p = params(8, 16);
        let (sol, _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| dis_css(cluster, kernel, &p).unwrap(),
        );
        assert!(sol.residual >= 0.0 && sol.residual <= sol.trace * (1.0 + 1e-9));
        // sparse selection stays sparse on the wire
        assert!(matches!(sol.y, PointSet::Sparse { .. }));
        let _ = Mat::zeros(1, 1);
    }
}
