//! Baselines the paper compares against (§6.2):
//! - **uniform + disLR** — uniform representative points, then Alg. 3.
//! - **uniform + batch KPCA** — ship a uniform sample to the master,
//!   solve the batch problem there.
//! - **batch KPCA** — single-machine ground truth (Figs 2–3), plus the
//!   optimum rank-k error for relative-error reporting.

use crate::comm::request as rq;
use crate::comm::{Cluster, CommError, PointSet};
use crate::data::Data;
use crate::kernels::{gram_sym, Kernel};
use crate::linalg::{eigh, top_eigh, Mat};
use crate::rng::{multinomial, Rng};

use super::master::dis_low_rank;
use super::{KpcaSolution, Params};

/// Gather a uniform sample of `total` points across workers
/// (allocation ∝ nᵢ — i.e. a uniform sample of the global dataset).
pub fn dis_uniform_sample(
    cluster: &Cluster,
    total: usize,
    seed: u64,
) -> Result<PointSet, CommError> {
    let sx = cluster.session("3-uniform");
    let counts: Vec<f64> = sx.broadcast(rq::Count)?.into_iter().map(|c| c as f64).collect();
    let mut rng = Rng::seed_from(seed ^ 0x0111f);
    let alloc = multinomial(&mut rng, &counts, total);
    let parts: Vec<PointSet> = sx
        .scatter(
            alloc
                .iter()
                .enumerate()
                .map(|(i, &c)| rq::SampleUniform { count: c, seed: seed ^ (0xbb + i as u64) })
                .collect(),
        )?
        .into_iter()
        .filter(|p| !p.is_empty())
        .collect();
    // cross-worker duplicates would make K(Y,Y) singular in disLR
    Ok(PointSet::concat_dedup(&parts))
}

/// Baseline 1: uniform sampling of Y, then the same distributed
/// low-rank step as disKPCA.
pub fn uniform_dis_lr(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    total_points: usize,
) -> Result<KpcaSolution, CommError> {
    params.apply_threads();
    let y = dis_uniform_sample(cluster, total_points, params.seed)?;
    dis_low_rank(cluster, kernel, params, &y)
}

/// Batch KPCA on a d×n matrix of points: top-k eigenpairs of the full
/// gram matrix. Returns the solution plus the optimum statistics.
pub struct BatchKpca {
    pub solution: KpcaSolution,
    /// all eigenvalues if `exact`, else the top k+ buffer.
    pub eigvals: Vec<f64>,
    /// tr(K) = Σᵢ κ(xᵢ,xᵢ).
    pub trace: f64,
    /// the optimum ‖φ(A) − [φ(A)]_k‖² = tr(K) − Σ_{i≤k} λᵢ.
    pub opt_error: f64,
}

/// `exact` uses the full Jacobi eigensolver (O(n³) — small n only);
/// otherwise randomized subspace iteration for the top k.
pub fn batch_kpca(points: &Mat, kernel: Kernel, k: usize, exact: bool, seed: u64) -> BatchKpca {
    let n = points.cols();
    let kmat = gram_sym(kernel, points);
    let trace: f64 = (0..n).map(|i| kmat[(i, i)]).sum();
    let (vals, vecs) = if exact {
        eigh(&kmat)
    } else {
        let mut rng = Rng::seed_from(seed);
        top_eigh(&kmat, k + 4, &mut rng)
    };
    let k = k.min(vals.len());
    let topsum: f64 = vals[..k].iter().sum();
    // L = φ(A)·V_k·Λ_k^{-1/2}: coefficients C = V_k Λ^{-1/2}.
    let mut coeffs = Mat::zeros(n, k);
    for j in 0..k {
        let lam = vals[j].max(1e-12);
        let scale = 1.0 / lam.sqrt();
        for i in 0..n {
            coeffs[(i, j)] = vecs[(i, j)] * scale;
        }
    }
    BatchKpca {
        solution: KpcaSolution { kernel, y: points.clone(), coeffs },
        eigvals: vals,
        trace,
        opt_error: (trace - topsum).max(0.0),
    }
}

/// Baseline 2: uniform sample to the master, batch KPCA there.
/// Communication = shipping the sample; computation = O(c³).
pub fn uniform_batch_kpca(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    total_points: usize,
) -> Result<KpcaSolution, CommError> {
    params.apply_threads();
    let sample = dis_uniform_sample(cluster, total_points, params.seed ^ 0xbbb)?;
    let pts = sample.to_mat();
    Ok(batch_kpca(&pts, kernel, params.k, false, params.seed).solution)
}

/// Single-machine exact evaluation helper: relative error of a
/// solution against the batch optimum.
pub fn relative_error(sol: &KpcaSolution, data: &Data, opt_error: f64) -> f64 {
    let err = sol.eval_error(data);
    if opt_error > 1e-12 {
        err / opt_error
    } else {
        err
    }
}

/// Distributed *linear* PCA baseline (the [7]-style comparator): each
/// worker sends a right-sketch of its raw data; the master SVDs. Used
/// by ablation benches to show why the kernel path needs the
/// embedding machinery.
pub fn dis_linear_pca(shards: &[Data], k: usize, p: usize, seed: u64) -> (Mat, usize) {
    let d = shards[0].dim();
    let mut rng = Rng::seed_from(seed);
    let mut stacked: Option<Mat> = None;
    let mut words = 0usize;
    for sh in shards {
        let dense = sh.to_dense();
        let sk = crate::sketch::right_countsketch(&dense, p.min(sh.len().max(1)), &mut rng);
        words += sk.rows() * sk.cols();
        stacked = Some(match stacked {
            None => sk,
            Some(acc) => acc.hcat(&sk),
        });
    }
    let all = stacked.unwrap();
    let (u, _s) = crate::linalg::top_k_left_singular(&all, k.min(d));
    (u, words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{dis_eval, dis_set_solution, run_cluster};
    use crate::kernels::gram;
    use crate::data::partition_power_law;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    fn test_data(n: usize) -> Data {
        let mut rng = Rng::seed_from(5);
        Data::Dense(crate::data::clusters(6, n, 3, 0.2, &mut rng))
    }

    #[test]
    fn batch_kpca_exact_vs_randomized() {
        let data = test_data(60);
        let pts = data.to_dense();
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let exact = batch_kpca(&pts, kernel, 3, true, 1);
        let fast = batch_kpca(&pts, kernel, 3, false, 1);
        assert!((exact.opt_error - fast.opt_error).abs() < 1e-3 * exact.trace);
        // achieved error of the exact solution == optimum
        let err = exact.solution.eval_error(&data);
        assert!(
            (err - exact.opt_error).abs() < 1e-6 * exact.trace,
            "{err} vs {}",
            exact.opt_error
        );
    }

    #[test]
    fn batch_kpca_solution_orthonormal() {
        let data = test_data(40);
        let pts = data.to_dense();
        let kernel = Kernel::Poly { q: 2 };
        let b = batch_kpca(&pts, kernel, 3, true, 1);
        let kyy = gram(kernel, &b.solution.y, &Data::Dense(b.solution.y.clone()));
        let ltl = b.solution.coeffs.matmul_at_b(&kyy.matmul(&b.solution.coeffs));
        assert!(ltl.max_abs_diff(&Mat::identity(3)) < 1e-6);
    }

    #[test]
    fn uniform_dis_lr_runs_and_evaluates() {
        let data = test_data(150);
        let shards = partition_power_law(&data, 3, 2);
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let params = Params { k: 3, w: 0, seed: 11, ..Params::default() };
        let ((err, trace), stats) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _sol = uniform_dis_lr(cluster, kernel, &params, 30).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err > 0.0 && err < trace);
        // no disLS rounds should appear
        assert_eq!(stats.round_words("2-disLS"), 0);
        assert!(stats.round_words("3-uniform") > 0);
    }

    #[test]
    fn uniform_batch_kpca_runs() {
        let data = test_data(120);
        let shards = partition_power_law(&data, 3, 4);
        let kernel = Kernel::Gauss { gamma: 0.7 };
        let params = Params { k: 3, seed: 13, ..Params::default() };
        let ((err, trace), _) = run_cluster(
            shards,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let sol = uniform_batch_kpca(cluster, kernel, &params, 40).unwrap();
                dis_set_solution(cluster, &sol).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        assert!(err > 0.0 && err < trace, "err {err} trace {trace}");
    }

    #[test]
    fn diskpca_beats_tiny_uniform_on_skewed_data() {
        // A dataset with a few dominant directions + rare outlier
        // cluster: leverage+adaptive sampling should capture it better
        // than a *small* uniform sample at equal |Y|.
        let mut rng = Rng::seed_from(9);
        let mut main = crate::data::clusters(8, 180, 2, 0.1, &mut rng);
        // rare cluster: 6 points far away
        for j in 0..6 {
            for i in 0..8 {
                main[(i, j)] = 4.0 * ((i * 13 + j) % 3) as f64 + rng.normal() * 0.05;
            }
        }
        let data = Data::Dense(main);
        let kernel = Kernel::Gauss { gamma: 0.25 };
        let params = Params {
            k: 4,
            t: 16,
            p: 40,
            n_lev: 10,
            n_adapt: 14,
            m_rff: 512,
            t2: 128,
            w: 0,
            seed: 17,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        };
        let shards1 = partition_power_law(&data, 3, 7);
        let ((err_dis, _), _) = run_cluster(
            shards1,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = super::super::dis_kpca(cluster, kernel, &params).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        let shards2 = partition_power_law(&data, 3, 7);
        let ((err_uni, _), _) = run_cluster(
            shards2,
            kernel,
            Arc::new(NativeBackend::new()),
            move |cluster| {
                let _ = uniform_dis_lr(cluster, kernel, &params, 24).unwrap();
                dis_eval(cluster).unwrap()
            },
        );
        // not a tight theorem — but with matched |Y| the informed
        // sampler should never be dramatically worse
        assert!(
            err_dis <= err_uni * 1.5,
            "disKPCA {err_dis} vs uniform {err_uni}"
        );
    }

    #[test]
    fn dis_linear_pca_shapes() {
        let data = test_data(100);
        let shards = partition_power_law(&data, 4, 3);
        let (u, words) = dis_linear_pca(&shards, 3, 20, 5);
        assert_eq!((u.rows(), u.cols()), (6, 3));
        assert!(words > 0);
        let utu = u.matmul_at_b(&u);
        assert!(utu.max_abs_diff(&Mat::identity(3)) < 1e-8);
    }
}
