//! Master-side protocol drivers: disLS (Alg. 1), RepSample (Alg. 2),
//! disLR (Alg. 3) and the full disKPCA (Alg. 4).
//!
//! Every driver speaks the typed session API
//! ([`crate::comm::Cluster::broadcast`] /
//! [`crate::comm::Cluster::scatter`]) and returns
//! `Result<_, CommError>`: a worker failure — a reported error, a
//! hang-up, a mismatched reply — aborts the round with the worker
//! index and round label attached instead of panicking the master.

use std::collections::VecDeque;

use crate::comm::request as rq;
use crate::comm::{Cluster, CommError, Inflight, PointSet};
use crate::embed::EmbedSpec;
use crate::kernels::{gram, Kernel};
use crate::linalg::{chol_psd, qr_r_only, solve_upper, top_k_left_singular, Mat};
use crate::rng::{multinomial, Rng};

use super::{GatherMode, KpcaSolution, Params};

/// Alg. 4 step 1: broadcast the shared embedding spec; workers build
/// E^i = S(φ(Aⁱ)) locally.
pub fn dis_embed(cluster: &Cluster, spec: EmbedSpec) -> Result<(), CommError> {
    cluster.session("1-embed").broadcast(rq::Embed { spec })?;
    Ok(())
}

/// The embedding spec `dis_kpca`/`dis_css` derive from `params` —
/// shared here so the serve layer can key warm-state reuse on the
/// exact spec the drivers would broadcast.
pub fn embed_spec_for(kernel: Kernel, params: &Params) -> EmbedSpec {
    EmbedSpec {
        kernel,
        m: params.m_rff,
        t2: params.t2,
        t: params.t,
        seed: params.seed ^ 0xeb3d,
    }
}

/// Alg. 1 (disLS): returns per-worker leverage-score masses. Workers
/// hold their individual scores; the master only ever sees the t×p
/// sketches, the t×t factor Z, and one scalar per worker.
pub fn dis_leverage_scores(cluster: &Cluster, params: &Params) -> Result<Vec<f64>, CommError> {
    Ok(dis_leverage_scores_z(cluster, params)?.0)
}

/// [`dis_leverage_scores`] that also returns the broadcast factor Z —
/// the round state a recovery checkpoint retains so `ReqScores` can be
/// replayed verbatim onto a revived worker.
pub fn dis_leverage_scores_z(
    cluster: &Cluster,
    params: &Params,
) -> Result<(Vec<f64>, Mat), CommError> {
    let sx = cluster.session("2-disLS");
    let s = sx.num_workers();
    let z = match params.gather {
        GatherMode::Flat => {
            // step 1: per-worker right-sketch E^i T^i (distinct seeds ⇒
            // the block-diagonal T of Lemma 6).
            let sketches: Vec<Mat> = sx.scatter(
                (0..s)
                    .map(|i| rq::SketchEmbed {
                        p: params.p,
                        seed: params.seed ^ (0x515 + i as u64),
                    })
                    .collect(),
            )?;
            // step 2: QR-factorize [E¹T¹, …, EˢTˢ]ᵀ = U·Z. The
            // per-worker transposes are independent — fan them out on
            // the pool.
            let transposed: Vec<Mat> = crate::par::par_join(
                sketches.iter().map(|sk| move || sk.transpose()).collect::<Vec<_>>(),
            );
            qr_r_only(&Mat::vcat_all(&transposed))
        }
        GatherMode::Tree => {
            // Same sketch per worker (same seeds), but each reply is
            // pre-compressed to its t×t R factor and the master
            // reduces them as a TSQR tree. Z has the same Gram
            // (ZᵀZ = Σᵢ EⁱTⁱ(EⁱTⁱ)ᵀ) as the flat factor, and the
            // worker-side scores only ever query that Gram, so the
            // scores are equal in exact arithmetic.
            let rs: Vec<Mat> = sx.scatter(
                (0..s)
                    .map(|i| rq::SketchEmbedR {
                        p: params.p,
                        seed: params.seed ^ (0x515 + i as u64),
                    })
                    .collect(),
            )?;
            tsqr_merge(rs)
        }
    };
    // step 3: workers compute ℓ̃ⱼ = ‖((Zᵀ)⁻¹Eⁱ)_{:j}‖², reply masses.
    let masses = sx.broadcast(rq::Scores { z: z.clone() })?;
    Ok((masses, z))
}

/// Pairwise TSQR reduction of per-worker R factors: QR-merge adjacent
/// pairs (`qr_r_only([Rᵃ; Rᵇ])` preserves the summed Gram
/// `RᵀR = RᵃᵀRᵃ + RᵇᵀRᵇ`) until one factor remains, carrying an odd
/// tail factor to the next level. The merges within one level are
/// independent — they fan out on the [`crate::par`] pool — so the
/// master's critical path is O(log s) small QRs instead of the flat
/// gather's single QR over all s stacked sketches. Deterministic for a
/// fixed worker count; not bit-identical to the flat factorization
/// (different FP association).
pub fn tsqr_merge(mut rs: Vec<Mat>) -> Mat {
    assert!(!rs.is_empty(), "tsqr_merge of zero factors");
    while rs.len() > 1 {
        let carry = if rs.len() % 2 == 1 { rs.pop() } else { None };
        let pairs: Vec<[Mat; 2]> = {
            let mut it = rs.into_iter();
            let mut v = Vec::new();
            while let (Some(a), Some(b)) = (it.next(), it.next()) {
                v.push([a, b]);
            }
            v
        };
        rs = crate::par::par_join(
            pairs.iter().map(|p| move || qr_r_only(&Mat::vcat_all(p))).collect::<Vec<_>>(),
        );
        if let Some(c) = carry {
            rs.push(c);
        }
    }
    rs.pop().expect("nonempty by construction")
}

/// Alg. 1 with an ε-accurate sketch (§5.2 closing remark): an
/// (ε/2)-subspace embedding instead of the ¼ one makes the worker-side
/// scores (1±ε)-accurate — "useful for other applications". The sketch
/// width grows as p = O(t/ε²); the masses returned here are the same
/// per-worker totals as [`dis_leverage_scores`], and the full vectors
/// can be pulled with [`dis_leverage_vectors`] (an O(n)-word offline
/// API, not part of the disKPCA budget).
pub fn dis_leverage_scores_eps(
    cluster: &Cluster,
    params: &Params,
    eps: f64,
) -> Result<Vec<f64>, CommError> {
    assert!(eps > 0.0 && eps <= 1.0);
    let p_eps = leverage_sketch_width(params.t, eps);
    let boosted = Params { p: p_eps.max(params.p), ..*params };
    dis_leverage_scores(cluster, &boosted)
}

/// Sketch width p for (1±ε)-accurate leverage scores. The right-sketch
/// is a CountSketch, whose subspace-embedding guarantee needs
/// p = O(t²/ε²) columns (Clarkson–Woodruff; the t² is the price of a
/// single nonzero per column). The disKPCA default (p = O(t)) only
/// targets constant accuracy, which is all Lemma 6 needs.
pub fn leverage_sketch_width(t: usize, eps: f64) -> usize {
    ((4.0 * (t * t) as f64) / (eps * eps)).ceil() as usize
}

/// Pull the full per-point leverage-score vectors from every worker
/// (order: worker 0's points, worker 1's, …). O(n) words — offline
/// validation/debug API, never used by disKPCA itself.
pub fn dis_leverage_vectors(cluster: &Cluster) -> Result<Vec<Vec<f64>>, CommError> {
    Ok(cluster
        .session("offline-scores")
        .broadcast(rq::ScoresVec)?
        .into_iter()
        .map(|v| v.row(0).to_vec())
        .collect())
}

/// Which parts of RepSample to run — the DESIGN.md ablation axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplingMode {
    /// the paper: leverage P, then adaptive Ŷ (Alg. 2).
    Full,
    /// leverage scores only, |P| = n_lev + n_adapt (Challenge III:
    /// rank-O(k/ε) span without the rank-k refinement).
    LeverageOnly,
    /// uniform P, then adaptive Ŷ (is the leverage stage pulling its
    /// weight, or is adaptive sampling doing all the work?).
    AdaptiveOnly,
}

/// Alg. 2 (RepSample): leverage sampling + adaptive sampling.
/// Returns the representative set Y (dense d×|Y|) — already known to
/// every worker because the requests carried it.
pub fn rep_sample(
    cluster: &Cluster,
    params: &Params,
    masses: &[f64],
) -> Result<PointSet, CommError> {
    rep_sample_mode(cluster, params, masses, SamplingMode::Full)
}

/// RepSample with an explicit [`SamplingMode`] (ablations).
pub fn rep_sample_mode(
    cluster: &Cluster,
    params: &Params,
    masses: &[f64],
    mode: SamplingMode,
) -> Result<PointSet, CommError> {
    match mode {
        SamplingMode::Full => rep_sample_impl(cluster, params, masses, params.n_lev, true),
        SamplingMode::LeverageOnly => {
            rep_sample_impl(cluster, params, masses, params.n_lev + params.n_adapt, false)
        }
        SamplingMode::AdaptiveOnly => {
            // uniform first stage of the same size
            let p_set = super::baselines::dis_uniform_sample(
                cluster,
                params.n_lev,
                params.seed ^ 0xab1a,
            )?;
            adaptive_stage(cluster, params, p_set)
        }
    }
}

/// Per-worker masses as sampling weights, guarded for degenerate
/// protocols: when the total is zero (the leverage/P stage already
/// spans every shard, so all residuals clamp to exactly 0) or any
/// mass is non-finite (NaN-poisoned shard), allocation by the raw
/// vector is undefined — fall back to a uniform split across workers.
/// Healthy masses pass through untouched (bit-identical allocation).
fn masses_or_uniform(masses: &[f64]) -> Vec<f64> {
    let degenerate =
        masses.iter().any(|m| !m.is_finite()) || masses.iter().sum::<f64>() <= 0.0;
    if degenerate {
        vec![1.0; masses.len()]
    } else {
        masses.to_vec()
    }
}

fn rep_sample_impl(
    cluster: &Cluster,
    params: &Params,
    masses: &[f64],
    n_lev: usize,
    adaptive: bool,
) -> Result<PointSet, CommError> {
    let mut rng = Rng::seed_from(params.seed ^ 0x5a3);
    // ---- step 1: leverage-weighted sample of O(k log k) points ----
    let sx = cluster.session("3-levSample");
    let alloc = multinomial(&mut rng, &masses_or_uniform(masses), n_lev);
    let parts: Vec<PointSet> = sx.scatter(
        alloc
            .iter()
            .enumerate()
            .map(|(i, &c)| rq::SampleLeverage {
                count: c,
                seed: params.seed ^ (0x1e7 + i as u64),
            })
            .collect(),
    )?;
    // dedup: two workers can draw the same point (per-worker samples
    // are only locally deduplicated) — an exact duplicate in Y makes
    // K(Y,Y) singular downstream.
    let p_set = PointSet::concat_dedup(&parts);
    if !adaptive {
        return Ok(p_set);
    }
    adaptive_stage(cluster, params, p_set)
}

/// Steps 2–3 of Alg. 2: broadcast P, sample ∝ residual distance².
fn adaptive_stage(
    cluster: &Cluster,
    params: &Params,
    p_set: PointSet,
) -> Result<PointSet, CommError> {
    let mut rng = Rng::seed_from(params.seed ^ 0xa5a3);
    let sx = cluster.session("4-adaptive");
    let res_masses: Vec<f64> = sx.broadcast(rq::Residuals { pts: p_set.clone() })?;
    // Zero total mass is reachable (P already spans every shard — the
    // full-coverage CSS scenario) and NaN masses are reachable from a
    // poisoned shard; both would make the allocation undefined.
    let alloc = multinomial(&mut rng, &masses_or_uniform(&res_masses), params.n_adapt);
    let extra: Vec<PointSet> = sx.scatter(
        alloc
            .iter()
            .enumerate()
            .map(|(i, &c)| rq::SampleAdaptive {
                count: c,
                seed: params.seed ^ (0xada + i as u64),
            })
            .collect(),
    )?;
    let mut all = vec![p_set];
    all.extend(extra.into_iter().filter(|p| !p.is_empty()));
    // dedup: an adaptive draw can repeat a point already in P (and
    // cross-worker duplicates survive local dedup) — see
    // [`PointSet::concat_dedup`].
    Ok(PointSet::concat_dedup(&all))
}

/// Alg. 3 (disLR): compute the best rank-k approximation in span φ(Y).
/// Returns the solution (Y, C) with L = φ(Y)·C orthonormal.
pub fn dis_low_rank(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    y: &PointSet,
) -> Result<KpcaSolution, CommError> {
    Ok(dis_low_rank_w(cluster, kernel, params, y)?.0)
}

/// [`dis_low_rank`] that also returns the broadcast coefficient matrix
/// W and the sketch width — the round state a recovery checkpoint
/// retains so `ReqProjectSketch`/`ReqFinal` can be replayed verbatim
/// onto a revived worker.
pub fn dis_low_rank_w(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    y: &PointSet,
) -> Result<(KpcaSolution, Mat, usize), CommError> {
    let (sol, w_mat, w_cols, _preserved) = dis_low_rank_frac(cluster, kernel, params, y, None)?;
    Ok((sol, w_mat, w_cols))
}

/// Smallest k whose leading eigenvalues hold at least `frac` of the
/// spectrum's total mass. `spectrum` is non-increasing eigenvalues
/// (σᵢ² of the sketched projection); non-finite and non-positive
/// entries contribute nothing. Degenerate inputs — an empty spectrum,
/// zero total mass — return the full length (callers clamp into
/// `1..=k_max`), so the conservative answer is always "keep
/// everything you have".
pub fn choose_k(spectrum: &[f64], frac: f64) -> usize {
    let total = spectrum.iter().filter(|v| v.is_finite() && **v > 0.0).sum();
    choose_k_mass(spectrum, total, frac)
}

/// [`choose_k`] against an externally supplied total mass — the
/// low-rank driver uses ‖ΠT‖²_F (every eigenvalue, not just the k_max
/// the truncated SVD surfaced), so the fraction measures genuinely
/// preserved variance.
fn choose_k_mass(spectrum: &[f64], total: f64, frac: f64) -> usize {
    if spectrum.is_empty() || !(total > 0.0) {
        return spectrum.len();
    }
    let target = frac * total;
    let mut acc = 0.0;
    for (i, &v) in spectrum.iter().enumerate() {
        if v.is_finite() && v > 0.0 {
            acc += v;
        }
        if acc >= target {
            return i + 1;
        }
    }
    spectrum.len()
}

/// Preserved-variance mass Σᵢ σᵢ² of a kept spectrum relative to
/// `total`, clamped into [0, 1]. A zero total preserves everything by
/// convention — there was no variance to lose.
fn preserved_fraction(spectrum: &[f64], total: f64) -> f64 {
    if !(total > 0.0) {
        return 1.0;
    }
    let kept: f64 = spectrum.iter().filter(|v| v.is_finite() && **v > 0.0).sum();
    (kept / total).clamp(0.0, 1.0)
}

/// Truncate (W, σ) to the variance-fraction rank when `frac` is set:
/// k = [`choose_k`] over σᵢ² against the full mass `total`, clamped
/// into `1..=k_max`. `frac = None` keeps every column — bit-identical
/// to the historical fixed-k path. Returns (W, k, kept eigenvalues).
fn truncate_by_frac(
    w_full: Mat,
    sv: &[f64],
    total: f64,
    frac: Option<f64>,
    k_max: usize,
) -> (Mat, usize, Vec<f64>) {
    let eig: Vec<f64> = sv.iter().map(|v| v * v).collect();
    let k = match frac {
        Some(f) => choose_k_mass(&eig, total, f).clamp(1, k_max.max(1)).min(w_full.cols()),
        None => w_full.cols(),
    };
    if k == w_full.cols() {
        (w_full, k, eig)
    } else {
        let keep: Vec<usize> = (0..k).collect();
        let eig_kept = eig[..k].to_vec();
        (w_full.select_cols(&keep), k, eig_kept)
    }
}

/// [`dis_low_rank_w`] with an optional variance-fraction rank rule,
/// also reporting the preserved-variance fraction of the returned
/// solution.
///
/// With `frac = None` the rank is `params.k` exactly as
/// [`dis_low_rank_w`] always chose it — same requests, same broadcast
/// W, bit-identical solution. With `frac = Some(f)` the rank becomes
/// [`choose_k`] over the sketched spectrum (eigenvalues σᵢ², total
/// mass ‖ΠT‖²_F — the tree path uses ‖R̃‖²_F, equal in exact
/// arithmetic; both are threshold inputs only, never solution bits),
/// clamped into `1..=params.k`; W is truncated *before* the
/// `ReqFinal` broadcast, so a tighter rank also ships fewer words.
/// The preserved fraction is what [`dis_kpca_refit`] gates its
/// cold-fit fallback on.
pub fn dis_low_rank_frac(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    y: &PointSet,
    frac: Option<f64>,
) -> Result<(KpcaSolution, Mat, usize, f64), CommError> {
    let sx = cluster.session("5-disLR");
    let timing = std::env::var_os("DISKPCA_TIMING").is_some();
    let mut stamp = std::time::Instant::now();
    let mut lap = |label: &str| {
        if timing {
            eprintln!("[timing]   disLR/{label:<10} {:?}", stamp.elapsed());
        }
        stamp = std::time::Instant::now();
    };
    let s = sx.num_workers();
    let w_cols = if params.w == 0 { y.len() } else { params.w };
    let (w_mat, k, preserved) = match params.gather {
        GatherMode::Flat => {
            // step 1: workers project + right-sketch.
            let sketches: Vec<Mat> = sx.scatter(
                (0..s)
                    .map(|i| rq::ProjectSketch {
                        pts: y.clone(),
                        w: w_cols,
                        seed: params.seed ^ (0xd15 + i as u64),
                    })
                    .collect(),
            )?;
            lap("project");
            // step 2: concatenate ΠT = [Π¹T¹ … ΠˢTˢ]; top-k left
            // vectors W.
            let pit = Mat::hcat_all(&sketches);
            let k_max = params.k.min(pit.rows()).min(pit.cols());
            let (w_full, sv) = top_k_left_singular(&pit, k_max);
            let total = pit.frob_norm_sq();
            let (w_mat, k, eig) = truncate_by_frac(w_full, &sv, total, frac, k_max);
            (w_mat, k, preserved_fraction(&eig, total))
        }
        GatherMode::Tree => {
            // Same per-worker sketch (same seeds, same worker state
            // effects), replies compressed to |Y|×|Y| R factors and
            // tree-merged. The top-k left singular vectors of R̃ᵀ are
            // the eigenvectors of R̃ᵀR̃ = (ΠT)(ΠT)ᵀ — exactly the left
            // singular vectors the flat concatenation yields.
            let rs: Vec<Mat> = sx.scatter(
                (0..s)
                    .map(|i| rq::ProjectSketchR {
                        pts: y.clone(),
                        w: w_cols,
                        seed: params.seed ^ (0xd15 + i as u64),
                    })
                    .collect(),
            )?;
            lap("project");
            let rt = tsqr_merge(rs);
            let k_max = params.k.min(rt.rows()).min(rt.cols());
            let (w_full, sv) = top_k_left_singular(&rt.transpose(), k_max);
            let total = rt.frob_norm_sq();
            let (w_mat, k, eig) = truncate_by_frac(w_full, &sv, total, frac, k_max);
            (w_mat, k, preserved_fraction(&eig, total))
        }
    };
    lap("svd");
    // step 3: broadcast W; workers cache LᵀΦ(Aⁱ) = WᵀΠⁱ.
    sx.broadcast(rq::Final { coeffs: w_mat.clone() })?;
    lap("final");
    // Master-side coefficients C = R⁻¹W so that L = φ(Y)·C.
    let y_mat = y.to_mat();
    let k_yy = gram(kernel, &y_mat, &crate::data::Data::Dense(y_mat.clone()));
    let (r, _) = chol_psd(&k_yy);
    let mut coeffs = Mat::zeros(y.len(), k);
    for j in 0..k {
        coeffs.set_col(j, &solve_upper(&r, &w_mat.col(j)));
    }
    lap("coeffs");
    Ok((KpcaSolution { kernel, y: y_mat, coeffs }, w_mat, w_cols, preserved))
}

/// Alg. 4 (disKPCA): the paper's headline algorithm.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use diskpca::coordinator::{dis_kpca, run_cluster, Params};
/// use diskpca::data::{clusters, partition_power_law, Data};
/// use diskpca::kernels::Kernel;
/// use diskpca::rng::Rng;
/// use diskpca::runtime::NativeBackend;
///
/// let mut rng = Rng::seed_from(1);
/// let data = Data::Dense(clusters(6, 90, 3, 0.2, &mut rng));
/// let shards = partition_power_law(&data, 2, 3);
/// let kernel = Kernel::Gauss { gamma: 0.6 };
/// let params = Params {
///     k: 2, t: 8, p: 16, n_lev: 6, n_adapt: 10, m_rff: 128, t2: 64,
///     ..Params::default()
/// };
/// let (sol, stats) = run_cluster(
///     shards,
///     kernel,
///     Arc::new(NativeBackend::new()),
///     move |cluster| dis_kpca(cluster, kernel, &params),
/// );
/// let sol = sol.unwrap();               // a worker failure would be Err
/// assert_eq!(sol.k(), 2);                // k components, as (Y, C)
/// assert!(sol.num_points() >= 1);        // |Y| sampled representatives
/// assert!(stats.total_words() > 0);      // every round was accounted
/// ```
pub fn dis_kpca(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
) -> Result<KpcaSolution, CommError> {
    dis_kpca_mode(cluster, kernel, params, SamplingMode::Full)
}

/// disKPCA with an ablated sampling stage (DESIGN.md ablations).
///
/// Set `DISKPCA_TIMING=1` to print per-round wall times to stderr —
/// the §Perf first-stop for locating protocol bottlenecks.
pub fn dis_kpca_mode(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    mode: SamplingMode,
) -> Result<KpcaSolution, CommError> {
    dis_kpca_warm(cluster, kernel, params, mode, false)
}

/// [`dis_kpca_mode`] with an explicit warm-start flag (the serve
/// layer's entry point). `embed_installed = true` asserts every worker
/// already holds E^i for exactly [`embed_spec_for`]`(kernel, params)`
/// — the `1-embed` broadcast is then skipped *entirely* (zero words in
/// that round). Bit-identity-safe: the embedding is a deterministic
/// function of (spec, shard), so a worker's cached E^i equals what the
/// skipped round would have rebuilt.
pub fn dis_kpca_warm(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    mode: SamplingMode,
    embed_installed: bool,
) -> Result<KpcaSolution, CommError> {
    params.apply_threads();
    let timing = std::env::var_os("DISKPCA_TIMING").is_some();
    let mut stamp = std::time::Instant::now();
    let mut lap = |label: &str| {
        if timing {
            eprintln!("[timing] {label:<12} {:?}", stamp.elapsed());
        }
        stamp = std::time::Instant::now();
    };
    let spec = embed_spec_for(kernel, params);
    let y = if mode == SamplingMode::AdaptiveOnly {
        // no embedding/leverage rounds at all in this ablation
        rep_sample_mode(cluster, params, &[], mode)?
    } else {
        if !embed_installed {
            dis_embed(cluster, spec)?;
        }
        lap("embed");
        let masses = dis_leverage_scores(cluster, params)?;
        lap("disLS");
        rep_sample_mode(cluster, params, &masses, mode)?
    };
    lap("repSample");
    let sol = dis_low_rank(cluster, kernel, params, &y)?;
    lap("disLR");
    Ok(sol)
}

/// Round `0-refresh`: every worker re-opens its disk-backed shard so
/// appends committed since the installed fit become visible, and
/// reports its delta relative to `epoch` (the epoch the installed
/// solution was fitted at). Returns `(max shard epoch, total delta
/// columns)` across the cluster. Resident shards are immutable and
/// report `[0, 0, n]`; a cluster of only resident shards therefore
/// always refreshes to `(0, 0)`.
pub fn dis_refresh_shards(cluster: &Cluster, epoch: u64) -> Result<(u64, usize), CommError> {
    let reports = cluster.session("0-refresh").broadcast(rq::RefreshShard { epoch })?;
    let mut max_epoch = 0u64;
    let mut delta = 0usize;
    for m in &reports {
        max_epoch = max_epoch.max(m[(0, 0)] as u64);
        delta += m[(0, 1)] as usize;
    }
    Ok((max_epoch, delta))
}

/// Incremental twin of [`dis_leverage_scores`]: identical round label
/// (`2-disLS`), identical request/reply word counts
/// (`ReqDeltaSketch.words() == ReqSketchEmbed.words()` by
/// construction), identical masses bit-for-bit — but each worker only
/// folds the columns appended since its retained sketch accumulator,
/// so the per-worker compute is O(delta) instead of O(nᵢ). The tree
/// gather compresses replies to R factors, which cannot be extended
/// incrementally — it falls back to the plain round (already
/// delta-free in words; the compute saving simply doesn't apply).
pub fn dis_leverage_scores_delta(
    cluster: &Cluster,
    params: &Params,
) -> Result<Vec<f64>, CommError> {
    if params.gather == GatherMode::Tree {
        return dis_leverage_scores(cluster, params);
    }
    let sx = cluster.session("2-disLS");
    let s = sx.num_workers();
    let sketches: Vec<Mat> = sx.scatter(
        (0..s)
            .map(|i| rq::DeltaSketch {
                p: params.p,
                seed: params.seed ^ (0x515 + i as u64),
            })
            .collect(),
    )?;
    let transposed: Vec<Mat> = crate::par::par_join(
        sketches.iter().map(|sk| move || sk.transpose()).collect::<Vec<_>>(),
    );
    let z = qr_r_only(&Mat::vcat_all(&transposed));
    sx.broadcast(rq::Scores { z })
}

/// What [`dis_kpca_refit`] produced and how it got there.
#[derive(Clone, Debug)]
pub struct RefitReport {
    /// The refreshed solution, installed on every worker.
    pub solution: KpcaSolution,
    /// Data epoch the solution now covers (max across shards).
    pub epoch: u64,
    /// Appended columns folded in (total across shards, relative to
    /// the epoch the previous fit covered).
    pub delta_cols: usize,
    /// `true` when the preserved-variance gate failed and the refit
    /// re-ran as a full cold fit (fresh `1-embed` round, no retained
    /// state trusted).
    pub fell_back: bool,
}

/// Incremental warm refit after shard appends — the epoch-aware
/// counterpart of [`dis_kpca_warm`].
///
/// Preconditions: a fit with the *same* `params` was previously run
/// on this cluster, so every worker still holds its embed state (the
/// spec under streaming, E^i under resident — the serve scheduler's
/// warm-embed reuse tracks exactly this) and, ideally, its disLS
/// sketch accumulator. The rounds are then:
///
/// 1. `0-refresh` — workers re-open shards, report epochs + deltas.
/// 2. `2-disLS` via [`dis_leverage_scores_delta`] — O(delta)
///    per-worker sketch work, no `1-embed` broadcast at all.
/// 3. `3-levSample`/`4-adaptive`/`5-disLR` — verbatim the cold
///    rounds (same seeds, same word counts).
///
/// The result is **bit-identical** to a cold [`dis_kpca`] over the
/// appended shards (`tests/incremental_parity.rs` pins this,
/// per-round word tables included), while shipping strictly fewer
/// total words (no embed round; the `0-refresh` round is 4 words per
/// worker) and doing delta-sized sketch work. If the top-k solution
/// preserves less than `variance_frac` of the sketched spectrum's
/// mass, the refit distrusts warm state entirely and re-runs as a
/// cold fit (`fell_back = true`).
pub fn dis_kpca_refit(
    cluster: &Cluster,
    kernel: Kernel,
    params: &Params,
    installed_epoch: u64,
    variance_frac: f64,
) -> Result<RefitReport, CommError> {
    params.apply_threads();
    let (epoch, delta_cols) = dis_refresh_shards(cluster, installed_epoch)?;
    let masses = dis_leverage_scores_delta(cluster, params)?;
    let y = rep_sample_mode(cluster, params, &masses, SamplingMode::Full)?;
    let (solution, _w, _wc, preserved) = dis_low_rank_frac(cluster, kernel, params, &y, None)?;
    if preserved >= variance_frac {
        Ok(RefitReport { solution, epoch, delta_cols, fell_back: false })
    } else {
        let solution = dis_kpca_warm(cluster, kernel, params, SamplingMode::Full, false)?;
        Ok(RefitReport { solution, epoch, delta_cols, fell_back: true })
    }
}

/// Distributed evaluation: (‖φ(A) − LLᵀφ(A)‖², tr K) for the solution
/// currently installed on the workers.
pub fn dis_eval(cluster: &Cluster) -> Result<(f64, f64), CommError> {
    let sx = cluster.session("6-eval");
    let err = sx.broadcast(rq::EvalError)?.into_iter().sum();
    let trace = sx.broadcast(rq::EvalTrace)?.into_iter().sum();
    Ok((err, trace))
}

/// Per-worker cumulative compute seconds (Fig-7 critical path: on a
/// single-core testbed, `max` over workers simulates the parallel
/// runtime an s-machine cluster would see).
pub fn dis_busy_times(cluster: &Cluster) -> Result<Vec<f64>, CommError> {
    cluster.session("8-stats").broadcast(rq::BusyTime)
}

/// Project a batch of new points (d×n, columns are points) through the
/// solution installed on the workers, pipelining the query stream:
/// up to `pipeline_depth` super-chunks of `workers × per_worker_cols`
/// columns are kept in flight at once
/// ([`Cluster::scatter_begin`]/[`Cluster::finish_scatter`]), so a
/// streaming worker's chunk I/O for super-chunk n overlaps the
/// master-side assembly — and the other workers' compute — of
/// super-chunk n−1. Results are assembled in issue order, so the
/// output is bitwise independent of `pipeline_depth`; depth 1 is
/// exactly the old scatter-per-chunk loop. Accounted under
/// `10-transform`.
///
/// An empty batch returns an empty `0×0` matrix without any
/// communication — the solution's `k` is unknown master-side until a
/// worker replies, so the k×0 shape cannot be produced.
pub fn dis_project_points(
    cluster: &Cluster,
    batch: &Mat,
    per_worker_cols: usize,
    pipeline_depth: usize,
) -> Result<Mat, CommError> {
    let n = batch.cols();
    let s = cluster.num_workers();
    if n == 0 {
        return Ok(Mat::zeros(0, 0));
    }
    cluster.set_round("10-transform");
    let super_cols = per_worker_cols.max(1) * s;
    let depth = pipeline_depth.max(1);
    let mut out: Option<Mat> = None;
    let mut inflight: VecDeque<(Vec<usize>, Inflight<rq::ProjectPoints>)> = VecDeque::new();
    let mut j0 = 0;
    loop {
        // keep the wire full: issue until `depth` super-chunks are in
        // flight or the batch is drained
        while j0 < n && inflight.len() < depth {
            let j1 = (j0 + super_cols).min(n);
            let cols = j1 - j0;
            // split [j0, j1) over workers as evenly as possible
            let bounds: Vec<usize> = (0..=s).map(|w| j0 + cols * w / s).collect();
            let reqs: Vec<rq::ProjectPoints> = (0..s)
                .map(|w| {
                    let idx: Vec<usize> = (bounds[w]..bounds[w + 1]).collect();
                    rq::ProjectPoints { pts: PointSet::Dense(batch.select_cols(&idx)) }
                })
                .collect();
            inflight.push_back((bounds, cluster.scatter_begin(reqs)?));
            j0 = j1;
        }
        let Some((bounds, fly)) = inflight.pop_front() else {
            break;
        };
        let parts = cluster.finish_scatter(fly)?;
        for (w, part) in parts.iter().enumerate() {
            let out_m = out.get_or_insert_with(|| Mat::zeros(part.rows(), n));
            for (jj, j) in (bounds[w]..bounds[w + 1]).enumerate() {
                for i in 0..part.rows() {
                    out_m[(i, j)] = part[(i, jj)];
                }
            }
        }
    }
    Ok(out.expect("n > 0 produced at least one scatter"))
}

/// Install an externally computed solution (baselines) on all workers.
pub fn dis_set_solution(cluster: &Cluster, sol: &KpcaSolution) -> Result<(), CommError> {
    cluster.session("5-setSolution").broadcast(rq::SetSolution {
        pts: PointSet::Dense(sol.y.clone()),
        coeffs: sol.coeffs.clone(),
    })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression: zero-total and NaN mass vectors must fall back to a
    /// deterministic uniform allocation; healthy masses pass through
    /// bit-identically.
    #[test]
    fn masses_or_uniform_guards_degenerate_vectors() {
        assert_eq!(masses_or_uniform(&[1.5, 2.5, 0.0]), vec![1.5, 2.5, 0.0]);
        assert_eq!(masses_or_uniform(&[0.0, 0.0, 0.0]), vec![1.0, 1.0, 1.0]);
        assert_eq!(masses_or_uniform(&[f64::NAN, 3.0]), vec![1.0, 1.0]);
        assert_eq!(masses_or_uniform(&[f64::INFINITY, 1.0]), vec![1.0, 1.0]);
        assert_eq!(masses_or_uniform(&[-1.0, 0.5]), vec![1.0, 1.0]);
    }

    /// `choose_k` picks the smallest prefix holding the requested
    /// eigenvalue mass, and degenerate spectra degrade to "keep all".
    #[test]
    fn choose_k_selects_minimal_rank_for_mass() {
        let sp = [6.0, 3.0, 0.9, 0.1];
        assert_eq!(choose_k(&sp, 0.5), 1); // 6/10
        assert_eq!(choose_k(&sp, 0.6), 1);
        assert_eq!(choose_k(&sp, 0.61), 2); // 9/10
        assert_eq!(choose_k(&sp, 0.9), 2);
        assert_eq!(choose_k(&sp, 0.95), 3); // 9.9/10
        assert_eq!(choose_k(&sp, 1.0), 4);
        // frac ≤ 0 keeps the minimum one component
        assert_eq!(choose_k(&sp, 0.0), 1);
        // negative / NaN entries carry no mass but occupy a slot
        assert_eq!(choose_k(&[4.0, f64::NAN, -2.0, 4.0], 0.9), 4);
        // degenerate spectra: keep everything
        assert_eq!(choose_k(&[], 0.9), 0);
        assert_eq!(choose_k(&[0.0, 0.0], 0.9), 2);
    }

    /// `choose_k_mass` against a larger external total (the ‖ΠT‖²_F
    /// the low-rank driver feeds it) needs more components than the
    /// truncated-spectrum view would suggest.
    #[test]
    fn choose_k_mass_uses_external_total() {
        let sp = [6.0, 3.0];
        // against its own total (9): one component holds 2/3
        assert_eq!(choose_k_mass(&sp, 9.0, 0.66), 1);
        // against the full mass 12, 6/12 = 0.5 < 0.66 → need both
        assert_eq!(choose_k_mass(&sp, 12.0, 0.66), 2);
        // unreachable target: keep the whole truncated spectrum
        assert_eq!(choose_k_mass(&sp, 100.0, 0.66), 2);
        assert_eq!(preserved_fraction(&sp, 12.0), 0.75);
        assert_eq!(preserved_fraction(&sp, 0.0), 1.0);
    }

    /// The truncation helper: `None` is the identity; `Some` clamps
    /// into `1..=k_max` and drops trailing W columns + eigenvalues.
    #[test]
    fn truncate_by_frac_respects_clamp_and_none() {
        let w = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as f64);
        let sv = [3.0, 2.0, 1.0]; // eig 9, 4, 1 (total 14)
        let (w_none, k_none, eig) = truncate_by_frac(w.clone(), &sv, 14.0, None, 3);
        assert_eq!((k_none, eig.len()), (3, 3));
        assert!(w_none.data() == w.data());
        let (w_cut, k_cut, eig_cut) = truncate_by_frac(w.clone(), &sv, 14.0, Some(0.6), 3);
        assert_eq!((k_cut, w_cut.cols(), eig_cut.len()), (1, 1, 1));
        assert_eq!(eig_cut[0], 9.0);
        for i in 0..5 {
            assert_eq!(w_cut[(i, 0)], w[(i, 0)]);
        }
        // an impossible fraction keeps every column
        let (_, k_all, _) = truncate_by_frac(w, &sv, 14.0, Some(1.0), 3);
        assert_eq!(k_all, 3);
    }
}
