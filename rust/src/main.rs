//! `diskpca` launcher: CLI → experiment drivers.
//!
//! The binary is self-contained after `make artifacts` — python never
//! runs from here (the XLA backend loads pre-lowered HLO text).

use diskpca::cli;
use diskpca::experiments::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = cli::parse(args).map_err(|e| anyhow::anyhow!(e))?;
    if parsed.command == "help" || parsed.command == "--help" || parsed.command == "-h" {
        println!("{}", cli::USAGE);
        return Ok(());
    }
    let ctx = Ctx::from_config(&parsed.config)?;
    let dataset = parsed
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("har_like");
    match parsed.command.as_str() {
        "run" => experiments::run_one(&ctx, dataset)?,
        "table1" => experiments::table1(&ctx)?,
        "fig2" => experiments::fig_small_vs_batch(&ctx, "poly", "fig2")?,
        "fig3" => experiments::fig_small_vs_batch(&ctx, "gauss", "fig3")?,
        "fig4" => experiments::fig_comm_tradeoff(
            &ctx,
            "poly",
            &["bow_like", "mnist8m_like", "susy_like", "higgs_like"],
            "fig4",
        )?,
        "fig5" => experiments::fig_comm_tradeoff(
            &ctx,
            "gauss",
            &["bow_like", "mnist8m_like", "susy_like", "higgs_like"],
            "fig5",
        )?,
        "fig6" => experiments::fig_comm_tradeoff(
            &ctx,
            "arccos",
            &["news20_like", "ctslice_like"],
            "fig6",
        )?,
        "fig7" => experiments::fig7(&ctx)?,
        "fig8" => experiments::fig8(&ctx)?,
        // extension (not in the paper): Laplacian kernel — another
        // shift-invariant family with a Fourier feature expansion
        "figL" => experiments::fig_comm_tradeoff(
            &ctx,
            "laplace",
            &["susy_like", "ctslice_like"],
            "figL",
        )?,
        "css" => experiments::css_report(&ctx, dataset)?,
        "bench-comm" => experiments::bench_comm(&ctx, dataset)?,
        "ablation" => experiments::ablation(&ctx, dataset)?,
        "master" => exit_on_launch_error(diskpca::launcher::master(&parsed.config)),
        "worker" => exit_on_launch_error(diskpca::launcher::worker(&parsed.config)),
        "serve" => exit_on_launch_error(diskpca::launcher::serve(&parsed.config, dataset)),
        "shard" => diskpca::launcher::shard(&parsed.config, dataset)?,
        other => {
            eprintln!("unknown command `{other}`\n\n{}", cli::USAGE);
            std::process::exit(2);
        }
    }
    Ok(())
}

/// The deployment subcommands map failures to distinct exit codes
/// (see `cli::USAGE`): protocol failures — a worker died or reported
/// an error mid-round — exit with `launcher::EXIT_PROTOCOL`;
/// environment failures with `launcher::EXIT_ENV`.
fn exit_on_launch_error(result: Result<(), diskpca::launcher::LaunchError>) {
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(e.exit_code());
    }
}
