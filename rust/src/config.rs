//! Experiment configuration: defaults + file/flag overrides.
//!
//! A config is a flat key=value set loadable from a simple
//! `key = value` file (comments with `#`) and overridable from CLI
//! flags (`--key value`). Typed accessors with defaults keep call
//! sites honest.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Config {
    vals: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key = value` lines; `#` starts a comment.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = Self::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.set(k.trim(), v.trim());
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.vals.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("config {key}={v}: not a usize")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("config {key}={v}: not a float")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("config {key}={v}: not a u64")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true" | "1" | "yes") => true,
            Some("false" | "0" | "no") => false,
            Some(v) => panic!("config {key}={v}: not a bool"),
            None => default,
        }
    }

    /// Merge overrides (other wins).
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.vals {
            self.vals.insert(k.clone(), v.clone());
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.vals.keys().map(|s| s.as_str())
    }

    /// The numeric compute tier encoded in this config (CLI flag
    /// `--compute-tier`; the underscore spelling works in config
    /// files, the dash spelling wins when both are present). Not a
    /// [`crate::coordinator::Params`] field — the tier is process-wide
    /// state ([`crate::linalg::simd::set_compute_tier`]), applied by
    /// the launcher entry points, and `exact` when unset.
    pub fn compute_tier(&self) -> crate::linalg::simd::ComputeTier {
        let raw = self.get("compute-tier").or_else(|| self.get("compute_tier"));
        match raw {
            None => crate::linalg::simd::ComputeTier::Exact,
            Some(v) => crate::linalg::simd::ComputeTier::from_name(v)
                .unwrap_or_else(|| panic!("config compute-tier={v}: expected exact|fast")),
        }
    }

    /// The refit variance gate encoded in this config (CLI flag
    /// `--variance-frac`; the underscore spelling works in config
    /// files, the dash spelling wins when both are present). Validated
    /// through the same strict parser as `DISKPCA_VARIANCE_FRAC`:
    /// a fraction in `(0, 1]`, default 0.95.
    pub fn variance_frac(&self) -> f64 {
        let raw = self.get("variance-frac").or_else(|| self.get("variance_frac"));
        match crate::serve::queue::parse_variance_frac(
            raw,
            crate::serve::ServeConfig::default().variance_frac,
        ) {
            Ok(f) => f,
            // the env-style message names DISKPCA_VARIANCE_FRAC;
            // re-key it to the config spelling
            Err(_) => panic!(
                "config variance-frac={}: expected a fraction in (0, 1]",
                raw.unwrap_or_default()
            ),
        }
    }

    /// The protocol parameters encoded in this config.
    pub fn params(&self) -> crate::coordinator::Params {
        let d = crate::coordinator::Params::default();
        crate::coordinator::Params {
            k: self.usize_or("k", d.k),
            t: self.usize_or("t", d.t),
            p: self.usize_or("p", d.p),
            n_lev: self.usize_or("n_lev", d.n_lev),
            n_adapt: self.usize_or("n_adapt", d.n_adapt),
            w: self.usize_or("w", d.w),
            m_rff: self.usize_or("m_rff", d.m_rff),
            t2: self.usize_or("t2", d.t2),
            seed: self.u64_or("seed", d.seed),
            threads: self.usize_or("threads", d.threads),
            // the CLI flag is `--chunk-rows`; accept the underscore
            // spelling too for config files
            chunk_rows: self.usize_or("chunk-rows", self.usize_or("chunk_rows", d.chunk_rows)),
            gather: match self.str_or("gather", "flat") {
                "flat" => crate::coordinator::GatherMode::Flat,
                "tree" => crate::coordinator::GatherMode::Tree,
                v => panic!("config gather={v}: expected flat|tree"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_access() {
        let cfg = Config::parse(
            "k = 10\n# comment\nscale=0.5  # trailing\nname = bow_like\nflag = true\n",
        )
        .unwrap();
        assert_eq!(cfg.usize_or("k", 0), 10);
        assert_eq!(cfg.f64_or("scale", 0.0), 0.5);
        assert_eq!(cfg.str_or("name", ""), "bow_like");
        assert!(cfg.bool_or("flag", false));
        assert_eq!(cfg.usize_or("absent", 7), 7);
    }

    #[test]
    fn parse_rejects_bad_lines() {
        assert!(Config::parse("novalue\n").is_err());
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("k = 1\nt = 2\n").unwrap();
        let b = Config::parse("t = 9\n").unwrap();
        a.merge(&b);
        assert_eq!(a.usize_or("k", 0), 1);
        assert_eq!(a.usize_or("t", 0), 9);
    }

    #[test]
    fn params_from_config() {
        let cfg = Config::parse("k = 5\nn_adapt = 77\nseed = 3\n").unwrap();
        let p = cfg.params();
        assert_eq!(p.k, 5);
        assert_eq!(p.n_adapt, 77);
        assert_eq!(p.seed, 3);
        assert_eq!(p.p, 250); // default preserved
        assert_eq!(p.chunk_rows, 0); // resident by default
    }

    #[test]
    fn chunk_rows_both_spellings() {
        let cfg = Config::parse("chunk_rows = 128\n").unwrap();
        assert_eq!(cfg.params().chunk_rows, 128);
        // the CLI flag spelling wins when both are present
        let cfg = Config::parse("chunk_rows = 128\nchunk-rows = 64\n").unwrap();
        assert_eq!(cfg.params().chunk_rows, 64);
    }

    #[test]
    #[should_panic]
    fn bad_type_panics() {
        let cfg = Config::parse("k = abc\n").unwrap();
        cfg.usize_or("k", 0);
    }

    #[test]
    fn compute_tier_both_spellings_default_exact() {
        use crate::linalg::simd::ComputeTier;
        assert_eq!(Config::new().compute_tier(), ComputeTier::Exact);
        let cfg = Config::parse("compute_tier = fast\n").unwrap();
        assert_eq!(cfg.compute_tier(), ComputeTier::Fast);
        // the CLI flag spelling wins when both are present
        let cfg = Config::parse("compute_tier = fast\ncompute-tier = exact\n").unwrap();
        assert_eq!(cfg.compute_tier(), ComputeTier::Exact);
    }

    #[test]
    #[should_panic(expected = "config compute-tier=turbo")]
    fn bad_compute_tier_panics() {
        let cfg = Config::parse("compute-tier = turbo\n").unwrap();
        cfg.compute_tier();
    }

    #[test]
    fn variance_frac_both_spellings_default() {
        assert_eq!(Config::new().variance_frac(), 0.95);
        let cfg = Config::parse("variance_frac = 0.8\n").unwrap();
        assert_eq!(cfg.variance_frac(), 0.8);
        // the CLI flag spelling wins when both are present
        let cfg = Config::parse("variance_frac = 0.8\nvariance-frac = 0.6\n").unwrap();
        assert_eq!(cfg.variance_frac(), 0.6);
    }

    #[test]
    #[should_panic(expected = "config variance-frac=1.5")]
    fn bad_variance_frac_panics() {
        let cfg = Config::parse("variance-frac = 1.5\n").unwrap();
        cfg.variance_frac();
    }
}
