//! Kernel functions, gram blocks and random feature expansions —
//! native (f64) reference implementations. The XLA artifacts compute
//! the same maps in f32 on the hot path; integration tests compare
//! the two (`tests/runtime_parity.rs`).

use crate::data::Data;
use crate::linalg::{dot, Mat};
use crate::rng::Rng;

/// The three kernel families the paper evaluates (§6.2), plus the
/// Laplacian — another shift-invariant kernel with a Fourier random
/// feature expansion (Cauchy spectral density), covered by Theorem 1's
/// "other properly regularized kernels" remark.
///
/// # Examples
///
/// ```
/// use diskpca::kernels::Kernel;
///
/// let k = Kernel::Gauss { gamma: 0.5 };
/// let x = [1.0, 0.0];
/// let y = [0.0, 1.0];
/// assert!((k.eval(&x, &x) - 1.0).abs() < 1e-12);
/// assert!((k.eval(&x, &y) - (-1.0f64).exp()).abs() < 1e-12);
///
/// let p = Kernel::Poly { q: 2 };
/// assert!((p.eval(&[2.0, 0.0], &[3.0, 1.0]) - 36.0).abs() < 1e-12);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// exp(-γ‖x−y‖²); the paper's σ via median trick, γ = 1/(2σ²).
    Gauss { gamma: f64 },
    /// ⟨x,y⟩^q (homogeneous, the paper's form; q=4 in experiments).
    Poly { q: u32 },
    /// Cho–Saul arc-cosine kernel of degree 0/1/2 (n=2 in the paper).
    ArcCos { degree: u32 },
    /// exp(-γ‖x−y‖₁); Fourier features with ω ~ Cauchy(0, γ) per
    /// coordinate (the Fourier transform of the Laplacian).
    Laplace { gamma: f64 },
}

impl Kernel {
    pub fn name(&self) -> String {
        match self {
            Kernel::Gauss { gamma } => format!("gauss(γ={gamma:.4})"),
            Kernel::Poly { q } => format!("poly(q={q})"),
            Kernel::ArcCos { degree } => format!("arccos(n={degree})"),
            Kernel::Laplace { gamma } => format!("laplace(γ={gamma:.4})"),
        }
    }

    /// κ(x, y) on dense vectors.
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        match *self {
            Kernel::Gauss { gamma } => {
                let mut d2 = 0.0;
                for i in 0..x.len() {
                    let d = x[i] - y[i];
                    d2 += d * d;
                }
                (-gamma * d2).exp()
            }
            Kernel::Poly { q } => dot(x, y).powi(q as i32),
            Kernel::ArcCos { degree } => {
                let nx = dot(x, x).sqrt();
                let ny = dot(y, y).sqrt();
                arccos_from_parts(dot(x, y), nx, ny, degree)
            }
            Kernel::Laplace { gamma } => {
                let mut d1 = 0.0;
                for i in 0..x.len() {
                    d1 += (x[i] - y[i]).abs();
                }
                (-gamma * d1).exp()
            }
        }
    }

    /// κ(x, x) — needed for residual distances without forming grams.
    pub fn diag(&self, x_norm_sq: f64) -> f64 {
        match *self {
            Kernel::Gauss { .. } | Kernel::Laplace { .. } => 1.0,
            Kernel::Poly { q } => x_norm_sq.powi(q as i32),
            Kernel::ArcCos { degree } => match degree {
                0 => 1.0,
                1 => x_norm_sq, // (1/π)‖x‖²·J₁(0)=π ⇒ ‖x‖²
                2 => 3.0 * x_norm_sq * x_norm_sq, // J₂(0)=3π
                _ => panic!("arccos degree {degree} unsupported"),
            },
        }
    }
}

/// Shared arc-cos formula from (⟨x,y⟩, ‖x‖, ‖y‖).
fn arccos_from_parts(xy: f64, nx: f64, ny: f64, degree: u32) -> f64 {
    let denom = (nx * ny).max(1e-300);
    let cos_t = (xy / denom).clamp(-1.0, 1.0);
    let theta = cos_t.acos();
    let sin_t = (1.0 - cos_t * cos_t).max(0.0).sqrt();
    let pi = std::f64::consts::PI;
    let (j, scale) = match degree {
        0 => (pi - theta, 1.0),
        1 => (sin_t + (pi - theta) * cos_t, nx * ny),
        2 => (
            3.0 * sin_t * cos_t + (pi - theta) * (1.0 + 2.0 * cos_t * cos_t),
            (nx * ny) * (nx * ny),
        ),
        _ => panic!("arccos degree {degree} unsupported"),
    };
    scale * j / pi
}

/// Gram block `K(Y, X)` with Y dense (d×|Y|) and X a data shard:
/// returns |Y|×n. Sparse shards use O(nnz) dot products.
///
/// Row-parallel on the [`crate::par`] pool for large blocks; every
/// output entry is computed by exactly one chunk with the same
/// operations as the serial loop, so results are bit-identical for
/// any thread count.
pub fn gram(kernel: Kernel, y: &Mat, x: &Data) -> Mat {
    let ny = y.cols();
    let n = x.len();
    assert_eq!(y.rows(), x.dim());
    if let Kernel::Laplace { gamma } = kernel {
        return gram_laplace(gamma, y, x);
    }
    let ycols: Vec<Vec<f64>> = (0..ny).map(|j| y.col(j)).collect();
    let ynorms: Vec<f64> = ycols.iter().map(|c| dot(c, c)).collect();
    let mut out = Mat::zeros(ny, n);
    if ny == 0 || n == 0 {
        return out;
    }
    match x {
        Data::Dense(xd) => {
            // one matmul for all inner products — the packed
            // register-tiled engine (`linalg::gemm`) — then a fused
            // elementwise kernel map; mirrors the L1 tiling.
            let dots = y.matmul_at_b(xd); // ny×n
            let xnorms = xd.col_norms_sq();
            // fast tier: stage the Gauss exponents into the output row
            // and exponentiate with the branchless polynomial exp (the
            // other families' maps have no transcendental hot loop)
            let fast_gauss = crate::linalg::simd::fast_tier_active()
                && matches!(kernel, Kernel::Gauss { .. });
            let body = |i0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for r in 0..rows {
                    let i = i0 + r;
                    let yn = ynorms[i];
                    let drow = dots.row(i);
                    let orow = &mut chunk[r * n..(r + 1) * n];
                    if let (true, Kernel::Gauss { gamma }) = (fast_gauss, kernel) {
                        for j in 0..n {
                            orow[j] = -gamma * (yn + xnorms[j] - 2.0 * drow[j]).max(0.0);
                        }
                        crate::linalg::simd::map_exp_fast(orow);
                    } else {
                        for j in 0..n {
                            orow[j] = gram_entry(kernel, drow[j], yn, xnorms[j]);
                        }
                    }
                }
            };
            if crate::linalg::parallel_worthwhile(ny * n, 8) {
                crate::par::par_chunks(out.data_mut(), n, body);
            } else {
                body(0, out.data_mut());
            }
        }
        Data::Sparse(xs) => {
            // one O(nnz) norm pass, shared by every chunk
            let xnorms: Vec<f64> = (0..n).map(|j| xs.col_norm_sq(j)).collect();
            let body = |i0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for j in 0..n {
                    let xn = xnorms[j];
                    for r in 0..rows {
                        let i = i0 + r;
                        let xy = xs.col_dot_dense(j, &ycols[i]);
                        chunk[r * n + j] = gram_entry(kernel, xy, ynorms[i], xn);
                    }
                }
            };
            if crate::linalg::parallel_worthwhile(ny * n, 16) {
                crate::par::par_chunks(out.data_mut(), n, body);
            } else {
                body(0, out.data_mut());
            }
        }
    }
    out
}

#[inline]
fn gram_entry(kernel: Kernel, xy: f64, ynorm_sq: f64, xnorm_sq: f64) -> f64 {
    match kernel {
        Kernel::Gauss { gamma } => (-gamma * (ynorm_sq + xnorm_sq - 2.0 * xy).max(0.0)).exp(),
        Kernel::Poly { q } => xy.powi(q as i32),
        Kernel::ArcCos { degree } => {
            arccos_from_parts(xy, ynorm_sq.sqrt(), xnorm_sq.sqrt(), degree)
        }
        Kernel::Laplace { .. } => unreachable!("laplace uses gram_laplace"),
    }
}

/// ‖a − b‖₁ with four independent accumulators (same reassociation
/// reasoning as `linalg::dot` — §Perf #9).
#[inline]
fn l1_dist(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += (a[i] - b[i]).abs();
        s1 += (a[i + 1] - b[i + 1]).abs();
        s2 += (a[i + 2] - b[i + 2]).abs();
        s3 += (a[i + 3] - b[i + 3]).abs();
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        acc += (a[i] - b[i]).abs();
    }
    acc
}

/// Laplacian gram block: L1 distances don't factor through inner
/// products, so compute them directly. Sparse shards use the identity
/// ‖x − y‖₁ = ‖y‖₁ + Σ_{r∈nnz(x)} (|x_r − y_r| − |y_r|) for O(nnz·|Y|)
/// instead of O(d·n·|Y|).
fn gram_laplace(gamma: f64, y: &Mat, x: &Data) -> Mat {
    let ny = y.cols();
    let n = x.len();
    let ycols: Vec<Vec<f64>> = (0..ny).map(|j| y.col(j)).collect();
    let mut out = Mat::zeros(ny, n);
    if ny == 0 || n == 0 {
        return out;
    }
    let d = y.rows();
    match x {
        Data::Dense(xd) => {
            // materialize the shard columns once (not once per chunk)
            let xcols: Vec<Vec<f64>> = (0..n).map(|j| xd.col(j)).collect();
            let fast = crate::linalg::simd::fast_tier_active();
            let body = |i0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for (j, xc) in xcols.iter().enumerate() {
                    for r in 0..rows {
                        let d1 = l1_dist(xc, &ycols[i0 + r]);
                        // fast tier: stage the exponent, map below
                        chunk[r * n + j] =
                            if fast { -gamma * d1 } else { (-gamma * d1).exp() };
                    }
                }
                if fast {
                    for r in 0..rows {
                        crate::linalg::simd::map_exp_fast(&mut chunk[r * n..(r + 1) * n]);
                    }
                }
            };
            if crate::linalg::parallel_worthwhile(ny * n, d) {
                crate::par::par_chunks(out.data_mut(), n, body);
            } else {
                body(0, out.data_mut());
            }
        }
        Data::Sparse(xs) => {
            let ybase: Vec<f64> = ycols.iter().map(|c| c.iter().map(|v| v.abs()).sum()).collect();
            let body = |i0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for j in 0..n {
                    for r in 0..rows {
                        let i = i0 + r;
                        let yc = &ycols[i];
                        let mut d1 = ybase[i];
                        for (rr, v) in xs.col_iter(j) {
                            d1 += (v - yc[rr]).abs() - yc[rr].abs();
                        }
                        chunk[r * n + j] = (-gamma * d1.max(0.0)).exp();
                    }
                }
            };
            if crate::linalg::parallel_worthwhile(ny * n, 16) {
                crate::par::par_chunks(out.data_mut(), n, body);
            } else {
                body(0, out.data_mut());
            }
        }
    }
    out
}

/// Dense symmetric gram `K(Y, Y)` for a d×m matrix of points.
pub fn gram_sym(kernel: Kernel, y: &Mat) -> Mat {
    gram(kernel, y, &Data::Dense(y.clone()))
}

/// κ(x_j, x_j) for every point of a shard.
pub fn diag(kernel: Kernel, x: &Data) -> Vec<f64> {
    let mut out = Vec::new();
    diag_into(kernel, x, &mut out);
    out
}

/// [`diag`] into a caller-owned buffer (cleared first) — the streaming
/// worker's chunk loop reuses one buffer across all chunks of a pass
/// instead of allocating per chunk. Values identical to [`diag`].
pub fn diag_into(kernel: Kernel, x: &Data, out: &mut Vec<f64>) {
    out.clear();
    out.extend((0..x.len()).map(|j| kernel.diag(x.col_norm_sq(j))));
}

/// Σⱼ κ(xⱼ, xⱼ) — a sequential left-to-right fold over the whole
/// shard. NOTE: f64 addition is not associative, so chunked callers
/// must NOT sum per-chunk partials of this; the streaming eval path
/// instead folds [`diag`] values one element at a time across chunks,
/// which reproduces this whole-shard fold bit for bit.
pub fn diag_sum(kernel: Kernel, x: &Data) -> f64 {
    (0..x.len()).map(|j| kernel.diag(x.col_norm_sq(j))).sum()
}

// ------------------------------------------------------------------
// Random feature expansions (paper §3 "Kernels and Random Features")
// ------------------------------------------------------------------

/// Fourier features for the Gaussian kernel exp(-γ‖x−y‖²):
/// ω ~ N(0, 2γ·I) (since κ(x−y)=exp(-‖δ‖²/2σ²) ⇔ ω ~ N(0, σ⁻²I) with
/// γ = 1/(2σ²) ⇒ σ⁻² = 2γ), b ~ U[0, 2π).
pub struct RffParams {
    /// d×m frequency matrix.
    pub omega: Mat,
    /// m phase offsets.
    pub b: Vec<f64>,
}

pub fn rff_params(d: usize, m: usize, gamma: f64, rng: &mut Rng) -> RffParams {
    let sd = (2.0 * gamma).sqrt();
    RffParams {
        omega: Mat::from_fn(d, m, |_, _| rng.normal() * sd),
        b: (0..m).map(|_| rng.uniform(0.0, 2.0 * std::f64::consts::PI)).collect(),
    }
}

/// z(x) = √(2/m)·cos(ωᵀx + b) for every point: returns m×n.
///
/// Perf note (EXPERIMENTS.md §Perf): the dense path runs ΩᵀX as one
/// blocked matmul instead of per-point strided projections — 20×+ on
/// mnist-sized shards.
pub fn rff_features(params: &RffParams, x: &Data) -> Mat {
    let m = params.omega.cols();
    let n = x.len();
    let scale = (2.0 / m as f64).sqrt();
    let mut out = project_all(&params.omega, x);
    if n == 0 {
        return out;
    }
    let b = &params.b;
    // Row-parallel cos map (each feature row is independent). The
    // fast tier swaps libm cos for the branchless polynomial map —
    // the single hottest transcendental loop in the embed path.
    let fast = crate::linalg::simd::fast_tier_active();
    let body = |i0: usize, chunk: &mut [f64]| {
        let rows = chunk.len() / n;
        for r in 0..rows {
            let bb = b[i0 + r];
            let row = &mut chunk[r * n..(r + 1) * n];
            if fast {
                crate::linalg::simd::map_cos_fast(row, bb, scale);
            } else {
                for v in row {
                    *v = scale * (*v + bb).cos();
                }
            }
        }
    };
    if crate::linalg::parallel_worthwhile(m * n, 8) {
        crate::par::par_chunks(out.data_mut(), n, body);
    } else {
        body(0, out.data_mut());
    }
    out
}

/// Fourier features for the Laplacian kernel exp(-γ‖x−y‖₁): the
/// spectral density is a product of Cauchy(0, γ) marginals, so
/// ω_ij = γ·tan(π(u−½)) with u ~ U(0,1); the feature map is the same
/// √(2/m)·cos(ωᵀx + b) as the Gaussian case (so [`rff_features`] and
/// the L1 Pallas kernel are shared).
pub fn laplace_rff_params(d: usize, m: usize, gamma: f64, rng: &mut Rng) -> RffParams {
    RffParams {
        omega: Mat::from_fn(d, m, |_, _| {
            let u: f64 = rng.uniform(0.0, 1.0);
            gamma * (std::f64::consts::PI * (u - 0.5)).tan()
        }),
        b: (0..m).map(|_| rng.uniform(0.0, 2.0 * std::f64::consts::PI)).collect(),
    }
}

/// Arc-cosine random features: √(2/m)·max(0, ωᵀx)^degree, ω ~ N(0, I).
pub fn arccos_params(d: usize, m: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(d, m, |_, _| rng.normal())
}

pub fn arccos_features(omega: &Mat, degree: u32, x: &Data) -> Mat {
    let m = omega.cols();
    let n = x.len();
    let scale = (2.0 / m as f64).sqrt();
    let mut out = project_all(omega, x);
    if n == 0 {
        return out;
    }
    // Fast tier: branchless ReLU-power via max(0, ·). For v > 0 the
    // arithmetic is identical to the powi form (deg 1: scale·v; deg 2:
    // scale·v·v) and v ≤ 0 / NaN clamp to zero in both, so this map is
    // value-identical to the exact branch (up to the sign of a zero) —
    // the win is purely the removed data-dependent branch (select
    // instead of jump).
    let fast = crate::linalg::simd::fast_tier_active();
    let body = |_i0: usize, chunk: &mut [f64]| {
        if fast {
            match degree {
                0 => {
                    for v in chunk {
                        *v = if *v > 0.0 { scale } else { 0.0 };
                    }
                }
                1 => {
                    for v in chunk {
                        *v = scale * v.max(0.0);
                    }
                }
                2 => {
                    for v in chunk {
                        let t = v.max(0.0);
                        *v = scale * t * t;
                    }
                }
                _ => {
                    for v in chunk {
                        let t = v.max(0.0);
                        *v = scale * t.powi(degree as i32);
                    }
                }
            }
        } else {
            for v in chunk {
                // Θ(wᵀx)·(wᵀx)^deg — degree 0 is the pure indicator
                // (a.powi(0) would wrongly turn clamped zeros into ones).
                *v = if *v > 0.0 { scale * v.powi(degree as i32) } else { 0.0 };
            }
        }
    };
    if crate::linalg::parallel_worthwhile(m * n, 4) {
        crate::par::par_chunks(out.data_mut(), n, body);
    } else {
        body(0, out.data_mut());
    }
    out
}

/// ΩᵀX for a whole shard — m×n. Dense: one packed register-tiled
/// matmul (`linalg::gemm`); sparse: O(nnz·m) with contiguous Ω-row
/// accumulation.
fn project_all(omega: &Mat, x: &Data) -> Mat {
    match x {
        Data::Dense(xd) => omega.matmul_at_b(xd),
        Data::Sparse(xs) => {
            let m = omega.cols();
            let n = xs.cols();
            let mut out = Mat::zeros(m, n);
            if m == 0 || n == 0 {
                return out;
            }
            // Row-parallel: each thread walks the whole sparse shard
            // but accumulates only its feature rows, in the same nnz
            // order as the serial loop (bit-identical).
            let body = |i0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for j in 0..n {
                    for (r, v) in xs.col_iter(j) {
                        let orow = omega.row(r);
                        for rr in 0..rows {
                            chunk[rr * n + j] += orow[i0 + rr] * v;
                        }
                    }
                }
            };
            if crate::linalg::parallel_worthwhile(m * n, 4) {
                crate::par::par_chunks(out.data_mut(), n, body);
            } else {
                body(0, out.data_mut());
            }
            out
        }
    }
}

/// The paper's "median trick": σ = c · median pairwise distance over a
/// random subsample; returns γ = 1/(2σ²).
pub fn median_trick_gamma(x: &Data, c: f64, sample: usize, rng: &mut Rng) -> f64 {
    let n = x.len();
    let idx = if n <= sample {
        (0..n).collect::<Vec<_>>()
    } else {
        rng.sample_without_replacement(n, sample)
    };
    let cols: Vec<Vec<f64>> = idx.iter().map(|&j| x.col_dense(j)).collect();
    let mut d2s = Vec::new();
    for i in 0..cols.len() {
        for j in (i + 1)..cols.len() {
            let mut d2 = 0.0;
            for r in 0..cols[i].len() {
                let d = cols[i][r] - cols[j][r];
                d2 += d * d;
            }
            d2s.push(d2);
        }
    }
    assert!(!d2s.is_empty(), "median trick needs ≥2 points");
    // total_cmp: NaN distances (NaN-poisoned input columns) sort to
    // the end deterministically instead of panicking.
    d2s.sort_by(f64::total_cmp);
    let med = d2s[d2s.len() / 2].sqrt();
    let sigma = (c * med).max(1e-12);
    1.0 / (2.0 * sigma * sigma)
}

/// Median trick for the Laplacian kernel: γ = 1/(c · median L1
/// pairwise distance) so that κ at the median distance is e^{-1/c}.
pub fn median_trick_gamma_l1(x: &Data, c: f64, sample: usize, rng: &mut Rng) -> f64 {
    let n = x.len();
    let idx = if n <= sample {
        (0..n).collect::<Vec<_>>()
    } else {
        rng.sample_without_replacement(n, sample)
    };
    let cols: Vec<Vec<f64>> = idx.iter().map(|&j| x.col_dense(j)).collect();
    let mut d1s = Vec::new();
    for i in 0..cols.len() {
        for j in (i + 1)..cols.len() {
            let mut d1 = 0.0;
            for r in 0..cols[i].len() {
                d1 += (cols[i][r] - cols[j][r]).abs();
            }
            d1s.push(d1);
        }
    }
    assert!(!d1s.is_empty(), "median trick needs ≥2 points");
    d1s.sort_by(f64::total_cmp);
    let med = d1s[d1s.len() / 2];
    1.0 / (c * med).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csc;

    fn shard(rng: &mut Rng, d: usize, n: usize) -> (Data, Data) {
        let m = Mat::from_fn(d, n, |i, j| {
            if (i + j) % 2 == 0 {
                rng.normal()
            } else {
                0.0
            }
        });
        (Data::Dense(m.clone()), Data::Sparse(Csc::from_dense(&m)))
    }

    #[test]
    fn gram_dense_sparse_agree() {
        let mut rng = Rng::seed_from(1);
        let (dd, ds) = shard(&mut rng, 6, 8);
        let y = Mat::from_fn(6, 4, |_, _| rng.normal());
        for k in [
            Kernel::Gauss { gamma: 0.3 },
            Kernel::Poly { q: 4 },
            Kernel::ArcCos { degree: 2 },
        ] {
            let a = gram(k, &y, &dd);
            let b = gram(k, &y, &ds);
            assert!(a.max_abs_diff(&b) < 1e-10, "{}", k.name());
        }
    }

    #[test]
    fn gram_matches_eval() {
        let mut rng = Rng::seed_from(2);
        let (dd, _) = shard(&mut rng, 5, 6);
        let y = Mat::from_fn(5, 3, |_, _| rng.normal());
        for k in [
            Kernel::Gauss { gamma: 1.0 },
            Kernel::Poly { q: 2 },
            Kernel::ArcCos { degree: 1 },
        ] {
            let g = gram(k, &y, &dd);
            for i in 0..3 {
                for j in 0..6 {
                    let wanted = k.eval(&y.col(i), &dd.col_dense(j));
                    assert!((g[(i, j)] - wanted).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn diag_consistent_with_eval() {
        let mut rng = Rng::seed_from(3);
        let (dd, _) = shard(&mut rng, 4, 5);
        for k in [
            Kernel::Gauss { gamma: 0.7 },
            Kernel::Poly { q: 3 },
            Kernel::ArcCos { degree: 0 },
            Kernel::ArcCos { degree: 1 },
            Kernel::ArcCos { degree: 2 },
        ] {
            let d = diag(k, &dd);
            for j in 0..5 {
                let c = dd.col_dense(j);
                // acos near cos=1 is ill-conditioned ⇒ loose tolerance
                assert!(
                    (d[j] - k.eval(&c, &c)).abs() < 1e-6,
                    "{} at {j}: {} vs {}",
                    k.name(),
                    d[j],
                    k.eval(&c, &c)
                );
            }
        }
    }

    #[test]
    fn gram_gauss_psd_and_bounded() {
        let mut rng = Rng::seed_from(4);
        let y = Mat::from_fn(4, 10, |_, _| rng.normal());
        let g = gram_sym(Kernel::Gauss { gamma: 0.5 }, &y);
        for i in 0..10 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-12);
            for j in 0..10 {
                assert!(g[(i, j)] > 0.0 && g[(i, j)] <= 1.0 + 1e-12);
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
        // PSD via eigh
        let (vals, _) = crate::linalg::eigh(&g);
        assert!(vals.last().unwrap() > &-1e-9);
    }

    #[test]
    fn rff_approximates_gauss_kernel() {
        let mut rng = Rng::seed_from(5);
        let d = 5;
        let gamma = 0.4;
        let x = Mat::from_fn(d, 10, |_, _| rng.normal());
        let data = Data::Dense(x.clone());
        let params = rff_params(d, 8192, gamma, &mut rng);
        let z = rff_features(&params, &data);
        let approx = z.matmul_at_b(&z);
        let exact = gram_sym(Kernel::Gauss { gamma }, &x);
        assert!(approx.max_abs_diff(&exact) < 0.1, "err {}", approx.max_abs_diff(&exact));
    }

    #[test]
    fn arccos_features_approximate_kernel() {
        let mut rng = Rng::seed_from(6);
        let d = 4;
        let x = Mat::from_fn(d, 8, |_, _| rng.normal());
        let data = Data::Dense(x.clone());
        for degree in [0u32, 1, 2] {
            let omega = arccos_params(d, 16384, &mut rng);
            let z = arccos_features(&omega, degree, &data);
            let approx = z.matmul_at_b(&z);
            let exact = gram_sym(Kernel::ArcCos { degree }, &x);
            let scale = exact.frob_norm() / 8.0 + 1.0;
            assert!(
                approx.max_abs_diff(&exact) < 0.25 * scale,
                "deg {degree} err {}",
                approx.max_abs_diff(&exact)
            );
        }
    }

    #[test]
    fn laplace_gram_dense_sparse_agree_and_match_eval() {
        let mut rng = Rng::seed_from(8);
        let (dd, ds) = shard(&mut rng, 6, 8);
        let y = Mat::from_fn(6, 4, |_, _| rng.normal());
        let k = Kernel::Laplace { gamma: 0.4 };
        let a = gram(k, &y, &dd);
        let b = gram(k, &y, &ds);
        assert!(a.max_abs_diff(&b) < 1e-10);
        for i in 0..4 {
            for j in 0..8 {
                let wanted = k.eval(&y.col(i), &dd.col_dense(j));
                assert!((a[(i, j)] - wanted).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn laplace_gram_psd_and_bounded() {
        let mut rng = Rng::seed_from(9);
        let y = Mat::from_fn(4, 10, |_, _| rng.normal());
        let g = gram_sym(Kernel::Laplace { gamma: 0.6 }, &y);
        for i in 0..10 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-12);
            for j in 0..10 {
                assert!(g[(i, j)] > 0.0 && g[(i, j)] <= 1.0 + 1e-12);
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
        let (vals, _) = crate::linalg::eigh(&g);
        assert!(vals.last().unwrap() > &-1e-9);
    }

    #[test]
    fn laplace_rff_approximates_kernel() {
        let mut rng = Rng::seed_from(10);
        let d = 5;
        let gamma = 0.5;
        let x = Mat::from_fn(d, 10, |_, _| rng.normal());
        let data = Data::Dense(x.clone());
        let params = laplace_rff_params(d, 16384, gamma, &mut rng);
        let z = rff_features(&params, &data);
        let approx = z.matmul_at_b(&z);
        let exact = gram_sym(Kernel::Laplace { gamma }, &x);
        assert!(approx.max_abs_diff(&exact) < 0.12, "err {}", approx.max_abs_diff(&exact));
    }

    #[test]
    fn median_trick_l1_scale_invariance() {
        let mut rng = Rng::seed_from(11);
        let x = Mat::from_fn(3, 40, |_, _| rng.normal());
        let g1 = median_trick_gamma_l1(&Data::Dense(x.clone()), 1.0, 40, &mut rng);
        let mut x2 = x.clone();
        x2.scale(2.0);
        let g2 = median_trick_gamma_l1(&Data::Dense(x2), 1.0, 40, &mut rng);
        // doubling distances halves gamma
        assert!((g1 / g2 - 2.0).abs() < 0.1, "{g1} {g2}");
    }

    #[test]
    fn median_trick_scale_invariance() {
        let mut rng = Rng::seed_from(7);
        let x = Mat::from_fn(3, 40, |_, _| rng.normal());
        let g1 = median_trick_gamma(&Data::Dense(x.clone()), 0.2, 40, &mut rng);
        let mut x2 = x.clone();
        x2.scale(2.0);
        let g2 = median_trick_gamma(&Data::Dense(x2), 0.2, 40, &mut rng);
        // doubling distances quarters gamma
        assert!((g1 / g2 - 4.0).abs() < 0.2, "{g1} {g2}");
    }

    /// Regression: a NaN coordinate used to panic the pairwise-distance
    /// sort (`partial_cmp(..).unwrap()`); NaN distances must now sort
    /// deterministically and leave a finite positive γ as long as the
    /// median pair is finite.
    #[test]
    fn median_trick_nan_coordinate_does_not_panic() {
        let mut rng = Rng::seed_from(9);
        let mut m = Mat::from_fn(4, 10, |_, _| rng.normal());
        m[(1, 3)] = f64::NAN;
        let d = Data::Dense(m);
        let g = median_trick_gamma(&d, 0.2, 16, &mut rng);
        assert!(g > 0.0 && g.is_finite(), "gamma {g}");
        let g1 = median_trick_gamma_l1(&d, 1.0, 16, &mut rng);
        assert!(g1 > 0.0 && g1.is_finite(), "gamma_l1 {g1}");
    }
}
