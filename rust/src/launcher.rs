//! Multi-process deployment: `diskpca master` / `diskpca worker`.
//!
//! A real (non-simulated) deployment of the protocol: the master binds
//! a TCP address and waits for `--workers N` connections; each worker
//! process loads its shard from a dataset file (`data::io` format or
//! CSV), connects, and serves the protocol until `Quit`. The exact
//! same `coordinator` code drives both this and the in-process star —
//! only the transport differs.
//!
//! ```text
//!   # terminal 1 (master)
//!   diskpca master --listen 127.0.0.1:7700 --workers 2 --kernel gauss --gamma 0.5
//!   # terminals 2, 3 (workers)
//!   diskpca worker --connect 127.0.0.1:7700 --data shard0.bin --kernel gauss --gamma 0.5
//!   diskpca worker --connect 127.0.0.1:7700 --data shard1.bin --kernel gauss --gamma 0.5
//! ```
//!
//! `diskpca shard <dataset> --out dir --parts N` writes power-law
//! shards of a registry dataset to disk for the above.
//!
//! # Failure semantics and exit codes
//!
//! The deployment subcommands separate *protocol* failures (a worker
//! died, reported an error, or replied garbage mid-round — a
//! [`CommError`] with worker + round context) from *environment*
//! failures (bad flags, unreadable shards, bind/connect errors).
//! [`LaunchError::exit_code`] maps them to distinct process exit
//! codes so orchestration scripts can tell "retry the job" from "fix
//! the config". A third class — permanent worker loss with
//! rebalancing off ([`CommError::Degraded`], exit [`EXIT_DEGRADED`])
//! — means the cluster itself shrank and neither retrying nor a
//! config fix will help; see [`EXIT_DEGRADED`] for the recourse. On a
//! protocol failure the master's [`Cluster`] drop
//! guard still fans `Quit` out to every surviving worker, so remote
//! worker processes exit instead of waiting on a dead coordinator.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::{tcp, Cluster, CommError, CommStats, ReplyEvent, WorkerLink};
use crate::config::Config;
use crate::coordinator::{dis_eval, dis_kpca, SamplingMode, Worker};
use crate::data::{self, Data};
use crate::kernels::Kernel;
use crate::recovery::{self, Recovery, ReviveHost};
use crate::runtime::backend_from_name;

/// Exit code for a protocol-layer failure ([`LaunchError::Protocol`]).
pub const EXIT_PROTOCOL: i32 = 3;
/// Exit code for an environment/setup failure ([`LaunchError::Env`]).
pub const EXIT_ENV: i32 = 1;
/// Exit code for a degraded cluster ([`CommError::Degraded`]): a
/// worker slot is permanently lost (its revival budget ran out or no
/// replacement rejoined) and rebalancing was off or impossible.
/// Unlike [`EXIT_PROTOCOL`] ("retry the job"), this one says "the
/// deployment shrank — re-shard or restart with `--rebalance`".
pub const EXIT_DEGRADED: i32 = 4;

/// A deployment subcommand failure, split by which exit code it maps
/// to (see the module docs).
#[derive(Debug)]
pub enum LaunchError {
    /// The protocol aborted: carries the worker index + round context.
    Protocol(CommError),
    /// Setup/IO/config failure before or around the protocol.
    Env(String),
}

impl LaunchError {
    pub fn exit_code(&self) -> i32 {
        match self {
            LaunchError::Protocol(CommError::Degraded { .. }) => EXIT_DEGRADED,
            LaunchError::Protocol(_) => EXIT_PROTOCOL,
            LaunchError::Env(_) => EXIT_ENV,
        }
    }
}

impl std::fmt::Display for LaunchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaunchError::Protocol(e) => write!(f, "protocol failure: {e}"),
            LaunchError::Env(e) => f.write_str(e),
        }
    }
}

impl std::error::Error for LaunchError {}

impl From<CommError> for LaunchError {
    fn from(e: CommError) -> Self {
        LaunchError::Protocol(e)
    }
}

impl From<std::io::Error> for LaunchError {
    fn from(e: std::io::Error) -> Self {
        LaunchError::Env(e.to_string())
    }
}

impl From<anyhow::Error> for LaunchError {
    fn from(e: anyhow::Error) -> Self {
        LaunchError::Env(e.to_string())
    }
}

/// Kernel from explicit flags (a worker process has no data-dependent
/// median trick — γ must be pinned so all nodes agree).
pub fn kernel_from_flags(cfg: &Config) -> anyhow::Result<Kernel> {
    Ok(match cfg.str_or("kernel", "gauss") {
        "gauss" => Kernel::Gauss { gamma: cfg.f64_or("gamma", 0.5) },
        "poly" => Kernel::Poly { q: cfg.usize_or("q", 4) as u32 },
        "arccos" => Kernel::ArcCos { degree: cfg.usize_or("degree", 2) as u32 },
        other => anyhow::bail!("unknown kernel {other}"),
    })
}

/// Master-side [`ReviveHost`] for the multi-process deployment: when
/// a worker dies, keep the original listening socket open and wait for
/// a replacement `diskpca worker` process to connect (`--rejoin-wait`
/// seconds). The fresh connection is attached to the dead slot; when
/// the master knows the slot's on-disk shard (`--shards`), the path is
/// re-shipped via `ReqLoadShard` so the replacement may start blank
/// (`diskpca worker` without `--data`).
pub struct TcpRejoinHost {
    listener: std::net::TcpListener,
    reply_tx: Sender<ReplyEvent>,
    /// Slot-ordered on-disk shard paths to re-assign on rejoin; empty
    /// when rejoining workers bring their own shard (`--data`).
    shard_paths: Vec<String>,
    chunk_rows: usize,
    wait: Duration,
}

impl TcpRejoinHost {
    pub fn new(
        listener: std::net::TcpListener,
        reply_tx: Sender<ReplyEvent>,
        shard_paths: Vec<String>,
        chunk_rows: usize,
        wait: Duration,
    ) -> Self {
        Self { listener, reply_tx, shard_paths, chunk_rows, wait }
    }
}

impl ReviveHost for TcpRejoinHost {
    fn revive(&mut self, slot: usize) -> Result<Box<dyn WorkerLink>, String> {
        eprintln!("master: worker {slot} lost; waiting up to {:?} for a rejoin …", self.wait);
        let deadline = Instant::now() + self.wait;
        self.listener
            .set_nonblocking(true)
            .map_err(|e| format!("listener nonblocking: {e}"))?;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false).map_err(|e| format!("stream blocking: {e}"))?;
                    eprintln!("master: worker {slot} rejoined from {peer}");
                    return tcp::attach(slot, stream, self.reply_tx.clone())
                        .map_err(|e| format!("attach: {e}"));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(format!("no worker rejoined within {:?}", self.wait));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => return Err(format!("accept: {e}")),
            }
        }
    }

    fn shard_path(&self, slot: usize) -> Option<(String, usize)> {
        self.shard_paths
            .get(slot)
            .filter(|p| !p.is_empty())
            .cloned()
            .map(|p| (p, self.chunk_rows))
    }

    fn rebalanced(&mut self, dead: usize, adopter: usize) {
        if self.shard_paths.is_empty() {
            return;
        }
        self.shard_paths.remove(dead);
        // `adopter` is the pre-shrink index; survivors above the dead
        // slot renumber down by one
        let at = if adopter > dead { adopter - 1 } else { adopter };
        if let Some(p) = self.shard_paths.get_mut(at) {
            // the adopter now holds own + adopted columns — no single
            // on-disk path describes that, so a later revival of this
            // slot cannot start blank (a rejoining worker must bring
            // its own --data)
            p.clear();
        }
    }
}

/// `diskpca master`: accept workers, run disKPCA, print the result.
/// A protocol failure returns [`LaunchError::Protocol`] — and the
/// cluster's drop guard has already sent `Quit` to the surviving
/// workers by the time this returns.
///
/// With `--elastic`, a worker dying mid-run does not abort: the master
/// keeps listening, attaches the next rejoining worker process to the
/// dead slot, replays the installed round state (shard assignment when
/// `--shards` names the slot-ordered paths, then embedding + scores +
/// solution state) and retries the interrupted unit — the final result
/// and per-round word table are bit-identical to a fault-free run.
///
/// Three degraded-mode knobs ride on `--elastic`:
/// - `--comm-retries N` (env `DISKPCA_COMM_RETRIES`): a reply timeout
///   retries up to N times with doubling bounds before poisoning, so
///   a slow-but-alive worker is waited out instead of declared dead.
///   (Honoured without `--elastic` too.)
/// - `--chaos-seed S` (env `DISKPCA_CHAOS_SEED`): wrap every worker
///   link in the seeded fault-injection transport
///   ([`crate::comm::chaos`]) — elastic only, since the injected
///   faults need recovery to heal them.
/// - `--rebalance`: when a dead slot's revival budget runs out (or no
///   worker rejoins within `--rejoin-wait`), adopt its shard onto a
///   survivor, shrink the cluster, and re-run the job cold on s−1
///   workers ([`recovery::with_rebalance`]). Off by default: the
///   degraded error then exits with code [`EXIT_DEGRADED`].
pub fn master(cfg: &Config) -> Result<(), LaunchError> {
    let addr = cfg.str_or("listen", "127.0.0.1:7700");
    let s = cfg.usize_or("workers", 2);
    let kernel = kernel_from_flags(cfg)?;
    let params = cfg.params();
    params.apply_threads();
    crate::linalg::simd::set_compute_tier(cfg.compute_tier());
    // degraded-mode knobs: environment first, explicit flags override
    let comm_retries = match cfg.get("comm-retries") {
        Some(v) => Some(v.trim().parse::<usize>().map_err(|_| {
            LaunchError::Env(format!("--comm-retries {v}: not a usize"))
        })?),
        None => None, // Cluster::new reads DISKPCA_COMM_RETRIES itself
    };
    let chaos_seed = match cfg.get("chaos-seed") {
        Some(v) => Some(v.trim().parse::<u64>().map_err(|_| {
            LaunchError::Env(format!("--chaos-seed {v}: not a u64"))
        })?),
        None => crate::serve::parse_chaos_seed(
            std::env::var("DISKPCA_CHAOS_SEED").ok().as_deref(),
        )
        .map_err(LaunchError::Env)?,
    };
    if cfg.get("chaos-seed").is_some() && !cfg.bool_or("elastic", false) {
        return Err(LaunchError::Env(
            "--chaos-seed requires --elastic: injected faults need recovery to heal".into(),
        ));
    }
    eprintln!("master: waiting for {s} workers on {addr} …");
    let t0;
    let (cluster, sol, err, trace) = if cfg.bool_or("elastic", false) {
        let (star, listener, reply_tx) = tcp::listen_elastic(addr, s)?;
        let star = match chaos_seed {
            Some(seed) => crate::comm::chaos::wrap_star(star, seed),
            None => star,
        };
        let cluster = Cluster::new(star, CommStats::new());
        if let Some(n) = comm_retries {
            cluster.set_comm_retries(n);
        }
        let shard_paths: Vec<String> = cfg
            .get("shards")
            .map(|v| v.split(',').map(str::to_string).collect())
            .unwrap_or_default();
        if !shard_paths.is_empty() && shard_paths.len() != s {
            return Err(LaunchError::Env(format!(
                "--shards names {} paths for {s} workers",
                shard_paths.len()
            )));
        }
        let host = TcpRejoinHost::new(
            listener,
            reply_tx,
            shard_paths,
            params.chunk_rows,
            Duration::from_secs(cfg.u64_or("rejoin-wait", 60)),
        );
        let mut rec = Recovery::new(Box::new(host));
        rec.set_rebalance(cfg.bool_or("rebalance", false));
        t0 = Instant::now();
        let (sol, err, trace) =
            recovery::with_rebalance(&cluster, &mut rec, |cluster, rec| {
                let sol = recovery::dis_kpca_recovering(
                    cluster,
                    rec,
                    kernel,
                    &params,
                    SamplingMode::Full,
                    false,
                )?;
                let (err, trace) = recovery::dis_eval_recovering(cluster, rec)?;
                Ok((sol, err, trace))
            })?;
        if rec.recoveries() > 0 {
            eprintln!("master: recovered from {} worker failure(s)", rec.recoveries());
        }
        if cluster.num_workers() < s {
            eprintln!(
                "master: degraded to {} worker(s) — lost shards were adopted by survivors",
                cluster.num_workers()
            );
        }
        (cluster, sol, err, trace)
    } else {
        let star = tcp::listen(addr, s)?;
        let cluster = Cluster::new(star, CommStats::new());
        if let Some(n) = comm_retries {
            cluster.set_comm_retries(n);
        }
        t0 = Instant::now();
        let sol = dis_kpca(&cluster, kernel, &params)?;
        let (err, trace) = dis_eval(&cluster)?;
        (cluster, sol, err, trace)
    };
    cluster.shutdown();
    println!(
        "disKPCA done: |Y|={} rel_err={:.4} comm={} words wall={:.2}s",
        sol.num_points(),
        err / trace,
        cluster.stats.total_words(),
        t0.elapsed().as_secs_f64()
    );
    for (round, up, down) in cluster.stats.table() {
        println!("  {round:<14} up {up:>10}  down {down:>10}");
    }
    if let Some(out) = cfg.get("save-solution") {
        data::io::save(&Data::Dense(sol.y.clone()), out)?;
        println!("representative points saved to {out}");
    }
    Ok(())
}

/// `diskpca worker`: load a shard, serve the protocol. A `.dkps`
/// shard store is mapped out-of-core (worker matrix memory tracks the
/// chunk/block size, not the shard size); `.bin`/`.csv` shards load
/// resident and stream only when `--chunk-rows` is set.
pub fn worker(cfg: &Config) -> Result<(), LaunchError> {
    let addr = cfg.str_or("connect", "127.0.0.1:7700");
    let params = cfg.params();
    // --data is optional: a worker rejoining an --elastic master may
    // start blank and receive its shard assignment (ReqLoadShard)
    // during the recovery replay.
    let source = match cfg.get("data") {
        Some(path) if path.ends_with(".dkps") => {
            data::ShardSource::Store(data::ShardStore::open(path)?)
        }
        Some(path) if path.ends_with(".csv") => {
            data::ShardSource::Resident(data::io::load_csv(path)?)
        }
        Some(path) => data::ShardSource::Resident(data::io::load(path)?),
        None => data::ShardSource::Resident(Data::Dense(crate::linalg::Mat::zeros(0, 0))),
    };
    let kernel = kernel_from_flags(cfg)?;
    // worker processes size their own pool from --threads (absent or
    // 0 leaves the pool and DISKPCA_THREADS untouched) and select
    // their numeric tier from --compute-tier (default exact)
    params.apply_threads();
    crate::linalg::simd::set_compute_tier(cfg.compute_tier());
    let backend = backend_from_name(
        cfg.str_or("backend", "native"),
        cfg.str_or("artifacts", "artifacts"),
    )?;
    eprintln!(
        "worker: {} points of dim {} → {addr} (backend {}, {})",
        source.len(),
        source.dim(),
        backend.name(),
        match (&source, params.chunk_rows) {
            (data::ShardSource::Store(_), 0) => "streaming block-sized chunks".to_string(),
            (_, 0) => "resident".to_string(),
            (_, c) => format!("streaming {c}-point chunks"),
        }
    );
    let mut endpoint = tcp::connect(addr)?;
    let mut worker = Worker::with_source(source, kernel, backend, params.chunk_rows);
    // serve-mode knob: bound the embed warm cache (0 disables). The
    // env default is DISKPCA_EMBED_CACHE_MB.
    if let Some(mb) = cfg.get("embed-cache-mb") {
        let mb: usize = mb
            .parse()
            .map_err(|_| LaunchError::Env(format!("--embed-cache-mb {mb}: not a usize")))?;
        worker.set_embed_cache_budget(mb.saturating_mul(1 << 20));
    }
    // Drive the loop here (rather than `Worker::run`) so a dropped
    // connection surfaces as an error with protocol context instead
    // of aborting the process mid-protocol.
    // Once the protocol is running, a lost master is a *protocol*
    // failure (exit 3, the documented retry signal) — only setup
    // problems above are environment errors.
    let mut served = 0usize;
    loop {
        let req = endpoint.try_recv().map_err(|e| {
            LaunchError::Protocol(CommError::Protocol {
                round: "serving".into(),
                detail: format!("connection to master lost after {served} requests: {e}"),
            })
        })?;
        if matches!(req, crate::comm::Message::Quit) {
            break;
        }
        let resp = worker.handle(req);
        if let crate::comm::Message::RespError(msg) = &resp {
            eprintln!("worker: request failed (reported to master): {msg}");
        }
        endpoint.try_send(&resp).map_err(|e| {
            LaunchError::Protocol(CommError::Protocol {
                round: "serving".into(),
                detail: format!("connection to master lost while replying (request {served}): {e}"),
            })
        })?;
        served += 1;
    }
    eprintln!("worker: done ({served} requests served)");
    Ok(())
}

/// `diskpca serve [dataset]`: a persistent multi-job serving session.
///
/// With `--listen addr --workers N` the master waits for external
/// `diskpca worker` processes (same flags as `master`); without
/// `--listen` it spawns an in-process cluster over power-law shards of
/// the registry dataset. Either way it then runs `--jobs` disKPCA fits
/// through the [`crate::serve::Service`] — the first cold, the rest
/// warm (identical [`crate::embed::EmbedSpec`], so the `1-embed`
/// round is skipped with zero words) — and finishes with a
/// `--transform`-point projection batch through the installed
/// solution, printing per-job word tables and the warm-reuse drop.
///
/// `--max-inflight N` runs the session's scheduler with N concurrent
/// job lanes (default 1 = the bit-identical sequential path) and
/// `--queue-depth D` bounds the admission queue: the query batch is
/// pumped through [`crate::serve::Service::submit`], and a full queue
/// is a typed rejection ([`crate::serve::Rejected`] — the wire form a
/// TCP front end sends is `Rejected::to_resp_error()`), never a stall.
pub fn serve(cfg: &Config, dataset: &str) -> Result<(), LaunchError> {
    let kernel = kernel_from_flags(cfg)?;
    let params = cfg.params();
    params.apply_threads();
    let jobs = cfg.usize_or("jobs", 3).max(1);
    let n_transform = cfg.usize_or("transform", 256);
    let scale = cfg.f64_or("scale", 0.05);
    let spec = data::by_name(cfg.str_or("dataset", dataset), scale)
        .ok_or_else(|| LaunchError::Env(format!("unknown dataset {dataset}")))?;

    // scheduling knobs: environment first (the ServeConfig::from_env
    // convention), explicit flags override
    let mut serve_cfg = crate::serve::ServeConfig::from_env();
    serve_cfg.max_inflight = cfg.usize_or("max-inflight", serve_cfg.max_inflight).max(1);
    serve_cfg.queue_depth = cfg.usize_or("queue-depth", serve_cfg.queue_depth).max(1);
    serve_cfg.pipeline_depth = cfg.usize_or("pipeline-depth", serve_cfg.pipeline_depth).max(1);
    // --variance-frac overrides DISKPCA_VARIANCE_FRAC when set (the
    // accessor validates the (0, 1] range either way)
    if cfg.get("variance-frac").or_else(|| cfg.get("variance_frac")).is_some() {
        serve_cfg.variance_frac = cfg.variance_frac();
    }
    // --compute-tier overrides DISKPCA_COMPUTE_TIER when set;
    // ServiceBuilder::build applies the result process-wide
    if cfg.get("compute-tier").or_else(|| cfg.get("compute_tier")).is_some() {
        serve_cfg.compute_tier = cfg.compute_tier();
    }

    let mut service = if let Some(addr) = cfg.get("listen") {
        let s = cfg.usize_or("workers", 2);
        eprintln!("serve: waiting for {s} workers on {addr} …");
        let star = tcp::listen(addr, s)?;
        crate::serve::Service::builder(kernel)
            .cluster(Cluster::new(star, CommStats::new()))
            .config(serve_cfg.clone())
            .build()
    } else {
        let s = cfg.usize_or("workers", spec.s);
        let global = spec.generate(cfg.u64_or("seed", 1));
        let shards = data::partition_power_law(&global, s, 1);
        let backend = backend_from_name(
            cfg.str_or("backend", "native"),
            cfg.str_or("artifacts", "artifacts"),
        )?;
        let cache_bytes = match cfg.get("embed-cache-mb") {
            Some(mb) => Some(
                mb.parse::<usize>()
                    .map_err(|_| {
                        LaunchError::Env(format!("--embed-cache-mb {mb}: not a usize"))
                    })?
                    .saturating_mul(1 << 20),
            ),
            None => None,
        };
        crate::serve::Service::builder(kernel)
            .shards(shards)
            .backend(backend)
            .chunk_rows(params.chunk_rows)
            .embed_cache_bytes(cache_bytes)
            .config(serve_cfg.clone())
            .build()
    };

    let t0 = std::time::Instant::now();
    let mut first_words = 0usize;
    for j in 0..jobs {
        let report = service.run_kpca(&params)?;
        let words = report.job.stats.total_words();
        if j == 0 {
            first_words = words;
        }
        println!(
            "job {j}: |Y|={} words={} embed_words={} {}",
            report.output.num_points(),
            words,
            report.job.stats.round_words("1-embed"),
            if report.embed_reused { "(warm: 1-embed skipped)" } else { "(cold)" }
        );
        for (round, up, down) in report.job.stats.table() {
            println!("    {round:<14} up {up:>10}  down {down:>10}");
        }
    }
    if jobs > 1 {
        let warm_words = service.stats().total_words() / jobs; // rough per-job mean
        println!(
            "warm reuse: first job {first_words} words, \
             mean {warm_words} words/job over {jobs} jobs"
        );
    }

    // --refit: close the session with an incremental warm refit —
    // against in-memory shards it refreshes to a zero delta, but the
    // word table shows the shape of the saving (no 1-embed round)
    if cfg.bool_or("refit", false) {
        let report = service.run_refit(&params)?;
        println!(
            "refit: epoch {} (+{} cols) words={} {}",
            report.output.epoch,
            report.output.delta_cols,
            report.job.stats.total_words(),
            if report.output.fell_back {
                "(fell back to a cold fit)"
            } else {
                "(incremental: 1-embed skipped)"
            }
        );
    }

    if n_transform > 0 {
        let mut rng = crate::rng::Rng::seed_from(cfg.u64_or("seed", 1) ^ 0x7ab5);
        let batch =
            crate::linalg::Mat::from_fn(spec.d, n_transform, |_, _| rng.normal());
        let tq = std::time::Instant::now();
        // pump the query batch through the bounded admission queue in
        // sub-batches — with --max-inflight > 1 these overlap on the
        // cluster; a full queue rejects (typed) and we drain the
        // oldest in-flight result before retrying
        let lanes = serve_cfg.max_inflight * 2;
        let per = n_transform.div_ceil(lanes).max(1);
        let mut inflight: std::collections::VecDeque<crate::serve::JobHandle> =
            std::collections::VecDeque::new();
        let mut parts: Vec<crate::linalg::Mat> = Vec::new();
        let mut deferred = 0usize;
        let take = |h: crate::serve::JobHandle| -> Result<crate::linalg::Mat, LaunchError> {
            match h.wait()? {
                crate::serve::JobOutput::Transform(m) => Ok(m),
                other => Err(LaunchError::Env(format!("unexpected job output {other:?}"))),
            }
        };
        let mut j0 = 0;
        while j0 < n_transform {
            let j1 = (j0 + per).min(n_transform);
            let cols: Vec<usize> = (j0..j1).collect();
            let sub = batch.select_cols(&cols);
            loop {
                match service.submit(crate::serve::JobSpec::Transform { batch: sub.clone() }) {
                    Ok(h) => {
                        inflight.push_back(h);
                        break;
                    }
                    Err(rej @ crate::serve::Rejected::QueueFull { .. }) => {
                        // a TCP front end would send rej.to_resp_error()
                        // to the client here; the session drains one
                        // result and retries instead
                        deferred += 1;
                        let _ = rej;
                        match inflight.pop_front() {
                            Some(h) => parts.push(take(h)?),
                            None => std::thread::yield_now(),
                        }
                    }
                    Err(rej) => return Err(LaunchError::Env(rej.to_string())),
                }
            }
            j0 = j1;
        }
        for h in inflight {
            parts.push(take(h)?);
        }
        let k = parts.first().map_or(0, |m| m.rows());
        let mut proj = crate::linalg::Mat::zeros(k, n_transform);
        let mut at = 0;
        for part in &parts {
            for j in 0..part.cols() {
                for i in 0..k {
                    proj[(i, at + j)] = part[(i, j)];
                }
            }
            at += part.cols();
        }
        let dt = tq.elapsed().as_secs_f64();
        println!(
            "transform: {} points → {}×{} in {:.1} ms ({:.0} points/s, {} words{})",
            n_transform,
            proj.rows(),
            proj.cols(),
            dt * 1e3,
            n_transform as f64 / dt.max(1e-9),
            service.stats().round_words("svc:10-transform"),
            if deferred > 0 {
                format!(", {deferred} submissions deferred by backpressure")
            } else {
                String::new()
            },
        );
    }
    println!(
        "serve session done: {} jobs, {} total words, wall {:.2}s",
        jobs,
        service.stats().total_words(),
        t0.elapsed().as_secs_f64()
    );
    service.shutdown();
    Ok(())
}

/// `diskpca shard <dataset>`: write power-law shards to disk. With
/// `--chunk-rows N` each shard is written as a chunked `.dkps` store
/// (N-point blocks) that `diskpca worker` maps out-of-core; without
/// it, the legacy resident `.bin` format.
pub fn shard(cfg: &Config, dataset: &str) -> anyhow::Result<()> {
    let scale = cfg.f64_or("scale", 0.1);
    let seed = cfg.u64_or("seed", 0xd15c);
    let spec = data::by_name(dataset, scale)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let parts = cfg.usize_or("parts", spec.s);
    let out = cfg.str_or("out", "shards");
    let chunk_rows = cfg.params().chunk_rows;
    std::fs::create_dir_all(out)?;
    let global = spec.generate(seed);
    let shards = data::partition_power_law(&global, parts, seed);
    for (i, sh) in shards.iter().enumerate() {
        if chunk_rows > 0 {
            let path = format!("{out}/{dataset}_{i:03}.dkps");
            data::shard_store::write(sh, &path, chunk_rows)?;
            println!(
                "{path}: {} points in {} blocks of ≤{chunk_rows}",
                sh.len(),
                sh.len().div_ceil(chunk_rows)
            );
        } else {
            let path = format!("{out}/{dataset}_{i:03}.bin");
            data::io::save(sh, &path)?;
            println!("{path}: {} points", sh.len());
        }
    }
    Ok(())
}

/// In-process end-to-end check of the multi-process path (used by the
/// integration test and `examples/multiprocess.rs`): spawns worker
/// *threads* that connect through real sockets to a listening master.
/// Honours `--chunk-rows` (streamed workers) and propagates worker
/// and master failures as [`LaunchError`]s with context instead of
/// aborting.
pub fn selftest(cfg: &Config) -> Result<(f64, f64), LaunchError> {
    let s = cfg.usize_or("workers", 3);
    let kernel = kernel_from_flags(cfg)?;
    let params = cfg.params();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    drop(listener); // free the port for `listen` (race-free enough on loopback CI)

    let scale = cfg.f64_or("scale", 0.05);
    let spec = data::by_name(cfg.str_or("dataset", "protein_like"), scale)
        .ok_or_else(|| LaunchError::Env("unknown dataset".into()))?;
    let global = spec.generate(cfg.u64_or("seed", 1));
    let shards = data::partition_power_law(&global, s, 1);

    let addr2 = addr.clone();
    let master_thread = std::thread::spawn(move || -> Result<(f64, f64), LaunchError> {
        let star = tcp::listen(&addr2, s)?;
        let cluster = Cluster::new(star, CommStats::new());
        let _ = dis_kpca(&cluster, kernel, &params)?;
        let res = dis_eval(&cluster)?;
        cluster.shutdown();
        Ok(res)
    });
    std::thread::sleep(std::time::Duration::from_millis(100));
    let chunk_rows = params.chunk_rows;
    let worker_threads: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            let addr = addr.clone();
            std::thread::spawn(move || -> Result<(), String> {
                let be = Arc::new(crate::runtime::NativeBackend::new());
                let ep = tcp::connect(&addr)
                    .map_err(|e| format!("worker {i}: connect to {addr} failed: {e}"))?;
                Worker::new_chunked(sh, kernel, be, chunk_rows).run(ep);
                Ok(())
            })
        })
        .collect();
    let res = master_thread
        .join()
        .map_err(|p| LaunchError::Env(format!("master thread panicked: {}", panic_text(&p))))?;
    let mut worker_errs = Vec::new();
    for (i, w) in worker_threads.into_iter().enumerate() {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => worker_errs.push(format!("worker {i}: {e}")),
            Err(p) => worker_errs.push(format!("worker {i} panicked: {}", panic_text(&p))),
        }
    }
    // The master outcome decides; a worker that errored after the
    // master already failed is secondary context.
    match res {
        Ok(res) => {
            if worker_errs.is_empty() {
                Ok(res)
            } else {
                Err(LaunchError::Env(format!("workers failed: {}", worker_errs.join("; "))))
            }
        }
        Err(e) if worker_errs.is_empty() => Err(e),
        // keep the Protocol classification (exit 3) — the worker
        // errors are secondary context, not a reclassification
        Err(LaunchError::Protocol(e)) => Err(LaunchError::Protocol(CommError::Protocol {
            round: e.round().to_string(),
            detail: format!("{e} (worker errors: {})", worker_errs.join("; ")),
        })),
        Err(LaunchError::Env(e)) => {
            Err(LaunchError::Env(format!("{e} (worker errors: {})", worker_errs.join("; "))))
        }
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_flags() {
        let mut cfg = Config::new();
        cfg.set("kernel", "poly");
        cfg.set("q", "3");
        assert!(matches!(kernel_from_flags(&cfg).unwrap(), Kernel::Poly { q: 3 }));
        cfg.set("kernel", "nope");
        assert!(kernel_from_flags(&cfg).is_err());
    }

    #[test]
    fn launch_error_exit_codes() {
        let p = LaunchError::Protocol(CommError::Timeout { round: "x".into(), pending: vec![0] });
        assert_eq!(p.exit_code(), EXIT_PROTOCOL);
        assert!(p.to_string().contains("protocol failure"));
        let e = LaunchError::Env("bad flag".into());
        assert_eq!(e.exit_code(), EXIT_ENV);
        let d = LaunchError::Protocol(CommError::Degraded {
            slot: 1,
            round: "recover".into(),
            detail: "no worker rejoined".into(),
        });
        assert_eq!(d.exit_code(), EXIT_DEGRADED, "permanent loss gets its own exit code");
        assert!(d.to_string().contains("degraded"));
    }

    #[test]
    fn multiprocess_selftest() {
        let mut cfg = Config::new();
        cfg.set("workers", "3");
        cfg.set("kernel", "gauss");
        cfg.set("gamma", "0.6");
        cfg.set("k", "3");
        cfg.set("t", "16");
        cfg.set("p", "32");
        cfg.set("n_lev", "8");
        cfg.set("n_adapt", "12");
        cfg.set("m_rff", "128");
        cfg.set("t2", "64");
        let (err, trace) = selftest(&cfg).unwrap();
        assert!(err >= 0.0 && err < trace, "{err} vs {trace}");
    }

    #[test]
    fn serve_in_process_session_runs_jobs_and_transform() {
        let mut cfg = Config::new();
        cfg.set("kernel", "gauss");
        cfg.set("gamma", "0.6");
        cfg.set("jobs", "2");
        cfg.set("refit", "true");
        cfg.set("variance-frac", "0.1");
        cfg.set("transform", "32");
        cfg.set("scale", "0.02");
        cfg.set("k", "3");
        cfg.set("t", "16");
        cfg.set("p", "32");
        cfg.set("n_lev", "8");
        cfg.set("n_adapt", "12");
        cfg.set("m_rff", "128");
        cfg.set("t2", "64");
        serve(&cfg, "protein_like").unwrap();
    }

    #[test]
    fn shard_writes_files() {
        let mut cfg = Config::new();
        let dir = std::env::temp_dir().join("diskpca_shards");
        cfg.set("out", dir.to_str().unwrap());
        cfg.set("parts", "3");
        cfg.set("scale", "0.02");
        shard(&cfg, "protein_like").unwrap();
        for i in 0..3 {
            let p = dir.join(format!("protein_like_{i:03}.bin"));
            assert!(p.exists());
            let d = crate::data::io::load(&p).unwrap();
            assert_eq!(d.dim(), 9);
        }
    }

    #[test]
    fn shard_writes_chunked_stores() {
        let mut cfg = Config::new();
        let dir = std::env::temp_dir().join("diskpca_shards_dkps");
        cfg.set("out", dir.to_str().unwrap());
        cfg.set("parts", "2");
        cfg.set("scale", "0.02");
        cfg.set("chunk-rows", "16");
        shard(&cfg, "protein_like").unwrap();
        for i in 0..2 {
            let p = dir.join(format!("protein_like_{i:03}.dkps"));
            assert!(p.exists(), "{p:?} missing");
            let s = crate::data::ShardStore::open(&p).unwrap();
            assert_eq!(s.dim(), 9);
            assert_eq!(s.block_points(), 16);
            assert_eq!(s.num_blocks(), s.len().div_ceil(16));
        }
    }

    #[test]
    fn multiprocess_selftest_chunked_matches_resident() {
        let mk = |chunk: &str| {
            let mut cfg = Config::new();
            cfg.set("workers", "3");
            cfg.set("kernel", "gauss");
            cfg.set("gamma", "0.6");
            cfg.set("k", "3");
            cfg.set("t", "16");
            cfg.set("p", "32");
            cfg.set("n_lev", "8");
            cfg.set("n_adapt", "12");
            cfg.set("m_rff", "128");
            cfg.set("t2", "64");
            if !chunk.is_empty() {
                cfg.set("chunk-rows", chunk);
            }
            cfg
        };
        let (err0, trace0) = selftest(&mk("")).unwrap();
        let (err64, trace64) = selftest(&mk("64")).unwrap();
        assert_eq!(err0.to_bits(), err64.to_bits(), "streamed TCP run must be bit-identical");
        assert_eq!(trace0.to_bits(), trace64.to_bits());
    }
}
