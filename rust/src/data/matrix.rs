//! Unified dense/sparse "columns are points" data matrix.

use crate::linalg::Mat;
use crate::sparse::Csc;

/// A local dataset shard: `d` features × `n` points, dense or sparse.
/// The protocol is generic over this — the paper's communication bound
/// depends on ρ = avg nnz/point, which only sparse storage exposes.
#[derive(Clone, Debug)]
pub enum Data {
    Dense(Mat),
    Sparse(Csc),
}

impl Data {
    pub fn dim(&self) -> usize {
        match self {
            Data::Dense(m) => m.rows(),
            Data::Sparse(s) => s.rows(),
        }
    }

    /// Number of points (columns).
    pub fn len(&self) -> usize {
        match self {
            Data::Dense(m) => m.cols(),
            Data::Sparse(s) => s.cols(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nnz(&self) -> usize {
        match self {
            Data::Dense(m) => m.data().iter().filter(|&&v| v != 0.0).count(),
            Data::Sparse(s) => s.nnz(),
        }
    }

    /// ρ — average nonzeros per point (a *word* count for comms).
    pub fn avg_nnz_per_point(&self) -> f64 {
        if self.len() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.len() as f64
        }
    }

    pub fn col_norm_sq(&self, j: usize) -> f64 {
        match self {
            Data::Dense(m) => m.col(j).iter().map(|v| v * v).sum(),
            Data::Sparse(s) => s.col_norm_sq(j),
        }
    }

    pub fn col_dense(&self, j: usize) -> Vec<f64> {
        match self {
            Data::Dense(m) => m.col(j),
            Data::Sparse(s) => s.col_dense(j),
        }
    }

    /// Gather columns into a dense d×k matrix (sampling output — the
    /// points that get *communicated*).
    pub fn select_cols_dense(&self, idx: &[usize]) -> Mat {
        match self {
            Data::Dense(m) => m.select_cols(idx),
            Data::Sparse(s) => s.select_cols_dense(idx),
        }
    }

    /// Words needed to transmit the selected points (paper's cost
    /// model: a sparse point costs ~2·nnz words (index+value), a dense
    /// point costs d words).
    pub fn transmit_words(&self, idx: &[usize]) -> usize {
        match self {
            Data::Dense(m) => idx.len() * m.rows(),
            Data::Sparse(s) => idx.iter().map(|&j| 2 * s.col_nnz(j)).sum(),
        }
    }

    /// Contiguous column block `[start, end)` as a new shard.
    pub fn slice_cols(&self, start: usize, end: usize) -> Data {
        match self {
            Data::Dense(m) => {
                Data::Dense(Mat::from_fn(m.rows(), end - start, |i, j| m[(i, j + start)]))
            }
            Data::Sparse(s) => Data::Sparse(s.slice_cols(start, end)),
        }
    }

    pub fn to_dense(&self) -> Mat {
        match self {
            Data::Dense(m) => m.clone(),
            Data::Sparse(s) => s.to_dense(),
        }
    }

    /// Scale every entry (used to fold √γ into the data for the
    /// γ-baked Gaussian artifacts).
    pub fn scaled(&self, a: f64) -> Data {
        match self {
            Data::Dense(m) => {
                let mut m = m.clone();
                m.scale(a);
                Data::Dense(m)
            }
            Data::Sparse(s) => {
                let cols = (0..s.cols())
                    .map(|j| s.col_iter(j).map(|(r, v)| (r as u32, v * a)).collect())
                    .collect();
                Data::Sparse(Csc::from_columns(s.rows(), cols))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn pair(rng: &mut Rng) -> (Data, Data) {
        let m = Mat::from_fn(6, 10, |i, j| {
            if (i + 2 * j) % 3 == 0 {
                rng.normal()
            } else {
                0.0
            }
        });
        (Data::Dense(m.clone()), Data::Sparse(Csc::from_dense(&m)))
    }

    #[test]
    fn dense_sparse_agree() {
        let mut rng = Rng::seed_from(1);
        let (d, s) = pair(&mut rng);
        assert_eq!(d.dim(), s.dim());
        assert_eq!(d.len(), s.len());
        assert_eq!(d.nnz(), s.nnz());
        for j in 0..d.len() {
            assert!((d.col_norm_sq(j) - s.col_norm_sq(j)).abs() < 1e-12);
            assert_eq!(d.col_dense(j), s.col_dense(j));
        }
        let idx = [0, 5, 5, 9];
        assert!(d
            .select_cols_dense(&idx)
            .max_abs_diff(&s.select_cols_dense(&idx))
            < 1e-15);
        assert!(d.slice_cols(2, 7).to_dense().max_abs_diff(&s.slice_cols(2, 7).to_dense()) < 1e-15);
    }

    #[test]
    fn transmit_words_cost_model() {
        let mut rng = Rng::seed_from(2);
        let (d, s) = pair(&mut rng);
        // dense: d words per point
        assert_eq!(d.transmit_words(&[0, 1]), 12);
        // sparse: 2·nnz words
        let want: usize = 2 * (s.nnz() / 1).min(usize::MAX); // sanity only
        let _ = want;
        let w = s.transmit_words(&[0, 1]);
        assert!(w <= 2 * 6 * 2 && w > 0);
    }

    #[test]
    fn scaled_scales_norms() {
        let mut rng = Rng::seed_from(3);
        let (d, s) = pair(&mut rng);
        for x in [d, s] {
            let y = x.scaled(2.0);
            for j in 0..x.len() {
                assert!((y.col_norm_sq(j) - 4.0 * x.col_norm_sq(j)).abs() < 1e-10);
            }
        }
    }
}
