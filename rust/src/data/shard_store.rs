//! Out-of-core shard store: fixed-size column blocks on disk.
//!
//! The paper's premise is that worker shards are too large to ship —
//! and at production scale they are also too large to hold in one
//! process's RAM. This module gives workers a disk-resident shard
//! format they can *fold over* in fixed-size blocks, so worker memory
//! is bounded by the block size, not the shard size.
//!
//! ## File format (`.dkps` v2, little-endian)
//!
//! ```text
//! magic "DKPS" | u8 version=2 | u8 kind (0 dense, 1 sparse)
//! u64 d | u64 block_points | u64 footer_off        // header (30 bytes)
//! column blocks …                                  // payload
//! footer @ footer_off:
//!   u64 footer_magic | u64 n | u64 num_blocks | u64 num_epochs
//!   num_blocks × (u64 byte_offset, u64 byte_len, u64 fnv1a64)
//!   num_epochs × u64 epoch_start_col
//!   u64 footer_checksum                            // fnv1a64 of the above
//! ```
//!
//! Block `b` holds columns `[b·block_points, min(n, (b+1)·block_points))`
//! with the same per-column payloads as the resident `data::io` format:
//! dense blocks are `d·c` f64 column-major, sparse blocks are per
//! column a `u64 nnz` then `(u32 row, f64 value)` pairs. f64 bits
//! round-trip exactly, so a streamed shard is bit-identical to the
//! resident one.
//!
//! ### Appends and epochs
//!
//! [`ShardStore::append`] adds columns as a new **epoch** without
//! rewriting committed data: the new blocks (including a fresh copy of
//! the old partial tail block, keeping the all-but-last-block-full
//! invariant) and a new footer are written strictly after the end of
//! the committed region, and only then is the header's `footer_off` —
//! the single commit word — overwritten. A crash anywhere before that
//! last 8-byte write leaves the old footer in force and the partial
//! append as dead bytes; a torn footer is caught by its magic and
//! checksum. Superseded footers are likewise dead bytes — the file is
//! its own append log. `epoch_start_col[e]` records how many columns
//! existed before epoch `e` was appended, so
//! [`ShardStore::delta_range`] can hand a worker exactly the columns
//! it has not folded yet.
//!
//! v1 files (inline index, no checksums, no epochs) still open
//! read-only; [`write_v1`] keeps the legacy writer for them.
//!
//! [`ShardStore`] is the memory-bounded reader: blocks decode on
//! demand through a small LRU, so a sequential fold touches one block
//! at a time and repeated point lookups (sampling rounds) amortize.
//! [`ShardSource`] unifies a resident [`Data`] and a [`ShardStore`]
//! behind the chunk-fold interface the streaming worker runs on.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::comm::PointSet;
use crate::linalg::Mat;
use crate::sparse::Csc;

use super::Data;

const MAGIC: &[u8; 4] = b"DKPS";
const VERSION_V1: u8 = 1;
const VERSION: u8 = 2;
/// magic "DKPS" + version + kind + d + block_points + footer_off.
const V2_HEADER_LEN: u64 = 4 + 1 + 1 + 8 + 8 + 8;
/// Byte offset of the header's `footer_off` word — the append commit
/// word (`magic + version + kind + d + block_points` precede it).
const FOOTER_OFF_AT: u64 = 4 + 1 + 1 + 8 + 8;
const FOOTER_MAGIC: u64 = u64::from_le_bytes(*b"DKPSFTR2");

/// Decoded blocks kept in memory by a [`ShardStore`] reader.
const DEFAULT_CACHE_BLOCKS: usize = 4;

/// Upper bound on a single block's payload (guards against a corrupt
/// index driving a huge allocation).
const MAX_BLOCK_BYTES: u64 = 1 << 33;

/// FNV-1a 64-bit, continued from `h` (seed with [`fnv1a64`]'s offset
/// basis for a fresh hash).
fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64-bit of `bytes` — the per-block and footer checksum.
fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(0xcbf2_9ce4_8422_2325, bytes)
}

/// Encode columns `[lo, hi)` of `data` in the block payload format.
fn encode_cols(data: &Data, lo: usize, hi: usize, out: &mut Vec<u8>) {
    match data {
        Data::Dense(m) => {
            for j in lo..hi {
                for i in 0..m.rows() {
                    out.extend_from_slice(&m[(i, j)].to_le_bytes());
                }
            }
        }
        Data::Sparse(s) => {
            for j in lo..hi {
                out.extend_from_slice(&(s.col_nnz(j) as u64).to_le_bytes());
                for (r, v) in s.col_iter(j) {
                    out.extend_from_slice(&(r as u32).to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
}

/// Encoded byte size of columns `[lo, hi)` — lets writers lay out the
/// file without buffering every block.
fn block_payload_size(data: &Data, lo: usize, hi: usize) -> u64 {
    match data {
        Data::Dense(_) => (data.dim() * (hi - lo) * 8) as u64,
        Data::Sparse(s) => (lo..hi).map(|j| 8 + 12 * s.col_nnz(j) as u64).sum(),
    }
}

/// Serialize a v2 footer (including its trailing checksum).
fn footer_bytes(n: u64, index: &[(u64, u64)], checksums: &[u64], epoch_starts: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + index.len() * 24 + epoch_starts.len() * 8 + 8);
    for v in [FOOTER_MAGIC, n, index.len() as u64, epoch_starts.len() as u64] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for (&(off, len), &ck) in index.iter().zip(checksums) {
        for v in [off, len, ck] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    for &e in epoch_starts {
        out.extend_from_slice(&e.to_le_bytes());
    }
    let ck = fnv1a64(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Write `data` as a v2 chunked shard store with `block_points`
/// columns per block (the last block may be short). The store starts
/// at epoch 0; grow it later with [`ShardStore::append`].
pub fn write(data: &Data, path: impl AsRef<Path>, block_points: usize) -> anyhow::Result<()> {
    anyhow::ensure!(block_points > 0, "shard store needs block_points > 0");
    let d = data.dim();
    let n = data.len();
    let num_blocks = n.div_ceil(block_points);
    let kind = match data {
        Data::Dense(_) => 0u8,
        Data::Sparse(_) => 1u8,
    };
    // Payload sizes are computable up front, so the header's footer
    // offset is known before any block is buffered.
    let mut index = Vec::with_capacity(num_blocks);
    let mut offset = V2_HEADER_LEN;
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        let sz = block_payload_size(data, lo, hi);
        index.push((offset, sz));
        offset += sz;
    }
    let footer_off = offset;
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION, kind])?;
    for v in [d as u64, block_points as u64, footer_off] {
        w.write_all(&v.to_le_bytes())?;
    }
    let mut checksums = Vec::with_capacity(num_blocks);
    let mut blkbuf = Vec::new();
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        blkbuf.clear();
        encode_cols(data, lo, hi, &mut blkbuf);
        debug_assert_eq!(blkbuf.len() as u64, index[b].1);
        checksums.push(fnv1a64(&blkbuf));
        w.write_all(&blkbuf)?;
    }
    w.write_all(&footer_bytes(n as u64, &index, &checksums, &[0]))?;
    w.flush()?;
    Ok(())
}

/// Write `data` in the legacy v1 layout (inline index, no checksums,
/// no epoch table). Kept for back-compat coverage; v1 stores open
/// read-only and cannot be appended to.
pub fn write_v1(data: &Data, path: impl AsRef<Path>, block_points: usize) -> anyhow::Result<()> {
    anyhow::ensure!(block_points > 0, "shard store needs block_points > 0");
    let d = data.dim();
    let n = data.len();
    let num_blocks = n.div_ceil(block_points);
    let kind = match data {
        Data::Dense(_) => 0u8,
        Data::Sparse(_) => 1u8,
    };
    let mut sizes = Vec::with_capacity(num_blocks);
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        sizes.push(block_payload_size(data, lo, hi));
    }
    let header_len = (4 + 1 + 1 + 8 * 4 + num_blocks * 16) as u64;
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION_V1, kind])?;
    for v in [d as u64, n as u64, block_points as u64, num_blocks as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    let mut offset = header_len;
    for &sz in &sizes {
        w.write_all(&offset.to_le_bytes())?;
        w.write_all(&sz.to_le_bytes())?;
        offset += sz;
    }
    let mut blkbuf = Vec::new();
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        blkbuf.clear();
        encode_cols(data, lo, hi, &mut blkbuf);
        w.write_all(&blkbuf)?;
    }
    w.flush()?;
    Ok(())
}

/// Memory-bounded reader over a `.dkps` file: decodes blocks on demand
/// behind a small LRU of [`Arc<Data>`] blocks.
pub struct ShardStore {
    file: Mutex<std::fs::File>,
    /// Backing file, kept for [`ShardStore::append`] (the read handle
    /// is read-only) and [`ShardStore::refresh`].
    path: std::path::PathBuf,
    /// Format version this file was opened as (1 = legacy read-only).
    version: u8,
    /// (byte_offset, byte_len) per block.
    index: Vec<(u64, u64)>,
    /// FNV-1a 64 per block payload; empty for v1 stores (unchecked).
    checksums: Vec<u64>,
    dim: usize,
    len: usize,
    block_points: usize,
    sparse: bool,
    /// `epoch_starts[e]` = column count before epoch `e` was appended
    /// (always starts with 0; one entry per committed epoch + 1).
    epoch_starts: Vec<u64>,
    /// Most-recently-used first.
    cache: Mutex<Vec<(usize, Arc<Data>)>>,
    cache_blocks: usize,
}

impl ShardStore {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut f = std::fs::File::open(&path)?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a diskpca shard store (bad magic)");
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        anyhow::ensure!(
            hdr[0] == VERSION_V1 || hdr[0] == VERSION,
            "unsupported shard store version {}",
            hdr[0]
        );
        anyhow::ensure!(hdr[1] <= 1, "unknown shard store kind {}", hdr[1]);
        let sparse = hdr[1] == 1;
        let mut u = [0u8; 8];
        let mut next = |f: &mut std::fs::File| -> anyhow::Result<u64> {
            f.read_exact(&mut u)?;
            Ok(u64::from_le_bytes(u))
        };
        if hdr[0] == VERSION_V1 {
            // legacy layout: n + inline index in the header, no
            // checksums, no epoch table — read-only, epoch pinned to 0
            let d = next(&mut f)? as usize;
            let n = next(&mut f)? as usize;
            let block_points = next(&mut f)? as usize;
            let num_blocks = next(&mut f)? as usize;
            anyhow::ensure!(block_points > 0, "shard store has block_points = 0");
            anyhow::ensure!(
                num_blocks == n.div_ceil(block_points),
                "shard store index length {num_blocks} inconsistent with n={n}, block_points={block_points}"
            );
            let mut index = Vec::with_capacity(num_blocks);
            for _ in 0..num_blocks {
                let off = next(&mut f)?;
                let len = next(&mut f)?;
                anyhow::ensure!(
                    len <= MAX_BLOCK_BYTES
                        && off.checked_add(len).is_some_and(|end| end <= file_len),
                    "shard store block range {off}+{len} outside file of {file_len} bytes"
                );
                index.push((off, len));
            }
            return Ok(Self {
                file: Mutex::new(f),
                path,
                version: VERSION_V1,
                index,
                checksums: Vec::new(),
                dim: d,
                len: n,
                block_points,
                sparse,
                epoch_starts: vec![0],
                cache: Mutex::new(Vec::new()),
                cache_blocks: DEFAULT_CACHE_BLOCKS,
            });
        }
        let d = next(&mut f)? as usize;
        let block_points = next(&mut f)? as usize;
        let footer_off = next(&mut f)?;
        anyhow::ensure!(block_points > 0, "shard store has block_points = 0");
        anyhow::ensure!(
            footer_off >= V2_HEADER_LEN
                && footer_off.checked_add(40).is_some_and(|end| end <= file_len),
            "shard store footer offset {footer_off} outside file of {file_len} bytes"
        );
        f.seek(SeekFrom::Start(footer_off))?;
        let mut head = [0u8; 32];
        f.read_exact(&mut head)?;
        let word = |i: usize| u64::from_le_bytes(head[8 * i..8 * i + 8].try_into().unwrap());
        anyhow::ensure!(
            word(0) == FOOTER_MAGIC,
            "shard store footer magic mismatch (torn or corrupt append)"
        );
        let n = word(1) as usize;
        let num_blocks = word(2) as usize;
        let num_epochs = word(3) as usize;
        anyhow::ensure!(num_epochs >= 1, "shard store footer has no epoch table");
        anyhow::ensure!(
            num_blocks == n.div_ceil(block_points),
            "shard store index length {num_blocks} inconsistent with n={n}, block_points={block_points}"
        );
        let tail_len = (num_blocks as u64)
            .checked_mul(24)
            .and_then(|v| v.checked_add((num_epochs as u64).checked_mul(8)?))
            .and_then(|v| v.checked_add(8));
        anyhow::ensure!(
            tail_len.is_some_and(|t| footer_off + 32 + t <= file_len),
            "shard store footer truncated"
        );
        let mut tail = vec![0u8; tail_len.unwrap() as usize];
        f.read_exact(&mut tail)?;
        let body_len = tail.len() - 8;
        let want = u64::from_le_bytes(tail[body_len..].try_into().unwrap());
        let got = fnv1a64_update(fnv1a64(&head), &tail[..body_len]);
        anyhow::ensure!(
            got == want,
            "shard store footer checksum mismatch (torn or corrupt append)"
        );
        let mut at = 0usize;
        let mut rd = |at: &mut usize| {
            let v = u64::from_le_bytes(tail[*at..*at + 8].try_into().unwrap());
            *at += 8;
            v
        };
        let mut index = Vec::with_capacity(num_blocks);
        let mut checksums = Vec::with_capacity(num_blocks);
        for _ in 0..num_blocks {
            let off = rd(&mut at);
            let len = rd(&mut at);
            let ck = rd(&mut at);
            anyhow::ensure!(
                len <= MAX_BLOCK_BYTES
                    && off >= V2_HEADER_LEN
                    && off.checked_add(len).is_some_and(|end| end <= file_len),
                "shard store block range {off}+{len} outside file of {file_len} bytes"
            );
            index.push((off, len));
            checksums.push(ck);
        }
        let mut epoch_starts = Vec::with_capacity(num_epochs);
        for _ in 0..num_epochs {
            epoch_starts.push(rd(&mut at));
        }
        anyhow::ensure!(
            epoch_starts[0] == 0
                && epoch_starts.windows(2).all(|w| w[0] <= w[1])
                && *epoch_starts.last().unwrap() <= n as u64,
            "shard store epoch table is not an ascending prefix of 0..n"
        );
        Ok(Self {
            file: Mutex::new(f),
            path,
            version: VERSION,
            index,
            checksums,
            dim: d,
            len: n,
            block_points,
            sparse,
            epoch_starts,
            cache: Mutex::new(Vec::new()),
            cache_blocks: DEFAULT_CACHE_BLOCKS,
        })
    }

    /// Append `cols` as a new epoch; returns the new epoch number.
    ///
    /// Crash-safe: the new blocks (including a fresh copy of the old
    /// partial tail block, preserving the every-block-but-last-full
    /// invariant) and a new footer are written strictly *after* the
    /// committed region, then the header's footer offset — the single
    /// 8-byte commit word — is overwritten last. A crash before that
    /// write leaves the prior footer in force and the partial append
    /// as dead bytes; [`ShardStore::open`] never sees it.
    pub fn append(&mut self, cols: &Data) -> anyhow::Result<u64> {
        anyhow::ensure!(
            self.version >= VERSION,
            "v1 shard store is read-only: rewrite it as v2 to append"
        );
        anyhow::ensure!(
            cols.dim() == self.dim,
            "append dim {} != store dim {}",
            cols.dim(),
            self.dim
        );
        anyhow::ensure!(
            matches!(cols, Data::Sparse(_)) == self.sparse,
            "append encoding must match the store (dense vs sparse)"
        );
        anyhow::ensure!(!cols.is_empty(), "refusing to append an empty epoch");
        let bp = self.block_points;
        let keep_blocks = self.len / bp;
        let tail_start = keep_blocks * bp;
        let combined = if tail_start == self.len {
            cols.clone()
        } else {
            concat_data(vec![self.read_cols(tail_start, self.len), cols.clone()])
        };
        let mut f = std::fs::OpenOptions::new().read(true).write(true).open(&self.path)?;
        let old_end = f.seek(SeekFrom::End(0))?;
        let new_n = self.len + cols.len();
        let mut index: Vec<(u64, u64)> = self.index[..keep_blocks].to_vec();
        let mut checksums: Vec<u64> = self.checksums[..keep_blocks].to_vec();
        let mut epoch_starts = self.epoch_starts.clone();
        epoch_starts.push(self.len as u64);
        let footer_off;
        {
            let mut w = std::io::BufWriter::new(&mut f);
            let mut offset = old_end;
            let mut blkbuf = Vec::new();
            let m = combined.len();
            let mut lo = 0;
            while lo < m {
                let hi = (lo + bp).min(m);
                blkbuf.clear();
                encode_cols(&combined, lo, hi, &mut blkbuf);
                w.write_all(&blkbuf)?;
                index.push((offset, blkbuf.len() as u64));
                checksums.push(fnv1a64(&blkbuf));
                offset += blkbuf.len() as u64;
                lo = hi;
            }
            footer_off = offset;
            w.write_all(&footer_bytes(new_n as u64, &index, &checksums, &epoch_starts))?;
            w.flush()?;
        }
        // everything must be durable before the commit word moves
        f.sync_all()?;
        f.seek(SeekFrom::Start(FOOTER_OFF_AT))?;
        f.write_all(&footer_off.to_le_bytes())?;
        f.sync_all()?;
        self.index = index;
        self.checksums = checksums;
        self.len = new_n;
        self.epoch_starts = epoch_starts;
        // the old partial tail block (if any) was superseded by a
        // rewritten copy at a new offset — drop any cached decode
        self.cache.lock().unwrap().retain(|(b, _)| *b < keep_blocks);
        Ok(self.epoch())
    }

    /// Number of appends committed to this store (a fresh store — and
    /// any v1 store — is epoch 0).
    pub fn epoch(&self) -> u64 {
        (self.epoch_starts.len() - 1) as u64
    }

    /// The columns appended *after* `epoch` was current: exactly what
    /// a worker holding state for `epoch` must fold to catch up.
    /// Empty when the store is at (or behind) the given epoch.
    pub fn delta_range(&self, epoch: u64) -> std::ops::Range<usize> {
        match usize::try_from(epoch).ok().and_then(|e| self.epoch_starts.get(e + 1)) {
            Some(&start) => start as usize..self.len,
            None => self.len..self.len,
        }
    }

    /// The backing file this store reads from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Re-open the backing file, picking up epochs committed through
    /// another handle (the worker-side `ReqRefreshShard` path).
    pub fn refresh(&mut self) -> anyhow::Result<()> {
        let mut fresh = ShardStore::open(&self.path)?;
        fresh.cache_blocks = self.cache_blocks;
        *self = fresh;
        Ok(())
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn block_points(&self) -> usize {
        self.block_points
    }

    pub fn num_blocks(&self) -> usize {
        self.index.len()
    }

    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// Column count of block `b`.
    fn block_cols(&self, b: usize) -> usize {
        let lo = b * self.block_points;
        (lo + self.block_points).min(self.len) - lo
    }

    /// Fetch block `b`, decoding through the LRU. IO/decode failures
    /// panic with context — over the protocol they surface to the
    /// master as a `RespError`.
    pub fn block(&self, b: usize) -> Arc<Data> {
        {
            let mut cache = self.cache.lock().unwrap();
            if let Some(pos) = cache.iter().position(|(i, _)| *i == b) {
                let hit = cache.remove(pos);
                let data = hit.1.clone();
                cache.insert(0, hit);
                return data;
            }
        }
        let decoded = Arc::new(
            self.read_block(b)
                .unwrap_or_else(|e| panic!("shard store: reading block {b} failed: {e}")),
        );
        let mut cache = self.cache.lock().unwrap();
        cache.insert(0, (b, decoded.clone()));
        cache.truncate(self.cache_blocks.max(1));
        decoded
    }

    fn read_block(&self, b: usize) -> anyhow::Result<Data> {
        let (off, len) = self.index[b];
        let cols = self.block_cols(b);
        let mut buf = vec![0u8; len as usize];
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(off))?;
            f.read_exact(&mut buf)?;
        }
        if let Some(&want) = self.checksums.get(b) {
            let got = fnv1a64(&buf);
            anyhow::ensure!(
                got == want,
                "block {b} checksum mismatch ({got:#018x} != {want:#018x}): shard store corrupt"
            );
        }
        fn take_u64(buf: &[u8], at: &mut usize) -> anyhow::Result<u64> {
            let end = *at + 8;
            let bytes = buf.get(*at..end).ok_or_else(|| anyhow::anyhow!("block truncated"))?;
            *at = end;
            Ok(u64::from_le_bytes(bytes.try_into().unwrap()))
        }
        let mut at = 0usize;
        if self.sparse {
            let mut out_cols: Vec<Vec<(u32, f64)>> = Vec::with_capacity(cols);
            for _ in 0..cols {
                let nnz = take_u64(&buf, &mut at)? as usize;
                let mut col = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let end = at + 12;
                    let bytes =
                        buf.get(at..end).ok_or_else(|| anyhow::anyhow!("block truncated"))?;
                    at = end;
                    let r = u32::from_le_bytes(bytes[..4].try_into().unwrap());
                    let v = f64::from_le_bytes(bytes[4..].try_into().unwrap());
                    col.push((r, v));
                }
                out_cols.push(col);
            }
            anyhow::ensure!(at == buf.len(), "sparse block has trailing bytes");
            Ok(Data::Sparse(Csc::from_columns(self.dim, out_cols)))
        } else {
            anyhow::ensure!(
                buf.len() == self.dim * cols * 8,
                "dense block is {} bytes, expected {}",
                buf.len(),
                self.dim * cols * 8
            );
            let mut m = Mat::zeros(self.dim, cols);
            for j in 0..cols {
                for i in 0..self.dim {
                    let end = at + 8;
                    m[(i, j)] = f64::from_le_bytes(buf[at..end].try_into().unwrap());
                    at = end;
                }
            }
            Ok(Data::Dense(m))
        }
    }

    /// Materialize the contiguous column range `[start, end)`,
    /// assembling across block boundaries when needed.
    pub fn read_cols(&self, start: usize, end: usize) -> Data {
        assert!(start <= end && end <= self.len, "read_cols {start}..{end} of {}", self.len);
        let bp = self.block_points;
        if start == end {
            return if self.sparse {
                Data::Sparse(Csc::from_columns(self.dim, Vec::new()))
            } else {
                Data::Dense(Mat::zeros(self.dim, 0))
            };
        }
        let b0 = start / bp;
        let b1 = (end - 1) / bp;
        let mut parts = Vec::with_capacity(b1 - b0 + 1);
        for b in b0..=b1 {
            let blk = self.block(b);
            let lo = (b * bp).max(start) - b * bp;
            let hi = ((b + 1) * bp).min(end) - b * bp;
            if lo == 0 && hi == self.block_cols(b) && b0 == b1 {
                // exact single-block hit (read_cols must return owned
                // Data, so this is one block copy; the hot sequential
                // fold avoids even that by borrowing the cached block
                // directly — see ShardSource::for_each_chunk)
                return (*blk).clone();
            }
            parts.push(blk.slice_cols(lo, hi));
        }
        concat_data(parts)
    }

    /// Gather arbitrary columns (in the given order, repetition
    /// allowed) in the shard's natural encoding.
    pub fn select(&self, idx: &[usize]) -> Data {
        let bp = self.block_points;
        if self.sparse {
            let cols = idx
                .iter()
                .map(|&j| {
                    let blk = self.block(j / bp);
                    match &*blk {
                        Data::Sparse(s) => s
                            .col_iter(j % bp)
                            .map(|(r, v)| (r as u32, v))
                            .collect::<Vec<_>>(),
                        Data::Dense(_) => unreachable!("sparse store holds dense block"),
                    }
                })
                .collect();
            Data::Sparse(Csc::from_columns(self.dim, cols))
        } else {
            let mut out = Mat::zeros(self.dim, idx.len());
            for (c, &j) in idx.iter().enumerate() {
                let blk = self.block(j / bp);
                match &*blk {
                    Data::Dense(m) => {
                        for i in 0..self.dim {
                            out[(i, c)] = m[(i, j % bp)];
                        }
                    }
                    Data::Sparse(_) => unreachable!("dense store holds sparse block"),
                }
            }
            Data::Dense(out)
        }
    }
}

/// Concatenate column chunks that share a dim and encoding.
fn concat_data(parts: Vec<Data>) -> Data {
    assert!(!parts.is_empty());
    if parts.len() == 1 {
        return parts.into_iter().next().unwrap();
    }
    if parts.iter().all(|p| matches!(p, Data::Sparse(_))) {
        let d = parts[0].dim();
        let mut cols = Vec::new();
        for p in &parts {
            if let Data::Sparse(s) = p {
                for j in 0..s.cols() {
                    cols.push(s.col_iter(j).map(|(r, v)| (r as u32, v)).collect());
                }
            }
        }
        Data::Sparse(Csc::from_columns(d, cols))
    } else {
        let mats: Vec<Mat> = parts.iter().map(|p| p.to_dense()).collect();
        Data::Dense(Mat::hcat_all(&mats))
    }
}

/// Where a worker's shard lives: resident in memory, or on disk behind
/// a [`ShardStore`]. The streaming worker folds over either through
/// [`ShardSource::for_each_chunk`]; per-column results are identical
/// either way (disk blocks round-trip f64 bits exactly).
pub enum ShardSource {
    Resident(Data),
    Store(ShardStore),
}

impl ShardSource {
    pub fn dim(&self) -> usize {
        match self {
            ShardSource::Resident(d) => d.dim(),
            ShardSource::Store(s) => s.dim(),
        }
    }

    /// Number of points (columns).
    pub fn len(&self) -> usize {
        match self {
            ShardSource::Resident(d) => d.len(),
            ShardSource::Store(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The resident shard, if this source is in-memory.
    pub fn resident(&self) -> Option<&Data> {
        match self {
            ShardSource::Resident(d) => Some(d),
            ShardSource::Store(_) => None,
        }
    }

    /// The store's committed epoch (a resident shard is always 0).
    pub fn epoch(&self) -> u64 {
        match self {
            ShardSource::Resident(_) => 0,
            ShardSource::Store(s) => s.epoch(),
        }
    }

    /// Fold `f(first_col, chunk)` over ascending column chunks of at
    /// most `chunk_rows` points (`0` ⇒ one chunk for a resident shard,
    /// block-sized chunks for a store).
    pub fn for_each_chunk(&self, chunk_rows: usize, f: impl FnMut(usize, &Data)) {
        self.for_each_chunk_from(chunk_rows, 0, f);
    }

    /// [`ShardSource::for_each_chunk`] restricted to columns
    /// `[from, len)` — the delta-fold entry: an epoch-aware worker
    /// starts at the first column its retained accumulator has not
    /// seen. Chunk boundaries never change per-column results (the
    /// sketch fold adds per ascending global column), so any `from`
    /// composed with any chunking is bit-identical to one full pass.
    pub fn for_each_chunk_from(
        &self,
        chunk_rows: usize,
        from: usize,
        mut f: impl FnMut(usize, &Data),
    ) {
        let n = self.len();
        if from >= n {
            return;
        }
        let step = match (self, chunk_rows) {
            (ShardSource::Resident(_), 0) => n,
            (ShardSource::Store(s), 0) => s.block_points(),
            (_, c) => c,
        };
        if let (ShardSource::Resident(d), true) = (self, from == 0 && step >= n) {
            f(0, d);
            return;
        }
        let mut at = from;
        while at < n {
            // block-step store folds re-align to block boundaries so
            // every chunk after the first is a zero-copy cached block
            let end = match (self, chunk_rows) {
                (ShardSource::Store(_), 0) => ((at / step + 1) * step).min(n),
                _ => (at + step).min(n),
            };
            match self {
                ShardSource::Resident(d) => f(at, &d.slice_cols(at, end)),
                ShardSource::Store(s) => {
                    let bp = s.block_points();
                    if at % bp == 0 && (end == (at / bp + 1) * bp || end == n) && end - at <= bp {
                        // chunk == exactly one stored block (the common
                        // block-sized fold): hand out the cached Arc's
                        // Data without copying it
                        let blk = s.block(at / bp);
                        f(at, &blk);
                    } else {
                        f(at, &s.read_cols(at, end));
                    }
                }
            }
            at = end;
        }
    }

    /// Gather the indexed points (in order) as a [`PointSet`] in the
    /// shard's natural encoding — the sampling-round reply path.
    pub fn point_set(&self, idx: &[usize]) -> PointSet {
        match self {
            ShardSource::Resident(d) => PointSet::from_data(d, idx),
            ShardSource::Store(s) => match s.select(idx) {
                Data::Dense(m) => PointSet::Dense(m),
                Data::Sparse(c) => PointSet::Sparse {
                    d: c.rows(),
                    cols: (0..c.cols())
                        .map(|j| c.col_iter(j).map(|(r, v)| (r as u32, v)).collect())
                        .collect(),
                },
            },
        }
    }

    /// Gather the indexed points (in order) as a [`Data`] in the
    /// shard's natural encoding.
    pub fn select(&self, idx: &[usize]) -> Data {
        match self {
            ShardSource::Resident(Data::Dense(m)) => Data::Dense(m.select_cols(idx)),
            ShardSource::Resident(Data::Sparse(s)) => Data::Sparse(s.select_cols(idx)),
            ShardSource::Store(s) => s.select(idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("diskpca_store_{name}.dkps"))
    }

    fn dense_data(rng: &mut Rng, d: usize, n: usize) -> Data {
        Data::Dense(Mat::from_fn(d, n, |_, _| rng.normal()))
    }

    fn sparse_data(rng: &mut Rng, d: usize, n: usize) -> Data {
        Data::Sparse(crate::data::zipf_sparse(d, n, 6, rng))
    }

    #[test]
    fn roundtrip_dense_bit_exact() {
        let mut rng = Rng::seed_from(1);
        let data = dense_data(&mut rng, 7, 53);
        let path = tmp("dense");
        write(&data, &path, 10).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert_eq!((store.dim(), store.len()), (7, 53));
        assert_eq!(store.num_blocks(), 6);
        assert!(!store.is_sparse());
        let back = store.read_cols(0, 53);
        assert_eq!(back.to_dense().data(), data.to_dense().data());
    }

    #[test]
    fn roundtrip_sparse_bit_exact() {
        let mut rng = Rng::seed_from(2);
        let data = sparse_data(&mut rng, 60, 41);
        let path = tmp("sparse");
        write(&data, &path, 8).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert!(store.is_sparse());
        assert_eq!(store.num_blocks(), 6);
        let back = store.read_cols(0, 41);
        assert_eq!(back.nnz(), data.nnz());
        assert_eq!(back.to_dense().data(), data.to_dense().data());
    }

    #[test]
    fn read_cols_spans_blocks() {
        let mut rng = Rng::seed_from(3);
        let data = dense_data(&mut rng, 5, 29);
        let path = tmp("span");
        write(&data, &path, 6).unwrap();
        let store = ShardStore::open(&path).unwrap();
        for (lo, hi) in [(0, 6), (4, 17), (27, 29), (3, 3), (0, 29)] {
            let got = store.read_cols(lo, hi);
            let want = data.slice_cols(lo, hi);
            assert_eq!(got.to_dense().data(), want.to_dense().data(), "{lo}..{hi}");
        }
    }

    #[test]
    fn select_and_point_set_match_resident() {
        let mut rng = Rng::seed_from(4);
        for data in [dense_data(&mut rng, 6, 23), sparse_data(&mut rng, 40, 23)] {
            let path = tmp(if matches!(data, Data::Dense(_)) { "sel_d" } else { "sel_s" });
            write(&data, &path, 5).unwrap();
            let store = ShardSource::Store(ShardStore::open(&path).unwrap());
            let resident = ShardSource::Resident(data.clone());
            let idx = [22, 0, 7, 7, 13];
            assert_eq!(
                store.select(&idx).to_dense().data(),
                resident.select(&idx).to_dense().data()
            );
            assert_eq!(
                store.point_set(&idx).to_mat().data(),
                resident.point_set(&idx).to_mat().data()
            );
            assert_eq!(store.point_set(&[]).len(), 0);
        }
    }

    #[test]
    fn chunk_fold_covers_exactly_once() {
        let mut rng = Rng::seed_from(5);
        let data = dense_data(&mut rng, 4, 37);
        let path = tmp("fold");
        write(&data, &path, 9).unwrap();
        for source in [
            ShardSource::Resident(data.clone()),
            ShardSource::Store(ShardStore::open(&path).unwrap()),
        ] {
            for chunk in [0, 1, 5, 37, 100] {
                let mut seen = Vec::new();
                let mut cols = 0;
                source.for_each_chunk(chunk, |j0, c| {
                    assert_eq!(j0, cols, "chunks must ascend contiguously");
                    assert_eq!(c.dim(), 4);
                    cols += c.len();
                    for j in 0..c.len() {
                        seen.push(c.col_norm_sq(j).to_bits());
                    }
                });
                assert_eq!(cols, 37, "chunk={chunk}");
                let want: Vec<u64> = (0..37).map(|j| data.col_norm_sq(j).to_bits()).collect();
                assert_eq!(seen, want, "chunk={chunk}");
            }
        }
    }

    #[test]
    fn lru_keeps_store_usable_under_random_access() {
        let mut rng = Rng::seed_from(6);
        let data = dense_data(&mut rng, 3, 64);
        let path = tmp("lru");
        write(&data, &path, 4).unwrap(); // 16 blocks ≫ cache of 4
        let store = ShardStore::open(&path).unwrap();
        for trial in 0..200 {
            let j = (trial * 37) % 64;
            let got = store.select(&[j]);
            assert_eq!(
                got.to_dense().data(),
                data.slice_cols(j, j + 1).to_dense().data(),
                "col {j}"
            );
        }
    }

    #[test]
    fn open_rejects_garbage_and_bad_index() {
        let path = tmp("garbage");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(ShardStore::open(&path).is_err());
        // valid v1 store, then corrupt one inline index entry's length
        let mut rng = Rng::seed_from(7);
        let data = dense_data(&mut rng, 3, 10);
        let path = tmp("corrupt");
        write_v1(&data, &path, 4).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let idx_at = 4 + 2 + 32 + 8; // first block's byte_len field (v1 layout)
        bytes[idx_at..idx_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardStore::open(&path).is_err(), "oversized block length must be rejected");
    }

    #[test]
    fn v1_opens_read_only_at_epoch_zero() {
        let mut rng = Rng::seed_from(10);
        let data = dense_data(&mut rng, 5, 23);
        let path = tmp("v1_compat");
        write_v1(&data, &path, 6).unwrap();
        let mut store = ShardStore::open(&path).unwrap();
        assert_eq!((store.dim(), store.len()), (5, 23));
        assert_eq!(store.epoch(), 0);
        assert!(store.delta_range(0).is_empty());
        assert_eq!(
            store.read_cols(0, 23).to_dense().data(),
            data.to_dense().data(),
            "v1 payload must still round-trip"
        );
        let extra = dense_data(&mut rng, 5, 3);
        let err = store.append(&extra).unwrap_err();
        assert!(err.to_string().contains("read-only"), "unexpected error: {err}");
    }

    #[test]
    fn v2_rejects_unknown_version_and_truncation() {
        let mut rng = Rng::seed_from(11);
        let data = dense_data(&mut rng, 4, 17);
        let path = tmp("v2_version");
        write(&data, &path, 5).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // unknown version byte
        let mut bad = bytes.clone();
        bad[4] = 3;
        std::fs::write(&path, &bad).unwrap();
        let err = ShardStore::open(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "unexpected error: {err}");
        // truncation anywhere in the footer must be caught cleanly
        for cut in [1, 8, 40] {
            std::fs::write(&path, &bytes[..bytes.len() - cut]).unwrap();
            assert!(ShardStore::open(&path).is_err(), "cut={cut} must be rejected");
        }
        // restore → opens again
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardStore::open(&path).is_ok());
    }

    #[test]
    fn v2_block_corruption_fails_checksum_on_read() {
        let mut rng = Rng::seed_from(12);
        let data = dense_data(&mut rng, 3, 12);
        let path = tmp("v2_blkcorrupt");
        write(&data, &path, 4).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one payload bit in block 0 (payload starts at byte 30);
        // the footer stays valid, so open succeeds and the *read* trips
        bytes[30 + 5] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let store = ShardStore::open(&path).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| store.block(0)));
        assert!(res.is_err(), "corrupt block payload must fail its checksum");
        // untouched blocks still read fine
        assert_eq!(store.block(1).len(), 4);
    }

    #[test]
    fn append_roundtrips_epochs_and_delta_ranges() {
        let mut rng = Rng::seed_from(13);
        // bp=6, n=14: the tail block is partial, so the first append
        // exercises the rewrite path
        for sparse in [false, true] {
            let gen = |rng: &mut Rng, n: usize| {
                if sparse {
                    sparse_data(rng, 9, n)
                } else {
                    dense_data(rng, 9, n)
                }
            };
            let base = gen(&mut rng, 14);
            let d1 = gen(&mut rng, 5);
            let d2 = gen(&mut rng, 7);
            let path = tmp(if sparse { "append_s" } else { "append_d" });
            write(&base, &path, 6).unwrap();
            let mut store = ShardStore::open(&path).unwrap();
            assert_eq!(store.append(&d1).unwrap(), 1);
            assert_eq!(store.append(&d2).unwrap(), 2);
            assert_eq!(store.len(), 26);
            assert_eq!(store.num_blocks(), 5);
            assert_eq!(store.delta_range(0), 14..26);
            assert_eq!(store.delta_range(1), 19..26);
            assert!(store.delta_range(2).is_empty());
            assert!(store.delta_range(99).is_empty());
            let want = concat_data(vec![base.clone(), d1.clone(), d2.clone()]);
            assert_eq!(
                store.read_cols(0, 26).to_dense().data(),
                want.to_dense().data(),
                "sparse={sparse}: appended store must read back bit-exact"
            );
            // a fresh open sees the same committed state
            let reopened = ShardStore::open(&path).unwrap();
            assert_eq!(reopened.epoch(), 2);
            assert_eq!(reopened.delta_range(1), 19..26);
            assert_eq!(
                reopened.read_cols(0, 26).to_dense().data(),
                want.to_dense().data()
            );
        }
    }

    #[test]
    fn append_rejects_mismatched_columns() {
        let mut rng = Rng::seed_from(14);
        let path = tmp("append_guard");
        write(&dense_data(&mut rng, 4, 10), &path, 4).unwrap();
        let mut store = ShardStore::open(&path).unwrap();
        assert!(store.append(&dense_data(&mut rng, 5, 3)).is_err(), "wrong dim");
        assert!(store.append(&sparse_data(&mut rng, 4, 3)).is_err(), "wrong encoding");
        assert!(store.append(&dense_data(&mut rng, 4, 0)).is_err(), "empty epoch");
        assert_eq!(store.epoch(), 0, "failed appends must not commit an epoch");
    }

    #[test]
    fn torn_append_leaves_committed_epochs_intact() {
        let mut rng = Rng::seed_from(15);
        let base = dense_data(&mut rng, 3, 10);
        let delta = dense_data(&mut rng, 3, 4);
        let path = tmp("torn");
        write(&base, &path, 4).unwrap();
        let before = std::fs::read(&path).unwrap();
        let mut store = ShardStore::open(&path).unwrap();
        store.append(&delta).unwrap();
        let after = std::fs::read(&path).unwrap();
        // simulate a crash after the blocks + footer landed but before
        // the header commit word: restore the old footer_off
        let mut torn = after.clone();
        let at = FOOTER_OFF_AT as usize;
        torn[at..at + 8].copy_from_slice(&before[at..at + 8]);
        std::fs::write(&path, &torn).unwrap();
        let recovered = ShardStore::open(&path).unwrap();
        assert_eq!(recovered.epoch(), 0, "uncommitted append must be invisible");
        assert_eq!(recovered.len(), 10);
        assert_eq!(
            recovered.read_cols(0, 10).to_dense().data(),
            base.to_dense().data(),
            "committed epoch must survive the torn append"
        );
        // a torn *footer pointer* (commit word pointing mid-payload)
        // must fail cleanly, not panic or misparse
        let mut wild = after;
        wild[at..at + 8].copy_from_slice(&35u64.to_le_bytes());
        std::fs::write(&path, &wild).unwrap();
        let err = ShardStore::open(&path).unwrap_err();
        assert!(
            err.to_string().contains("footer"),
            "torn commit word must surface a footer error, got: {err}"
        );
    }

    #[test]
    fn refresh_picks_up_epochs_from_another_handle() {
        let mut rng = Rng::seed_from(16);
        let base = dense_data(&mut rng, 4, 9);
        let delta = dense_data(&mut rng, 4, 6);
        let path = tmp("refresh");
        write(&base, &path, 4).unwrap();
        let mut reader = ShardStore::open(&path).unwrap();
        assert_eq!(reader.epoch(), 0);
        let mut writer = ShardStore::open(&path).unwrap();
        writer.append(&delta).unwrap();
        // the stale handle still sees epoch 0 until refreshed
        assert_eq!(reader.epoch(), 0);
        reader.refresh().unwrap();
        assert_eq!(reader.epoch(), 1);
        assert_eq!(reader.len(), 15);
        assert_eq!(reader.delta_range(0), 9..15);
        let want = concat_data(vec![base, delta]);
        assert_eq!(reader.read_cols(0, 15).to_dense().data(), want.to_dense().data());
    }

    #[test]
    fn chunk_fold_from_covers_exactly_the_tail() {
        let mut rng = Rng::seed_from(17);
        let data = dense_data(&mut rng, 4, 37);
        let path = tmp("fold_from");
        write(&data, &path, 9).unwrap();
        for source in [
            ShardSource::Resident(data.clone()),
            ShardSource::Store(ShardStore::open(&path).unwrap()),
        ] {
            for from in [0, 1, 9, 20, 36, 37, 50] {
                for chunk in [0, 1, 5, 37] {
                    let mut cols = from;
                    let mut seen = Vec::new();
                    source.for_each_chunk_from(chunk, from, |j0, c| {
                        assert_eq!(j0, cols, "chunks must ascend contiguously from {from}");
                        cols += c.len();
                        for j in 0..c.len() {
                            seen.push(c.col_norm_sq(j).to_bits());
                        }
                    });
                    assert_eq!(cols, 37.max(from), "from={from} chunk={chunk}");
                    let want: Vec<u64> =
                        (from.min(37)..37).map(|j| data.col_norm_sq(j).to_bits()).collect();
                    assert_eq!(seen, want, "from={from} chunk={chunk}");
                }
            }
        }
    }
}
