//! Out-of-core shard store: fixed-size column blocks on disk.
//!
//! The paper's premise is that worker shards are too large to ship —
//! and at production scale they are also too large to hold in one
//! process's RAM. This module gives workers a disk-resident shard
//! format they can *fold over* in fixed-size blocks, so worker memory
//! is bounded by the block size, not the shard size.
//!
//! ## File format (`.dkps`, little-endian)
//!
//! ```text
//! magic "DKPS" | u8 version=1 | u8 kind (0 dense, 1 sparse)
//! u64 d | u64 n | u64 block_points | u64 num_blocks
//! num_blocks × (u64 byte_offset, u64 byte_len)     // block index
//! num_blocks × payload                             // column blocks
//! ```
//!
//! Block `b` holds columns `[b·block_points, min(n, (b+1)·block_points))`
//! with the same per-column payloads as the resident `data::io` format:
//! dense blocks are `d·c` f64 column-major, sparse blocks are per
//! column a `u64 nnz` then `(u32 row, f64 value)` pairs. f64 bits
//! round-trip exactly, so a streamed shard is bit-identical to the
//! resident one.
//!
//! [`ShardStore`] is the memory-bounded reader: blocks decode on
//! demand through a small LRU, so a sequential fold touches one block
//! at a time and repeated point lookups (sampling rounds) amortize.
//! [`ShardSource`] unifies a resident [`Data`] and a [`ShardStore`]
//! behind the chunk-fold interface the streaming worker runs on.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::comm::PointSet;
use crate::linalg::Mat;
use crate::sparse::Csc;

use super::Data;

const MAGIC: &[u8; 4] = b"DKPS";
const VERSION: u8 = 1;

/// Decoded blocks kept in memory by a [`ShardStore`] reader.
const DEFAULT_CACHE_BLOCKS: usize = 4;

/// Upper bound on a single block's payload (guards against a corrupt
/// index driving a huge allocation).
const MAX_BLOCK_BYTES: u64 = 1 << 33;

/// Write `data` as a chunked shard store with `block_points` columns
/// per block (the last block may be short).
pub fn write(data: &Data, path: impl AsRef<Path>, block_points: usize) -> anyhow::Result<()> {
    anyhow::ensure!(block_points > 0, "shard store needs block_points > 0");
    let d = data.dim();
    let n = data.len();
    let num_blocks = n.div_ceil(block_points);
    let kind = match data {
        Data::Dense(_) => 0u8,
        Data::Sparse(_) => 1u8,
    };
    // Payload sizes are computable up front, so the index can be
    // written before any block without buffering the whole store.
    let mut sizes = Vec::with_capacity(num_blocks);
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        let bytes: u64 = match data {
            Data::Dense(_) => (d * (hi - lo) * 8) as u64,
            Data::Sparse(s) => (lo..hi).map(|j| 8 + 12 * s.col_nnz(j) as u64).sum(),
        };
        sizes.push(bytes);
    }
    let header_len = (4 + 1 + 1 + 8 * 4 + num_blocks * 16) as u64;
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION, kind])?;
    for v in [d as u64, n as u64, block_points as u64, num_blocks as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    let mut offset = header_len;
    for &sz in &sizes {
        w.write_all(&offset.to_le_bytes())?;
        w.write_all(&sz.to_le_bytes())?;
        offset += sz;
    }
    for b in 0..num_blocks {
        let lo = b * block_points;
        let hi = (lo + block_points).min(n);
        match data {
            Data::Dense(m) => {
                for j in lo..hi {
                    for i in 0..d {
                        w.write_all(&m[(i, j)].to_le_bytes())?;
                    }
                }
            }
            Data::Sparse(s) => {
                for j in lo..hi {
                    w.write_all(&(s.col_nnz(j) as u64).to_le_bytes())?;
                    for (r, v) in s.col_iter(j) {
                        w.write_all(&(r as u32).to_le_bytes())?;
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Memory-bounded reader over a `.dkps` file: decodes blocks on demand
/// behind a small LRU of [`Arc<Data>`] blocks.
pub struct ShardStore {
    file: Mutex<std::fs::File>,
    /// (byte_offset, byte_len) per block.
    index: Vec<(u64, u64)>,
    dim: usize,
    len: usize,
    block_points: usize,
    sparse: bool,
    /// Most-recently-used first.
    cache: Mutex<Vec<(usize, Arc<Data>)>>,
    cache_blocks: usize,
}

impl ShardStore {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a diskpca shard store (bad magic)");
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        anyhow::ensure!(hdr[0] == VERSION, "unsupported shard store version {}", hdr[0]);
        anyhow::ensure!(hdr[1] <= 1, "unknown shard store kind {}", hdr[1]);
        let mut u = [0u8; 8];
        let mut next = |f: &mut std::fs::File| -> anyhow::Result<u64> {
            f.read_exact(&mut u)?;
            Ok(u64::from_le_bytes(u))
        };
        let d = next(&mut f)? as usize;
        let n = next(&mut f)? as usize;
        let block_points = next(&mut f)? as usize;
        let num_blocks = next(&mut f)? as usize;
        anyhow::ensure!(block_points > 0, "shard store has block_points = 0");
        anyhow::ensure!(
            num_blocks == n.div_ceil(block_points),
            "shard store index length {num_blocks} inconsistent with n={n}, block_points={block_points}"
        );
        let mut index = Vec::with_capacity(num_blocks);
        for _ in 0..num_blocks {
            let off = next(&mut f)?;
            let len = next(&mut f)?;
            anyhow::ensure!(
                len <= MAX_BLOCK_BYTES && off.checked_add(len).is_some_and(|end| end <= file_len),
                "shard store block range {off}+{len} outside file of {file_len} bytes"
            );
            index.push((off, len));
        }
        Ok(Self {
            file: Mutex::new(f),
            index,
            dim: d,
            len: n,
            block_points,
            sparse: hdr[1] == 1,
            cache: Mutex::new(Vec::new()),
            cache_blocks: DEFAULT_CACHE_BLOCKS,
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn block_points(&self) -> usize {
        self.block_points
    }

    pub fn num_blocks(&self) -> usize {
        self.index.len()
    }

    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// Column count of block `b`.
    fn block_cols(&self, b: usize) -> usize {
        let lo = b * self.block_points;
        (lo + self.block_points).min(self.len) - lo
    }

    /// Fetch block `b`, decoding through the LRU. IO/decode failures
    /// panic with context — over the protocol they surface to the
    /// master as a `RespError`.
    pub fn block(&self, b: usize) -> Arc<Data> {
        {
            let mut cache = self.cache.lock().unwrap();
            if let Some(pos) = cache.iter().position(|(i, _)| *i == b) {
                let hit = cache.remove(pos);
                let data = hit.1.clone();
                cache.insert(0, hit);
                return data;
            }
        }
        let decoded = Arc::new(
            self.read_block(b)
                .unwrap_or_else(|e| panic!("shard store: reading block {b} failed: {e}")),
        );
        let mut cache = self.cache.lock().unwrap();
        cache.insert(0, (b, decoded.clone()));
        cache.truncate(self.cache_blocks.max(1));
        decoded
    }

    fn read_block(&self, b: usize) -> anyhow::Result<Data> {
        let (off, len) = self.index[b];
        let cols = self.block_cols(b);
        let mut buf = vec![0u8; len as usize];
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(off))?;
            f.read_exact(&mut buf)?;
        }
        fn take_u64(buf: &[u8], at: &mut usize) -> anyhow::Result<u64> {
            let end = *at + 8;
            let bytes = buf.get(*at..end).ok_or_else(|| anyhow::anyhow!("block truncated"))?;
            *at = end;
            Ok(u64::from_le_bytes(bytes.try_into().unwrap()))
        }
        let mut at = 0usize;
        if self.sparse {
            let mut out_cols: Vec<Vec<(u32, f64)>> = Vec::with_capacity(cols);
            for _ in 0..cols {
                let nnz = take_u64(&buf, &mut at)? as usize;
                let mut col = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let end = at + 12;
                    let bytes =
                        buf.get(at..end).ok_or_else(|| anyhow::anyhow!("block truncated"))?;
                    at = end;
                    let r = u32::from_le_bytes(bytes[..4].try_into().unwrap());
                    let v = f64::from_le_bytes(bytes[4..].try_into().unwrap());
                    col.push((r, v));
                }
                out_cols.push(col);
            }
            anyhow::ensure!(at == buf.len(), "sparse block has trailing bytes");
            Ok(Data::Sparse(Csc::from_columns(self.dim, out_cols)))
        } else {
            anyhow::ensure!(
                buf.len() == self.dim * cols * 8,
                "dense block is {} bytes, expected {}",
                buf.len(),
                self.dim * cols * 8
            );
            let mut m = Mat::zeros(self.dim, cols);
            for j in 0..cols {
                for i in 0..self.dim {
                    let end = at + 8;
                    m[(i, j)] = f64::from_le_bytes(buf[at..end].try_into().unwrap());
                    at = end;
                }
            }
            Ok(Data::Dense(m))
        }
    }

    /// Materialize the contiguous column range `[start, end)`,
    /// assembling across block boundaries when needed.
    pub fn read_cols(&self, start: usize, end: usize) -> Data {
        assert!(start <= end && end <= self.len, "read_cols {start}..{end} of {}", self.len);
        let bp = self.block_points;
        if start == end {
            return if self.sparse {
                Data::Sparse(Csc::from_columns(self.dim, Vec::new()))
            } else {
                Data::Dense(Mat::zeros(self.dim, 0))
            };
        }
        let b0 = start / bp;
        let b1 = (end - 1) / bp;
        let mut parts = Vec::with_capacity(b1 - b0 + 1);
        for b in b0..=b1 {
            let blk = self.block(b);
            let lo = (b * bp).max(start) - b * bp;
            let hi = ((b + 1) * bp).min(end) - b * bp;
            if lo == 0 && hi == self.block_cols(b) && b0 == b1 {
                // exact single-block hit (read_cols must return owned
                // Data, so this is one block copy; the hot sequential
                // fold avoids even that by borrowing the cached block
                // directly — see ShardSource::for_each_chunk)
                return (*blk).clone();
            }
            parts.push(blk.slice_cols(lo, hi));
        }
        concat_data(parts)
    }

    /// Gather arbitrary columns (in the given order, repetition
    /// allowed) in the shard's natural encoding.
    pub fn select(&self, idx: &[usize]) -> Data {
        let bp = self.block_points;
        if self.sparse {
            let cols = idx
                .iter()
                .map(|&j| {
                    let blk = self.block(j / bp);
                    match &*blk {
                        Data::Sparse(s) => s
                            .col_iter(j % bp)
                            .map(|(r, v)| (r as u32, v))
                            .collect::<Vec<_>>(),
                        Data::Dense(_) => unreachable!("sparse store holds dense block"),
                    }
                })
                .collect();
            Data::Sparse(Csc::from_columns(self.dim, cols))
        } else {
            let mut out = Mat::zeros(self.dim, idx.len());
            for (c, &j) in idx.iter().enumerate() {
                let blk = self.block(j / bp);
                match &*blk {
                    Data::Dense(m) => {
                        for i in 0..self.dim {
                            out[(i, c)] = m[(i, j % bp)];
                        }
                    }
                    Data::Sparse(_) => unreachable!("dense store holds sparse block"),
                }
            }
            Data::Dense(out)
        }
    }
}

/// Concatenate column chunks that share a dim and encoding.
fn concat_data(parts: Vec<Data>) -> Data {
    assert!(!parts.is_empty());
    if parts.len() == 1 {
        return parts.into_iter().next().unwrap();
    }
    if parts.iter().all(|p| matches!(p, Data::Sparse(_))) {
        let d = parts[0].dim();
        let mut cols = Vec::new();
        for p in &parts {
            if let Data::Sparse(s) = p {
                for j in 0..s.cols() {
                    cols.push(s.col_iter(j).map(|(r, v)| (r as u32, v)).collect());
                }
            }
        }
        Data::Sparse(Csc::from_columns(d, cols))
    } else {
        let mats: Vec<Mat> = parts.iter().map(|p| p.to_dense()).collect();
        Data::Dense(Mat::hcat_all(&mats))
    }
}

/// Where a worker's shard lives: resident in memory, or on disk behind
/// a [`ShardStore`]. The streaming worker folds over either through
/// [`ShardSource::for_each_chunk`]; per-column results are identical
/// either way (disk blocks round-trip f64 bits exactly).
pub enum ShardSource {
    Resident(Data),
    Store(ShardStore),
}

impl ShardSource {
    pub fn dim(&self) -> usize {
        match self {
            ShardSource::Resident(d) => d.dim(),
            ShardSource::Store(s) => s.dim(),
        }
    }

    /// Number of points (columns).
    pub fn len(&self) -> usize {
        match self {
            ShardSource::Resident(d) => d.len(),
            ShardSource::Store(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The resident shard, if this source is in-memory.
    pub fn resident(&self) -> Option<&Data> {
        match self {
            ShardSource::Resident(d) => Some(d),
            ShardSource::Store(_) => None,
        }
    }

    /// Fold `f(first_col, chunk)` over ascending column chunks of at
    /// most `chunk_rows` points (`0` ⇒ one chunk for a resident shard,
    /// block-sized chunks for a store).
    pub fn for_each_chunk(&self, chunk_rows: usize, mut f: impl FnMut(usize, &Data)) {
        let n = self.len();
        if n == 0 {
            return;
        }
        let step = match (self, chunk_rows) {
            (ShardSource::Resident(_), 0) => n,
            (ShardSource::Store(s), 0) => s.block_points(),
            (_, c) => c,
        };
        if let (ShardSource::Resident(d), true) = (self, step >= n) {
            f(0, d);
            return;
        }
        let mut at = 0;
        while at < n {
            let end = (at + step).min(n);
            match self {
                ShardSource::Resident(d) => f(at, &d.slice_cols(at, end)),
                ShardSource::Store(s) => {
                    let bp = s.block_points();
                    if at % bp == 0 && (end == (at / bp + 1) * bp || end == n) && end - at <= bp {
                        // chunk == exactly one stored block (the common
                        // block-sized fold): hand out the cached Arc's
                        // Data without copying it
                        let blk = s.block(at / bp);
                        f(at, &blk);
                    } else {
                        f(at, &s.read_cols(at, end));
                    }
                }
            }
            at = end;
        }
    }

    /// Gather the indexed points (in order) as a [`PointSet`] in the
    /// shard's natural encoding — the sampling-round reply path.
    pub fn point_set(&self, idx: &[usize]) -> PointSet {
        match self {
            ShardSource::Resident(d) => PointSet::from_data(d, idx),
            ShardSource::Store(s) => match s.select(idx) {
                Data::Dense(m) => PointSet::Dense(m),
                Data::Sparse(c) => PointSet::Sparse {
                    d: c.rows(),
                    cols: (0..c.cols())
                        .map(|j| c.col_iter(j).map(|(r, v)| (r as u32, v)).collect())
                        .collect(),
                },
            },
        }
    }

    /// Gather the indexed points (in order) as a [`Data`] in the
    /// shard's natural encoding.
    pub fn select(&self, idx: &[usize]) -> Data {
        match self {
            ShardSource::Resident(Data::Dense(m)) => Data::Dense(m.select_cols(idx)),
            ShardSource::Resident(Data::Sparse(s)) => Data::Sparse(s.select_cols(idx)),
            ShardSource::Store(s) => s.select(idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("diskpca_store_{name}.dkps"))
    }

    fn dense_data(rng: &mut Rng, d: usize, n: usize) -> Data {
        Data::Dense(Mat::from_fn(d, n, |_, _| rng.normal()))
    }

    fn sparse_data(rng: &mut Rng, d: usize, n: usize) -> Data {
        Data::Sparse(crate::data::zipf_sparse(d, n, 6, rng))
    }

    #[test]
    fn roundtrip_dense_bit_exact() {
        let mut rng = Rng::seed_from(1);
        let data = dense_data(&mut rng, 7, 53);
        let path = tmp("dense");
        write(&data, &path, 10).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert_eq!((store.dim(), store.len()), (7, 53));
        assert_eq!(store.num_blocks(), 6);
        assert!(!store.is_sparse());
        let back = store.read_cols(0, 53);
        assert_eq!(back.to_dense().data(), data.to_dense().data());
    }

    #[test]
    fn roundtrip_sparse_bit_exact() {
        let mut rng = Rng::seed_from(2);
        let data = sparse_data(&mut rng, 60, 41);
        let path = tmp("sparse");
        write(&data, &path, 8).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert!(store.is_sparse());
        assert_eq!(store.num_blocks(), 6);
        let back = store.read_cols(0, 41);
        assert_eq!(back.nnz(), data.nnz());
        assert_eq!(back.to_dense().data(), data.to_dense().data());
    }

    #[test]
    fn read_cols_spans_blocks() {
        let mut rng = Rng::seed_from(3);
        let data = dense_data(&mut rng, 5, 29);
        let path = tmp("span");
        write(&data, &path, 6).unwrap();
        let store = ShardStore::open(&path).unwrap();
        for (lo, hi) in [(0, 6), (4, 17), (27, 29), (3, 3), (0, 29)] {
            let got = store.read_cols(lo, hi);
            let want = data.slice_cols(lo, hi);
            assert_eq!(got.to_dense().data(), want.to_dense().data(), "{lo}..{hi}");
        }
    }

    #[test]
    fn select_and_point_set_match_resident() {
        let mut rng = Rng::seed_from(4);
        for data in [dense_data(&mut rng, 6, 23), sparse_data(&mut rng, 40, 23)] {
            let path = tmp(if matches!(data, Data::Dense(_)) { "sel_d" } else { "sel_s" });
            write(&data, &path, 5).unwrap();
            let store = ShardSource::Store(ShardStore::open(&path).unwrap());
            let resident = ShardSource::Resident(data.clone());
            let idx = [22, 0, 7, 7, 13];
            assert_eq!(
                store.select(&idx).to_dense().data(),
                resident.select(&idx).to_dense().data()
            );
            assert_eq!(
                store.point_set(&idx).to_mat().data(),
                resident.point_set(&idx).to_mat().data()
            );
            assert_eq!(store.point_set(&[]).len(), 0);
        }
    }

    #[test]
    fn chunk_fold_covers_exactly_once() {
        let mut rng = Rng::seed_from(5);
        let data = dense_data(&mut rng, 4, 37);
        let path = tmp("fold");
        write(&data, &path, 9).unwrap();
        for source in [
            ShardSource::Resident(data.clone()),
            ShardSource::Store(ShardStore::open(&path).unwrap()),
        ] {
            for chunk in [0, 1, 5, 37, 100] {
                let mut seen = Vec::new();
                let mut cols = 0;
                source.for_each_chunk(chunk, |j0, c| {
                    assert_eq!(j0, cols, "chunks must ascend contiguously");
                    assert_eq!(c.dim(), 4);
                    cols += c.len();
                    for j in 0..c.len() {
                        seen.push(c.col_norm_sq(j).to_bits());
                    }
                });
                assert_eq!(cols, 37, "chunk={chunk}");
                let want: Vec<u64> = (0..37).map(|j| data.col_norm_sq(j).to_bits()).collect();
                assert_eq!(seen, want, "chunk={chunk}");
            }
        }
    }

    #[test]
    fn lru_keeps_store_usable_under_random_access() {
        let mut rng = Rng::seed_from(6);
        let data = dense_data(&mut rng, 3, 64);
        let path = tmp("lru");
        write(&data, &path, 4).unwrap(); // 16 blocks ≫ cache of 4
        let store = ShardStore::open(&path).unwrap();
        for trial in 0..200 {
            let j = (trial * 37) % 64;
            let got = store.select(&[j]);
            assert_eq!(
                got.to_dense().data(),
                data.slice_cols(j, j + 1).to_dense().data(),
                "col {j}"
            );
        }
    }

    #[test]
    fn open_rejects_garbage_and_bad_index() {
        let path = tmp("garbage");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(ShardStore::open(&path).is_err());
        // valid store, then corrupt one index entry's length
        let mut rng = Rng::seed_from(7);
        let data = dense_data(&mut rng, 3, 10);
        let path = tmp("corrupt");
        write(&data, &path, 4).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let idx_at = 4 + 2 + 32 + 8; // first block's byte_len field
        bytes[idx_at..idx_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardStore::open(&path).is_err(), "oversized block length must be rejected");
    }
}
