//! Dataset (de)serialization — the launcher's on-disk format.
//!
//! Binary format (little-endian):
//!   magic "DKPC" | u8 version | u8 kind (0 dense, 1 sparse)
//!   u64 d | u64 n | payload
//! Dense payload: d·n f64 column-major. Sparse payload: per column a
//! u64 nnz then (u32 row, f64 value) pairs.
//!
//! A CSV loader (one point per row, comma-separated features) covers
//! ad-hoc external data.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::linalg::Mat;
use crate::sparse::Csc;

use super::Data;

const MAGIC: &[u8; 4] = b"DKPC";

pub fn save(data: &Data, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&[1u8])?;
    match data {
        Data::Dense(m) => {
            w.write_all(&[0u8])?;
            w.write_all(&(m.rows() as u64).to_le_bytes())?;
            w.write_all(&(m.cols() as u64).to_le_bytes())?;
            // column-major so shard slicing maps to contiguous ranges
            for j in 0..m.cols() {
                for i in 0..m.rows() {
                    w.write_all(&m[(i, j)].to_le_bytes())?;
                }
            }
        }
        Data::Sparse(s) => {
            w.write_all(&[1u8])?;
            w.write_all(&(s.rows() as u64).to_le_bytes())?;
            w.write_all(&(s.cols() as u64).to_le_bytes())?;
            for j in 0..s.cols() {
                w.write_all(&(s.col_nnz(j) as u64).to_le_bytes())?;
                for (r, v) in s.col_iter(j) {
                    w.write_all(&(r as u32).to_le_bytes())?;
                    w.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Data> {
    let f = std::fs::File::open(path.as_ref())?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a diskpca dataset file");
    let mut hdr = [0u8; 2];
    r.read_exact(&mut hdr)?;
    anyhow::ensure!(hdr[0] == 1, "unsupported version {}", hdr[0]);
    let mut u = [0u8; 8];
    r.read_exact(&mut u)?;
    let d = u64::from_le_bytes(u) as usize;
    r.read_exact(&mut u)?;
    let n = u64::from_le_bytes(u) as usize;
    match hdr[1] {
        0 => {
            let mut m = Mat::zeros(d, n);
            for j in 0..n {
                for i in 0..d {
                    r.read_exact(&mut u)?;
                    m[(i, j)] = f64::from_le_bytes(u);
                }
            }
            Ok(Data::Dense(m))
        }
        1 => {
            let mut cols = Vec::with_capacity(n);
            let mut u4 = [0u8; 4];
            for _ in 0..n {
                r.read_exact(&mut u)?;
                let nnz = u64::from_le_bytes(u) as usize;
                let mut col = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    r.read_exact(&mut u4)?;
                    let row = u32::from_le_bytes(u4);
                    r.read_exact(&mut u)?;
                    col.push((row, f64::from_le_bytes(u)));
                }
                cols.push(col);
            }
            Ok(Data::Sparse(Csc::from_columns(d, cols)))
        }
        k => anyhow::bail!("unknown kind {k}"),
    }
}

/// CSV: one data point per row, comma-separated features → dense d×n.
pub fn load_csv(path: impl AsRef<Path>) -> anyhow::Result<Data> {
    let f = std::fs::File::open(path.as_ref())?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let row: Vec<f64> = t
            .split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))
            })
            .collect::<anyhow::Result<_>>()?;
        if let Some(first) = rows.first() {
            anyhow::ensure!(
                row.len() == first.len(),
                "line {}: ragged row ({} vs {})",
                lineno + 1,
                row.len(),
                first.len()
            );
        }
        rows.push(row);
    }
    anyhow::ensure!(!rows.is_empty(), "empty csv");
    let (n, d) = (rows.len(), rows[0].len());
    let mut m = Mat::zeros(d, n);
    for (j, row) in rows.iter().enumerate() {
        for (i, &v) in row.iter().enumerate() {
            m[(i, j)] = v;
        }
    }
    Ok(Data::Dense(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let data = Data::Dense(Mat::from_fn(7, 11, |_, _| rng.normal()));
        let path = std::env::temp_dir().join("diskpca_io_dense.bin");
        save(&data, &path).unwrap();
        let back = load(&path).unwrap();
        assert!(back.to_dense().max_abs_diff(&data.to_dense()) == 0.0);
        assert!(matches!(back, Data::Dense(_)));
    }

    #[test]
    fn sparse_roundtrip() {
        let mut rng = Rng::seed_from(2);
        let data = Data::Sparse(crate::data::zipf_sparse(200, 30, 10, &mut rng));
        let path = std::env::temp_dir().join("diskpca_io_sparse.bin");
        save(&data, &path).unwrap();
        let back = load(&path).unwrap();
        assert!(matches!(back, Data::Sparse(_)));
        assert_eq!(back.nnz(), data.nnz());
        assert!(back.to_dense().max_abs_diff(&data.to_dense()) == 0.0);
    }

    #[test]
    fn csv_load() {
        let path = std::env::temp_dir().join("diskpca_io.csv");
        std::fs::write(&path, "# header comment\n1.0, 2.0, 3.5\n4,5,6\n").unwrap();
        let data = load_csv(&path).unwrap();
        assert_eq!((data.dim(), data.len()), (3, 2));
        assert_eq!(data.col_dense(1), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let path = std::env::temp_dir().join("diskpca_io_bad.csv");
        std::fs::write(&path, "1,2\n3\n").unwrap();
        assert!(load_csv(&path).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("diskpca_io_garbage.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
    }
}
