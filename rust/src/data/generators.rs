//! Synthetic data generators (structural analogues of the paper's
//! datasets; see DESIGN.md §3 for the substitution rationale).

use crate::linalg::Mat;
use crate::rng::{AliasTable, Rng};
use crate::sparse::Csc;

/// Low-rank + decaying spectral tail + white noise:
/// A = U·diag(decay^i)·Vᵀ + noise·N. Columns are points (d×n).
/// Mirrors regression-style UCI sets (yearpredmsd, insurance) whose
/// KPCA error curves are driven by spectral decay.
pub fn low_rank_noise(
    d: usize,
    n: usize,
    rank: usize,
    decay: f64,
    noise: f64,
    rng: &mut Rng,
) -> Mat {
    let rank = rank.min(d);
    let u = Mat::from_fn(d, rank, |_, _| rng.normal() / (d as f64).sqrt());
    let mut out = Mat::zeros(d, n);
    for j in 0..n {
        // latent coordinates with geometric scale
        let mut z = vec![0.0; rank];
        for (l, zl) in z.iter_mut().enumerate() {
            *zl = rng.normal() * decay.powi(l as i32) * (d as f64).sqrt();
        }
        for i in 0..d {
            let mut v = 0.0;
            for l in 0..rank {
                v += u[(i, l)] * z[l];
            }
            out[(i, j)] = v + noise * rng.normal();
        }
    }
    out
}

/// Gaussian mixture with k random centers and **Zipf-skewed cluster
/// sizes** (weight ∝ rank^{-1.5}). Mirrors classification sets
/// (mnist8m, har, protein): real class/density distributions are
/// imbalanced, which is exactly what leverage + adaptive sampling
/// exploit over uniform sampling (paper §5.3) — a uniform sample of
/// |Y| ≈ 100 points routinely misses the rare clusters entirely.
pub fn clusters(d: usize, n: usize, k: usize, spread: f64, rng: &mut Rng) -> Mat {
    let centers = Mat::from_fn(d, k, |_, _| rng.normal());
    // normalize centers to ~unit norm so spread is meaningful
    let norms: Vec<f64> = (0..k)
        .map(|c| centers.col(c).iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12))
        .collect();
    let weights: Vec<f64> = (1..=k).map(|r| (r as f64).powf(-1.5)).collect();
    let table = AliasTable::new(&weights);
    let mut out = Mat::zeros(d, n);
    for j in 0..n {
        let c = table.draw(rng);
        let inv = 1.0 / norms[c];
        for i in 0..d {
            out[(i, j)] =
                centers[(i, c)] * inv + spread * rng.normal() / (d as f64).sqrt();
        }
    }
    out
}

/// Zipf bag-of-words: per-point nnz ~ 0.5·avg..1.5·avg, word ids drawn
/// from a Zipf(1.1) over the vocabulary, values log(1 + count). This
/// matches bow/20news structure: a few very frequent words, a long
/// tail, non-negative sparse counts.
pub fn zipf_sparse(d: usize, n: usize, avg_nnz: usize, rng: &mut Rng) -> Csc {
    // Zipf weights over the vocabulary.
    let weights: Vec<f64> = (1..=d).map(|r| (r as f64).powf(-1.1)).collect();
    let table = AliasTable::new(&weights);
    let mut cols = Vec::with_capacity(n);
    for _ in 0..n {
        // heavy-tailed document lengths: ~10% of documents are 4×
        // longer (real corpora mix tweets with essays). Long docs have
        // huge polynomial-kernel norms ⇒ high leverage — the uniform
        // baseline undersamples exactly what matters.
        let boost = if rng.below(10) == 0 { 4 } else { 1 };
        let nnz = (boost * (avg_nnz / 2 + rng.below(avg_nnz.max(1)))).max(1);
        let mut counts: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        for _ in 0..nnz {
            *counts.entry(table.draw(rng) as u32).or_insert(0) += 1;
        }
        let col: Vec<(u32, f64)> = counts
            .into_iter()
            .map(|(w, c)| (w, (1.0 + c as f64).ln()))
            .collect();
        cols.push(col);
    }
    Csc::from_columns(d, cols)
}

/// Smooth low-dimensional manifold embedded by random sinusoids:
/// x(t) = [sin(ωᵢᵀt + φᵢ)]ᵢ for t ∈ R^intrinsic. Mirrors ctslice
/// (CT scan slices vary smoothly along the body axis) — fast spectral
/// decay in the Gaussian kernel space.
/// Latent coordinates are drawn with a *non-uniform density*
/// (t = u⁵, concentrated near the manifold's core with a thin tail):
/// like real sensor/physics data, most mass sits in a dense region
/// while the informative extremes are rare — the regime where the
/// paper's residual-driven adaptive sampling beats uniform.
pub fn manifold(d: usize, n: usize, intrinsic: usize, rng: &mut Rng) -> Mat {
    let omega = Mat::from_fn(intrinsic, d, |_, _| rng.normal() * 1.5);
    let phase: Vec<f64> = (0..d)
        .map(|_| rng.uniform(0.0, 2.0 * std::f64::consts::PI))
        .collect();
    let mut out = Mat::zeros(d, n);
    for j in 0..n {
        let t: Vec<f64> = (0..intrinsic)
            .map(|_| {
                let u = rng.uniform(-1.0, 1.0);
                u.powi(5)
            })
            .collect();
        for i in 0..d {
            let mut a = phase[i];
            for l in 0..intrinsic {
                a += omega[(l, i)] * t[l];
            }
            out[(i, j)] = a.sin();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd;

    #[test]
    fn low_rank_has_decaying_spectrum() {
        let mut rng = Rng::seed_from(1);
        let a = low_rank_noise(30, 100, 5, 0.5, 0.01, &mut rng);
        let (_, s, _) = svd(&a);
        // strong decay over the first ranks, then a small noise tail
        assert!(s[0] > 3.0 * s[4], "spectrum {:?}", &s[..8]);
        assert!(s[5] < 0.2 * s[0]);
    }

    #[test]
    fn clusters_are_separated() {
        let mut rng = Rng::seed_from(2);
        let k = 4;
        let a = clusters(16, 200, k, 0.1, &mut rng);
        // With tiny spread, pairwise distances are bimodal: near-0
        // (same cluster) or ~O(1) (cross cluster). Check both modes.
        let mut same = 0;
        let mut far = 0;
        for i in 0..50 {
            for j in (i + 1)..50 {
                let mut d2 = 0.0;
                for r in 0..16 {
                    let d = a[(r, i)] - a[(r, j)];
                    d2 += d * d;
                }
                if d2 < 0.2 {
                    same += 1;
                } else if d2 > 0.5 {
                    far += 1;
                }
            }
        }
        assert!(same > 50, "same {same}");
        assert!(far > 200, "far {far}");
    }

    #[test]
    fn zipf_sparse_head_heavy() {
        let mut rng = Rng::seed_from(3);
        let s = zipf_sparse(500, 300, 40, &mut rng);
        assert_eq!(s.cols(), 300);
        assert!(s.avg_nnz_per_col() > 15.0 && s.avg_nnz_per_col() < 80.0);
        // head word (row 0) should occur in many more columns than any
        // single tail word (Zipf head-heaviness)
        let mut head = 0;
        let mut tail = 0;
        for j in 0..300 {
            for (r, _) in s.col_iter(j) {
                if r == 0 {
                    head += 1;
                }
                if r == 450 {
                    tail += 1;
                }
            }
        }
        assert!(head > 4 * tail, "head {head} tail {tail}");
        // all values positive (log counts)
        for j in 0..300 {
            for (_, v) in s.col_iter(j) {
                assert!(v > 0.0);
            }
        }
    }

    #[test]
    fn manifold_bounded_and_smoothish() {
        let mut rng = Rng::seed_from(4);
        let a = manifold(20, 100, 2, &mut rng);
        for v in a.data() {
            assert!(v.abs() <= 1.0 + 1e-12);
        }
        // intrinsic dim 2 + sinusoids ⇒ fast decay: σ₁₀ ≪ σ₁
        let (_, s, _) = svd(&a);
        assert!(s[15] < 0.3 * s[0], "{:?}", &s[..16]);
    }
}
