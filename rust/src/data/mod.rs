//! Synthetic dataset registry — Table-1 analogues.
//!
//! The paper's ten datasets (UCI + mnist8m, up to 11M×100k) are not
//! available here; per DESIGN.md §3 we generate structural analogues
//! that preserve what drives the algorithm: dimensionality class,
//! sparsity pattern (Zipfian word counts for bow/20news), spectral
//! decay, and cluster structure. `n` is scaled down ~1000× (factor
//! recorded per dataset) so full-protocol runs and exact feature-space
//! error evaluation fit one box. Partitioning over workers follows the
//! paper exactly: power law with exponent 2.

mod generators;
pub mod io;
mod matrix;
pub mod shard_store;

pub use generators::*;
pub use matrix::Data;
pub use shard_store::{ShardSource, ShardStore};

use crate::rng::{power_law_sizes, Rng};

/// How a dataset's points are synthesized.
#[derive(Clone, Copy, Debug)]
pub enum Family {
    /// Low-rank + spectral tail (yearpred/insurance-like).
    LowRank { rank: usize, decay: f64, noise: f64 },
    /// Gaussian mixture with `k` centers (mnist/har/susy/higgs-like).
    Clusters { k: usize, spread: f64 },
    /// Zipf-sparse bag-of-words (bow/20news-like).
    ZipfSparse { avg_nnz: usize },
    /// Smooth 1-D manifold embedded nonlinearly (ctslice-like).
    Manifold { intrinsic: usize },
}

/// One Table-1 row (analogue).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// paper's original feature dim / point count (for the table).
    pub paper_d: usize,
    pub paper_n: usize,
    /// our analogue sizes.
    pub d: usize,
    pub n: usize,
    /// workers (paper's s).
    pub s: usize,
    pub family: Family,
    /// marked "small" in the paper ⇒ used for batch comparison.
    pub small: bool,
}

impl DatasetSpec {
    /// Generate the global dataset deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Data {
        let mut rng = Rng::seed_from(seed ^ fxhash(self.name));
        match self.family {
            Family::LowRank { rank, decay, noise } => {
                Data::Dense(low_rank_noise(self.d, self.n, rank, decay, noise, &mut rng))
            }
            Family::Clusters { k, spread } => {
                Data::Dense(clusters(self.d, self.n, k, spread, &mut rng))
            }
            Family::ZipfSparse { avg_nnz } => {
                Data::Sparse(zipf_sparse(self.d, self.n, avg_nnz, &mut rng))
            }
            Family::Manifold { intrinsic } => {
                Data::Dense(manifold(self.d, self.n, intrinsic, &mut rng))
            }
        }
    }

    /// Partition into `self.s` shards by the paper's power-law (α=2).
    pub fn partition(&self, data: &Data, seed: u64) -> Vec<Data> {
        partition_power_law(data, self.s, seed)
    }
}

/// Split a dataset over `s` workers, sizes ∝ rank^{-2} (paper §6.1).
pub fn partition_power_law(data: &Data, s: usize, seed: u64) -> Vec<Data> {
    let mut rng = Rng::seed_from(seed ^ 0x9a7c);
    let sizes = power_law_sizes(&mut rng, data.len(), s, 2.0, 1);
    let mut shards = Vec::with_capacity(s);
    let mut at = 0;
    for sz in sizes {
        shards.push(data.slice_cols(at, at + sz));
        at += sz;
    }
    shards
}

/// Split a dataset over `s` workers as evenly as possible — the
/// balanced regime for the Figure-7 scaling study (under the α=2
/// power-law partition the heaviest worker keeps ≥ 60% of the data
/// however large s grows, capping critical-path speedup at ~1.6×).
pub fn partition_uniform(data: &Data, s: usize) -> Vec<Data> {
    let n = data.len();
    let mut shards = Vec::with_capacity(s);
    let mut at = 0;
    for i in 0..s {
        let end = n * (i + 1) / s;
        shards.push(data.slice_cols(at, end));
        at = end;
    }
    shards
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The ten Table-1 analogues. `scale` multiplies every n (1.0 = the
/// defaults used by EXPERIMENTS.md; CI tests use smaller).
pub fn registry(scale: f64) -> Vec<DatasetSpec> {
    let n = |base: usize| ((base as f64 * scale) as usize).max(64);
    vec![
        DatasetSpec {
            name: "bow_like",
            paper_d: 100_000,
            paper_n: 8_000_000,
            d: 4096,
            n: n(8000),
            s: 200,
            family: Family::ZipfSparse { avg_nnz: 60 },
            small: false,
        },
        DatasetSpec {
            name: "higgs_like",
            paper_d: 28,
            paper_n: 11_000_000,
            d: 28,
            n: n(11000),
            s: 200,
            family: Family::Manifold { intrinsic: 4 },
            small: false,
        },
        DatasetSpec {
            name: "mnist8m_like",
            paper_d: 784,
            paper_n: 8_000_000,
            d: 784,
            n: n(8000),
            s: 100,
            family: Family::Clusters { k: 10, spread: 0.15 },
            small: false,
        },
        DatasetSpec {
            name: "susy_like",
            paper_d: 18,
            paper_n: 5_000_000,
            d: 18,
            n: n(5000),
            s: 100,
            family: Family::Manifold { intrinsic: 3 },
            small: false,
        },
        DatasetSpec {
            name: "yearpredmsd_like",
            paper_d: 90,
            paper_n: 463_715,
            d: 90,
            n: n(4637),
            s: 10,
            family: Family::LowRank { rank: 20, decay: 0.75, noise: 0.05 },
            small: false,
        },
        DatasetSpec {
            name: "ctslice_like",
            paper_d: 384,
            paper_n: 53_500,
            d: 384,
            n: n(2675),
            s: 10,
            family: Family::Manifold { intrinsic: 3 },
            small: false,
        },
        DatasetSpec {
            name: "news20_like",
            paper_d: 61_118,
            paper_n: 11_269,
            d: 2048,
            n: n(1127),
            s: 5,
            family: Family::ZipfSparse { avg_nnz: 80 },
            small: false,
        },
        DatasetSpec {
            name: "protein_like",
            paper_d: 9,
            paper_n: 41_157,
            d: 9,
            n: n(4116),
            s: 5,
            family: Family::Clusters { k: 3, spread: 0.3 },
            small: false,
        },
        DatasetSpec {
            name: "har_like",
            paper_d: 561,
            paper_n: 10_299,
            d: 561,
            n: n(2060),
            s: 5,
            family: Family::Clusters { k: 6, spread: 0.15 },
            small: true,
        },
        DatasetSpec {
            name: "insurance_like",
            paper_d: 85,
            paper_n: 9_822,
            d: 85,
            n: n(1964),
            s: 5,
            family: Family::LowRank { rank: 15, decay: 0.7, noise: 0.03 },
            small: true,
        },
    ]
}

/// Look up a dataset by name.
pub fn by_name(name: &str, scale: f64) -> Option<DatasetSpec> {
    registry(scale).into_iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_table1() {
        let r = registry(1.0);
        assert_eq!(r.len(), 10);
        let names: Vec<_> = r.iter().map(|d| d.name).collect();
        for want in [
            "bow_like",
            "higgs_like",
            "mnist8m_like",
            "susy_like",
            "yearpredmsd_like",
            "ctslice_like",
            "news20_like",
            "protein_like",
            "har_like",
            "insurance_like",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
        assert_eq!(r.iter().filter(|d| d.small).count(), 2);
    }

    #[test]
    fn generation_deterministic_and_sized() {
        for spec in registry(0.05) {
            let a = spec.generate(7);
            let b = spec.generate(7);
            assert_eq!(a.len(), spec.n, "{}", spec.name);
            assert_eq!(a.dim(), spec.d);
            assert_eq!(a.nnz(), b.nnz());
            // different seed differs
            let c = spec.generate(8);
            assert_ne!(
                (0..4).map(|j| a.col_norm_sq(j).to_bits()).collect::<Vec<_>>(),
                (0..4).map(|j| c.col_norm_sq(j).to_bits()).collect::<Vec<_>>(),
                "{} not seed-sensitive",
                spec.name
            );
        }
    }

    #[test]
    fn sparse_datasets_are_sparse() {
        let spec = by_name("bow_like", 0.05).unwrap();
        let d = spec.generate(1);
        assert!(matches!(d, Data::Sparse(_)));
        let rho = d.avg_nnz_per_point();
        assert!(rho < spec.d as f64 * 0.1, "ρ={rho} not sparse");
        assert!(rho > 5.0);
    }

    #[test]
    fn partition_sizes_sum() {
        let spec = by_name("har_like", 0.1).unwrap();
        let d = spec.generate(3);
        let shards = spec.partition(&d, 3);
        assert_eq!(shards.len(), spec.s);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), d.len());
        assert!(shards.iter().all(|s| s.dim() == spec.d));
    }

    #[test]
    fn partition_preserves_points() {
        let spec = by_name("protein_like", 0.05).unwrap();
        let d = spec.generate(5);
        let shards = spec.partition(&d, 5);
        // concatenated norms match the global dataset's
        let mut global: Vec<f64> = (0..d.len()).map(|j| d.col_norm_sq(j)).collect();
        let mut parts: Vec<f64> = shards
            .iter()
            .flat_map(|s| (0..s.len()).map(|j| s.col_norm_sq(j)).collect::<Vec<_>>())
            .collect();
        global.sort_by(f64::total_cmp);
        parts.sort_by(f64::total_cmp);
        for (g, p) in global.iter().zip(&parts) {
            assert!((g - p).abs() < 1e-12);
        }
    }
}
