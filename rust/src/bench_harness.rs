//! Micro/meso benchmark harness (criterion unavailable offline).
//!
//! Warmup + timed iterations, reporting median / mean / min / MAD.
//! `cargo bench` targets use [`Bencher`] with `harness = false`.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// [`crate::par`] pool size this sample ran with — recorded so
    /// bench CSVs track the thread-scaling curve per operation.
    pub threads: usize,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    /// median absolute deviation — stability indicator.
    pub mad: Duration,
    /// Throughput in GFLOP/s (from the median), when the bench row
    /// declared its flop count via [`Bencher::bench_flops`] — so the
    /// `BENCH_*.json` trajectory tracks throughput, not just wall
    /// time.
    pub gflops: Option<f64>,
}

impl Sample {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} t={:<2} {:>10.3?} median  {:>10.3?} min  ±{:>8.3?} mad  ({} iters)",
            self.name, self.threads, self.median, self.min, self.mad, self.iters
        )?;
        if let Some(g) = self.gflops {
            write!(f, "  {g:>7.2} GF/s")?;
        }
        Ok(())
    }
}

pub struct Bencher {
    /// minimum wall-clock budget per benchmark.
    pub budget: Duration,
    /// max iterations regardless of budget.
    pub max_iters: usize,
    pub samples: Vec<Sample>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Honour a quick mode for CI: DISKPCA_BENCH_FAST=1
        let fast = std::env::var("DISKPCA_BENCH_FAST").is_ok();
        Self {
            budget: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_iters: if fast { 5 } else { 200 },
            samples: Vec::new(),
        }
    }

    /// Run one benchmark; `f` returns a value that is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        // warmup: one run (compiles caches, faults pages)
        black_box(f());
        let mut times = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget && times.len() < self.max_iters)
            || times.len() < 3
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let median = times[times.len() / 2];
        let min = times[0];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let mut devs: Vec<Duration> = times
            .iter()
            .map(|&t| if t > median { t - median } else { median - t })
            .collect();
        devs.sort();
        let mad = devs[devs.len() / 2];
        let sample = Sample {
            name: name.to_string(),
            threads: crate::par::threads(),
            iters: times.len(),
            median,
            mean,
            min,
            mad,
            gflops: None,
        };
        println!("{sample}");
        self.samples.push(sample.clone());
        sample
    }

    /// [`Bencher::bench`] for a row with a known flop count: records
    /// the achieved GFLOP/s (from the median) on the sample, so the
    /// JSON/CSV artifacts track throughput alongside wall time.
    pub fn bench_flops<T>(&mut self, name: &str, flops: f64, f: impl FnMut() -> T) -> Sample {
        let mut sample = self.bench(name, f);
        let g = flops / sample.median.as_secs_f64().max(1e-12) / 1e9;
        sample.gflops = Some(g);
        if let Some(last) = self.samples.last_mut() {
            last.gflops = Some(g);
        }
        println!("    {name}: {g:.2} GFLOP/s");
        sample
    }

    /// Write the samples as a flat `{name: median_ns}` JSON object —
    /// the format `BENCH_streaming.json` uses so CI can diff a run
    /// against the checked-in baseline. Rows recorded via
    /// [`Bencher::bench_flops`] additionally emit a `"<name>#gflops"`
    /// key with the achieved throughput; [`Bencher::regressions_vs`]
    /// diffs only the wall-time keys, so the throughput keys are pure
    /// trend record.
    pub fn write_median_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut pairs: Vec<(String, crate::json::Value)> = Vec::new();
        for s in &self.samples {
            pairs.push((s.name.clone(), crate::json::num(s.median.as_nanos() as f64)));
            if let Some(g) = s.gflops {
                pairs.push((format!("{}#gflops", s.name), crate::json::num(g)));
            }
        }
        let borrowed: Vec<(&str, crate::json::Value)> =
            pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        std::fs::write(path, crate::json::write(&crate::json::obj(borrowed)))
    }

    /// Diff this run's medians against a baseline JSON written by
    /// [`Bencher::write_median_json`]. Returns one human-readable
    /// warning line per row slower than `threshold`× its baseline
    /// (plus notes for rows missing from the baseline). Wall-clock
    /// noise means callers should *warn*, not fail, on these.
    pub fn regressions_vs(&self, baseline_json: &str, threshold: f64) -> Vec<String> {
        let baseline = match crate::json::parse(baseline_json) {
            Ok(v) => v,
            Err(e) => return vec![format!("baseline unreadable: {e}")],
        };
        let mut out = Vec::new();
        for s in &self.samples {
            match baseline.get(&s.name).and_then(|v| v.as_f64()) {
                Some(base_ns) if base_ns > 0.0 => {
                    let new_ns = s.median.as_nanos() as f64;
                    if new_ns > base_ns * threshold {
                        out.push(format!(
                            "{}: median {:.2}ms vs baseline {:.2}ms ({:+.0}%, threshold {:+.0}%)",
                            s.name,
                            new_ns / 1e6,
                            base_ns / 1e6,
                            (new_ns / base_ns - 1.0) * 100.0,
                            (threshold - 1.0) * 100.0,
                        ));
                    }
                }
                _ => out.push(format!("{}: no baseline entry (new bench row?)", s.name)),
            }
        }
        out
    }

    /// Write all samples as CSV
    /// (name,threads,median_ns,mean_ns,min_ns,mad_ns,iters).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("name,threads,median_ns,mean_ns,min_ns,mad_ns,iters,gflops\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                s.name,
                s.threads,
                s.median.as_nanos(),
                s.mean.as_nanos(),
                s.min.as_nanos(),
                s.mad.as_nanos(),
                s.iters,
                s.gflops.map(|g| format!("{g:.3}")).unwrap_or_default()
            ));
        }
        std::fs::write(path, out)
    }
}

/// Thread counts for bench scaling sweeps: parsed from the
/// `DISKPCA_BENCH_THREADS` environment variable (comma-separated),
/// defaulting to `[1, 2, 4]`. Shared by the `sketches` and `linalg`
/// bench suites so the sweep definition cannot diverge.
pub fn thread_sweep() -> Vec<usize> {
    let parsed: Vec<usize> = match std::env::var("DISKPCA_BENCH_THREADS") {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|&n| n >= 1)
            .collect(),
        Err(_) => Vec::new(),
    };
    if parsed.is_empty() {
        vec![1, 2, 4]
    } else {
        parsed
    }
}

/// Prevent the optimizer from deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher { budget: Duration::from_millis(30), max_iters: 50, samples: vec![] };
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
        assert_eq!(b.samples.len(), 1);
    }

    #[test]
    fn median_json_roundtrip_and_regression_diff() {
        let mut b = Bencher { budget: Duration::from_millis(5), max_iters: 3, samples: vec![] };
        b.bench("row_a", || std::thread::sleep(Duration::from_micros(50)));
        b.bench("row_b", || 1);
        let path = std::env::temp_dir().join("diskpca_bench_medians.json");
        b.write_median_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // self-diff: nothing regresses against itself
        assert!(b.regressions_vs(&text, 1.25).is_empty(), "{:?}", b.regressions_vs(&text, 1.25));
        // a baseline 100× faster flags every row
        let fast = r#"{"row_a": 1.0, "row_b": 1.0}"#;
        assert_eq!(b.regressions_vs(fast, 1.25).len(), 2);
        // missing rows are reported, not ignored
        let partial = crate::json::write(&crate::json::obj(vec![(
            "row_a",
            crate::json::num(1e18),
        )]));
        let notes = b.regressions_vs(&partial, 1.25);
        assert_eq!(notes.len(), 1);
        assert!(notes[0].contains("row_b"));
        // garbage baseline degrades to a single warning
        assert_eq!(b.regressions_vs("not json", 1.25).len(), 1);
    }

    #[test]
    fn bench_flops_records_throughput_and_emits_gflops_keys() {
        let mut b = Bencher { budget: Duration::from_millis(5), max_iters: 3, samples: vec![] };
        let s = b.bench_flops("flops_row", 1e6, || {
            let mut acc = 0.0f64;
            for i in 0..1000 {
                acc += (i as f64) * 1.5;
            }
            acc
        });
        assert!(s.gflops.unwrap() > 0.0);
        let path = std::env::temp_dir().join("diskpca_bench_gflops.json");
        b.write_median_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::json::parse(&text).unwrap();
        assert!(v.get("flops_row").and_then(|x| x.as_f64()).unwrap() > 0.0);
        assert!(v.get("flops_row#gflops").and_then(|x| x.as_f64()).unwrap() > 0.0);
        // the wall-time regression diff ignores the throughput keys
        assert!(b.regressions_vs(&text, 1.25).is_empty());
    }

    #[test]
    fn csv_written() {
        let mut b = Bencher { budget: Duration::from_millis(5), max_iters: 3, samples: vec![] };
        b.bench("noop", || 1);
        let path = std::env::temp_dir().join("diskpca_bench_test.csv");
        b.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,"));
        assert!(text.contains("noop"));
    }
}
