//! Concurrent job scheduler: interleaves rounds of *independent* jobs
//! on one persistent cluster.
//!
//! Round labels are already job-namespaced (`job3:2-disLS`) and the
//! comm layer multiplexes any number of in-flight exchanges over the
//! shared reply queue ([`Cluster::lane`]), so two jobs whose worker
//! state does not overlap can share the wire: while one job's workers
//! grind through a streaming KRR Gram fold, another job's transform
//! batches ride the same links. What *cannot* overlap is worker-side
//! state: a KPCA fit installs embeddings, score state and finally the
//! solution, and a query that reads the solution mid-install would be
//! garbage. The scheduler encodes this as a small read/write
//! footprint per job kind and dispatches strictly head-of-line: the
//! oldest pending job runs as soon as its footprint is compatible
//! with everything running, and nothing younger may overtake it —
//! FIFO submission order therefore stays the completion order of
//! conflicting jobs, which is what keeps `--max-inflight 1`
//! bit-identical to the historical sequential service and per-job
//! word tables row-for-row comparable to fresh single-job clusters.
//!
//! Admission is bounded ([`ServeConfig::queue_depth`]): a full queue
//! rejects with a typed [`Rejected`] instead of stalling the caller —
//! on the TCP front end that becomes a `RespError` the client can
//! retry, keeping the accept loop live under overload.
//!
//! Failure handling depends on the mode. Sequentially
//! (`max_inflight == 1`) jobs run under the PR-6 recovering drivers:
//! revive + replay + stats rewind, bit-identical to a fault-free run.
//! Concurrently, a dead worker fails every exchange it owes; the
//! first runner that sees the `Link`/`Worker` error quiesces the
//! scheduler (no new dispatches, wait for running attempts to drain),
//! revives the dead slots *without* round replay
//! ([`crate::recovery::Recovery::revive_only`] — there is no single
//! round to replay when several jobs were mid-flight), bumps the
//! epoch, and every affected job reruns from scratch with a fresh
//! per-job sink. Solutions and per-job tables stay bit-identical;
//! the *lifetime* table keeps the failed attempt's words (documented
//! concurrency caveat).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::comm::{Cluster, CommError, CommStats};
use crate::coordinator::{
    dis_css_warm, dis_eval, dis_kpca_refit, dis_kpca_warm, dis_krr, dis_project_points,
    dis_refresh_shards, embed_spec_for, Params, RefitReport, SamplingMode,
};
use crate::embed::EmbedSpec;
use crate::kernels::Kernel;
use crate::recovery::Recovery;

use super::queue::{Rejected, ServeConfig};
use super::{JobCtx, JobOutput, JobReport, JobSpec};

/// Retry budget per job in concurrent mode (revivals themselves are
/// additionally bounded by [`Recovery::set_max_recoveries`]).
const MAX_ATTEMPTS: usize = 3;

/// Worker-state bits a job reads or writes — the conflict model the
/// dispatcher runs on. Two jobs may interleave iff neither writes
/// state the other touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Footprint {
    reads: u8,
    writes: u8,
}

const EMBED: u8 = 1 << 0;
const SCORES: u8 = 1 << 1;
const RESID: u8 = 1 << 2;
const BASIS: u8 = 1 << 3;
const SOLUTION: u8 = 1 << 4;

impl Footprint {
    const NONE: Footprint = Footprint { reads: 0, writes: 0 };
    /// `run_job` bodies may touch anything — serialize against all.
    pub(crate) const EXCLUSIVE: Footprint = Footprint { reads: 0xff, writes: 0xff };

    fn compatible(self, other: Footprint) -> bool {
        self.writes & (other.reads | other.writes) == 0 && other.writes & self.reads == 0
    }
}

/// The footprint of one job kind. KRR is stateless on the workers
/// (`ReqKrrStats` recomputes K(Y,·) from the shard each time), so it
/// interleaves with everything — including a KPCA fit — which is
/// where the concurrent QPS win comes from.
fn footprint(spec: &JobSpec) -> Footprint {
    match spec {
        JobSpec::Kpca { .. } => Footprint {
            reads: 0,
            writes: EMBED | SCORES | RESID | BASIS | SOLUTION,
        },
        // a refit rewrites the same worker state a fit does (and
        // additionally advances the shard views), so it serializes
        // against everything a fit would
        JobSpec::Refit { .. } => Footprint {
            reads: 0,
            writes: EMBED | SCORES | RESID | BASIS | SOLUTION,
        },
        JobSpec::Css { .. } => Footprint { reads: 0, writes: EMBED | SCORES | RESID | BASIS },
        JobSpec::Krr { .. } => Footprint::NONE,
        JobSpec::Eval => Footprint { reads: SOLUTION, writes: 0 },
        JobSpec::Transform { .. } => Footprint { reads: SOLUTION, writes: 0 },
    }
}

/// A submitted-but-not-dispatched job and the channel its result goes
/// back on.
struct PendingJob {
    spec: JobSpec,
    tx: Sender<Result<JobOutput, CommError>>,
}

struct SchedState {
    pending: VecDeque<PendingJob>,
    /// Footprints of every dispatched-and-unfinished job (kept across
    /// that job's retries).
    running: Vec<Footprint>,
    /// Attempts executing right now (drops to 0 while every failed
    /// job waits for a revival).
    active: usize,
    /// Monotone job-id source (transform queries don't consume one).
    next_job: usize,
    /// The [`EmbedSpec`] currently installed on every worker, when
    /// known — the key for skipping the `1-embed` round.
    warm_embed: Option<EmbedSpec>,
    /// Data epoch the installed solution covers — what a refit's
    /// `0-refresh` round measures its delta against. Distinct from
    /// `epoch` below, which counts worker *revivals*.
    data_epoch: u64,
    shutting: bool,
    /// A revival is in progress: no new dispatches until it finishes.
    recovering: bool,
    /// Bumped after every successful revival — a failed attempt whose
    /// epoch is stale knows the world was already healed.
    epoch: u64,
    /// Sticky: revival failed (or an unrecoverable abort poisoned the
    /// cluster); waiting victims give up instead of waiting forever.
    healing_off: bool,
}

struct SchedInner {
    state: Mutex<SchedState>,
    cv: Condvar,
    cfg: ServeConfig,
    kernel: Kernel,
    recovery: Mutex<Option<Recovery>>,
    /// Per-worker column bound for one transform scatter round.
    batch_cols: AtomicUsize,
}

/// A pending or running job's result slot. One-shot: whichever of
/// [`JobHandle::wait`] / [`JobHandle::try_poll`] first observes the
/// result takes it.
pub struct JobHandle {
    rx: Receiver<Result<JobOutput, CommError>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutput, CommError> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(CommError::Protocol {
                round: "scheduler".into(),
                detail: "service shut down before the job completed".into(),
            })
        })
    }

    /// Non-blocking poll: `None` while the job is still queued or
    /// running. A `Some` transfers the result out of the handle.
    pub fn try_poll(&mut self) -> Option<Result<JobOutput, CommError>> {
        match self.rx.try_recv() {
            Ok(res) => Some(res),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(CommError::Protocol {
                round: "scheduler".into(),
                detail: "service shut down before the job completed".into(),
            })),
        }
    }
}

/// The scheduler: an admission queue, `max_inflight` runner threads
/// each owning one [`Cluster::lane`], and the shared dispatch state.
pub(crate) struct Scheduler {
    inner: Arc<SchedInner>,
    runners: Vec<JoinHandle<()>>,
}

impl Scheduler {
    pub(crate) fn new(
        cluster: &Cluster,
        kernel: Kernel,
        cfg: ServeConfig,
        recovery: Option<Recovery>,
    ) -> Self {
        let inner = Arc::new(SchedInner {
            state: Mutex::new(SchedState {
                pending: VecDeque::new(),
                running: Vec::new(),
                active: 0,
                next_job: 0,
                warm_embed: None,
                data_epoch: 0,
                shutting: false,
                recovering: false,
                epoch: 0,
                healing_off: false,
            }),
            cv: Condvar::new(),
            cfg,
            kernel,
            recovery: Mutex::new(recovery),
            batch_cols: AtomicUsize::new(1024),
        });
        let runners = (0..inner.cfg.max_inflight)
            .map(|_| {
                let inner = Arc::clone(&inner);
                let lane = cluster.lane();
                lane.set_round_prefix("svc:");
                std::thread::spawn(move || runner_loop(&inner, &lane))
            })
            .collect();
        Self { inner, runners }
    }

    /// Admit one job, or reject if the queue is at `queue_depth` (or
    /// the service is shutting down). Never blocks.
    pub(crate) fn submit(&self, spec: JobSpec) -> Result<JobHandle, Rejected> {
        let mut st = self.inner.state.lock().unwrap();
        if st.shutting {
            return Err(Rejected::ShuttingDown);
        }
        if st.pending.len() >= self.inner.cfg.queue_depth {
            return Err(Rejected::QueueFull { depth: self.inner.cfg.queue_depth });
        }
        let (tx, rx) = mpsc::channel();
        st.pending.push_back(PendingJob { spec, tx });
        drop(st);
        self.inner.cv.notify_all();
        Ok(JobHandle { rx })
    }

    /// [`Scheduler::submit`] that waits for queue space instead of
    /// rejecting — the blocking `run_*` wrappers use this so their
    /// historical never-rejected semantics survive admission control.
    pub(crate) fn submit_blocking(&self, spec: JobSpec) -> Result<JobHandle, Rejected> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if st.shutting {
                return Err(Rejected::ShuttingDown);
            }
            if st.pending.len() < self.inner.cfg.queue_depth {
                break;
            }
            st = self.inner.cv.wait(st).unwrap();
        }
        let (tx, rx) = mpsc::channel();
        st.pending.push_back(PendingJob { spec, tx });
        drop(st);
        self.inner.cv.notify_all();
        Ok(JobHandle { rx })
    }

    /// Claim the whole cluster for a caller-thread job body
    /// (`Service::run_job`): waits until nothing is pending or
    /// running, then registers an exclusive footprint so no job
    /// dispatches until [`Scheduler::end_exclusive`]. Returns the
    /// job id.
    pub(crate) fn begin_exclusive(&self) -> usize {
        let mut st = self.inner.state.lock().unwrap();
        while st.recovering || !st.pending.is_empty() || !st.running.is_empty() {
            st = self.inner.cv.wait(st).unwrap();
        }
        let id = st.next_job;
        st.next_job += 1;
        st.running.push(Footprint::EXCLUSIVE);
        st.active += 1;
        id
    }

    /// Release [`Scheduler::begin_exclusive`]. The body may have
    /// installed any worker state, so the warm-embed key is
    /// conservatively invalidated.
    pub(crate) fn end_exclusive(&self) {
        let mut st = self.inner.state.lock().unwrap();
        remove_footprint(&mut st, Footprint::EXCLUSIVE);
        st.active -= 1;
        st.warm_embed = None;
        drop(st);
        self.inner.cv.notify_all();
    }

    /// Stop admitting, let running attempts finish, join the runners,
    /// and drop every still-queued job (their handles resolve to a
    /// shutdown error).
    pub(crate) fn shutdown(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutting = true;
        }
        self.inner.cv.notify_all();
        for h in self.runners.drain(..) {
            let _ = h.join();
        }
        self.inner.state.lock().unwrap().pending.clear();
    }

    pub(crate) fn jobs_run(&self) -> usize {
        self.inner.state.lock().unwrap().next_job
    }

    pub(crate) fn set_transform_chunk(&self, cols: usize) {
        self.inner.batch_cols.store(cols.max(1), Ordering::Relaxed);
    }

    pub(crate) fn set_recovery(&self, recovery: Recovery) {
        *self.inner.recovery.lock().unwrap() = Some(recovery);
    }

    pub(crate) fn recoveries(&self) -> usize {
        self.inner.recovery.lock().unwrap().as_ref().map_or(0, |r| r.recoveries())
    }

    pub(crate) fn join_recovery_host(&self) {
        if let Some(rec) = self.inner.recovery.lock().unwrap().as_mut() {
            rec.join_host();
        }
    }

    pub(crate) fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }
}

fn remove_footprint(st: &mut SchedState, fp: Footprint) {
    let pos = st.running.iter().position(|r| *r == fp).expect("footprint registered");
    st.running.swap_remove(pos);
}

/// Whether this spec would reuse the installed embedding, and whether
/// it installs one on success (`None` = does not embed).
fn embed_key(spec: &JobSpec, kernel: Kernel) -> Option<EmbedSpec> {
    match spec {
        JobSpec::Kpca { params, mode } if *mode != SamplingMode::AdaptiveOnly => {
            Some(embed_spec_for(kernel, params))
        }
        JobSpec::Refit { params } => Some(embed_spec_for(kernel, params)),
        JobSpec::Css { params } => Some(embed_spec_for(kernel, params)),
        _ => None,
    }
}

fn runner_loop(inner: &SchedInner, lane: &Cluster) {
    loop {
        // dispatch strictly head-of-line: only the oldest pending job
        // is eligible, and only once its footprint fits what's running
        let (job, id, mut my_epoch, mut reuse) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutting {
                    return;
                }
                if !st.recovering {
                    if let Some(front) = st.pending.front() {
                        let fp = footprint(&front.spec);
                        if st.running.iter().all(|r| fp.compatible(*r)) {
                            break;
                        }
                    }
                }
                st = inner.cv.wait(st).unwrap();
            }
            let job = st.pending.pop_front().expect("front checked");
            st.running.push(footprint(&job.spec));
            st.active += 1;
            let id = match &job.spec {
                JobSpec::Transform { .. } => None,
                _ => {
                    let id = st.next_job;
                    st.next_job += 1;
                    Some(id)
                }
            };
            let reuse = match embed_key(&job.spec, inner.kernel) {
                Some(spec) => st.warm_embed == Some(spec),
                None => false,
            };
            (job, id, st.epoch, reuse)
        };
        // the new front may be dispatchable by an idle runner
        inner.cv.notify_all();

        let mut attempt = 0usize;
        let final_res = loop {
            let res = run_attempt(inner, lane, &job.spec, id, reuse);
            match res {
                Ok(out) => break Ok(out),
                Err(err) => {
                    let healable = matches!(
                        err,
                        CommError::Worker { .. } | CommError::Link { .. } | CommError::Poisoned { .. }
                    );
                    // sequential mode already ran the PR-6 recovering
                    // drivers inside the attempt — a surviving error
                    // is final there
                    if inner.cfg.max_inflight == 1 || !healable {
                        break Err(err);
                    }
                    // pause this job: stop counting as active so a
                    // healer can quiesce, but keep the footprint so
                    // nothing conflicting sneaks in before the rerun
                    {
                        let mut st = inner.state.lock().unwrap();
                        st.active -= 1;
                    }
                    inner.cv.notify_all();
                    let healed = match &err {
                        CommError::Worker { worker, .. } | CommError::Link { worker, .. } => {
                            heal(inner, lane, *worker, my_epoch)
                        }
                        _ => wait_for_heal(inner, my_epoch),
                    };
                    // retries against an unhealed cluster are futile
                    if healed.is_none() {
                        let mut st = inner.state.lock().unwrap();
                        st.active += 1;
                        drop(st);
                        break Err(err);
                    }
                    attempt += 1;
                    if attempt >= MAX_ATTEMPTS {
                        let mut st = inner.state.lock().unwrap();
                        st.active += 1;
                        drop(st);
                        break Err(err);
                    }
                    let mut st = inner.state.lock().unwrap();
                    st.active += 1;
                    my_epoch = st.epoch;
                    reuse = match embed_key(&job.spec, inner.kernel) {
                        Some(spec) => st.warm_embed == Some(spec),
                        None => false,
                    };
                }
            }
        };

        // completion bookkeeping under one lock: footprint out, warm
        // key updated, waiters woken
        {
            let mut st = inner.state.lock().unwrap();
            remove_footprint(&mut st, footprint(&job.spec));
            st.active -= 1;
            if let Some(spec) = embed_key(&job.spec, inner.kernel) {
                st.warm_embed = match &final_res {
                    Ok(_) => Some(spec),
                    Err(_) => None,
                };
            }
            // a completed refit advances the epoch the installed
            // solution covers; the next refit's delta starts there
            if let Ok(JobOutput::Refit(rep)) = &final_res {
                st.data_epoch = rep.output.epoch;
            }
        }
        inner.cv.notify_all();
        // a gone receiver just means nobody is waiting — fine
        let _ = job.tx.send(final_res);
    }
}

/// Run one attempt of one job on this runner's lane, with the lane
/// labelled for the job's accounting scope.
fn run_attempt(
    inner: &SchedInner,
    lane: &Cluster,
    spec: &JobSpec,
    id: Option<usize>,
    reuse: bool,
) -> Result<JobOutput, CommError> {
    let sink = CommStats::new();
    match id {
        Some(id) => {
            lane.set_round_prefix(&format!("job{id}:"));
            lane.set_job_stats(Some(sink.clone()));
        }
        None => {
            lane.set_round_prefix("svc:");
            lane.set_job_stats(None);
        }
    }
    let kernel = inner.kernel;
    // sequential mode with an elastic host: the PR-6 recovering
    // drivers (revive + replay + stats rewind) keep fits bit-identical
    // through worker deaths — exactly the historical Service behavior
    let seq = inner.cfg.max_inflight == 1;
    let report = |output| JobReport {
        job: JobCtx {
            id: id.expect("job specs carry an id"),
            label: format!("job{}:", id.expect("job specs carry an id")),
            stats: sink.clone(),
        },
        output,
        embed_reused: reuse,
    };
    let res = match spec {
        JobSpec::Kpca { params, mode } => {
            let r = if seq {
                let mut guard = inner.recovery.lock().unwrap();
                match guard.as_mut() {
                    Some(rec) => crate::recovery::dis_kpca_recovering(
                        lane, rec, kernel, params, *mode, reuse,
                    ),
                    None => dis_kpca_warm(lane, kernel, params, *mode, reuse),
                }
            } else {
                dis_kpca_warm(lane, kernel, params, *mode, reuse)
            };
            r.map(|sol| JobOutput::Kpca(report(sol)))
        }
        JobSpec::Refit { params } => {
            let installed = inner.state.lock().unwrap().data_epoch;
            let frac = inner.cfg.variance_frac;
            let r = if reuse {
                if seq {
                    let mut guard = inner.recovery.lock().unwrap();
                    match guard.as_mut() {
                        Some(rec) => crate::recovery::dis_kpca_refit_recovering(
                            lane, rec, kernel, params, installed, frac,
                        ),
                        None => dis_kpca_refit(lane, kernel, params, installed, frac),
                    }
                } else {
                    dis_kpca_refit(lane, kernel, params, installed, frac)
                }
            } else {
                // no warm state to refit from: refresh the store views
                // so appended columns become visible, then fit cold
                dis_refresh_shards(lane, installed).and_then(|(epoch, delta_cols)| {
                    let solution = if seq {
                        let mut guard = inner.recovery.lock().unwrap();
                        match guard.as_mut() {
                            Some(rec) => crate::recovery::dis_kpca_recovering(
                                lane,
                                rec,
                                kernel,
                                params,
                                SamplingMode::Full,
                                false,
                            ),
                            None => {
                                dis_kpca_warm(lane, kernel, params, SamplingMode::Full, false)
                            }
                        }
                    } else {
                        dis_kpca_warm(lane, kernel, params, SamplingMode::Full, false)
                    }?;
                    Ok(RefitReport { solution, epoch, delta_cols, fell_back: true })
                })
            };
            r.map(|rep| JobOutput::Refit(report(rep)))
        }
        JobSpec::Css { params } => {
            let r = if seq {
                let mut guard = inner.recovery.lock().unwrap();
                match guard.as_mut() {
                    Some(rec) => {
                        crate::recovery::dis_css_recovering(lane, rec, kernel, params, reuse)
                    }
                    None => dis_css_warm(lane, kernel, params, reuse),
                }
            } else {
                dis_css_warm(lane, kernel, params, reuse)
            };
            r.map(|sol| JobOutput::Css(report(sol)))
        }
        JobSpec::Krr { y, lambda, teacher_seed } => {
            let r = if seq {
                let mut guard = inner.recovery.lock().unwrap();
                match guard.as_mut() {
                    Some(rec) => crate::recovery::dis_krr_recovering(
                        lane,
                        rec,
                        kernel,
                        y,
                        *lambda,
                        *teacher_seed,
                    ),
                    None => dis_krr(lane, kernel, y, *lambda, *teacher_seed),
                }
            } else {
                dis_krr(lane, kernel, y, *lambda, *teacher_seed)
            };
            r.map(|model| JobOutput::Krr(report(model)))
        }
        JobSpec::Eval => {
            let r = if seq {
                let mut guard = inner.recovery.lock().unwrap();
                match guard.as_mut() {
                    Some(rec) => crate::recovery::dis_eval_recovering(lane, rec),
                    None => dis_eval(lane),
                }
            } else {
                dis_eval(lane)
            };
            r.map(|ev| JobOutput::Eval(report(ev)))
        }
        JobSpec::Transform { batch } => dis_project_points(
            lane,
            batch,
            inner.batch_cols.load(Ordering::Relaxed),
            inner.cfg.pipeline_depth,
        )
        .map(JobOutput::Transform),
    };
    lane.set_job_stats(None);
    lane.set_round_prefix("svc:");
    res
}

/// Concurrent-mode recovery entry for a runner holding a
/// `Worker`/`Link` error: become the healer (quiesce, revive the dead
/// slots, bump the epoch) unless one already healed past `my_epoch`.
/// When a revival comes back [`CommError::Degraded`] and the recovery
/// has rebalancing enabled, the dead slot's shard is adopted onto a
/// survivor instead and serving continues on the shrunken cluster.
/// Returns the post-heal epoch, or `None` when healing is off (no
/// recovery installed, a revive failed, or an unrecoverable abort).
fn heal(inner: &SchedInner, lane: &Cluster, first_dead: usize, my_epoch: u64) -> Option<u64> {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.healing_off {
            return None;
        }
        if st.epoch != my_epoch {
            return Some(st.epoch);
        }
        if !st.recovering {
            break;
        }
        st = inner.cv.wait(st).unwrap();
    }
    st.recovering = true;
    while st.active > 0 {
        st = inner.cv.wait(st).unwrap();
    }
    drop(st);
    // replay-free revival: every affected job reruns from scratch, so
    // the only state a revived slot needs back is its shard
    let revived = {
        let mut guard = inner.recovery.lock().unwrap();
        match guard.as_mut() {
            Some(rec) => match rec.revive_only(lane, first_dead) {
                Ok(()) => Ok(true),
                // permanent loss: adopt the dead slot's shard onto a
                // survivor and keep serving on the shrunken cluster
                Err(CommError::Degraded { slot, .. }) if rec.rebalance_enabled() => {
                    rec.rebalance(lane, slot).map(|()| true)
                }
                Err(e) => Err(e),
            },
            None => Ok(false),
        }
    };
    let mut st = inner.state.lock().unwrap();
    st.recovering = false;
    let out = match revived {
        Ok(true) => {
            st.epoch += 1;
            st.warm_embed = None;
            // revived workers hold no retained sketch state and their
            // store views were reopened from scratch: the next refit
            // must measure its delta from epoch 0, not trust ours
            st.data_epoch = 0;
            Some(st.epoch)
        }
        Ok(false) | Err(_) => {
            st.healing_off = true;
            None
        }
    };
    drop(st);
    inner.cv.notify_all();
    out
}

/// Concurrent-mode wait for a collateral victim (`Poisoned`): some
/// runner holding the underlying `Worker`/`Link` error is guaranteed
/// to drive [`heal`], so wait for its epoch bump (or for healing to
/// be declared off).
fn wait_for_heal(inner: &SchedInner, my_epoch: u64) -> Option<u64> {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.healing_off {
            return None;
        }
        if st.epoch != my_epoch {
            return Some(st.epoch);
        }
        st = inner.cv.wait(st).unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::PointSet;
    use crate::linalg::Mat;

    #[test]
    fn footprint_conflicts_encode_worker_state() {
        let params = Params::default();
        let kpca = footprint(&JobSpec::Kpca { params, mode: SamplingMode::Full });
        let krr = footprint(&JobSpec::Krr {
            y: PointSet::Dense(Mat::zeros(2, 2)),
            lambda: 1e-3,
            teacher_seed: 1,
        });
        let eval = footprint(&JobSpec::Eval);
        let transform = footprint(&JobSpec::Transform { batch: Mat::zeros(2, 2) });
        // the QPS-relevant interleavings
        assert!(kpca.compatible(krr), "stateless KRR rides along a fit");
        assert!(eval.compatible(transform), "two solution readers coexist");
        assert!(krr.compatible(transform));
        // the must-serialize pairs
        let refit = footprint(&JobSpec::Refit { params });
        assert!(!kpca.compatible(kpca), "two fits contend for worker state");
        assert!(!refit.compatible(kpca), "a refit rewrites fit state");
        assert!(!refit.compatible(transform), "no reading mid-refit");
        assert!(refit.compatible(krr), "stateless KRR rides along a refit");
        assert!(!kpca.compatible(eval), "no reading a half-installed solution");
        assert!(!kpca.compatible(transform));
        assert!(!Footprint::EXCLUSIVE.compatible(krr));
    }
}
