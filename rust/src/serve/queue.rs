//! Typed serving configuration and admission control.
//!
//! One struct, one strict parser: every environment knob the serving
//! stack reads ([`ServeConfig::from_env`]) funnels through
//! [`ServeConfig::parse`], so a mistyped value is a configuration
//! error at startup — never a silent fallback to a default — and
//! `tests/env_knobs.rs` exercises a single entry point instead of
//! three scattered parsers.

use std::fmt;
use std::time::Duration;

use crate::comm::{parse_comm_retries, parse_comm_timeout, Message};
use crate::coordinator::worker::parse_embed_cache_mb;
use crate::linalg::simd::{parse_compute_tier, ComputeTier};
use crate::runtime::parse_table_cache_mb;

/// Every tunable the serving stack reads, in one typed struct.
///
/// | field | env knob | default |
/// |---|---|---|
/// | `comm_timeout` | `DISKPCA_COMM_TIMEOUT_SECS` | none (unbounded) |
/// | `comm_retries` | `DISKPCA_COMM_RETRIES` | 0 (fail fast) |
/// | `chaos_seed` | `DISKPCA_CHAOS_SEED` | none (chaos off) |
/// | `embed_cache_mb` | `DISKPCA_EMBED_CACHE_MB` | 64 MiB |
/// | `table_cache_mb` | `DISKPCA_TABLE_CACHE_MB` | 128 MiB |
/// | `max_inflight` | `DISKPCA_MAX_INFLIGHT` | 1 (sequential) |
/// | `queue_depth` | `DISKPCA_QUEUE_DEPTH` | 32 |
/// | `pipeline_depth` | `DISKPCA_PIPELINE_DEPTH` | 2 |
/// | `compute_tier` | `DISKPCA_COMPUTE_TIER` | exact |
/// | `variance_frac` | `DISKPCA_VARIANCE_FRAC` | 0.95 |
///
/// `max_inflight` is the scheduler's concurrent-job bound (1 keeps
/// the bit-identical sequential path), `queue_depth` the admission
/// queue bound beyond which submissions are rejected
/// ([`Rejected::QueueFull`]), and `pipeline_depth` how many transform
/// super-chunks [`crate::coordinator::dis_project_points`] keeps in
/// flight per query batch. `compute_tier` selects the numeric kernels
/// ([`crate::linalg::simd::ComputeTier`]): `exact` is the
/// bit-reproducible default, `fast` opts into the accuracy-gated SIMD
/// tier. `variance_frac` is the refit acceptance gate: a warm refit
/// ([`crate::coordinator::dis_kpca_refit`]) whose top-k solution
/// preserves less than this fraction of the sketched spectrum's mass
/// re-runs as a cold fit. `comm_retries` is the reply-timeout retry
/// budget ([`crate::comm::Cluster::set_comm_retries`]: each expired
/// bound doubles and re-waits before poisoning; 0 keeps today's
/// fail-fast contract). `chaos_seed` arms the seeded fault-injection
/// transport ([`crate::comm::chaos`]) for soak runs — unset (the
/// default) means no chaos wrapping at all.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub comm_timeout: Option<Duration>,
    pub comm_retries: usize,
    pub chaos_seed: Option<u64>,
    pub embed_cache_mb: usize,
    pub table_cache_mb: usize,
    pub max_inflight: usize,
    pub queue_depth: usize,
    pub pipeline_depth: usize,
    pub compute_tier: ComputeTier,
    pub variance_frac: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            comm_timeout: None,
            comm_retries: 0,
            chaos_seed: None,
            embed_cache_mb: 64,
            table_cache_mb: 128,
            max_inflight: 1,
            queue_depth: 32,
            pipeline_depth: 2,
            compute_tier: ComputeTier::Exact,
            variance_frac: 0.95,
        }
    }
}

/// Parse the refit variance gate: a fraction in `(0, 1]` (`None` =
/// unset ⇒ default). Out-of-range values are rejected rather than
/// clamped — a gate of 0 would accept any refit and a gate above 1
/// would reject every one, both misconfigurations.
pub fn parse_variance_frac(raw: Option<&str>, default: f64) -> Result<f64, String> {
    let Some(raw) = raw else { return Ok(default) };
    match raw.trim().parse::<f64>() {
        Ok(f) if f > 0.0 && f <= 1.0 => Ok(f),
        Ok(_) => Err(format!(
            "DISKPCA_VARIANCE_FRAC={raw}: must be in (0, 1]"
        )),
        Err(_) => Err(format!("DISKPCA_VARIANCE_FRAC={raw}: not a number")),
    }
}

/// Parse a `DISKPCA_CHAOS_SEED` value: any `u64` (0 included — a seed
/// is a seed) arms the chaos transport with that schedule; unset
/// leaves chaos off entirely. There is no "disable" spelling by
/// design: fault injection must be impossible to switch on by typo.
pub fn parse_chaos_seed(raw: Option<&str>) -> Result<Option<u64>, String> {
    let Some(raw) = raw else { return Ok(None) };
    raw.trim()
        .parse::<u64>()
        .map(Some)
        .map_err(|_| format!("DISKPCA_CHAOS_SEED={raw}: not a whole-number seed"))
}

/// Parse a count knob that must be a whole number ≥ 1 (`None` = unset
/// ⇒ default). Zero is rejected rather than clamped: a scheduler with
/// zero runners or a zero-deep pipeline is a misconfiguration, not a
/// mode.
fn parse_count(name: &str, raw: Option<&str>, default: usize) -> Result<usize, String> {
    let Some(raw) = raw else { return Ok(default) };
    match raw.trim().parse::<usize>() {
        Ok(0) => Err(format!("{name}={raw}: must be at least 1")),
        Ok(n) => Ok(n),
        Err(_) => Err(format!("{name}={raw}: not a whole number")),
    }
}

impl ServeConfig {
    /// Parse every serving knob through one strict entry point.
    /// `lookup` maps a variable name to its (possibly unset) value —
    /// `std::env::var(..).ok()` in production, a closure over a map in
    /// tests. The first offending variable aborts the parse with a
    /// message naming it and echoing the rejected value.
    pub fn parse(lookup: impl Fn(&str) -> Option<String>) -> Result<Self, String> {
        let get = |name: &str| lookup(name);
        let defaults = Self::default();
        Ok(Self {
            comm_timeout: parse_comm_timeout(get("DISKPCA_COMM_TIMEOUT_SECS").as_deref())?,
            comm_retries: parse_comm_retries(get("DISKPCA_COMM_RETRIES").as_deref())?,
            chaos_seed: parse_chaos_seed(get("DISKPCA_CHAOS_SEED").as_deref())?,
            embed_cache_mb: parse_embed_cache_mb(get("DISKPCA_EMBED_CACHE_MB").as_deref())?,
            table_cache_mb: parse_table_cache_mb(get("DISKPCA_TABLE_CACHE_MB").as_deref())?,
            max_inflight: parse_count(
                "DISKPCA_MAX_INFLIGHT",
                get("DISKPCA_MAX_INFLIGHT").as_deref(),
                defaults.max_inflight,
            )?,
            queue_depth: parse_count(
                "DISKPCA_QUEUE_DEPTH",
                get("DISKPCA_QUEUE_DEPTH").as_deref(),
                defaults.queue_depth,
            )?,
            pipeline_depth: parse_count(
                "DISKPCA_PIPELINE_DEPTH",
                get("DISKPCA_PIPELINE_DEPTH").as_deref(),
                defaults.pipeline_depth,
            )?,
            compute_tier: parse_compute_tier(get("DISKPCA_COMPUTE_TIER").as_deref())?,
            variance_frac: parse_variance_frac(
                get("DISKPCA_VARIANCE_FRAC").as_deref(),
                defaults.variance_frac,
            )?,
        })
    }

    /// [`ServeConfig::parse`] over the process environment. Panics on
    /// a malformed value — the same hard-error convention every knob
    /// parser here has always had.
    pub fn from_env() -> Self {
        match Self::parse(|name| std::env::var(name).ok()) {
            Ok(cfg) => cfg,
            Err(msg) => panic!("config {msg}"),
        }
    }

    /// Embed-cache budget in bytes (what the worker constructor takes).
    pub fn embed_cache_bytes(&self) -> usize {
        self.embed_cache_mb.saturating_mul(1 << 20)
    }
}

/// Why the scheduler refused a submission. Admission control is
/// load-shedding, not an error in the job itself: the caller may
/// retry later (or block via `submit_blocking`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The admission queue already holds `depth` jobs — the configured
    /// bound (`--queue-depth`). Shedding here keeps the TCP accept
    /// loop responsive instead of letting a burst stall every client.
    QueueFull { depth: usize },
    /// The service is shutting down; no new work is admitted.
    ShuttingDown,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejected::QueueFull { depth } => {
                write!(f, "admission queue full ({depth} jobs queued); retry later")
            }
            Rejected::ShuttingDown => write!(f, "service shutting down"),
        }
    }
}

impl Rejected {
    /// The wire form the `--listen` front end sends instead of
    /// stalling the accept loop: a typed [`Message::RespError`] the
    /// client can distinguish from a compute failure by its
    /// `rejected:` prefix.
    pub fn to_resp_error(&self) -> Message {
        Message::RespError(format!("rejected: {self}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&str, &str)]) -> impl Fn(&str) -> Option<String> + '_ {
        move |name| {
            pairs
                .iter()
                .find(|(k, _)| *k == name)
                .map(|(_, v)| v.to_string())
        }
    }

    #[test]
    fn defaults_when_nothing_is_set() {
        let cfg = ServeConfig::parse(|_| None).unwrap();
        assert_eq!(cfg, ServeConfig::default());
        // the robustness knobs default to "off": fail fast, no chaos
        assert_eq!(cfg.comm_retries, 0);
        assert_eq!(cfg.chaos_seed, None);
    }

    #[test]
    fn comm_retries_and_chaos_seed_parse_and_reject_garbage() {
        let cfg = ServeConfig::parse(env(&[
            ("DISKPCA_COMM_RETRIES", "3"),
            ("DISKPCA_CHAOS_SEED", "0"),
        ]))
        .unwrap();
        assert_eq!(cfg.comm_retries, 3);
        assert_eq!(cfg.chaos_seed, Some(0), "seed 0 is a schedule, not 'off'");
        let err = ServeConfig::parse(env(&[("DISKPCA_COMM_RETRIES", "many")])).unwrap_err();
        assert!(err.contains("DISKPCA_COMM_RETRIES") && err.contains("many"), "{err}");
        let err = ServeConfig::parse(env(&[("DISKPCA_CHAOS_SEED", "-7")])).unwrap_err();
        assert!(err.contains("DISKPCA_CHAOS_SEED") && err.contains("-7"), "{err}");
    }

    #[test]
    fn queue_knobs_parse_and_reject_zero() {
        let cfg = ServeConfig::parse(env(&[
            ("DISKPCA_MAX_INFLIGHT", "4"),
            ("DISKPCA_QUEUE_DEPTH", "2"),
            ("DISKPCA_PIPELINE_DEPTH", "8"),
        ]))
        .unwrap();
        assert_eq!((cfg.max_inflight, cfg.queue_depth, cfg.pipeline_depth), (4, 2, 8));
        let err = ServeConfig::parse(env(&[("DISKPCA_MAX_INFLIGHT", "0")])).unwrap_err();
        assert!(err.contains("DISKPCA_MAX_INFLIGHT") && err.contains("at least 1"), "{err}");
    }

    #[test]
    fn compute_tier_parses_and_rejects_unknown_names() {
        let cfg = ServeConfig::parse(env(&[("DISKPCA_COMPUTE_TIER", "fast")])).unwrap();
        assert_eq!(cfg.compute_tier, ComputeTier::Fast);
        let cfg = ServeConfig::parse(env(&[("DISKPCA_COMPUTE_TIER", " exact ")])).unwrap();
        assert_eq!(cfg.compute_tier, ComputeTier::Exact);
        let err = ServeConfig::parse(env(&[("DISKPCA_COMPUTE_TIER", "turbo")])).unwrap_err();
        assert!(
            err.contains("DISKPCA_COMPUTE_TIER") && err.contains("turbo"),
            "{err}"
        );
    }

    #[test]
    fn variance_frac_parses_and_rejects_out_of_range() {
        let cfg = ServeConfig::parse(env(&[("DISKPCA_VARIANCE_FRAC", "0.8")])).unwrap();
        assert_eq!(cfg.variance_frac, 0.8);
        let cfg = ServeConfig::parse(env(&[("DISKPCA_VARIANCE_FRAC", " 1.0 ")])).unwrap();
        assert_eq!(cfg.variance_frac, 1.0);
        for bad in ["0", "0.0", "1.5", "-0.3", "lots"] {
            let err = ServeConfig::parse(env(&[("DISKPCA_VARIANCE_FRAC", bad)])).unwrap_err();
            assert!(
                err.contains("DISKPCA_VARIANCE_FRAC") && err.contains(bad),
                "{bad}: {err}"
            );
        }
    }

    #[test]
    fn rejection_reasons_render_and_bridge_to_resp_error() {
        let full = Rejected::QueueFull { depth: 32 };
        assert!(full.to_string().contains("32"));
        match full.to_resp_error() {
            Message::RespError(detail) => assert!(detail.starts_with("rejected: ")),
            other => panic!("expected RespError, got {other:?}"),
        }
        assert!(Rejected::ShuttingDown.to_string().contains("shutting down"));
    }
}
