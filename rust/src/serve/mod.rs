//! Multi-job serving layer: many KPCA/CSS/KRR jobs on one persistent
//! cluster, plus a batched projection path for query traffic.
//!
//! The paper's disKPCA produces a compact solution (Y, C) precisely so
//! it can be *used* cheaply afterwards — but a cluster that must be
//! relaunched per fit cannot serve traffic. A [`Service`] wraps a
//! live [`Cluster`] and runs jobs against it sequentially, with three
//! properties the one-shot drivers don't have:
//!
//! 1. **Job isolation.** Every job gets a [`JobCtx`]: its round labels
//!    are namespaced (`job3:1-embed`) in the cluster's lifetime
//!    [`CommStats`], so two jobs can never alias each other's
//!    accounting rows, and a private per-job [`CommStats`] records the
//!    *bare* labels — directly comparable, row for row, to a fresh
//!    single-job cluster's table (pinned by `tests/serve_parity.rs`).
//! 2. **Warm-state reuse.** The service tracks which [`EmbedSpec`] is
//!    installed on the workers. A job whose spec matches skips the
//!    `1-embed` broadcast entirely — zero words in that round — and
//!    each worker additionally keeps an LRU embedding cache (byte
//!    budget, `DISKPCA_EMBED_CACHE_MB`) so jobs *alternating* between
//!    specs skip the recompute even when the round must be resent.
//!    Reuse is bit-identity-safe: the embedding is a deterministic
//!    function of (spec, shard), so a warm job's solution equals a
//!    cold cluster's bit for bit.
//! 3. **Query serving.** [`Service::transform`] projects batches of
//!    *new* points through the installed solution: batches are split
//!    across the star (any worker can answer — the result depends
//!    only on the solution) and streamed in bounded column chunks;
//!    streaming workers additionally fold each sub-batch through the
//!    out-of-core chunk loop, so worker memory tracks the chunk size.
//!
//! Jobs run strictly sequentially (`&mut self`), which is what makes
//! the namespacing airtight without worker-side job tags; sharded
//! tenants and async dispatch layer on top of this in later work.
//!
//! # Examples
//!
//! ```
//! use std::sync::Arc;
//! use diskpca::coordinator::Params;
//! use diskpca::data::{clusters, partition_power_law, Data};
//! use diskpca::kernels::Kernel;
//! use diskpca::rng::Rng;
//! use diskpca::runtime::NativeBackend;
//! use diskpca::serve::Service;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = Data::Dense(clusters(6, 90, 3, 0.2, &mut rng));
//! let shards = partition_power_law(&data, 2, 3);
//! let kernel = Kernel::Gauss { gamma: 0.6 };
//! let params = Params {
//!     k: 2, t: 8, p: 16, n_lev: 6, n_adapt: 10, m_rff: 128, t2: 64,
//!     ..Params::default()
//! };
//! let mut svc = Service::in_process(shards, kernel, Arc::new(NativeBackend::new()), 0);
//!
//! let cold = svc.run_kpca(&params).unwrap();
//! assert!(!cold.embed_reused);
//! assert!(cold.job.stats.round_words("1-embed") > 0);
//!
//! // same spec ⇒ the second job skips the embed round entirely
//! let warm = svc.run_kpca(&Params { n_adapt: 20, ..params }).unwrap();
//! assert!(warm.embed_reused);
//! assert_eq!(warm.job.stats.round_words("1-embed"), 0);
//!
//! // serve fresh points through the installed solution
//! let batch = diskpca::linalg::Mat::from_fn(6, 5, |_, _| rng.normal());
//! let proj = svc.transform(&batch).unwrap();
//! assert_eq!((proj.rows(), proj.cols()), (2, 5));
//! svc.shutdown();
//! ```

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::comm::request as rq;
use crate::comm::{memory, Cluster, CommError, CommStats, PointSet};
use crate::coordinator::{
    dis_css_warm, dis_eval, dis_kpca_warm, dis_krr, embed_spec_for, CssSolution, KpcaSolution,
    KrrModel, Params, SamplingMode, Worker,
};
use crate::data::Data;
use crate::embed::EmbedSpec;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::recovery::{LocalHost, Recovery, Transport};
use crate::runtime::Backend;

/// Identity and accounting scope of one job on a [`Service`] cluster.
#[derive(Clone, Debug)]
pub struct JobCtx {
    /// Monotone job index on this service.
    pub id: usize,
    /// Round-label namespace this job's exchanges carry in the
    /// cluster's lifetime stats (e.g. `"job3:"`).
    pub label: String,
    /// This job's own word counters, recorded under *bare* round
    /// labels — row-for-row comparable to a fresh single-job cluster.
    pub stats: CommStats,
}

/// A completed job: its output plus its isolated accounting.
#[derive(Clone, Debug)]
pub struct JobReport<T> {
    pub job: JobCtx,
    pub output: T,
    /// Whether the `1-embed` round was skipped via warm-state reuse
    /// (always `false` for jobs that never embed, e.g. KRR).
    pub embed_reused: bool,
}

/// A job service over a persistent [`Cluster`]: run many fits without
/// relaunching workers, reuse worker-resident warm state across jobs,
/// and serve projection queries. See the module docs.
pub struct Service {
    cluster: Cluster,
    kernel: Kernel,
    /// In-process worker threads (empty when serving over an external
    /// transport); joined on shutdown/drop.
    handles: Vec<JoinHandle<()>>,
    /// The [`EmbedSpec`] currently installed on every worker, when
    /// known — the key for skipping the `1-embed` round.
    warm_embed: Option<EmbedSpec>,
    next_job: usize,
    /// Per-worker column bound for one transform scatter round.
    batch_cols: usize,
    /// When present, fit/eval jobs run under the elastic recovery
    /// driver: a worker dying mid-job is revived and the job completes
    /// with a bit-identical result ([`crate::recovery`]).
    recovery: Option<Recovery>,
}

impl Service {
    /// Serve over an already-connected cluster (e.g. [`crate::comm::tcp`]
    /// workers). The workers' `kernel` must match.
    pub fn new(cluster: Cluster, kernel: Kernel) -> Self {
        cluster.set_round_prefix("svc:");
        Self {
            cluster,
            kernel,
            handles: Vec::new(),
            warm_embed: None,
            next_job: 0,
            batch_cols: 1024,
            recovery: None,
        }
    }

    /// Spawn an in-process serving cluster over the memory transport —
    /// the [`crate::coordinator::run_cluster`] topology, kept alive for
    /// many jobs. `chunk_rows > 0` makes the workers stream
    /// out-of-core (see the worker docs). Workers keep the default
    /// embed warm-cache budget; see [`Service::in_process_opts`].
    pub fn in_process(
        shards: Vec<Data>,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
    ) -> Self {
        Self::in_process_opts(shards, kernel, backend, chunk_rows, None)
    }

    /// [`Service::in_process`] with an explicit per-worker embed
    /// warm-cache byte budget (`None` keeps the
    /// `DISKPCA_EMBED_CACHE_MB` default, `Some(0)` disables caching) —
    /// what `diskpca serve --embed-cache-mb` sets.
    pub fn in_process_opts(
        shards: Vec<Data>,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
        embed_cache_bytes: Option<usize>,
    ) -> Self {
        let (star, endpoints) = memory::star(shards.len());
        let handles: Vec<JoinHandle<()>> = shards
            .into_iter()
            .zip(endpoints)
            .map(|(shard, ep)| {
                let be = backend.clone();
                std::thread::spawn(move || {
                    let mut worker = Worker::new_chunked(shard, kernel, be, chunk_rows);
                    if let Some(bytes) = embed_cache_bytes {
                        worker.set_embed_cache_budget(bytes);
                    }
                    worker.run(ep)
                })
            })
            .collect();
        let mut svc = Self::new(Cluster::new(star, CommStats::new()), kernel);
        svc.handles = handles;
        svc
    }

    /// [`Service::in_process_opts`] on the elastic memory transport: a
    /// worker thread dying mid-job is revived from a retained shard
    /// copy and the job replays to a bit-identical result. Costs one
    /// extra in-memory copy of every shard (the revival source).
    pub fn in_process_elastic(
        shards: Vec<Data>,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
        embed_cache_bytes: Option<usize>,
    ) -> Self {
        let (star, endpoints, reply_tx) = memory::star_elastic(shards.len());
        let handles: Vec<JoinHandle<()>> = shards
            .iter()
            .cloned()
            .zip(endpoints)
            .map(|(shard, ep)| {
                let be = backend.clone();
                std::thread::spawn(move || {
                    let mut worker = Worker::new_chunked(shard, kernel, be, chunk_rows);
                    if let Some(bytes) = embed_cache_bytes {
                        worker.set_embed_cache_budget(bytes);
                    }
                    worker.run(ep)
                })
            })
            .collect();
        let mut host = LocalHost::new(
            shards,
            kernel,
            backend,
            chunk_rows,
            reply_tx,
            Transport::Memory,
        );
        if let Some(bytes) = embed_cache_bytes {
            host.set_embed_cache_bytes(bytes);
        }
        let mut svc = Self::new(Cluster::new(star, CommStats::new()), kernel);
        svc.handles = handles;
        svc.recovery = Some(Recovery::new(Box::new(host)));
        svc
    }

    /// Attach an elastic recovery driver to an externally-connected
    /// service (the host must revive onto this cluster's reply queue).
    pub fn set_recovery(&mut self, recovery: Recovery) {
        self.recovery = Some(recovery);
    }

    /// Worker revivals performed across all jobs so far (0 for a
    /// non-elastic service).
    pub fn recoveries(&self) -> usize {
        self.recovery.as_ref().map_or(0, |r| r.recoveries())
    }

    pub fn num_workers(&self) -> usize {
        self.cluster.num_workers()
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Jobs run so far (monotone id source).
    pub fn jobs_run(&self) -> usize {
        self.next_job
    }

    /// Lifetime stats of the whole service — every job appears under
    /// its namespaced labels, queries under `svc:`.
    pub fn stats(&self) -> &CommStats {
        &self.cluster.stats
    }

    /// The underlying cluster (advanced use; prefer the job API —
    /// exchanges made here are accounted under the ambient `svc:`
    /// namespace and invalidate no warm state).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Bound the per-worker column width of one transform scatter
    /// round (default 1024): larger batches stream through in
    /// `workers × cols` chunks.
    pub fn set_transform_chunk(&mut self, cols: usize) {
        self.batch_cols = cols.max(1);
    }

    /// Open a job scope: namespace the round labels and install the
    /// per-job stats sink.
    fn begin(&mut self) -> JobCtx {
        let id = self.next_job;
        self.next_job += 1;
        let label = format!("job{id}:");
        let stats = CommStats::new();
        self.cluster.set_round_prefix(&label);
        self.cluster.set_job_stats(Some(stats.clone()));
        JobCtx { id, label, stats }
    }

    /// Close the job scope: back to the ambient `svc:` namespace.
    fn finish(&self) {
        self.cluster.set_job_stats(None);
        self.cluster.set_round_prefix("svc:");
    }

    /// Run one disKPCA job (Alg. 4), reusing the installed embedding
    /// when this job's [`EmbedSpec`] matches — the reused job performs
    /// **zero** `1-embed` communication and its solution is
    /// bit-identical to a cold run.
    pub fn run_kpca(&mut self, params: &Params) -> Result<JobReport<KpcaSolution>, CommError> {
        self.run_kpca_mode(params, SamplingMode::Full)
    }

    /// [`Service::run_kpca`] with an ablated sampling stage.
    pub fn run_kpca_mode(
        &mut self,
        params: &Params,
        mode: SamplingMode,
    ) -> Result<JobReport<KpcaSolution>, CommError> {
        let embeds = mode != SamplingMode::AdaptiveOnly;
        let spec = embed_spec_for(self.kernel, params);
        let reuse = embeds && self.warm_embed == Some(spec);
        let job = self.begin();
        let res = match self.recovery.as_mut() {
            Some(rec) => crate::recovery::dis_kpca_recovering(
                &self.cluster,
                rec,
                self.kernel,
                params,
                mode,
                reuse,
            ),
            None => dis_kpca_warm(&self.cluster, self.kernel, params, mode, reuse),
        };
        self.finish();
        self.note_embed_outcome(embeds, spec, &res);
        let output = res?;
        Ok(JobReport { job, output, embed_reused: reuse })
    }

    /// Run one kernel CSS job (§5.3), with the same warm-embed reuse.
    pub fn run_css(&mut self, params: &Params) -> Result<JobReport<CssSolution>, CommError> {
        let spec = embed_spec_for(self.kernel, params);
        let reuse = self.warm_embed == Some(spec);
        let job = self.begin();
        let res = match self.recovery.as_mut() {
            Some(rec) => {
                crate::recovery::dis_css_recovering(&self.cluster, rec, self.kernel, params, reuse)
            }
            None => dis_css_warm(&self.cluster, self.kernel, params, reuse),
        };
        self.finish();
        self.note_embed_outcome(true, spec, &res);
        let output = res?;
        Ok(JobReport { job, output, embed_reused: reuse })
    }

    /// Run one distributed KRR job on a representative set (no
    /// embedding rounds — warm state is untouched).
    pub fn run_krr(
        &mut self,
        y: &PointSet,
        lambda: f64,
        teacher_seed: u64,
    ) -> Result<JobReport<KrrModel>, CommError> {
        let job = self.begin();
        let res = match self.recovery.as_mut() {
            Some(rec) => crate::recovery::dis_krr_recovering(
                &self.cluster,
                rec,
                self.kernel,
                y,
                lambda,
                teacher_seed,
            ),
            None => dis_krr(&self.cluster, self.kernel, y, lambda, teacher_seed),
        };
        self.finish();
        let output = res?;
        Ok(JobReport { job, output, embed_reused: false })
    }

    /// Evaluate the installed solution (`(error, trace)`, Alg. 4's
    /// quality metric) as its own job.
    pub fn run_eval(&mut self) -> Result<JobReport<(f64, f64)>, CommError> {
        let job = self.begin();
        let res = match self.recovery.as_mut() {
            Some(rec) => crate::recovery::dis_eval_recovering(&self.cluster, rec),
            None => dis_eval(&self.cluster),
        };
        self.finish();
        let output = res?;
        Ok(JobReport { job, output, embed_reused: false })
    }

    /// Run an arbitrary driver sequence as one job (e.g. fit + eval in
    /// a single accounting scope). The body may install any worker
    /// state, so the warm-embed key is conservatively invalidated.
    pub fn run_job<T>(
        &mut self,
        body: impl FnOnce(&Cluster) -> Result<T, CommError>,
    ) -> Result<JobReport<T>, CommError> {
        let job = self.begin();
        let res = body(&self.cluster);
        self.finish();
        self.warm_embed = None;
        let output = res?;
        Ok(JobReport { job, output, embed_reused: false })
    }

    /// Track what the workers hold after a job that embeds: on
    /// success the job's spec is installed; on failure the state is
    /// unknown — drop the key so the next job re-embeds (harmless).
    fn note_embed_outcome<T, E>(&mut self, embeds: bool, spec: EmbedSpec, res: &Result<T, E>) {
        if !embeds {
            return;
        }
        self.warm_embed = match res {
            Ok(_) => Some(spec),
            Err(_) => None,
        };
    }

    /// Project a batch of new points (d×n, columns are points) through
    /// the solution installed by the most recent fit job: returns the
    /// k×n coordinates LᵀΦ(batch).
    ///
    /// The batch is scattered across the workers in worker-order
    /// column ranges (any worker computes the same answer — the
    /// projection depends only on the installed solution) and large
    /// batches stream through in `workers ×` [`Service::set_transform_chunk`]
    /// super-chunks, so neither master nor workers ever hold more
    /// than a bounded slice in flight. Exchanges are accounted under
    /// `svc:10-transform`.
    ///
    /// An empty batch returns an empty `0×0` matrix without any
    /// communication — the solution's `k` is unknown master-side
    /// until a worker replies, so the k×0 shape cannot be produced.
    pub fn transform(&mut self, batch: &Mat) -> Result<Mat, CommError> {
        let n = batch.cols();
        let s = self.cluster.num_workers();
        if n == 0 {
            return Ok(Mat::zeros(0, 0));
        }
        self.cluster.set_round("10-transform");
        let mut out: Option<Mat> = None;
        let super_cols = self.batch_cols * s;
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + super_cols).min(n);
            let cols = j1 - j0;
            // split [j0, j1) over workers as evenly as possible
            let bounds: Vec<usize> = (0..=s).map(|w| j0 + cols * w / s).collect();
            let reqs: Vec<rq::ProjectPoints> = (0..s)
                .map(|w| {
                    let idx: Vec<usize> = (bounds[w]..bounds[w + 1]).collect();
                    rq::ProjectPoints { pts: PointSet::Dense(batch.select_cols(&idx)) }
                })
                .collect();
            let parts = self.cluster.scatter(reqs)?;
            for (w, part) in parts.iter().enumerate() {
                let out_m = out.get_or_insert_with(|| Mat::zeros(part.rows(), n));
                for (jj, j) in (bounds[w]..bounds[w + 1]).enumerate() {
                    for i in 0..part.rows() {
                        out_m[(i, j)] = part[(i, jj)];
                    }
                }
            }
            j0 = j1;
        }
        Ok(out.expect("n > 0 produced at least one scatter"))
    }

    /// Quit the workers and join in-process worker threads. Dropping
    /// the service does the same; this form just makes the point
    /// explicit at call sites.
    pub fn shutdown(self) {}
}

impl Drop for Service {
    fn drop(&mut self) {
        self.cluster.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // replacement workers spawned by revivals exit on the same
        // Quit fan-out; join them too
        if let Some(rec) = self.recovery.as_mut() {
            rec.join_host();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{clusters, partition_power_law};
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;

    fn service(s: usize) -> (Service, Data, Params) {
        let mut rng = Rng::seed_from(11);
        let data = Data::Dense(clusters(7, 140, 3, 0.2, &mut rng));
        let shards = partition_power_law(&data, s, 5);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let params = Params {
            k: 3,
            t: 16,
            p: 32,
            n_lev: 8,
            n_adapt: 14,
            m_rff: 128,
            t2: 64,
            seed: 21,
            ..Params::default()
        };
        let svc = Service::in_process(shards, kernel, Arc::new(NativeBackend::new()), 0);
        (svc, data, params)
    }

    #[test]
    fn warm_job_skips_embed_round_with_identical_solution() {
        let (mut svc, _, params) = service(3);
        let cold = svc.run_kpca(&params).unwrap();
        assert!(!cold.embed_reused);
        assert!(cold.job.stats.round_words("1-embed") > 0);
        let warm = svc.run_kpca(&params).unwrap();
        assert!(warm.embed_reused);
        assert_eq!(
            warm.job.stats.round_words("1-embed"),
            0,
            "warm job must perform zero 1-embed communication"
        );
        assert!(warm.job.stats.total_words() < cold.job.stats.total_words());
        // identical params ⇒ bit-identical solution despite the skip
        assert!(cold.output.y.data() == warm.output.y.data());
        assert!(cold.output.coeffs.data() == warm.output.coeffs.data());
        // lifetime stats kept the jobs apart by namespace
        assert!(svc.stats().round_words("job0:1-embed") > 0);
        assert_eq!(svc.stats().round_words("job1:1-embed"), 0);
        assert!(svc.stats().round_words("job1:2-disLS") > 0);
    }

    #[test]
    fn different_spec_invalidates_warm_state() {
        let (mut svc, _, params) = service(2);
        svc.run_kpca(&params).unwrap();
        let other = Params { seed: params.seed + 1, ..params };
        let cold = svc.run_kpca(&other).unwrap();
        assert!(!cold.embed_reused);
        assert!(cold.job.stats.round_words("1-embed") > 0);
        // returning to the first spec re-sends the round (master-side
        // tracking is last-installed; the worker-side cache still
        // saves the recompute)
        let back = svc.run_kpca(&params).unwrap();
        assert!(!back.embed_reused);
        assert!(back.job.stats.round_words("1-embed") > 0);
    }

    #[test]
    fn css_and_krr_jobs_share_the_warm_cluster() {
        let (mut svc, _, params) = service(2);
        let css = svc.run_css(&params).unwrap();
        assert!(!css.embed_reused);
        // same spec: the CSS warm state carries over to a KPCA job
        let kpca = svc.run_kpca(&params).unwrap();
        assert!(kpca.embed_reused);
        let krr = svc.run_krr(&css.output.y, 1e-3, 9).unwrap();
        assert_eq!(krr.output.alpha.len(), css.output.y.len());
        assert!(!krr.embed_reused);
        assert_eq!(svc.jobs_run(), 3);
    }

    #[test]
    fn transform_matches_solution_projection() {
        let (mut svc, _, params) = service(3);
        let sol = svc.run_kpca(&params).unwrap().output;
        let mut rng = Rng::seed_from(99);
        let batch = Mat::from_fn(7, 23, |_, _| rng.normal());
        let served = svc.transform(&batch).unwrap();
        assert_eq!((served.rows(), served.cols()), (sol.k(), 23));
        // master-side projection associates differently (C = R⁻¹W is
        // pre-multiplied), so compare to tolerance, not bits
        let local = sol.project(&Data::Dense(batch.clone()));
        assert!(
            served.max_abs_diff(&local) < 1e-6,
            "served vs local diff {}",
            served.max_abs_diff(&local)
        );
        // chunked dispatch must not change results
        svc.set_transform_chunk(3);
        let chunked = svc.transform(&batch).unwrap();
        assert!(chunked.data() == served.data(), "chunked transform differs");
        // words accounted under the ambient svc: namespace
        assert!(svc.stats().round_words("svc:10-transform") > 0);
    }

    #[test]
    fn run_job_composes_drivers_in_one_scope() {
        let (mut svc, data, params) = service(2);
        let kernel = svc.kernel();
        let report = svc
            .run_job(move |cluster| {
                let sol = crate::coordinator::dis_kpca(cluster, kernel, &params)?;
                let (err, trace) = dis_eval(cluster)?;
                Ok((sol, err, trace))
            })
            .unwrap();
        let (sol, err, trace) = report.output;
        assert!(err >= 0.0 && err <= trace);
        assert!((sol.eval_error(&data) - err).abs() < 1e-6 * trace);
        for round in ["1-embed", "2-disLS", "5-disLR", "6-eval"] {
            assert!(report.job.stats.round_words(round) > 0, "{round} missing");
        }
    }
}
