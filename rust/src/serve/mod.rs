//! Multi-job serving layer: many KPCA/CSS/KRR jobs on one persistent
//! cluster, a concurrent scheduler, and a pipelined projection path
//! for query traffic.
//!
//! The paper's disKPCA produces a compact solution (Y, C) precisely so
//! it can be *used* cheaply afterwards — but a cluster that must be
//! relaunched per fit cannot serve traffic. A [`Service`] wraps a
//! live [`Cluster`] and runs jobs against it, with four properties the
//! one-shot drivers don't have:
//!
//! 1. **Job isolation.** Every job gets a [`JobCtx`]: its round labels
//!    are namespaced (`job3:1-embed`) in the cluster's lifetime
//!    [`CommStats`], so two jobs can never alias each other's
//!    accounting rows, and a private per-job [`CommStats`] records the
//!    *bare* labels — directly comparable, row for row, to a fresh
//!    single-job cluster's table (pinned by `tests/serve_parity.rs`).
//! 2. **Warm-state reuse.** The service tracks which
//!    [`crate::embed::EmbedSpec`] is
//!    installed on the workers. A job whose spec matches skips the
//!    `1-embed` broadcast entirely — zero words in that round — and
//!    each worker additionally keeps an LRU embedding cache (byte
//!    budget, `DISKPCA_EMBED_CACHE_MB`) so jobs *alternating* between
//!    specs skip the recompute even when the round must be resent.
//!    Reuse is bit-identity-safe: the embedding is a deterministic
//!    function of (spec, shard), so a warm job's solution equals a
//!    cold cluster's bit for bit.
//! 3. **Query serving.** [`Service::transform`] projects batches of
//!    *new* points through the installed solution: batches are split
//!    across the star in worker-order column ranges, streamed in
//!    bounded super-chunks, and *pipelined* — up to
//!    [`ServeConfig::pipeline_depth`] super-chunks ride the wire at
//!    once, so worker chunk I/O overlaps master-side assembly
//!    ([`crate::coordinator::dis_project_points`]).
//! 4. **Concurrent scheduling.** Jobs are admitted through a bounded
//!    queue ([`Service::submit`] → [`JobHandle`]) and dispatched by
//!    [`scheduler`] onto `max_inflight` runner lanes, head-of-line,
//!    gated by a worker-state conflict model: independent jobs (a KRR
//!    fit, a transform batch) interleave their rounds on one cluster;
//!    conflicting jobs (two KPCA fits) serialize in submission order.
//!    `--max-inflight 1` (the default) is bit-identical to the
//!    historical strictly-sequential service.
//!
//! # Examples
//!
//! ```
//! use std::sync::Arc;
//! use diskpca::coordinator::Params;
//! use diskpca::data::{clusters, partition_power_law, Data};
//! use diskpca::kernels::Kernel;
//! use diskpca::rng::Rng;
//! use diskpca::runtime::NativeBackend;
//! use diskpca::serve::Service;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = Data::Dense(clusters(6, 90, 3, 0.2, &mut rng));
//! let shards = partition_power_law(&data, 2, 3);
//! let kernel = Kernel::Gauss { gamma: 0.6 };
//! let params = Params {
//!     k: 2, t: 8, p: 16, n_lev: 6, n_adapt: 10, m_rff: 128, t2: 64,
//!     ..Params::default()
//! };
//! let mut svc = Service::builder(kernel)
//!     .shards(shards)
//!     .backend(Arc::new(NativeBackend::new()))
//!     .build();
//!
//! let cold = svc.run_kpca(&params).unwrap();
//! assert!(!cold.embed_reused);
//! assert!(cold.job.stats.round_words("1-embed") > 0);
//!
//! // same spec ⇒ the second job skips the embed round entirely
//! let warm = svc.run_kpca(&Params { n_adapt: 20, ..params }).unwrap();
//! assert!(warm.embed_reused);
//! assert_eq!(warm.job.stats.round_words("1-embed"), 0);
//!
//! // serve fresh points through the installed solution
//! let batch = diskpca::linalg::Mat::from_fn(6, 5, |_, _| rng.normal());
//! let proj = svc.transform(&batch).unwrap();
//! assert_eq!((proj.rows(), proj.cols()), (2, 5));
//! svc.shutdown();
//! ```

pub mod queue;
pub mod scheduler;

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::comm::{memory, Cluster, CommError, CommStats, PointSet};
use crate::coordinator::{
    CssSolution, KpcaSolution, KrrModel, Params, RefitReport, SamplingMode, Worker,
};
use crate::data::Data;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::recovery::{LocalHost, Recovery, Transport};
use crate::runtime::Backend;

pub use queue::{parse_chaos_seed, Rejected, ServeConfig};
pub use scheduler::JobHandle;

use scheduler::Scheduler;

/// Identity and accounting scope of one job on a [`Service`] cluster.
#[derive(Clone, Debug)]
pub struct JobCtx {
    /// Monotone job index on this service.
    pub id: usize,
    /// Round-label namespace this job's exchanges carry in the
    /// cluster's lifetime stats (e.g. `"job3:"`).
    pub label: String,
    /// This job's own word counters, recorded under *bare* round
    /// labels — row-for-row comparable to a fresh single-job cluster.
    pub stats: CommStats,
}

/// A completed job: its output plus its isolated accounting.
#[derive(Clone, Debug)]
pub struct JobReport<T> {
    pub job: JobCtx,
    pub output: T,
    /// Whether the `1-embed` round was skipped via warm-state reuse
    /// (always `false` for jobs that never embed, e.g. KRR).
    pub embed_reused: bool,
}

/// What to run — the submission unit of [`Service::submit`].
#[derive(Clone, Debug)]
pub enum JobSpec {
    /// One disKPCA fit (Alg. 4), with an ablatable sampling stage.
    Kpca { params: Params, mode: SamplingMode },
    /// Incremental warm refit after shard appends: refresh every
    /// worker's store view, fold only the appended delta columns into
    /// the retained sketch state, and re-solve — falling back to a
    /// cold fit when the warm embedding doesn't match or the refreshed
    /// sketch preserves too little variance
    /// ([`crate::coordinator::dis_kpca_refit`]).
    Refit { params: Params },
    /// One kernel CSS job (§5.3).
    Css { params: Params },
    /// One distributed KRR fit on a representative set.
    Krr { y: PointSet, lambda: f64, teacher_seed: u64 },
    /// Evaluate the installed solution (`(error, trace)`).
    Eval,
    /// Project a batch of new points (d×n, columns are points)
    /// through the installed solution. Queries don't consume a job id
    /// and are accounted under `svc:10-transform`.
    Transform { batch: Mat },
}

impl JobSpec {
    /// Sugar for the common fit submission.
    pub fn kpca(params: &Params) -> Self {
        JobSpec::Kpca { params: *params, mode: SamplingMode::Full }
    }
}

/// What a completed [`JobSpec`] yields — variant-matched to the spec.
#[derive(Clone, Debug)]
pub enum JobOutput {
    Kpca(JobReport<KpcaSolution>),
    Refit(JobReport<RefitReport>),
    Css(JobReport<CssSolution>),
    Krr(JobReport<KrrModel>),
    Eval(JobReport<(f64, f64)>),
    Transform(Mat),
}

/// A job service over a persistent [`Cluster`]: run many fits without
/// relaunching workers, reuse worker-resident warm state across jobs,
/// and serve projection queries — sequentially by default, or
/// concurrently with `max_inflight > 1`. See the module docs.
pub struct Service {
    cluster: Cluster,
    kernel: Kernel,
    sched: Scheduler,
    /// In-process worker threads (empty when serving over an external
    /// transport); joined on shutdown/drop.
    handles: Vec<JoinHandle<()>>,
}

/// Configures and builds a [`Service`].
///
/// Provide a data source: either [`ServiceBuilder::shards`] (spawns
/// in-process workers over the memory transport) or
/// [`ServiceBuilder::cluster`] (serve over an already-connected
/// cluster, e.g. TCP workers). Everything else has defaults.
pub struct ServiceBuilder {
    kernel: Kernel,
    shards: Option<Vec<Data>>,
    cluster: Option<Cluster>,
    backend: Option<Arc<dyn Backend>>,
    chunk_rows: usize,
    /// `None` = worker default (`DISKPCA_EMBED_CACHE_MB`).
    embed_cache_bytes: Option<usize>,
    elastic: bool,
    transform_chunk: Option<usize>,
    recovery: Option<Recovery>,
    config: Option<ServeConfig>,
}

impl ServiceBuilder {
    /// In-process mode: shard the data across spawned worker threads
    /// (one per shard) over the memory transport.
    pub fn shards(mut self, shards: Vec<Data>) -> Self {
        self.shards = Some(shards);
        self
    }

    /// Serve over an already-connected cluster (e.g.
    /// [`crate::comm::tcp`] workers). The workers' kernel must match.
    pub fn cluster(mut self, cluster: Cluster) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Compute backend for in-process workers (required with
    /// [`ServiceBuilder::shards`]).
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// `> 0` makes in-process workers stream out-of-core in
    /// `chunk_rows`-point chunks (see the worker docs). Default 0
    /// (resident).
    pub fn chunk_rows(mut self, chunk_rows: usize) -> Self {
        self.chunk_rows = chunk_rows;
        self
    }

    /// Per-worker embed warm-cache byte budget (`None` keeps the
    /// `DISKPCA_EMBED_CACHE_MB` default, `Some(0)` disables caching) —
    /// what `diskpca serve --embed-cache-mb` sets.
    pub fn embed_cache_bytes(mut self, bytes: Option<usize>) -> Self {
        self.embed_cache_bytes = bytes;
        self
    }

    /// In-process mode only: use the elastic memory transport and
    /// attach a revival host, so a worker thread dying mid-job is
    /// revived from a retained shard copy and the job completes with
    /// a bit-identical result. Costs one extra in-memory copy of
    /// every shard (the revival source).
    pub fn elastic(mut self, elastic: bool) -> Self {
        self.elastic = elastic;
        self
    }

    /// Per-worker column bound for one transform scatter round
    /// (default 1024) — [`Service::set_transform_chunk`] at build
    /// time.
    pub fn transform_chunk(mut self, cols: usize) -> Self {
        self.transform_chunk = Some(cols);
        self
    }

    /// Attach an elastic recovery driver (external-transport setups;
    /// the host must revive onto this cluster's reply queue). The
    /// in-process equivalent is [`ServiceBuilder::elastic`].
    pub fn recovery(mut self, recovery: Recovery) -> Self {
        self.recovery = Some(recovery);
        self
    }

    /// Scheduling/queue configuration (`max_inflight`, `queue_depth`,
    /// `pipeline_depth`, cache budgets). Default:
    /// [`ServeConfig::from_env`].
    pub fn config(mut self, config: ServeConfig) -> Self {
        self.config = Some(config);
        self
    }

    /// Build the service, spawning in-process workers when shards
    /// were provided.
    ///
    /// # Panics
    ///
    /// When neither [`ServiceBuilder::shards`] nor
    /// [`ServiceBuilder::cluster`] was set (or both were), when
    /// shards are given without a [`ServiceBuilder::backend`], or on
    /// a malformed environment knob (the [`ServeConfig::from_env`]
    /// convention).
    pub fn build(self) -> Service {
        let cfg = self.config.unwrap_or_else(ServeConfig::from_env);
        // apply the configured numeric tier process-wide before any
        // worker spawns, so every kernel the service runs sees it
        crate::linalg::simd::set_compute_tier(cfg.compute_tier);
        let embed_cache_bytes = self.embed_cache_bytes;
        let mut recovery = self.recovery;
        let (cluster, handles) = match (self.cluster, self.shards) {
            (Some(cluster), None) => {
                assert!(!self.elastic, "elastic mode spawns in-process workers; \
                     external clusters attach a recovery host instead");
                (cluster, Vec::new())
            }
            (None, Some(shards)) => {
                let backend = self
                    .backend
                    .expect("ServiceBuilder::shards requires ServiceBuilder::backend");
                let chunk_rows = self.chunk_rows;
                let spawn = |shard: Data, ep: memory::WorkerEndpoint, be: Arc<dyn Backend>| {
                    let kernel = self.kernel;
                    std::thread::spawn(move || {
                        let mut worker = Worker::new_chunked(shard, kernel, be, chunk_rows);
                        if let Some(bytes) = embed_cache_bytes {
                            worker.set_embed_cache_budget(bytes);
                        }
                        worker.run(ep)
                    })
                };
                if self.elastic {
                    let (star, endpoints, reply_tx) = memory::star_elastic(shards.len());
                    // chaos soaks: wrap the links only where recovery
                    // exists to heal the injected faults
                    let star = match cfg.chaos_seed {
                        Some(seed) => crate::comm::chaos::wrap_star(star, seed),
                        None => star,
                    };
                    let handles: Vec<JoinHandle<()>> = shards
                        .iter()
                        .cloned()
                        .zip(endpoints)
                        .map(|(shard, ep)| spawn(shard, ep, backend.clone()))
                        .collect();
                    let mut host = LocalHost::new(
                        shards,
                        self.kernel,
                        backend,
                        chunk_rows,
                        reply_tx,
                        Transport::Memory,
                    );
                    if let Some(bytes) = embed_cache_bytes {
                        host.set_embed_cache_bytes(bytes);
                    }
                    recovery = Some(Recovery::new(Box::new(host)));
                    (Cluster::new(star, CommStats::new()), handles)
                } else {
                    let (star, endpoints) = memory::star(shards.len());
                    let handles: Vec<JoinHandle<()>> = shards
                        .into_iter()
                        .zip(endpoints)
                        .map(|(shard, ep)| spawn(shard, ep, backend.clone()))
                        .collect();
                    (Cluster::new(star, CommStats::new()), handles)
                }
            }
            (None, None) => panic!("ServiceBuilder needs shards(..) or cluster(..)"),
            (Some(_), Some(_)) => panic!("ServiceBuilder takes shards(..) or cluster(..), not both"),
        };
        cluster.set_round_prefix("svc:");
        // explicit config wins over whatever the cluster read from env
        cluster.set_comm_retries(cfg.comm_retries);
        let sched = Scheduler::new(&cluster, self.kernel, cfg, recovery);
        let svc = Service { cluster, kernel: self.kernel, sched, handles };
        if let Some(cols) = self.transform_chunk {
            svc.sched.set_transform_chunk(cols);
        }
        svc
    }
}

impl Service {
    /// Start configuring a service — see [`ServiceBuilder`].
    pub fn builder(kernel: Kernel) -> ServiceBuilder {
        ServiceBuilder {
            kernel,
            shards: None,
            cluster: None,
            backend: None,
            chunk_rows: 0,
            embed_cache_bytes: None,
            elastic: false,
            transform_chunk: None,
            recovery: None,
            config: None,
        }
    }

    /// Serve over an already-connected cluster (e.g. [`crate::comm::tcp`]
    /// workers). The workers' `kernel` must match. Equivalent to
    /// `Service::builder(kernel).cluster(cluster).build()`.
    pub fn new(cluster: Cluster, kernel: Kernel) -> Self {
        Service::builder(kernel).cluster(cluster).build()
    }

    /// Attach an elastic recovery driver to an externally-connected
    /// service (the host must revive onto this cluster's reply queue).
    pub fn set_recovery(&mut self, recovery: Recovery) {
        self.sched.set_recovery(recovery);
    }

    /// Worker revivals performed across all jobs so far (0 for a
    /// non-elastic service).
    pub fn recoveries(&self) -> usize {
        self.sched.recoveries()
    }

    pub fn num_workers(&self) -> usize {
        self.cluster.num_workers()
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Jobs run so far (monotone id source; queries don't count).
    pub fn jobs_run(&self) -> usize {
        self.sched.jobs_run()
    }

    /// The active scheduling/queue configuration.
    pub fn config(&self) -> &ServeConfig {
        self.sched.config()
    }

    /// Lifetime stats of the whole service — every job appears under
    /// its namespaced labels, queries under `svc:`.
    pub fn stats(&self) -> &CommStats {
        &self.cluster.stats
    }

    /// The underlying cluster (advanced use; prefer the job API —
    /// exchanges made here are accounted under the ambient `svc:`
    /// namespace, invalidate no warm state, and are NOT coordinated
    /// with in-flight scheduled jobs).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Bound the per-worker column width of one transform scatter
    /// round (default 1024): larger batches stream through in
    /// `workers × cols` chunks.
    pub fn set_transform_chunk(&mut self, cols: usize) {
        self.sched.set_transform_chunk(cols);
    }

    /// Submit a job without blocking: the job queues for dispatch and
    /// the returned [`JobHandle`] resolves when it completes. Rejects
    /// (typed, never a hang) when the admission queue is at
    /// `queue_depth` — the backpressure contract the TCP front end
    /// relies on.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle, Rejected> {
        self.sched.submit(spec)
    }

    /// [`Service::submit`] that waits for queue space instead of
    /// rejecting.
    fn submit_wait(&self, spec: JobSpec) -> Result<JobOutput, CommError> {
        let handle = self.sched.submit_blocking(spec).map_err(|rej| CommError::Protocol {
            round: "scheduler".into(),
            detail: rej.to_string(),
        })?;
        handle.wait()
    }

    /// Run one disKPCA job (Alg. 4), reusing the installed embedding
    /// when this job's [`crate::embed::EmbedSpec`] matches — the
    /// reused job performs
    /// **zero** `1-embed` communication and its solution is
    /// bit-identical to a cold run.
    pub fn run_kpca(&mut self, params: &Params) -> Result<JobReport<KpcaSolution>, CommError> {
        self.run_kpca_mode(params, SamplingMode::Full)
    }

    /// [`Service::run_kpca`] with an ablated sampling stage.
    pub fn run_kpca_mode(
        &mut self,
        params: &Params,
        mode: SamplingMode,
    ) -> Result<JobReport<KpcaSolution>, CommError> {
        match self.submit_wait(JobSpec::Kpca { params: *params, mode })? {
            JobOutput::Kpca(report) => Ok(report),
            _ => unreachable!("kpca spec yields kpca output"),
        }
    }

    /// Incremental warm refit after shard appends
    /// ([`crate::coordinator::dis_kpca_refit`] as a scheduled job):
    /// refreshes every worker's store view and folds only the appended
    /// delta columns through the retained sketch state, so a refit
    /// ships **zero** `1-embed` words and delta-sized sketch work.
    /// When the warm embedding doesn't match this job's spec (cold
    /// service, intervening job with another spec) the refit degrades
    /// to a full fit and the report's `fell_back` flag is set.
    pub fn run_refit(&mut self, params: &Params) -> Result<JobReport<RefitReport>, CommError> {
        match self.submit_wait(JobSpec::Refit { params: *params })? {
            JobOutput::Refit(report) => Ok(report),
            _ => unreachable!("refit spec yields refit output"),
        }
    }

    /// Run one kernel CSS job (§5.3), with the same warm-embed reuse.
    pub fn run_css(&mut self, params: &Params) -> Result<JobReport<CssSolution>, CommError> {
        match self.submit_wait(JobSpec::Css { params: *params })? {
            JobOutput::Css(report) => Ok(report),
            _ => unreachable!("css spec yields css output"),
        }
    }

    /// Run one distributed KRR job on a representative set (no
    /// embedding rounds — warm state is untouched).
    pub fn run_krr(
        &mut self,
        y: &PointSet,
        lambda: f64,
        teacher_seed: u64,
    ) -> Result<JobReport<KrrModel>, CommError> {
        match self.submit_wait(JobSpec::Krr { y: y.clone(), lambda, teacher_seed })? {
            JobOutput::Krr(report) => Ok(report),
            _ => unreachable!("krr spec yields krr output"),
        }
    }

    /// Evaluate the installed solution (`(error, trace)`, Alg. 4's
    /// quality metric) as its own job.
    pub fn run_eval(&mut self) -> Result<JobReport<(f64, f64)>, CommError> {
        match self.submit_wait(JobSpec::Eval)? {
            JobOutput::Eval(report) => Ok(report),
            _ => unreachable!("eval spec yields eval output"),
        }
    }

    /// Run an arbitrary driver sequence as one job (e.g. fit + eval in
    /// a single accounting scope), exclusively: the body waits for
    /// every queued and running job, then owns the whole cluster. The
    /// body may install any worker state, so the warm-embed key is
    /// conservatively invalidated.
    pub fn run_job<T>(
        &mut self,
        body: impl FnOnce(&Cluster) -> Result<T, CommError>,
    ) -> Result<JobReport<T>, CommError> {
        let id = self.sched.begin_exclusive();
        let label = format!("job{id}:");
        let stats = CommStats::new();
        let lane = self.cluster.lane();
        lane.set_round_prefix(&label);
        lane.set_job_stats(Some(stats.clone()));
        let res = body(&lane);
        lane.set_job_stats(None);
        self.sched.end_exclusive();
        let output = res?;
        Ok(JobReport { job: JobCtx { id, label, stats }, output, embed_reused: false })
    }

    /// Project a batch of new points (d×n, columns are points) through
    /// the solution installed by the most recent fit job: returns the
    /// k×n coordinates LᵀΦ(batch). Scheduled like any job (a running
    /// fit finishes installing its solution first), pipelined on the
    /// wire ([`crate::coordinator::dis_project_points`]), accounted
    /// under `svc:10-transform`.
    ///
    /// An empty batch returns an empty `0×0` matrix without any
    /// communication — the solution's `k` is unknown master-side
    /// until a worker replies, so the k×0 shape cannot be produced.
    pub fn transform(&mut self, batch: &Mat) -> Result<Mat, CommError> {
        if batch.cols() == 0 {
            return Ok(Mat::zeros(0, 0));
        }
        match self.submit_wait(JobSpec::Transform { batch: batch.clone() })? {
            JobOutput::Transform(out) => Ok(out),
            _ => unreachable!("transform spec yields a matrix"),
        }
    }

    /// Quit the workers and join in-process worker threads. Dropping
    /// the service does the same; this form just makes the point
    /// explicit at call sites.
    pub fn shutdown(self) {}
}

impl Drop for Service {
    fn drop(&mut self) {
        // order matters: stop the scheduler (runners drain and join)
        // before quitting the workers, or a runner mid-exchange would
        // see its worker hang up
        self.sched.shutdown();
        self.cluster.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // replacement workers spawned by revivals exit on the same
        // Quit fan-out; join them too
        self.sched.join_recovery_host();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{clusters, partition_power_law};
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;

    fn service(s: usize) -> (Service, Data, Params) {
        service_cfg(s, ServeConfig::default())
    }

    fn service_cfg(s: usize, cfg: ServeConfig) -> (Service, Data, Params) {
        let mut rng = Rng::seed_from(11);
        let data = Data::Dense(clusters(7, 140, 3, 0.2, &mut rng));
        let shards = partition_power_law(&data, s, 5);
        let kernel = Kernel::Gauss { gamma: 0.6 };
        let params = Params {
            k: 3,
            t: 16,
            p: 32,
            n_lev: 8,
            n_adapt: 14,
            m_rff: 128,
            t2: 64,
            seed: 21,
            ..Params::default()
        };
        let svc = Service::builder(kernel)
            .shards(shards)
            .backend(Arc::new(NativeBackend::new()))
            .config(cfg)
            .build();
        (svc, data, params)
    }

    #[test]
    fn warm_job_skips_embed_round_with_identical_solution() {
        let (mut svc, _, params) = service(3);
        let cold = svc.run_kpca(&params).unwrap();
        assert!(!cold.embed_reused);
        assert!(cold.job.stats.round_words("1-embed") > 0);
        let warm = svc.run_kpca(&params).unwrap();
        assert!(warm.embed_reused);
        assert_eq!(
            warm.job.stats.round_words("1-embed"),
            0,
            "warm job must perform zero 1-embed communication"
        );
        assert!(warm.job.stats.total_words() < cold.job.stats.total_words());
        // identical params ⇒ bit-identical solution despite the skip
        assert!(cold.output.y.data() == warm.output.y.data());
        assert!(cold.output.coeffs.data() == warm.output.coeffs.data());
        // lifetime stats kept the jobs apart by namespace
        assert!(svc.stats().round_words("job0:1-embed") > 0);
        assert_eq!(svc.stats().round_words("job1:1-embed"), 0);
        assert!(svc.stats().round_words("job1:2-disLS") > 0);
    }

    #[test]
    fn different_spec_invalidates_warm_state() {
        let (mut svc, _, params) = service(2);
        svc.run_kpca(&params).unwrap();
        let other = Params { seed: params.seed + 1, ..params };
        let cold = svc.run_kpca(&other).unwrap();
        assert!(!cold.embed_reused);
        assert!(cold.job.stats.round_words("1-embed") > 0);
        // returning to the first spec re-sends the round (master-side
        // tracking is last-installed; the worker-side cache still
        // saves the recompute)
        let back = svc.run_kpca(&params).unwrap();
        assert!(!back.embed_reused);
        assert!(back.job.stats.round_words("1-embed") > 0);
    }

    #[test]
    fn refit_reuses_warm_state_and_matches_cold_fit() {
        // a permissive gate keeps the assertion about the warm path
        // independent of this dataset's exact spectrum
        let cfg = ServeConfig { variance_frac: 0.1, ..ServeConfig::default() };
        let (mut svc, _, params) = service_cfg(3, cfg);
        let cold = svc.run_kpca(&params).unwrap();
        let refit = svc.run_refit(&params).unwrap();
        assert!(refit.embed_reused);
        assert!(!refit.output.fell_back);
        // resident shards are immutable: nothing was appended
        assert_eq!(refit.output.epoch, 0);
        assert_eq!(refit.output.delta_cols, 0);
        assert_eq!(
            refit.job.stats.round_words("1-embed"),
            0,
            "refit must skip the embed broadcast entirely"
        );
        assert!(refit.job.stats.round_words("0-refresh") > 0);
        assert!(refit.job.stats.total_words() < cold.job.stats.total_words());
        // no appended data ⇒ bit-identical to the cold fit
        assert!(cold.output.y.data() == refit.output.solution.y.data());
        assert!(cold.output.coeffs.data() == refit.output.solution.coeffs.data());
    }

    #[test]
    fn refit_without_warm_state_falls_back_to_cold_fit() {
        let (mut svc, _, params) = service(2);
        let refit = svc.run_refit(&params).unwrap();
        assert!(!refit.embed_reused);
        assert!(refit.output.fell_back);
        assert!(refit.job.stats.round_words("1-embed") > 0);
        // the fallback installed real warm state: a same-spec fit now
        // reuses it and reproduces the same solution bit for bit
        let warm = svc.run_kpca(&params).unwrap();
        assert!(warm.embed_reused);
        assert!(warm.output.y.data() == refit.output.solution.y.data());
        assert!(warm.output.coeffs.data() == refit.output.solution.coeffs.data());
    }

    #[test]
    fn refit_variance_gate_forces_cold_fallback() {
        // a 3-component solution cannot hold the entire sketched
        // spectrum of 7 noisy clusters, so frac = 1.0 must trip
        let cfg = ServeConfig { variance_frac: 1.0, ..ServeConfig::default() };
        let (mut svc, _, params) = service_cfg(2, cfg);
        let cold = svc.run_kpca(&params).unwrap();
        let refit = svc.run_refit(&params).unwrap();
        assert!(refit.embed_reused, "gate fires inside the warm attempt");
        assert!(refit.output.fell_back);
        // the cold re-run is deterministic: same solution as the fit
        assert!(cold.output.y.data() == refit.output.solution.y.data());
    }

    #[test]
    fn css_and_krr_jobs_share_the_warm_cluster() {
        let (mut svc, _, params) = service(2);
        let css = svc.run_css(&params).unwrap();
        assert!(!css.embed_reused);
        // same spec: the CSS warm state carries over to a KPCA job
        let kpca = svc.run_kpca(&params).unwrap();
        assert!(kpca.embed_reused);
        let krr = svc.run_krr(&css.output.y, 1e-3, 9).unwrap();
        assert_eq!(krr.output.alpha.len(), css.output.y.len());
        assert!(!krr.embed_reused);
        assert_eq!(svc.jobs_run(), 3);
    }

    #[test]
    fn transform_matches_solution_projection() {
        let (mut svc, _, params) = service(3);
        let sol = svc.run_kpca(&params).unwrap().output;
        let mut rng = Rng::seed_from(99);
        let batch = Mat::from_fn(7, 23, |_, _| rng.normal());
        let served = svc.transform(&batch).unwrap();
        assert_eq!((served.rows(), served.cols()), (sol.k(), 23));
        // master-side projection associates differently (C = R⁻¹W is
        // pre-multiplied), so compare to tolerance, not bits
        let local = sol.project(&Data::Dense(batch.clone()));
        assert!(
            served.max_abs_diff(&local) < 1e-6,
            "served vs local diff {}",
            served.max_abs_diff(&local)
        );
        // chunked dispatch must not change results
        svc.set_transform_chunk(3);
        let chunked = svc.transform(&batch).unwrap();
        assert!(chunked.data() == served.data(), "chunked transform differs");
        // words accounted under the ambient svc: namespace
        assert!(svc.stats().round_words("svc:10-transform") > 0);
    }

    #[test]
    fn run_job_composes_drivers_in_one_scope() {
        let (mut svc, data, params) = service(2);
        let kernel = svc.kernel();
        let report = svc
            .run_job(move |cluster| {
                let sol = crate::coordinator::dis_kpca(cluster, kernel, &params)?;
                let (err, trace) = crate::coordinator::dis_eval(cluster)?;
                Ok((sol, err, trace))
            })
            .unwrap();
        let (sol, err, trace) = report.output;
        assert!(err >= 0.0 && err <= trace);
        assert!((sol.eval_error(&data) - err).abs() < 1e-6 * trace);
        for round in ["1-embed", "2-disLS", "5-disLR", "6-eval"] {
            assert!(report.job.stats.round_words(round) > 0, "{round} missing");
        }
    }

    #[test]
    fn submit_returns_a_handle_that_polls_then_resolves() {
        let (svc, _, params) = service(2);
        let mut handle = svc.submit(JobSpec::kpca(&params)).unwrap();
        // resolve via wait (try_poll may or may not see it first —
        // the job runs on its own schedule)
        let first = match handle.try_poll() {
            Some(res) => res,
            None => handle.wait(),
        };
        match first.unwrap() {
            JobOutput::Kpca(report) => {
                assert_eq!(report.job.id, 0);
                assert!(!report.embed_reused);
            }
            other => panic!("expected a kpca output, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn concurrent_service_runs_the_same_jobs() {
        let cfg = ServeConfig { max_inflight: 3, ..ServeConfig::default() };
        let (mut svc, _, params) = service_cfg(3, cfg);
        let sol = svc.run_kpca(&params).unwrap();
        let y = PointSet::Dense(sol.output.y.clone());
        // a KRR fit and two transform batches in flight together
        let krr = svc.submit(JobSpec::Krr { y, lambda: 1e-3, teacher_seed: 5 }).unwrap();
        let mut rng = Rng::seed_from(3);
        let batch = Mat::from_fn(7, 9, |_, _| rng.normal());
        let t1 = svc.submit(JobSpec::Transform { batch: batch.clone() }).unwrap();
        let t2 = svc.submit(JobSpec::Transform { batch: batch.clone() }).unwrap();
        let a = match t1.wait().unwrap() {
            JobOutput::Transform(m) => m,
            other => panic!("{other:?}"),
        };
        let b = match t2.wait().unwrap() {
            JobOutput::Transform(m) => m,
            other => panic!("{other:?}"),
        };
        assert!(a.data() == b.data(), "same batch, same solution, same answer");
        assert!(matches!(krr.wait().unwrap(), JobOutput::Krr(_)));
        svc.shutdown();
    }
}
