//! Dense row-major `f64` matrix — the coordinator's workhorse type.
//!
//! Master-side protocol objects are small (t×t, |Y|×w, sp×t), so a
//! straightforward cache-blocked implementation is plenty; the bulk
//! flops (gram blocks, feature expansions) run through XLA artifacts
//! or the native kernels in `crate::kernels`, both over `f32`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

/// High-water mark of the largest single [`Mat`] buffer allocated
/// since the last [`reset_peak_mat_elems`] (in f64 elements,
/// process-wide). Instrumentation for the out-of-core worker tests:
/// under `--chunk-rows` a worker's peak must track the chunk size, not
/// the shard size. The relaxed `fetch_max` costs a few ns per
/// allocation — invisible next to the O(rows·cols) zero-fill.
static PEAK_MAT_ELEMS: AtomicUsize = AtomicUsize::new(0);

#[inline]
fn note_mat_alloc(elems: usize) {
    PEAK_MAT_ELEMS.fetch_max(elems, Ordering::Relaxed);
}

/// Largest single matrix allocation (elements) since the last reset.
pub fn peak_mat_elems() -> usize {
    PEAK_MAT_ELEMS.load(Ordering::Relaxed)
}

/// Reset the allocation high-water mark (tests bracket a protocol
/// phase with reset/read).
pub fn reset_peak_mat_elems() {
    PEAK_MAT_ELEMS.store(0, Ordering::Relaxed);
}

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        note_mat_alloc(rows * cols);
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        note_mat_alloc(data.len());
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self[(i, j)] = x;
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self * other` via the packed register-tiled engine in
    /// [`crate::linalg::gemm`] (row-parallel on the [`crate::par`]
    /// pool for large products; small ones run the retained reference
    /// loops — bit-identical either way).
    ///
    /// # Zero-skip semantics (pinned)
    ///
    /// The axpy-style pair — `matmul` and [`Mat::matmul_at_b`] —
    /// **skips terms whose `self` factor is exactly `±0.0`**. This is
    /// observable semantics, not an optimization detail: a true GEMM
    /// computes `0.0 * b + acc`, which turns `b ∈ {∞, NaN}` into NaN
    /// and can flip the sign of an exact `-0.0` accumulator, while
    /// the skip leaves the accumulator untouched. The skip is part of
    /// these two methods' contract: every implementation (reference
    /// loops, packed microkernel) must reproduce it exactly —
    /// `tests/gemm_parity.rs` pins old-vs-new bitwise on NaN/∞
    /// inputs. The dot-based pair ([`Mat::matmul_a_bt`],
    /// [`Mat::gram_self`]) has **no** skip — `dot` multiplies every
    /// term, so a `0.0 · ∞` there is NaN, exactly as it always was;
    /// the same parity suite pins that behavior too.
    pub fn matmul(&self, other: &Mat) -> Mat {
        super::gemm::matmul(self, other)
    }

    /// `selfᵀ * other` without materializing the transpose, via the
    /// packed engine ([`crate::linalg::gemm`]): per output element the
    /// sum runs over kk in the same ascending order as the historical
    /// serial loop, with the same zero-skip (see [`Mat::matmul`]), so
    /// results are bit-identical for any tile size and thread count.
    pub fn matmul_at_b(&self, other: &Mat) -> Mat {
        super::gemm::matmul_at_b(self, other)
    }

    /// `self * otherᵀ` — register-tiled row dots
    /// ([`crate::linalg::gemm::dot4`]: four output columns per pass,
    /// per-element arithmetic identical to [`dot`]; row-parallel,
    /// bit-identical for any thread count).
    pub fn matmul_a_bt(&self, other: &Mat) -> Mat {
        super::gemm::matmul_a_bt(self, other)
    }

    /// `self * selfᵀ` exploiting symmetry (half the dot products) and
    /// cache-blocked over both rows and the long shared dimension, so
    /// each row is streamed from memory O(m/16) times instead of O(m)
    /// (§Perf #4–5: the disLR master gram A·Aᵀ with A = |Y|×s·w is the
    /// single hottest master-side op; naive row-pair dots moved 36 GB
    /// on the |Y|=357, s=100 susy run).
    pub fn gram_self(&self) -> Mat {
        let m = self.rows;
        let n = self.cols;
        let mut out = Mat::zeros(m, m);
        if m == 0 {
            return out;
        }
        const BR: usize = 16; // row-block: 2·16 rows of a k-chunk stay in L1/L2
        const BK: usize = 1024; // k-chunk: 8 KiB per row slice
        // Upper-triangle accumulation over a contiguous row range.
        // Per-entry the sum runs over kb-chunks in ascending order —
        // identical for any row partitioning, so the parallel split
        // below is bit-identical to the serial pass. The fast tier
        // swaps in the FMA dots (read once per product; chunk order
        // and partitioning unchanged, so it stays self-deterministic).
        let fast = super::simd::fast_tier_active();
        let body = |r0: usize, chunk: &mut [f64]| {
            let rows = chunk.len() / m;
            for kb in (0..n).step_by(BK) {
                let kend = (kb + BK).min(n);
                for bi in (0..rows).step_by(BR) {
                    let iend = (bi + BR).min(rows);
                    for bj in ((r0 + bi)..m).step_by(BR) {
                        let jend = (bj + BR).min(m);
                        for i in bi..iend {
                            let gi = r0 + i;
                            let ri = &self.row(gi)[kb..kend];
                            let j0 = bj.max(gi);
                            // four j's per pass over ri (gemm::dot4 —
                            // per-element arithmetic identical to dot,
                            // so per-entry sums are unchanged bitwise)
                            let mut j = j0;
                            while j + 4 <= jend {
                                let rows4 = [
                                    &self.row(j)[kb..kend],
                                    &self.row(j + 1)[kb..kend],
                                    &self.row(j + 2)[kb..kend],
                                    &self.row(j + 3)[kb..kend],
                                ];
                                let d = if fast {
                                    super::simd::dot4_fast(ri, rows4)
                                } else {
                                    super::gemm::dot4(ri, rows4)
                                };
                                for l in 0..4 {
                                    chunk[i * m + j + l] += d[l];
                                }
                                j += 4;
                            }
                            while j < jend {
                                let rj = &self.row(j)[kb..kend];
                                chunk[i * m + j] += if fast {
                                    super::simd::dot_fast(ri, rj)
                                } else {
                                    dot(ri, rj)
                                };
                                j += 1;
                            }
                        }
                    }
                }
            }
        };
        let nt = crate::par::threads();
        if nt > 1 && m.saturating_mul(m).saturating_mul(n.max(1)) / 2 >= PAR_FLOPS_MIN {
            // Row i of the upper triangle costs ~(m - i) dots: balance
            // chunks by triangle weight, not by row count.
            let nt = nt.min(m);
            let total = m * (m + 1) / 2;
            let target = (total + nt - 1) / nt;
            let mut rows_per: Vec<usize> = Vec::with_capacity(nt);
            let (mut acc, mut cnt) = (0usize, 0usize);
            for i in 0..m {
                acc += m - i;
                cnt += 1;
                if acc >= target && rows_per.len() + 1 < nt {
                    rows_per.push(cnt);
                    acc = 0;
                    cnt = 0;
                }
            }
            if cnt > 0 {
                rows_per.push(cnt);
            }
            crate::par::par_chunks_with(&mut out.data, m, &rows_per, &body);
        } else {
            body(0, &mut out.data);
        }
        // mirror the upper triangle
        for i in 0..m {
            for j in (i + 1)..m {
                out.data[j * m + i] = out.data[i * m + j];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += y;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.frob_norm_sq().sqrt()
    }

    /// Squared 2-norm of every column.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                out[j] += x * x;
            }
        }
        out
    }

    /// Concatenate many blocks side by side in one allocation —
    /// O(total) instead of the O(s²) of folding `hcat` over s blocks
    /// (§Perf #3: the disLR master stacks s=100+ worker sketches).
    pub fn hcat_all(blocks: &[Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for i in 0..rows {
            let orow = &mut out.data[i * cols..(i + 1) * cols];
            let mut at = 0;
            for b in blocks {
                assert_eq!(b.rows, rows, "hcat_all: row mismatch");
                orow[at..at + b.cols].copy_from_slice(b.row(i));
                at += b.cols;
            }
        }
        out
    }

    /// Stack many blocks vertically in one allocation (see
    /// [`Mat::hcat_all`]).
    pub fn vcat_all(blocks: &[Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows: usize = blocks.iter().map(|b| b.rows).sum();
        let mut out = Mat::zeros(rows, cols);
        let mut at = 0;
        for b in blocks {
            assert_eq!(b.cols, cols, "vcat_all: col mismatch");
            out.data[at * cols..(at + b.rows) * cols].copy_from_slice(&b.data);
            at += b.rows;
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        Mat::from_fn(self.rows, self.cols + other.cols, |i, j| {
            if j < self.cols {
                self[(i, j)]
            } else {
                other[(i, j - self.cols)]
            }
        })
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Select columns by index (with repetition allowed — sampling).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    /// Select rows by index.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(idx.len(), self.cols, |i, j| self[(idx[i], j)])
    }

    /// Leading block `[..rows, ..cols]`.
    pub fn block(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows <= self.rows && cols <= self.cols);
        Mat::from_fn(rows, cols, |i, j| self[(i, j)])
    }

    /// Max |a - b| entry difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// f32 round-trip helpers at the XLA boundary.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }
}

/// Minimum flop count before a matrix op engages the [`crate::par`]
/// pool — below this, enqueue/latch overhead beats the speedup.
pub(crate) const PAR_FLOPS_MIN: usize = 1 << 15;

/// Should an op with `out_elems` outputs and an inner dimension of
/// `inner` run on the pool? (Numerics are identical either way.)
#[inline]
pub(crate) fn parallel_worthwhile(out_elems: usize, inner: usize) -> bool {
    crate::par::threads() > 1 && out_elems.saturating_mul(inner.max(1)) >= PAR_FLOPS_MIN
}

/// Dense dot product.
#[inline]
/// Dot product with four independent accumulators — a single-chain
/// f64 reduction cannot be reassociated by the compiler, pinning it at
/// one add per cycle; splitting the chain lets it vectorize/pipeline
/// (§Perf #4: ~4× on the disLR master gram).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arange(r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |i, j| (i * c + j) as f64)
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = arange(5, 5);
        assert_eq!(a.matmul(&Mat::identity(5)), a);
        assert_eq!(Mat::identity(5).matmul(&a), a);
    }

    #[test]
    fn matmul_at_b_consistent() {
        let a = arange(7, 3);
        let b = arange(7, 4);
        let got = a.matmul_at_b(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn matmul_a_bt_consistent() {
        let a = arange(3, 6);
        let b = arange(5, 6);
        let got = a.matmul_a_bt(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let a = arange(4, 7);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn col_norms() {
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 1.0]);
        assert_eq!(a.col_norms_sq(), vec![25.0, 1.0]);
    }

    #[test]
    fn concat_and_select() {
        let a = arange(2, 2);
        let b = arange(2, 3);
        let h = a.hcat(&b);
        assert_eq!(h.cols(), 5);
        assert_eq!(h[(1, 4)], b[(1, 2)]);
        let sel = h.select_cols(&[4, 0, 4]);
        assert_eq!(sel.cols(), 3);
        assert_eq!(sel[(0, 0)], h[(0, 4)]);
        assert_eq!(sel[(0, 2)], h[(0, 4)]);
        let v = a.vcat(&arange(3, 2));
        assert_eq!(v.rows(), 5);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = arange(4, 3);
        let v = vec![1.0, -1.0, 2.0];
        let got = a.matvec(&v);
        let want = a.matmul(&Mat::from_vec(3, 1, v.clone()));
        for i in 0..4 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn f32_roundtrip() {
        let a = arange(3, 3);
        let b = Mat::from_f32(3, 3, &a.to_f32());
        assert!(a.max_abs_diff(&b) < 1e-6);
    }
}
