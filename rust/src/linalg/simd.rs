//! Explicit-SIMD fast compute tier: f64×4 kernels with runtime
//! feature dispatch, behind an opt-in process-global [`ComputeTier`].
//!
//! The exact tier (the default) is the bit-identity contract the rest
//! of the crate pins in its parity suites: single accumulation chain
//! per output element, ascending k, the `a == 0.0` zero-skip of
//! [`crate::linalg::gemm`]. The fast tier trades that contract for
//! throughput: FMA contraction, no zero-skip, branchless polynomial
//! transcendentals. It is **self-deterministic** — for a fixed binary
//! on fixed hardware, results are identical across runs and thread
//! counts, because tiling still partitions output elements and never
//! splits a reduction — but it is *not* bit-identical to the exact
//! tier, and may differ across CPUs (AVX2 vs portable fallback).
//! `tests/fast_tier_accuracy.rs` gates it with documented bounds.
//!
//! # Lane layout and dispatch
//!
//! | kernel | AVX2+FMA (f64x4) | portable fallback |
//! |---|---|---|
//! | GEMM microkernel | 4×8 tile in 8 ymm accumulators | `[[f64; 8]; 4]` loop, no zero-skip |
//! | [`dot_fast`]/[`dot4_fast`] | fused multiply-add lanes | exact [`dot`]/[`dot4`] |
//! | [`fwht_butterfly_fast`] | `_mm256_add_pd`/`_mm256_sub_pd` | pairwise a+b / a−b |
//! | [`cos_fast`]/[`exp_fast`] | autovectorized branchless poly | same code (scalar) |
//!
//! Dispatch is decided at runtime via `is_x86_feature_detected!`
//! (cached by std after the first query); [`set_force_portable`] pins
//! the portable fallback for tests. An f64×8 AVX-512 microkernel
//! exists behind `cfg(target_feature = "avx512f")` — compiled only
//! when the build itself targets AVX-512 (`-C
//! target-feature=+avx512f` / `target-cpu=native` on such a machine),
//! never in default builds, because the intrinsics' availability
//! cannot be assumed of every toolchain the crate must build on.
//!
//! # Accuracy contract (asserted by `tests/fast_tier_accuracy.rs`)
//!
//! - GEMM / dot kernels: same products and sums as the exact tier but
//!   FMA-contracted and without the zero-skip ⇒ per-element relative
//!   error vs exact ≤ a few ulp of the accumulated magnitude; the
//!   suite asserts relative Frobenius error ≤ 1e-13 on conditioned
//!   inputs. NaN/∞/-0.0 propagation may differ (no zero-skip).
//! - [`fwht_butterfly_fast`]: pairwise a+b / a−b with no
//!   reassociation — **bit-identical** to the scalar butterfly.
//! - [`cos_fast`]: Cody–Waite 3-term π/2 reduction + fdlibm minimax
//!   polynomials; |err| ≤ 5e-15 absolute for |x| ≤ 1e6 (larger
//!   arguments take the libm path).
//! - [`exp_fast`]: cephes-style 2^n·expm1 rational; relative error
//!   ≤ 1e-14 for |x| ≤ 708 (extremes and NaN take the libm path).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

use super::gemm::{dot4, MR, NR};
use super::mat::dot;

// ------------------------------------------------------------------
// Tier selection
// ------------------------------------------------------------------

/// Which compute tier the process-wide hot loops run.
///
/// `Exact` (the default) keeps the bit-identity contract of the
/// historical loops; `Fast` enables the explicit-SIMD kernels in this
/// module. Selected via `--compute-tier`, the `compute-tier` config
/// key, or `DISKPCA_COMPUTE_TIER` (strictly parsed through
/// [`crate::serve::ServeConfig::parse`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ComputeTier {
    #[default]
    Exact,
    Fast,
}

impl ComputeTier {
    /// The CLI/config/env spelling (`exact` | `fast`).
    pub fn name(self) -> &'static str {
        match self {
            ComputeTier::Exact => "exact",
            ComputeTier::Fast => "fast",
        }
    }

    /// Inverse of [`ComputeTier::name`]; `None` on anything else.
    pub fn from_name(v: &str) -> Option<Self> {
        match v.trim() {
            "exact" => Some(ComputeTier::Exact),
            "fast" => Some(ComputeTier::Fast),
            _ => None,
        }
    }
}

/// Parse a `DISKPCA_COMPUTE_TIER` value (`None` = unset ⇒ the exact
/// default). A malformed value is a hard error naming the variable —
/// the same strict convention as every other serving knob.
pub fn parse_compute_tier(raw: Option<&str>) -> Result<ComputeTier, String> {
    match raw {
        None => Ok(ComputeTier::Exact),
        Some(v) => ComputeTier::from_name(v)
            .ok_or_else(|| format!("DISKPCA_COMPUTE_TIER={v}: expected exact|fast")),
    }
}

/// Process-global tier, mirroring the `crate::par` thread-count knob:
/// 0 = Exact, 1 = Fast. Relaxed ordering suffices — hot loops read it
/// once per product, and a tier flip between products is exactly the
/// granularity the knob promises.
static TIER: AtomicU8 = AtomicU8::new(0);

/// Select the process-wide compute tier (see [`ComputeTier`]).
pub fn set_compute_tier(tier: ComputeTier) {
    TIER.store(tier as u8, Ordering::Relaxed);
}

/// The currently selected tier.
pub fn compute_tier() -> ComputeTier {
    if fast_tier_active() {
        ComputeTier::Fast
    } else {
        ComputeTier::Exact
    }
}

/// `compute_tier() == Fast` — the predicate the hot loops read once
/// per product (so a mid-product flip can never mix kernels within
/// one result).
#[inline]
pub fn fast_tier_active() -> bool {
    TIER.load(Ordering::Relaxed) != 0
}

/// Test hook: pin the portable fallback even when AVX2 is available,
/// so the accuracy suite exercises both dispatch arms on one machine.
pub fn set_force_portable(on: bool) {
    FORCE_PORTABLE.store(on, Ordering::Relaxed);
}

static FORCE_PORTABLE: AtomicBool = AtomicBool::new(false);

#[inline]
fn simd_allowed() -> bool {
    !FORCE_PORTABLE.load(Ordering::Relaxed)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    // std caches the CPUID probe behind an atomic after first use
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn avx2_available() -> bool {
    false
}

/// Which fast-tier kernel arm dispatch would pick right now — the
/// attribution note benches and tests record next to their rows.
pub fn dispatch_name() -> &'static str {
    #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
    {
        if simd_allowed() {
            return "avx512";
        }
    }
    if simd_allowed() && avx2_available() {
        "avx2"
    } else {
        "portable"
    }
}

// ------------------------------------------------------------------
// GEMM microkernel (fast tier's counterpart of gemm::microkernel)
// ------------------------------------------------------------------

/// Fast-tier `MR`×`NR` register tile: accumulates `apack · bpanel`
/// into `acc` over k in ascending order, FMA-contracted, **without**
/// the exact tier's `a == 0.0` skip. Same packing layout and tile
/// semantics as `gemm::microkernel`, so the two are drop-in
/// interchangeable inside `panel_body`.
#[inline]
pub fn microkernel_fast(k: usize, apack: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    debug_assert!(apack.len() >= k * MR);
    debug_assert!(bpanel.len() >= k * NR);
    #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
    {
        if simd_allowed() {
            unsafe { microkernel_avx512(k, apack, bpanel, acc) };
            return;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if simd_allowed() && avx2_available() {
            unsafe { microkernel_avx2(k, apack, bpanel, acc) };
            return;
        }
    }
    microkernel_portable(k, apack, bpanel, acc);
}

/// Portable 4-lane-shaped fallback: the exact microkernel's loop
/// minus the zero-skip, which is what lets LLVM autovectorize the
/// column sweep. Differs from exact only where the skip is observable
/// (`0.0 · {∞, NaN}`, `-0.0` accumulators) and by any FMA the
/// autovectorizer contracts.
fn microkernel_portable(k: usize, apack: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    for kk in 0..k {
        let a = &apack[kk * MR..kk * MR + MR];
        let b = &bpanel[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let av = a[r];
            for (ac, &bc) in acc[r].iter_mut().zip(b.iter()) {
                *ac += av * bc;
            }
        }
    }
}

/// 4×8 tile in 8 ymm accumulators (4 rows × 2 f64x4 column vectors),
/// one broadcast + two FMAs per row per k step.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_avx2(k: usize, apack: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    use core::arch::x86_64::*;
    let mut c0 = [_mm256_setzero_pd(); MR];
    let mut c1 = [_mm256_setzero_pd(); MR];
    for r in 0..MR {
        c0[r] = _mm256_loadu_pd(acc[r].as_ptr());
        c1[r] = _mm256_loadu_pd(acc[r].as_ptr().add(4));
    }
    let ap = apack.as_ptr();
    let bp = bpanel.as_ptr();
    for kk in 0..k {
        let b0 = _mm256_loadu_pd(bp.add(kk * NR));
        let b1 = _mm256_loadu_pd(bp.add(kk * NR + 4));
        for r in 0..MR {
            let a = _mm256_set1_pd(*ap.add(kk * MR + r));
            c0[r] = _mm256_fmadd_pd(a, b0, c0[r]);
            c1[r] = _mm256_fmadd_pd(a, b1, c1[r]);
        }
    }
    for r in 0..MR {
        _mm256_storeu_pd(acc[r].as_mut_ptr(), c0[r]);
        _mm256_storeu_pd(acc[r].as_mut_ptr().add(4), c1[r]);
    }
}

/// f64x8 variant: the whole `NR`-wide tile row is one zmm register.
/// Only compiled when the build itself targets AVX-512 (see module
/// docs) — default builds never see this code.
#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
#[target_feature(enable = "avx512f")]
unsafe fn microkernel_avx512(k: usize, apack: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    use core::arch::x86_64::*;
    let mut c = [_mm512_setzero_pd(); MR];
    for r in 0..MR {
        c[r] = _mm512_loadu_pd(acc[r].as_ptr());
    }
    let ap = apack.as_ptr();
    let bp = bpanel.as_ptr();
    for kk in 0..k {
        let b = _mm512_loadu_pd(bp.add(kk * NR));
        for r in 0..MR {
            c[r] = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(kk * MR + r)), b, c[r]);
        }
    }
    for r in 0..MR {
        _mm512_storeu_pd(acc[r].as_mut_ptr(), c[r]);
    }
}

// ------------------------------------------------------------------
// Dot products (fast tier's counterpart of mat::dot / gemm::dot4)
// ------------------------------------------------------------------

/// Fast-tier dot product: FMA lanes with [`dot`]'s `(s0+s1)+(s2+s3)`
/// combine and sequential tail. Portable fallback *is* the exact
/// [`dot`].
#[inline]
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if simd_allowed() && avx2_available() {
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot(a, b)
}

/// Fast-tier [`dot4`]: four FMA accumulator vectors sharing one pass
/// over `a`. Portable fallback is the exact [`dot4`].
#[inline]
pub fn dot4_fast(a: &[f64], bs: [&[f64]; 4]) -> [f64; 4] {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_allowed() && avx2_available() {
            return unsafe { dot4_avx2(a, bs) };
        }
    }
    dot4(a, bs)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let i = 4 * c;
        let av = _mm256_loadu_pd(a.as_ptr().add(i));
        let bv = _mm256_loadu_pd(b.as_ptr().add(i));
        acc = _mm256_fmadd_pd(av, bv, acc);
    }
    let mut l = [0.0f64; 4];
    _mm256_storeu_pd(l.as_mut_ptr(), acc);
    let mut s = (l[0] + l[1]) + (l[2] + l[3]);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot4_avx2(a: &[f64], bs: [&[f64]; 4]) -> [f64; 4] {
    use core::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 4;
    let mut acc = [_mm256_setzero_pd(); 4];
    for c in 0..chunks {
        let i = 4 * c;
        let av = _mm256_loadu_pd(a.as_ptr().add(i));
        for (j, accj) in acc.iter_mut().enumerate() {
            let bv = _mm256_loadu_pd(bs[j].as_ptr().add(i));
            *accj = _mm256_fmadd_pd(av, bv, *accj);
        }
    }
    let mut out = [0.0f64; 4];
    for j in 0..4 {
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), acc[j]);
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        let b = bs[j];
        for i in 4 * chunks..n {
            s += a[i] * b[i];
        }
        out[j] = s;
    }
    out
}

// ------------------------------------------------------------------
// FWHT butterfly (fast tier's inner loop of fft::fwht_inplace)
// ------------------------------------------------------------------

/// One butterfly layer over a stride-`h` block: `lo`/`hi` are the two
/// length-`h` halves; computes `(a+b, a−b)` pairwise. The arithmetic
/// is exactly the scalar butterfly's (one add, one sub per pair, no
/// reassociation), so this is **bit-identical** to the exact tier —
/// the lane layout only changes the instruction, not the math.
pub fn fwht_butterfly_fast(lo: &mut [f64], hi: &mut [f64]) {
    debug_assert_eq!(lo.len(), hi.len());
    #[cfg(target_arch = "x86_64")]
    {
        if simd_allowed() && avx2_available() && lo.len() % 4 == 0 {
            unsafe { fwht_butterfly_avx2(lo, hi) };
            return;
        }
    }
    for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
        let (x, y) = (*a, *b);
        *a = x + y;
        *b = x - y;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fwht_butterfly_avx2(lo: &mut [f64], hi: &mut [f64]) {
    use core::arch::x86_64::*;
    let n = lo.len();
    let lp = lo.as_mut_ptr();
    let hp = hi.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let a = _mm256_loadu_pd(lp.add(i));
        let b = _mm256_loadu_pd(hp.add(i));
        _mm256_storeu_pd(lp.add(i), _mm256_add_pd(a, b));
        _mm256_storeu_pd(hp.add(i), _mm256_sub_pd(a, b));
        i += 4;
    }
}

// ------------------------------------------------------------------
// Branchless transcendentals (fast tier's cos / exp maps)
// ------------------------------------------------------------------

// Cody–Waite 3-term split of π/2 (fdlibm): q·π/2 subtracted in three
// exact-ish pieces keeps the reduced argument accurate while q·PIO2_1
// stays exactly representable (q < 2^20).
const PIO2_1: f64 = 1.570_796_326_734_125_614_17;
const PIO2_2: f64 = 6.077_100_506_506_192_249_32e-11;
const PIO2_3: f64 = 2.022_266_248_795_950_631_54e-21;

// fdlibm minimax coefficients on |r| ≤ π/4:
// sin(r) ≈ r + r·z·(S1 + z·(…)), z = r².
const S1: f64 = -1.666_666_666_666_663_243_48e-1;
const S2: f64 = 8.333_333_333_322_489_461_24e-3;
const S3: f64 = -1.984_126_982_985_794_931_34e-4;
const S4: f64 = 2.755_731_370_707_006_767_89e-6;
const S5: f64 = -2.505_076_025_340_686_341_95e-8;
const S6: f64 = 1.589_690_995_211_550_102_21e-10;
// cos(r) ≈ 1 − z/2 + z²·(C1 + z·(…)).
const CC1: f64 = 4.166_666_666_666_660_190_37e-2;
const CC2: f64 = -1.388_888_888_887_410_957_49e-3;
const CC3: f64 = 2.475_756_233_595_816_708_17e-5;
const CC4: f64 = -2.755_731_435_139_066_330_35e-7;
const CC5: f64 = 2.087_572_321_298_174_827_90e-9;
const CC6: f64 = -1.135_964_755_778_819_482_65e-11;

/// Branchless `cos` for the RFF feature map: Cody–Waite reduction +
/// fdlibm sin/cos polynomials with a quadrant select, no table, no
/// data-dependent branch on the hot range — so the 4-lane loop in
/// [`map_cos_fast`] autovectorizes. |err| ≤ 5e-15 for |x| ≤ 1e6;
/// larger (or non-finite) arguments take the libm path.
#[inline]
pub fn cos_fast(x: f64) -> f64 {
    if !(x.abs() <= 1.0e6) {
        return x.cos(); // rare: huge args, ±∞, NaN
    }
    let qf = (x * std::f64::consts::FRAC_2_PI + 0.5).floor();
    let iq = qf as i64;
    let r = x - qf * PIO2_1 - qf * PIO2_2 - qf * PIO2_3;
    let z = r * r;
    let sinv = r + r * z * (S1 + z * (S2 + z * (S3 + z * (S4 + z * (S5 + z * S6)))));
    let cosv =
        1.0 - 0.5 * z + z * z * (CC1 + z * (CC2 + z * (CC3 + z * (CC4 + z * (CC5 + z * CC6)))));
    // cos(r + q·π/2) cycles {cos r, −sin r, −cos r, sin r} with q mod 4
    let v = if (iq & 1) != 0 { sinv } else { cosv };
    if ((iq + 1) & 2) != 0 {
        -v
    } else {
        v
    }
}

// cephes-style exp: x = n·ln2 + r, exp(r) from a rational in r², then
// one exact 2^n scale built from bits.
const EXP_C1: f64 = 6.931_457_519_531_25e-1;
const EXP_C2: f64 = 1.428_606_820_309_417_232_12e-6;
const EXP_P0: f64 = 1.261_771_930_748_105_908_78e-4;
const EXP_P1: f64 = 3.029_944_077_074_419_613_00e-2;
const EXP_P2: f64 = 9.999_999_999_999_999_999_10e-1;
const EXP_Q0: f64 = 3.001_985_051_386_644_550_42e-6;
const EXP_Q1: f64 = 2.524_483_403_496_841_041_92e-3;
const EXP_Q2: f64 = 2.272_655_482_081_550_287_66e-1;
const EXP_Q3: f64 = 2.000_000_000_000_000_000_05;

/// Branchless `exp` for the Gauss/Laplace gram maps. Relative error
/// ≤ 1e-14 for |x| ≤ 708; extremes (overflow/underflow territory) and
/// NaN take the libm path.
#[inline]
pub fn exp_fast(x: f64) -> f64 {
    if !(x.abs() <= 708.0) {
        return x.exp(); // rare: saturating args, ±∞, NaN
    }
    let qf = (std::f64::consts::LOG2_E * x + 0.5).floor();
    let n = qf as i64;
    let r = x - qf * EXP_C1 - qf * EXP_C2;
    let z = r * r;
    let p = r * ((EXP_P0 * z + EXP_P1) * z + EXP_P2);
    let e = p / ((((EXP_Q0 * z + EXP_Q1) * z + EXP_Q2) * z + EXP_Q3) - p);
    // 2^n exactly, via the exponent field (|n| ≤ 1022 after the clamp)
    let scale = f64::from_bits(((n + 1023) as u64) << 52);
    (1.0 + 2.0 * e) * scale
}

/// Fast-tier RFF map over one feature row: `v ← scale·cos(v + bias)`.
pub fn map_cos_fast(v: &mut [f64], bias: f64, scale: f64) {
    for x in v.iter_mut() {
        *x = scale * cos_fast(*x + bias);
    }
}

/// Fast-tier elementwise `v ← exp(v)` (the gram maps stage their
/// exponents into the output row, then exponentiate in place).
pub fn map_exp_fast(v: &mut [f64]) {
    for x in v.iter_mut() {
        *x = exp_fast(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    // NOTE: these unit tests never flip the process-global tier or the
    // force-portable hook — lib tests share one process, and the gemm/
    // parity suites in sibling modules assume the exact tier. The
    // fast-tier switches are exercised in `tests/fast_tier_accuracy.rs`
    // (its own binary, serialized around the global state).

    #[test]
    fn tier_parse_and_names_round_trip() {
        assert_eq!(parse_compute_tier(None).unwrap(), ComputeTier::Exact);
        assert_eq!(parse_compute_tier(Some("exact")).unwrap(), ComputeTier::Exact);
        assert_eq!(parse_compute_tier(Some(" fast ")).unwrap(), ComputeTier::Fast);
        for bad in ["Fast", "simd", "", "1"] {
            let err = parse_compute_tier(Some(bad)).unwrap_err();
            assert!(err.contains("DISKPCA_COMPUTE_TIER"), "{err}");
            assert!(err.contains("expected exact|fast"), "{err}");
        }
        for t in [ComputeTier::Exact, ComputeTier::Fast] {
            assert_eq!(ComputeTier::from_name(t.name()), Some(t));
        }
        assert_eq!(ComputeTier::default(), ComputeTier::Exact);
    }

    #[test]
    fn microkernel_fast_matches_exact_tile_closely() {
        let mut rng = Rng::seed_from(1);
        for k in [1usize, 2, 7, 64, 257] {
            let apack: Vec<f64> = (0..k * MR).map(|_| rng.normal()).collect();
            let bpanel: Vec<f64> = (0..k * NR).map(|_| rng.normal()).collect();
            // exact arithmetic oracle: one chain per element, ascending
            // k, no skip needed (inputs are nonzero w.p. 1)
            let mut want = [[0.0f64; NR]; MR];
            for kk in 0..k {
                for r in 0..MR {
                    for c in 0..NR {
                        want[r][c] += apack[kk * MR + r] * bpanel[kk * NR + c];
                    }
                }
            }
            let mut got = [[0.0f64; NR]; MR];
            microkernel_fast(k, &apack, &bpanel, &mut got);
            for r in 0..MR {
                for c in 0..NR {
                    let scale = (k as f64).sqrt().max(1.0);
                    assert!(
                        (got[r][c] - want[r][c]).abs() <= 1e-13 * scale,
                        "k={k} ({r},{c}): {} vs {}",
                        got[r][c],
                        want[r][c]
                    );
                }
            }
        }
    }

    #[test]
    fn microkernel_fast_accumulates_into_acc() {
        // panel_body hands the kernel a zeroed tile, but the contract
        // is accumulation — pin it so the AVX2 load/store round trip
        // can't silently become an overwrite
        let apack = vec![1.0; MR];
        let bpanel = vec![1.0; NR];
        let mut acc = [[10.0f64; NR]; MR];
        microkernel_fast(1, &apack, &bpanel, &mut acc);
        for row in &acc {
            for &v in row {
                assert_eq!(v, 11.0);
            }
        }
    }

    #[test]
    fn dot_fast_and_dot4_fast_match_exact_closely() {
        let mut rng = Rng::seed_from(2);
        for n in [0usize, 1, 3, 4, 5, 31, 128, 1001] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let bs: Vec<Vec<f64>> = (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let tol = 1e-13 * (n as f64).sqrt().max(1.0);
            let got = dot_fast(&a, &bs[0]);
            assert!((got - dot(&a, &bs[0])).abs() <= tol, "n={n}");
            let got4 = dot4_fast(&a, [&bs[0], &bs[1], &bs[2], &bs[3]]);
            let want4 = dot4(&a, [&bs[0], &bs[1], &bs[2], &bs[3]]);
            for j in 0..4 {
                assert!((got4[j] - want4[j]).abs() <= tol, "n={n} j={j}");
            }
        }
    }

    #[test]
    fn fwht_butterfly_fast_is_bit_identical_to_scalar() {
        let mut rng = Rng::seed_from(3);
        for h in [4usize, 8, 32, 256] {
            let lo0: Vec<f64> = (0..h).map(|_| rng.normal()).collect();
            let hi0: Vec<f64> = (0..h).map(|_| rng.normal()).collect();
            let (mut lo, mut hi) = (lo0.clone(), hi0.clone());
            fwht_butterfly_fast(&mut lo, &mut hi);
            for i in 0..h {
                assert_eq!(lo[i].to_bits(), (lo0[i] + hi0[i]).to_bits());
                assert_eq!(hi[i].to_bits(), (lo0[i] - hi0[i]).to_bits());
            }
        }
    }

    #[test]
    fn cos_fast_within_documented_bound() {
        let mut rng = Rng::seed_from(4);
        // quadrant edges and sign flips are the risk spots
        for mult in 0..32 {
            let x = mult as f64 * std::f64::consts::FRAC_PI_2;
            for d in [-1e-8, 0.0, 1e-8] {
                for s in [1.0, -1.0] {
                    let t = s * (x + d);
                    assert!((cos_fast(t) - t.cos()).abs() <= 5e-15, "x={t}");
                }
            }
        }
        for _ in 0..2000 {
            let x = rng.uniform(-1.0e4, 1.0e4);
            assert!((cos_fast(x) - x.cos()).abs() <= 5e-15, "x={x}");
        }
        assert!(cos_fast(f64::NAN).is_nan());
        assert!(cos_fast(f64::INFINITY).is_nan());
        // beyond the reduction range the libm path takes over exactly
        let big = 3.7e7;
        assert_eq!(cos_fast(big).to_bits(), big.cos().to_bits());
    }

    #[test]
    fn exp_fast_within_documented_bound() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..2000 {
            let x = rng.uniform(-600.0, 600.0);
            let got = exp_fast(x);
            let want = x.exp();
            assert!((got - want).abs() <= 1e-14 * want, "x={x}: {got} vs {want}");
        }
        for x in [0.0, -0.0, 1.0, -1.0, 700.0, -700.0] {
            let got = exp_fast(x);
            let want = x.exp();
            assert!((got - want).abs() <= 1e-13 * want.max(f64::MIN_POSITIVE), "x={x}");
        }
        assert!(exp_fast(f64::NAN).is_nan());
        assert_eq!(exp_fast(f64::INFINITY), f64::INFINITY);
        assert_eq!(exp_fast(f64::NEG_INFINITY), 0.0);
        assert_eq!(exp_fast(1000.0), f64::INFINITY);
        assert_eq!(exp_fast(-1000.0), 0.0);
    }

    #[test]
    fn dispatch_name_is_one_of_the_known_arms() {
        assert!(["avx2", "avx512", "portable"].contains(&dispatch_name()));
    }
}
