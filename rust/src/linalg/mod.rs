//! Dense linear algebra substrate (built from scratch — no external
//! LA crates offline). Sized for the coordinator's master-side math:
//! matrices up to a few thousand square.

mod mat;
pub mod chol;
pub mod eig;
pub mod fft;
pub mod gemm;
pub mod qr;
pub mod simd;
mod svd;

pub use chol::{chol_psd, cholesky};
pub use eig::{eigh, top_eigh};
pub use mat::{dot, peak_mat_elems, reset_peak_mat_elems, Mat};
pub use simd::{compute_tier, set_compute_tier, ComputeTier};
pub(crate) use mat::{parallel_worthwhile, PAR_FLOPS_MIN};
pub use qr::{inv_upper, qr_r_only, qr_thin, solve_lower, solve_upper, solve_upper_transpose_mat};
pub use svd::{svd, top_k_left_singular};

/// Exact statistical leverage scores of the columns of `e` (t×n,
/// t ≤ n): ℓⱼ = Eⱼᵀ(EEᵀ)⁺Eⱼ = ‖(Rᵀ)⁻¹Eⱼ‖² with RᵀR = EEᵀ. The
/// reference the sketched disLS scores are validated against.
pub fn exact_leverage_scores(e: &Mat) -> Vec<f64> {
    let gram = e.matmul_a_bt(e);
    let (r, _) = chol_psd(&gram);
    solve_upper_transpose_mat(&r, e).col_norms_sq()
}

#[cfg(test)]
mod leverage_tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn leverage_scores_sum_to_rank_and_bounded() {
        let mut rng = Rng::seed_from(1);
        let e = Mat::from_fn(5, 30, |_, _| rng.normal());
        let l = exact_leverage_scores(&e);
        // Σℓⱼ = rank(E) = 5 for generic E; 0 ≤ ℓⱼ ≤ 1
        let sum: f64 = l.iter().sum();
        assert!((sum - 5.0).abs() < 1e-6, "sum {sum}");
        for &v in &l {
            assert!((-1e-9..=1.0 + 1e-9).contains(&v), "score {v}");
        }
    }

    #[test]
    fn duplicated_heavy_column_splits_leverage() {
        // a column duplicated twice shares its leverage mass
        let mut rng = Rng::seed_from(2);
        let mut e = Mat::from_fn(3, 10, |_, _| rng.normal());
        let c = e.col(0);
        e.set_col(9, &c);
        let l = exact_leverage_scores(&e);
        assert!((l[0] - l[9]).abs() < 1e-8);
        assert!(l[0] < 1.0 - 1e-6, "duplicate can't have full leverage");
    }
}
