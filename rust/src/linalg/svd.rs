//! Singular value decomposition via one-sided Jacobi (Hestenes).
//!
//! The master SVDs `ΠT ∈ R^{|Y|×w}` in disLR (paper Alg. 3 step 2) —
//! a few-hundred-square problem, well inside one-sided Jacobi's
//! comfort zone, and Jacobi gives high relative accuracy on the small
//! singular values we truncate at.

use super::Mat;

/// Thin SVD: `A = U · diag(s) · Vᵀ` with U: m×r, s: r, V: n×r where
/// r = min(m, n); singular values sorted descending.
pub fn svd(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // SVD of Aᵀ and swap factors.
        let (u, s, v) = svd(&a.transpose());
        return (v, s, u);
    }
    // One-sided Jacobi orthogonalizes the columns of W = A·V.
    let mut w = a.clone();
    let mut v = Mat::identity(n);
    let eps = 1e-13;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p,q) gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // Singular values = column norms of W; U = W normalized.
    let mut sv: Vec<f64> = w.col_norms_sq().iter().map(|x| x.sqrt()).collect();
    let mut u = w;
    for j in 0..n {
        let s = sv[j];
        if s > 1e-300 {
            for i in 0..m {
                u[(i, j)] /= s;
            }
        }
    }
    // Rank-deficient inputs leave σ≈0 columns of W at (near-)zero —
    // unnormalizable, so U would not be orthonormal and
    // `top_k_left_singular` could hand disLR junk directions.
    // Complete the basis: replace each such column with a unit vector
    // orthogonal to every other column (Gram–Schmidt over standard
    // basis candidates, largest surviving norm wins — deterministic).
    complete_orthonormal_basis(&mut u, &sv);
    // Sort descending. total_cmp: NaN-poisoned values (degenerate
    // input) must sort deterministically instead of panicking.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| sv[j].total_cmp(&sv[i]));
    let u = u.select_cols(&order);
    let v = v.select_cols(&order);
    sv = order.iter().map(|&i| sv[i]).collect();
    (u, sv, v)
}

/// Replace the σ ≤ 1e-300 columns of `u` (m×n, m ≥ n) with unit
/// vectors orthogonal to all other columns, so U is orthonormal even
/// for rank-deficient inputs. The kept columns are untouched —
/// full-rank inputs are bit-identical to the uncompleted result.
fn complete_orthonormal_basis(u: &mut Mat, sv: &[f64]) {
    let (m, n) = (u.rows(), u.cols());
    for j in 0..n {
        if sv[j] > 1e-300 {
            continue;
        }
        // Best standard-basis candidate: project out every *other*
        // column (normalized ones and already-completed ones alike)
        // and keep the candidate with the largest residual norm.
        let mut best: Option<(f64, Vec<f64>)> = None;
        for cand in 0..m {
            let mut v = vec![0.0; m];
            v[cand] = 1.0;
            for c in 0..n {
                if c == j || (c > j && sv[c] <= 1e-300) {
                    // skip self and not-yet-completed zero columns
                    continue;
                }
                let mut dot = 0.0;
                for i in 0..m {
                    dot += u[(i, c)] * v[i];
                }
                for i in 0..m {
                    v[i] -= dot * u[(i, c)];
                }
            }
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if best.as_ref().map_or(true, |(b, _)| norm > *b) {
                best = Some((norm, v));
            }
        }
        if let Some((norm, v)) = best {
            if norm > 1e-8 {
                for i in 0..m {
                    u[(i, j)] = v[i] / norm;
                }
            }
        }
    }
}

/// Top-k left singular vectors of `A` (m×n) — what disLR's master
/// broadcasts as `W` (paper Alg. 3).
///
/// For wide inputs (n ≫ m, the disLR shape |Y|×s·w) the left vectors
/// are the top eigenvectors of the m×m Gram A·Aᵀ, which costs one
/// blocked matmul (m²n) plus a small randomized eigensolve — the
/// Gram squaring loses relative accuracy only on the *small* singular
/// values we truncate anyway. (§Perf #2: the previous Householder QR
/// of Aᵀ was 2nm² scalar flops and dominated the whole disKPCA wall
/// time at |Y| ≳ 300 — 90 s → <1 s on the susy |Y|=350 run.)
pub fn top_k_left_singular(a: &Mat, k: usize) -> (Mat, Vec<f64>) {
    let (m, n) = (a.rows(), a.cols());
    let k = k.min(m.min(n));
    if n > 2 * m {
        let g = a.gram_self(); // m×m, symmetric half the work
        let mut rng = crate::rng::Rng::seed_from(0x705f_u64 ^ ((m as u64) << 16) ^ n as u64);
        let (vals, vecs) = super::top_eigh(&g, k, &mut rng);
        let s: Vec<f64> = vals.iter().map(|&v| v.max(0.0).sqrt()).collect();
        return (vecs.block(m, k), s);
    }
    let (u, s, _) = svd(a);
    (u.block(m, k), s[..k].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
        Mat::from_fn(m, n, |_, _| rng.normal())
    }

    fn check_svd(a: &Mat, tol: f64) {
        let (u, s, v) = svd(a);
        let r = a.rows().min(a.cols());
        assert_eq!(s.len(), r);
        // reconstruct
        let mut us = u.clone();
        for j in 0..r {
            for i in 0..u.rows() {
                us[(i, j)] *= s[j];
            }
        }
        let back = us.matmul_a_bt(&v);
        assert!(back.max_abs_diff(a) < tol, "recon err {}", back.max_abs_diff(a));
        // orthonormal factors
        assert!(u.matmul_at_b(&u).max_abs_diff(&Mat::identity(r)) < tol);
        assert!(v.matmul_at_b(&v).max_abs_diff(&Mat::identity(r)) < tol);
        // descending
        for i in 1..s.len() {
            assert!(s[i - 1] >= s[i] - 1e-12);
        }
    }

    #[test]
    fn svd_reconstructs_various_shapes() {
        let mut rng = Rng::seed_from(1);
        for &(m, n) in &[(6, 6), (20, 5), (5, 20), (1, 4), (4, 1), (12, 12)] {
            let a = randmat(&mut rng, m, n);
            check_svd(&a, 1e-9);
        }
    }

    #[test]
    fn svd_known_diagonal() {
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, -2.0]);
        let (_, s, _) = svd(&a);
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn svd_low_rank() {
        let mut rng = Rng::seed_from(2);
        let b = randmat(&mut rng, 10, 2);
        let c = randmat(&mut rng, 2, 8);
        let a = b.matmul(&c); // rank 2
        let (_, s, _) = svd(&a);
        assert!(s[2] < 1e-9 * s[0]);
        check_svd(&a, 1e-9);
    }

    /// Regression: exactly-zero singular values used to leave their U
    /// columns unnormalized (zero vectors), so U was not orthonormal
    /// for rank-deficient inputs and `top_k_left_singular` could hand
    /// disLR junk directions. The basis must now be completed.
    #[test]
    fn svd_rank_deficient_u_is_orthonormal() {
        // exact zero columns survive Jacobi untouched (every rotation
        // against them is skipped), hitting the completion path
        let mut a = Mat::zeros(6, 4);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 2.0;
        let (u, s, v) = svd(&a);
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!(s[2].abs() < 1e-12 && s[3].abs() < 1e-12);
        let utu = u.matmul_at_b(&u);
        assert!(
            utu.max_abs_diff(&Mat::identity(4)) < 1e-9,
            "UᵀU err {} — zero-σ columns left unnormalized",
            utu.max_abs_diff(&Mat::identity(4))
        );
        // reconstruction unaffected: completed columns carry σ = 0
        let mut us = u.clone();
        for j in 0..4 {
            for i in 0..6 {
                us[(i, j)] *= s[j];
            }
        }
        assert!(us.matmul_a_bt(&v).max_abs_diff(&a) < 1e-9);
        // the all-zero matrix completes to an exact orthonormal basis
        let (u0, s0, _) = svd(&Mat::zeros(5, 3));
        assert!(s0.iter().all(|&x| x == 0.0));
        assert!(u0.matmul_at_b(&u0).max_abs_diff(&Mat::identity(3)) < 1e-12);
    }

    /// Regression: NaN entries used to panic the singular-value sort.
    #[test]
    fn svd_nan_input_does_not_panic() {
        let mut a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f64 * 0.5);
        a[(2, 1)] = f64::NAN;
        let (u, s, _) = svd(&a);
        assert_eq!(s.len(), 3);
        assert_eq!((u.rows(), u.cols()), (4, 3));
    }

    #[test]
    fn top_k_matches_full() {
        let mut rng = Rng::seed_from(3);
        let a = randmat(&mut rng, 8, 40);
        let (uk, sk) = top_k_left_singular(&a, 3);
        let (u, s, _) = svd(&a);
        for j in 0..3 {
            assert!((sk[j] - s[j]).abs() < 1e-8);
            // compare up to sign
            let mut dot = 0.0;
            for i in 0..8 {
                dot += uk[(i, j)] * u[(i, j)];
            }
            assert!(dot.abs() > 1.0 - 1e-8, "col {j} dot {dot}");
        }
    }

    #[test]
    fn singular_values_match_eigs_of_gram() {
        let mut rng = Rng::seed_from(4);
        let a = randmat(&mut rng, 9, 5);
        let (_, s, _) = svd(&a);
        let g = a.matmul_at_b(&a);
        // tr(AᵀA) = Σ sᵢ²
        let tr: f64 = (0..5).map(|i| g[(i, i)]).sum();
        let ssum: f64 = s.iter().map(|x| x * x).sum();
        assert!((tr - ssum).abs() < 1e-9 * tr.max(1.0));
    }
}
