//! Symmetric eigensolvers.
//!
//! Batch KPCA (the paper's small-dataset ground truth, Figs 2–3) needs
//! the spectrum of the full n×n gram matrix. Two paths:
//! - `eigh`: cyclic Jacobi — exact, O(n³) with a big constant; used
//!   for n up to ~500 and as the test oracle.
//! - `top_eigh`: randomized subspace iteration — top-k eigenpairs of a
//!   PSD matrix, O(n²·(k+p)·iters); used for the n in the thousands
//!   that our scaled "small" datasets have.

use super::{qr::qr_thin, Mat};
use crate::rng::Rng;

/// Full symmetric eigendecomposition via cyclic Jacobi.
/// Returns `(eigenvalues desc, eigenvectors as columns)`.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut m = a.clone();
    let mut v = Mat::identity(n);
    let eps = 1e-14;
    for _sweep in 0..100 {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let scale: f64 = (0..n).map(|i| m[(i, i)] * m[(i, i)]).sum::<f64>().max(1e-300);
        if off <= eps * eps * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let theta = (m[(q, q)] - m[(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // M <- JᵀMJ over rows/cols p, q
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for j in 0..n {
                    let mpj = m[(p, j)];
                    let mqj = m[(q, j)];
                    m[(p, j)] = c * mpj - s * mqj;
                    m[(q, j)] = s * mpj + c * mqj;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }
    let mut vals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp: a NaN-poisoned spectrum (degenerate input) must sort
    // deterministically instead of panicking the master mid-protocol.
    order.sort_by(|&i, &j| vals[j].total_cmp(&vals[i]));
    let vecs = v.select_cols(&order);
    vals = order.iter().map(|&i| vals[i]).collect();
    (vals, vecs)
}

/// Top-k eigenpairs of a symmetric PSD matrix by randomized subspace
/// iteration with oversampling `p` and `iters` power steps.
pub fn top_eigh(a: &Mat, k: usize, rng: &mut Rng) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let k = k.min(n);
    let p = (k + 8).min(n);
    let iters = 12;
    let g = Mat::from_fn(n, p, |_, _| rng.normal());
    let mut q = qr_thin(&a.matmul(&g)).0;
    for _ in 0..iters {
        q = qr_thin(&a.matmul(&q)).0;
    }
    // Rayleigh–Ritz on the subspace.
    let b = q.matmul_at_b(&a.matmul(&q)); // p×p
    let (vals, vecs) = eigh(&b);
    let topv = vecs.block(p, k);
    (vals[..k].to_vec(), q.matmul(&topv))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_sym(rng: &mut Rng, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut s = b.matmul_at_b(&b);
        s.scale(1.0 / n as f64);
        s
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::seed_from(1);
        let a = rand_sym(&mut rng, 12);
        let (vals, vecs) = eigh(&a);
        // A·V = V·diag(vals)
        let av = a.matmul(&vecs);
        let mut vd = vecs.clone();
        for j in 0..12 {
            for i in 0..12 {
                vd[(i, j)] *= vals[j];
            }
        }
        assert!(av.max_abs_diff(&vd) < 1e-9);
        // orthonormal
        assert!(vecs.matmul_at_b(&vecs).max_abs_diff(&Mat::identity(12)) < 1e-10);
        // trace preserved
        let tr: f64 = (0..12).map(|i| a[(i, i)]).sum();
        assert!((vals.iter().sum::<f64>() - tr).abs() < 1e-9);
    }

    #[test]
    fn eigh_known_2x2() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eigh_sorted_descending() {
        let mut rng = Rng::seed_from(2);
        let a = rand_sym(&mut rng, 9);
        let (vals, _) = eigh(&a);
        for i in 1..vals.len() {
            assert!(vals[i - 1] >= vals[i] - 1e-12);
        }
    }

    /// Regression: a NaN-poisoned input used to panic the eigenvalue
    /// sort (`partial_cmp(..).unwrap()` on a NaN); it must now return
    /// (NaN values, deterministic order) instead of killing the master.
    #[test]
    fn eigh_nan_input_does_not_panic() {
        let mut a = Mat::from_vec(3, 3, vec![2.0, 1.0, 0.0, 1.0, 2.0, 0.0, 0.0, 0.0, 1.0]);
        a[(0, 0)] = f64::NAN;
        a[(0, 1)] = f64::NAN;
        a[(1, 0)] = f64::NAN;
        let (vals, vecs) = eigh(&a);
        assert_eq!(vals.len(), 3);
        assert_eq!((vecs.rows(), vecs.cols()), (3, 3));
        assert!(vals.iter().any(|v| v.is_nan()));
    }

    #[test]
    fn top_eigh_matches_full_for_decaying_spectrum() {
        let mut rng = Rng::seed_from(3);
        let n = 40;
        // PSD with geometric spectral decay — favourable for power iters.
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let (q, _) = qr_thin(&b);
        let mut a = Mat::zeros(n, n);
        for l in 0..n {
            let lam = 2.0f64.powi(-(l as i32));
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] += lam * q[(i, l)] * q[(j, l)];
                }
            }
        }
        let (full_vals, _) = eigh(&a);
        let (top_vals, top_vecs) = top_eigh(&a, 5, &mut rng);
        for i in 0..5 {
            assert!(
                (top_vals[i] - full_vals[i]).abs() < 1e-8 * full_vals[0],
                "eig {i}: {} vs {}",
                top_vals[i],
                full_vals[i]
            );
        }
        // residual ‖A·v − λv‖ small
        let av = a.matmul(&top_vecs);
        for j in 0..5 {
            let mut res = 0.0;
            for i in 0..n {
                let r = av[(i, j)] - top_vals[j] * top_vecs[(i, j)];
                res += r * r;
            }
            assert!(res.sqrt() < 1e-7, "col {j} residual {res}");
        }
    }
}
