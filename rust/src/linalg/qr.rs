//! Householder QR + triangular solves.
//!
//! Used by the master in disLS (QR of the stacked sketched embeddings,
//! paper Alg. 1 step 2), for the implicit Gram–Schmidt / Cholesky of
//! K(Y,Y) (Appendix A), and inside the randomized eigensolver.

use super::{mat::dot, Mat, PAR_FLOPS_MIN};

/// Phase 1 of applying `H = I − β·v·vᵀ` to the trailing block of `a`
/// rooted at `(row0, col0)`: the per-column scalars `β·vᵀa[:,j]`.
///
/// Column-parallel on big panels; each column's reduction keeps the
/// serial i-ascending order, so the result is bit-identical to the
/// scalar loop for any thread count. Columns are walked four at a
/// time with one register accumulator each, so the panel is streamed
/// row-contiguously (one pass per 4 columns) instead of one strided
/// column gather per output — same per-column accumulation chain,
/// ~4× fewer row fetches.
fn householder_dots(a: &Mat, v: &[f64], row0: usize, col0: usize, beta: f64) -> Vec<f64> {
    let (m, n) = (a.rows(), a.cols());
    let ncols = n - col0;
    let compute = |j0: usize, j1: usize| -> Vec<f64> {
        let mut out = vec![0.0; j1 - j0];
        let data = a.data();
        let mut j = j0;
        while j < j1 {
            let jw = 4.min(j1 - j);
            let mut s = [0.0f64; 4];
            for i in row0..m {
                let vi = v[i - row0];
                let arow = &data[i * n + j..i * n + j + jw];
                for (c, &x) in arow.iter().enumerate() {
                    s[c] += vi * x;
                }
            }
            for c in 0..jw {
                out[j - j0 + c] = s[c] * beta;
            }
            j += jw;
        }
        out
    };
    let nt = crate::par::threads();
    if nt > 1 && ncols >= 2 && (m - row0).saturating_mul(ncols) >= PAR_FLOPS_MIN {
        let nt = nt.min(ncols);
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nt);
        let mut at = col0;
        for i in 0..nt {
            let take = (n - at + (nt - i) - 1) / (nt - i);
            ranges.push((at, at + take));
            at += take;
        }
        let cref = &compute;
        crate::par::par_join(
            ranges
                .into_iter()
                .map(|(a0, b0)| move || cref(a0, b0))
                .collect::<Vec<_>>(),
        )
        .into_iter()
        .flatten()
        .collect()
    } else {
        compute(col0, n)
    }
}

/// Phase 2: the rank-1 update `a[i,j] −= s[j−col0]·v[i−row0]` over the
/// trailing block. Row-parallel; exactly one fused multiply-subtract
/// per element, so results match the scalar loop bit-for-bit.
fn householder_update(a: &mut Mat, v: &[f64], s: &[f64], row0: usize, col0: usize) {
    let (m, n) = (a.rows(), a.cols());
    let ncols = n - col0;
    let tail = &mut a.data_mut()[row0 * n..];
    let body = |rr0: usize, chunk: &mut [f64]| {
        let rows = chunk.len() / n;
        for rr in 0..rows {
            let vi = v[rr0 + rr];
            let row = &mut chunk[rr * n + col0..rr * n + n];
            for (j, x) in row.iter_mut().enumerate() {
                *x -= s[j] * vi;
            }
        }
    };
    if crate::par::threads() > 1 && (m - row0).saturating_mul(ncols) >= PAR_FLOPS_MIN {
        crate::par::par_chunks(tail, n, body);
    } else {
        body(0, tail);
    }
}

/// Thin QR of an m×n matrix with m ≥ n: returns `(Q: m×n, R: n×n)`
/// with `A = Q·R`, Q having orthonormal columns, R upper-triangular.
///
/// Panel updates run through the two-phase Householder application
/// above, so large factorizations use the [`crate::par`] pool while
/// staying bit-identical to the single-threaded result.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "qr_thin needs m >= n, got {m}x{n}");
    let mut r = a.clone();
    // Store Householder vectors aside along with their betas.
    let mut betas = vec![0.0f64; n];
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k.
        let x: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -x[0].signum() * x.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut v = x;
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|t| t * t).sum();
        let beta = if vnorm_sq > 0.0 { 2.0 / vnorm_sq } else { 0.0 };
        // Apply H = I - beta v vᵀ to the trailing block of R.
        let s = householder_dots(&r, &v, k, k, beta);
        householder_update(&mut r, &v, &s, k, k);
        betas[k] = beta;
        vs.push(v);
    }
    // Extract R (upper n×n) and zero below.
    let rmat = Mat::from_fn(n, n, |i, j| if j >= i { r[(i, j)] } else { 0.0 });
    // Accumulate Q = H_0 H_1 … H_{n-1} · [I_n; 0].
    let mut q = Mat::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        let s = householder_dots(&q, v, k, 0, beta);
        householder_update(&mut q, v, &s, k, 0);
    }
    (q, rmat)
}

/// R-only QR (the master never needs Q in disLS): returns the n×n
/// upper-triangular factor of an m×n matrix, m ≥ n.
///
/// For tall inputs (m ≫ n — the disLS stack is (s·p)×t) this is
/// CholeskyQR: R = chol(AᵀA), identical to the Householder R up to
/// column signs and exact for the uses here (only RᵀR = AᵀA matters:
/// leverage scores are ‖(Zᵀ)⁻¹E‖², invariant to any orthogonal factor
/// on the left). Householder walks columns of a row-major matrix —
/// stride-m gathers; AᵀA is one cache-blocked pass (§Perf #7).
pub fn qr_r_only(a: &Mat) -> Mat {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n);
    if m > 4 * n {
        let gram = a.matmul_at_b(a);
        let (r, _jitter) = super::chol_psd(&gram);
        return r;
    }
    let mut r = a.clone();
    for k in 0..n {
        let x0 = r[(k, k)];
        let norm: f64 = (k..m).map(|i| r[(i, k)] * r[(i, k)]).sum::<f64>().sqrt();
        if norm == 0.0 {
            continue;
        }
        let alpha = -x0.signum() * norm;
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|t| t * t).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm_sq;
        let s = householder_dots(&r, &v, k, k, beta);
        householder_update(&mut r, &v, &s, k, k);
    }
    Mat::from_fn(n, n, |i, j| if j >= i { r[(i, j)] } else { 0.0 })
}

/// Solve `U x = b` for upper-triangular U (back substitution).
pub fn solve_upper(u: &Mat, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        for j in i + 1..n {
            x[i] -= u[(i, j)] * x[j];
        }
        let d = u[(i, i)];
        x[i] /= if d.abs() > 1e-300 { d } else { 1e-300_f64.copysign(d) };
    }
    x
}

/// Solve `L x = b` for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in 0..n {
        for j in 0..i {
            x[i] -= l[(i, j)] * x[j];
        }
        let d = l[(i, i)];
        x[i] /= if d.abs() > 1e-300 { d } else { 1e-300_f64.copysign(d) };
    }
    x
}

/// Inverse of an upper-triangular matrix.
pub fn inv_upper(u: &Mat) -> Mat {
    let n = u.rows();
    let mut inv = Mat::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        inv.set_col(j, &solve_upper(u, &e));
    }
    inv
}

/// Solve `Uᵀ X = B` column-wise — i.e. X = U⁻ᵀ B (used for
/// Π = R⁻ᵀ K(Y,A) and the (Zᵀ)⁻¹E leverage computation).
///
/// Perf note (§Perf): transposes U once so the inner reduction is a
/// contiguous prefix dot instead of a stride-n gather.
pub fn solve_upper_transpose_mat(u: &Mat, b: &Mat) -> Mat {
    let n = u.rows();
    assert_eq!(b.rows(), n);
    let l = u.transpose(); // lower-triangular, rows contiguous
    let mut out = Mat::zeros(n, b.cols());
    let mut x = vec![0.0; n];
    for c in 0..b.cols() {
        for i in 0..n {
            let lrow = l.row(i);
            let d = lrow[i];
            let s = b[(i, c)] - dot(&lrow[..i], &x[..i]);
            x[i] = s / if d.abs() > 1e-300 { d } else { 1e-300_f64.copysign(d) };
        }
        out.set_col(c, &x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
        Mat::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::seed_from(1);
        for &(m, n) in &[(5, 5), (10, 4), (30, 7), (4, 1)] {
            let a = randmat(&mut rng, m, n);
            let (q, r) = qr_thin(&a);
            let qr = q.matmul(&r);
            assert!(qr.max_abs_diff(&a) < 1e-10, "{m}x{n}");
        }
    }

    #[test]
    fn qr_q_orthonormal() {
        let mut rng = Rng::seed_from(2);
        let a = randmat(&mut rng, 20, 6);
        let (q, _) = qr_thin(&a);
        let qtq = q.matmul_at_b(&q);
        assert!(qtq.max_abs_diff(&Mat::identity(6)) < 1e-10);
    }

    #[test]
    fn qr_r_upper_triangular() {
        let mut rng = Rng::seed_from(3);
        let a = randmat(&mut rng, 8, 8);
        let (_, r) = qr_thin(&a);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn r_only_matches_full_qr_up_to_signs() {
        let mut rng = Rng::seed_from(4);
        let a = randmat(&mut rng, 12, 5);
        let (_, r1) = qr_thin(&a);
        let r2 = qr_r_only(&a);
        // RᵀR = AᵀA is sign-invariant — compare gramians.
        let g1 = r1.matmul_at_b(&r1);
        let g2 = r2.matmul_at_b(&r2);
        assert!(g1.max_abs_diff(&g2) < 1e-9);
    }

    #[test]
    fn triangular_solves() {
        let u = Mat::from_vec(3, 3, vec![2.0, 1.0, 1.0, 0.0, 3.0, 2.0, 0.0, 0.0, 4.0]);
        let x = vec![1.0, -1.0, 2.0];
        let b = u.matvec(&x);
        let got = solve_upper(&u, &b);
        for i in 0..3 {
            assert!((got[i] - x[i]).abs() < 1e-12);
        }
        let l = u.transpose();
        let bl = l.matvec(&x);
        let gotl = solve_lower(&l, &bl);
        for i in 0..3 {
            assert!((gotl[i] - x[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn inv_upper_correct() {
        let mut rng = Rng::seed_from(5);
        let a = randmat(&mut rng, 6, 6);
        let (_, r) = qr_thin(&a);
        let rinv = inv_upper(&r);
        assert!(r.matmul(&rinv).max_abs_diff(&Mat::identity(6)) < 1e-8);
    }

    #[test]
    fn solve_upper_transpose_mat_correct() {
        let mut rng = Rng::seed_from(6);
        let a = randmat(&mut rng, 7, 4);
        let (_, r) = qr_thin(&a.matmul_at_b(&a)); // SPD-ish → well-conditioned R
        let b = randmat(&mut rng, 4, 5);
        let x = solve_upper_transpose_mat(&r, &b);
        let back = r.transpose().matmul(&x);
        assert!(back.max_abs_diff(&b) < 1e-8);
    }
}
