//! Packed, register-tiled GEMM engine for the dense matmul family.
//!
//! Every round of the paper's Algorithms 1–3 bottoms out in the
//! [`Mat::matmul`]/[`Mat::matmul_at_b`]/[`Mat::matmul_a_bt`] family
//! (subspace-embedding applies, Gram inner-product blocks, projection
//! passes). This module replaces their scalar k-blocked triple loops
//! with the classic pack-and-microkernel structure: the B operand is
//! packed once into `NR`-wide column panels, each worker packs its
//! `MR`-row A tile into a k-major strip, and an unrolled `MR`×`NR`
//! microkernel keeps the whole accumulator tile in registers while it
//! sweeps k — O(`MR`·`NR`) flops per O(`MR`+`NR`) loads instead of
//! one output row of memory traffic per k step.
//!
//! # Bit-identity contract
//!
//! The engine is a *drop-in* for the historical loops — results are
//! bit-identical for every shape, tile size and thread count:
//!
//! - Each output element keeps **one** accumulation chain, traversing
//!   k in **ascending order** — exactly the order of the retained
//!   [`reference`] loops. Tiling partitions *output elements*, never a
//!   reduction, so no floating-point sum is reassociated (the same
//!   invariant the [`crate::par`] pool pins).
//! - The microkernel reproduces the reference loops' `a == 0.0` skip
//!   **exactly** (see [`Mat::matmul`] for why the skip is observable
//!   semantics, not an optimization detail).
//! - Ragged edges are handled by zero-padding the *packed* operands:
//!   padded A lanes are skipped by the `a == 0.0` test and padded B
//!   lanes land in accumulator columns that are never written back,
//!   so padding cannot perturb (or even observe) a real output.
//! - [`dot4`] serves the dot-product-associated paths
//!   ([`Mat::matmul_a_bt`], [`Mat::gram_self`], whose per-element sums
//!   use [`dot`]'s four-lane split): it computes four dots in one pass
//!   over the shared left operand with *per-element arithmetic
//!   identical to [`dot`]*.
//!
//! `tests/gemm_parity.rs` pins all of this against the [`reference`]
//! loops bit-for-bit, including NaN/∞ inputs and ragged shapes.
//!
//! When the opt-in fast tier is active ([`super::simd`]), `panel_body`
//! swaps the scalar tile for the explicit-SIMD
//! [`super::simd::microkernel_fast`] and the dot-based paths use the
//! FMA dots — same packing, same tiling, same output partitioning, so
//! the fast tier stays self-deterministic across thread counts; only
//! the per-element rounding differs (see the simd module's accuracy
//! contract). The exact tier's bit-identity contract above is
//! untouched: tier selection happens strictly outside the pinned
//! kernels.
//!
//! # Scratch arenas
//!
//! Packing buffers live in a reusable [`Scratch`] arena. The zero-
//! allocation steady state comes from two thread-local homes:
//! the *calling* thread's arena holds the shared B panels for the
//! duration of a parallel region, and each participating thread packs
//! its A tiles into its own arena. Re-entrant use (a pool thread
//! stealing a job that itself multiplies) falls back to a fresh
//! buffer instead of aliasing, so the arenas are always safe to
//! borrow. Streaming workers get per-chunk reuse for free: every
//! chunk of a [`crate::coordinator`] worker's fold runs on the same
//! thread, hence hits the same warm arena.

use std::cell::RefCell;

use super::mat::{dot, parallel_worthwhile, Mat};

/// Microkernel tile rows (A panel height).
pub const MR: usize = 4;
/// Microkernel tile columns (B panel width).
pub const NR: usize = 8;

/// Dispatch threshold on the product `m·n·k` — the number of fused
/// multiply-adds in the product, **not** FLOPs (each m·n·k step is one
/// multiply plus one add, i.e. 2·m·n·k FLOPs; the constant's old name
/// `PACKED_MIN_FLOPS` misstated this by 2×). Below it the packed
/// path's pack passes cost more than they save, so dispatch runs the
/// [`reference`] loops instead (bit-identical either way — this is
/// purely a latency knob). See [`uses_packed`] for the predicate the
/// dispatchers share.
const PACKED_MIN_MNK: usize = 1 << 13;

/// Would `matmul`/`matmul_at_b` take the packed register-tiled path
/// for an m×k · k×n product? True iff `m·n·max(k, 1)` (saturating)
/// reaches [`PACKED_MIN_MNK`]. Exposed so tests can pin the dispatch
/// boundary from both sides without timing anything.
pub fn uses_packed(m: usize, k: usize, n: usize) -> bool {
    m.saturating_mul(n).saturating_mul(k.max(1)) >= PACKED_MIN_MNK
}

/// Reusable packing arena holding the shared B column panels for one
/// product (read-only while a parallel region runs; the per-thread A
/// tile strips live in a separate thread-local). The buffer grows to
/// the high-water mark of the shapes seen and is then reused
/// allocation-free — the steady state for a streaming worker's chunk
/// loop or a bench sweep.
#[derive(Default)]
pub struct Scratch {
    bpack: Vec<f64>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

thread_local! {
    /// Caller-side arena (B panels). Held borrowed across the whole
    /// parallel region.
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
    /// Microkernel-side arena (A tile strips) — a separate cell so a
    /// caller that both packs B *and* executes its own chunk never
    /// self-conflicts.
    static APACK: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// Ceiling on what a thread-local arena keeps *between* products
/// (elements; 32 MiB of f64). Reuse exists for the steady state of
/// chunk-sized products — a one-off shard-sized B pack must not pin a
/// shard-sized buffer on the thread forever, so oversized arenas are
/// dropped on the way out (the next big product simply re-allocates,
/// i.e. the historical behavior).
const SCRATCH_RETAIN_ELEMS: usize = 4 << 20;

/// Run `f` with this thread's [`Scratch`] arena. Falls back to a
/// fresh arena if the thread-local one is already borrowed (re-entrant
/// multiply from a stolen pool job) — correctness never depends on
/// reuse. Arenas that grew past `SCRATCH_RETAIN_ELEMS` are released
/// after `f` returns.
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|c| match c.try_borrow_mut() {
        Ok(mut s) => {
            let r = f(&mut s);
            if s.bpack.capacity() > SCRATCH_RETAIN_ELEMS {
                s.bpack = Vec::new();
            }
            r
        }
        Err(_) => f(&mut Scratch::new()),
    })
}

fn with_apack<R>(f: impl FnOnce(&mut Vec<f64>) -> R) -> R {
    APACK.with(|c| match c.try_borrow_mut() {
        Ok(mut b) => {
            let r = f(&mut b);
            if b.capacity() > SCRATCH_RETAIN_ELEMS {
                *b = Vec::new();
            }
            r
        }
        Err(_) => f(&mut Vec::new()),
    })
}

// ------------------------------------------------------------------
// Packing
// ------------------------------------------------------------------

/// Pack `b` (k×n) into `NR`-wide column panels: panel `p` covers
/// columns `p·NR..`, stored k-major so the microkernel reads one
/// contiguous `NR`-slice per k step. Ragged final panel is
/// zero-padded (pad lanes are never written back).
fn pack_b(b: &Mat, bpack: &mut Vec<f64>) {
    let k = b.rows();
    let n = b.cols();
    let npad = (n + NR - 1) / NR * NR;
    bpack.clear();
    bpack.resize(npad * k, 0.0);
    for kk in 0..k {
        let brow = b.row(kk);
        let mut jp = 0;
        while jp < n {
            let jw = NR.min(n - jp);
            let at = jp * k + kk * NR;
            bpack[at..at + jw].copy_from_slice(&brow[jp..jp + jw]);
            jp += NR;
        }
    }
}

/// Pack `MR` rows of `a` starting at `row0` into a k-major strip
/// (`apack[kk·MR + r] = a[row0+r][kk]`), zero-padding rows past `mw`.
/// Used by `C = A·B` (tile = A rows).
fn pack_a_rows(a: &Mat, row0: usize, mw: usize, apack: &mut [f64]) {
    let k = a.cols();
    for r in 0..mw {
        let arow = a.row(row0 + r);
        for kk in 0..k {
            apack[kk * MR + r] = arow[kk];
        }
    }
    for r in mw..MR {
        for kk in 0..k {
            apack[kk * MR + r] = 0.0;
        }
    }
}

/// Pack `MR` *columns* of `a` starting at `col0` into the same
/// k-major strip (`apack[kk·MR + r] = a[kk][col0+r]`). Used by
/// `C = Aᵀ·B` (tile = A columns) — this is where packing pays most:
/// the strided column gather happens once per tile instead of once
/// per k sweep.
fn pack_a_cols(a: &Mat, col0: usize, mw: usize, apack: &mut [f64]) {
    let k = a.rows();
    for kk in 0..k {
        let arow = a.row(kk);
        let dst = &mut apack[kk * MR..kk * MR + MR];
        for r in 0..mw {
            dst[r] = arow[col0 + r];
        }
        for d in dst[mw..MR].iter_mut() {
            *d = 0.0;
        }
    }
}

// ------------------------------------------------------------------
// Microkernel
// ------------------------------------------------------------------

/// The register tile: `MR`×`NR` accumulators swept over k in ascending
/// order. Per output element this is a single accumulation chain with
/// the `a != 0.0` skip — the exact arithmetic of the reference loops,
/// just with the tile held in registers. The fixed-size local arrays
/// let the compiler keep `acc` in vector registers and unroll the
/// column loop.
#[inline(always)]
fn microkernel(k: usize, apack: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    debug_assert!(apack.len() >= k * MR);
    debug_assert!(bpanel.len() >= k * NR);
    for kk in 0..k {
        let a = &apack[kk * MR..kk * MR + MR];
        let b = &bpanel[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let av = a[r];
            if av != 0.0 {
                for c in 0..NR {
                    acc[r][c] += av * b[c];
                }
            }
        }
    }
}

/// Sweep one block of output rows: pack each `MR`-row A tile once,
/// then run the microkernel against every B panel, writing the live
/// `mw`×`jw` corner of each accumulator tile back to `chunk`.
///
/// `fast` selects the explicit-SIMD fast-tier microkernel
/// ([`super::simd::microkernel_fast`]) instead of the exact scalar
/// tile. The caller reads the tier **once per product** and threads it
/// here, so a mid-product tier flip can never mix kernels within one
/// result. Note: fast-tier padding stays sound without the zero-skip —
/// padded A lanes contribute `0.0 · b` to accumulator rows `mw..MR`
/// that are never written back, and padded B lanes land in columns
/// `jw..NR` that are never written back either.
fn panel_body<F: Fn(usize, usize, &mut [f64])>(
    row0: usize,
    chunk: &mut [f64],
    n: usize,
    k: usize,
    bpack: &[f64],
    pack_tile: &F,
    fast: bool,
) {
    let rows = chunk.len() / n;
    with_apack(|apack| {
        // grow-only: pack_a_rows/pack_a_cols overwrite every lane of
        // the strip (padding included), so stale contents are fine
        if apack.len() < k * MR {
            apack.resize(k * MR, 0.0);
        }
        let mut bi = 0;
        while bi < rows {
            let mw = MR.min(rows - bi);
            pack_tile(row0 + bi, mw, apack);
            let mut jp = 0;
            while jp < n {
                let jw = NR.min(n - jp);
                let bpanel = &bpack[jp * k..jp * k + k * NR];
                let mut acc = [[0.0f64; NR]; MR];
                if fast {
                    super::simd::microkernel_fast(k, apack, bpanel, &mut acc);
                } else {
                    microkernel(k, apack, bpanel, &mut acc);
                }
                for r in 0..mw {
                    let at = (bi + r) * n + jp;
                    let orow = &mut chunk[at..at + jw];
                    for (c, o) in orow.iter_mut().enumerate() {
                        *o = acc[r][c];
                    }
                }
                jp += NR;
            }
            bi += MR;
        }
    });
}

// ------------------------------------------------------------------
// Entry points (wired from `Mat`)
// ------------------------------------------------------------------

/// `a · b` — dispatch: reference loops below `PACKED_MIN_MNK`,
/// packed microkernel (row-parallel on the [`crate::par`] pool) above.
/// Both paths are bit-identical.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul dims {}x{} * {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if m == 0 || n == 0 {
        return Mat::zeros(m, n);
    }
    if !uses_packed(m, k, n) {
        return reference::matmul(a, b);
    }
    with_thread_scratch(|s| matmul_with(a, b, s))
}

/// Packed `a · b` using an explicit [`Scratch`] arena (the dispatch
/// path reuses the thread-local arena; tests and benches call this
/// directly to force the packed engine on any shape).
pub fn matmul_with(a: &Mat, b: &Mat, scratch: &mut Scratch) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    pack_b(b, &mut scratch.bpack);
    let bpack = &scratch.bpack[..];
    let fast = super::simd::fast_tier_active();
    let pack_tile = |row0: usize, mw: usize, apack: &mut [f64]| pack_a_rows(a, row0, mw, apack);
    let body =
        |row0: usize, chunk: &mut [f64]| panel_body(row0, chunk, n, k, bpack, &pack_tile, fast);
    if parallel_worthwhile(m * n, k) {
        crate::par::par_chunks(out.data_mut(), n, body);
    } else {
        body(0, out.data_mut());
    }
    out
}

/// `aᵀ · b` without materializing the transpose — see [`matmul`].
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    if m == 0 || n == 0 {
        return Mat::zeros(m, n);
    }
    if !uses_packed(m, k, n) {
        return reference::matmul_at_b(a, b);
    }
    with_thread_scratch(|s| matmul_at_b_with(a, b, s))
}

/// Packed `aᵀ · b` with an explicit [`Scratch`] arena.
pub fn matmul_at_b_with(a: &Mat, b: &Mat, scratch: &mut Scratch) -> Mat {
    assert_eq!(a.rows(), b.rows());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    pack_b(b, &mut scratch.bpack);
    let bpack = &scratch.bpack[..];
    let fast = super::simd::fast_tier_active();
    let pack_tile = |col0: usize, mw: usize, apack: &mut [f64]| pack_a_cols(a, col0, mw, apack);
    let body =
        |row0: usize, chunk: &mut [f64]| panel_body(row0, chunk, n, k, bpack, &pack_tile, fast);
    if parallel_worthwhile(m * n, k) {
        crate::par::par_chunks(out.data_mut(), n, body);
    } else {
        body(0, out.data_mut());
    }
    out
}

/// `a · bᵀ` — register-tiled over four output columns per pass via
/// [`dot4`] (per-element arithmetic identical to the reference's
/// per-element [`dot`], so bit-identity holds without a dispatch
/// threshold).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    let fast = super::simd::fast_tier_active();
    let body = |row0: usize, chunk: &mut [f64]| {
        let rows = chunk.len() / n;
        for r in 0..rows {
            let arow = a.row(row0 + r);
            let orow = &mut chunk[r * n..(r + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let rows4 = [b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3)];
                let d = if fast {
                    super::simd::dot4_fast(arow, rows4)
                } else {
                    dot4(arow, rows4)
                };
                orow[j..j + 4].copy_from_slice(&d);
                j += 4;
            }
            while j < n {
                orow[j] = if fast {
                    super::simd::dot_fast(arow, b.row(j))
                } else {
                    dot(arow, b.row(j))
                };
                j += 1;
            }
        }
    };
    if parallel_worthwhile(m * n, k) {
        crate::par::par_chunks(out.data_mut(), n, body);
    } else {
        body(0, out.data_mut());
    }
    out
}

/// Four dot products sharing one pass over `a`, each with arithmetic
/// *identical* to [`dot`] (four-lane split, `(s0+s1)+(s2+s3)` combine,
/// sequential tail). One traversal of `a` serves four right-hand
/// sides, and the 16 live lane accumulators give the compiler a full
/// register tile to vectorize.
pub fn dot4(a: &[f64], bs: [&[f64]; 4]) -> [f64; 4] {
    let n = a.len();
    debug_assert!(bs.iter().all(|b| b.len() == n));
    let chunks = n / 4;
    let mut s = [[0.0f64; 4]; 4];
    for c in 0..chunks {
        let i = 4 * c;
        let (a0, a1, a2, a3) = (a[i], a[i + 1], a[i + 2], a[i + 3]);
        for (sj, b) in s.iter_mut().zip(bs.iter()) {
            sj[0] += a0 * b[i];
            sj[1] += a1 * b[i + 1];
            sj[2] += a2 * b[i + 2];
            sj[3] += a3 * b[i + 3];
        }
    }
    let mut out = [0.0f64; 4];
    for j in 0..4 {
        let b = bs[j];
        let mut acc = (s[j][0] + s[j][1]) + (s[j][2] + s[j][3]);
        for i in 4 * chunks..n {
            acc += a[i] * b[i];
        }
        out[j] = acc;
    }
    out
}

// ------------------------------------------------------------------
// Reference loops
// ------------------------------------------------------------------

/// The pre-engine serial loops, retained verbatim: the bit-identity
/// oracle for `tests/gemm_parity.rs` and the small-matrix fast path
/// of the dispatchers above. Do not "optimize" these — their exact
/// accumulation order and `a == 0.0` skip *are* the specification.
pub mod reference {
    use super::super::mat::{dot, Mat};

    /// Serial k-blocked `a · b` (single chain per element, ascending
    /// k, `a == 0.0` terms skipped).
    pub fn matmul(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols(), b.rows());
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        const BK: usize = 64;
        let data = out.data_mut();
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for r in 0..m {
                let arow = a.row(r);
                let orow = &mut data[r * n..(r + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
        out
    }

    /// Serial `aᵀ · b` (ascending k, `a == 0.0` skip).
    pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.rows(), b.rows());
        let (k, m, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        let data = out.data_mut();
        for kk in 0..k {
            let arow = a.row(kk);
            let brow = b.row(kk);
            for r in 0..m {
                let av = arow[r];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut data[r * n..(r + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    /// Serial `a · bᵀ` (per-element [`dot`]).
    pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols(), b.cols());
        let (m, n) = (a.rows(), b.rows());
        let mut out = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        let data = out.data_mut();
        for r in 0..m {
            let arow = a.row(r);
            let orow = &mut data[r * n..(r + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, b.row(j));
            }
        }
        out
    }

    /// Serial `a · aᵀ` with the same BR/BK blocking and per-chunk
    /// [`dot`] accumulation as [`Mat::gram_self`].
    pub fn gram_self(a: &Mat) -> Mat {
        let m = a.rows();
        let n = a.cols();
        let mut out = Mat::zeros(m, m);
        if m == 0 {
            return out;
        }
        const BR: usize = 16;
        const BK: usize = 1024;
        {
            let data = out.data_mut();
            for kb in (0..n).step_by(BK) {
                let kend = (kb + BK).min(n);
                for bi in (0..m).step_by(BR) {
                    let iend = (bi + BR).min(m);
                    for bj in (bi..m).step_by(BR) {
                        let jend = (bj + BR).min(m);
                        for i in bi..iend {
                            let ri = &a.row(i)[kb..kend];
                            let j0 = bj.max(i);
                            for j in j0..jend {
                                let rj = &a.row(j)[kb..kend];
                                data[i * m + j] += dot(ri, rj);
                            }
                        }
                    }
                }
            }
            for i in 0..m {
                for j in (i + 1)..m {
                    data[j * m + i] = data[i * m + j];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits_equal(a: &Mat, b: &Mat) -> bool {
        (a.rows(), a.cols()) == (b.rows(), b.cols())
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn testmat(seed: u64, m: usize, n: usize) -> Mat {
        let mut rng = crate::rng::Rng::seed_from(seed);
        Mat::from_fn(m, n, |i, j| {
            if (i * 7 + j) % 3 == 0 {
                0.0
            } else {
                rng.normal()
            }
        })
    }

    #[test]
    fn packed_matches_reference_on_mixed_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 9), (17, 33, 26)] {
            let a = testmat(1, m, k);
            let b = testmat(2, k, n);
            let got = with_thread_scratch(|s| matmul_with(&a, &b, s));
            let want = reference::matmul(&a, &b);
            assert!(bits_equal(&got, &want), "matmul {m}x{k}x{n}");
            let at = testmat(3, k, m);
            let got = with_thread_scratch(|s| matmul_at_b_with(&at, &b, s));
            let want = reference::matmul_at_b(&at, &b);
            assert!(bits_equal(&got, &want), "matmul_at_b {m}x{k}x{n}");
        }
    }

    #[test]
    fn dot4_matches_dot_bitwise() {
        for n in [0usize, 1, 3, 4, 5, 8, 31, 64, 129] {
            let a = testmat(5, 1, n);
            let b = testmat(6, 4, n);
            let got = dot4(a.row(0), [b.row(0), b.row(1), b.row(2), b.row(3)]);
            for j in 0..4 {
                let want = dot(a.row(0), b.row(j));
                assert_eq!(got[j].to_bits(), want.to_bits(), "n={n} j={j}");
            }
        }
    }

    #[test]
    fn dispatch_boundary_is_pinned_on_both_sides() {
        // PACKED_MIN_MNK counts m·n·k fused multiply-adds (not FLOPs);
        // 8192 = 16·32·16 sits exactly on the packed side
        assert_eq!(PACKED_MIN_MNK, 16 * 32 * 16);
        assert!(uses_packed(16, 32, 16), "at the threshold: packed");
        assert!(!uses_packed(16, 31, 16), "one k below: reference");
        assert!(uses_packed(1, 8192, 1));
        assert!(!uses_packed(1, 8191, 1));
        // k = 0 counts as 1, so degenerate inner dims still dispatch
        assert!(!uses_packed(64, 0, 64));
        assert!(uses_packed(128, 0, 64));
        // saturating product: absurd shapes must not overflow
        assert!(uses_packed(usize::MAX, usize::MAX, usize::MAX));
        // either side of the boundary, results are bit-identical
        for &(m, k, n) in &[(16usize, 32usize, 16usize), (16, 31, 16)] {
            let a = testmat(11, m, k);
            let b = testmat(12, k, n);
            assert!(bits_equal(&matmul(&a, &b), &reference::matmul(&a, &b)), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn empty_dims_are_fine() {
        for &(m, k, n) in &[(0, 4, 4), (4, 0, 4), (4, 4, 0), (0, 0, 0)] {
            let a = Mat::zeros(m, k);
            let b = Mat::zeros(k, n);
            let got = matmul(&a, &b);
            assert_eq!((got.rows(), got.cols()), (m, n));
            let got = with_thread_scratch(|s| matmul_with(&a, &b, s));
            assert_eq!((got.rows(), got.cols()), (m, n));
            assert!(got.data().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn scratch_reuse_is_observationally_pure() {
        // same scratch across differently-shaped products — stale
        // panel contents must never leak into a later result
        let mut s = Scratch::new();
        let a1 = testmat(7, 11, 29);
        let b1 = testmat(8, 29, 13);
        let r1 = matmul_with(&a1, &b1, &mut s);
        let a2 = testmat(9, 5, 6);
        let b2 = testmat(10, 6, 3);
        let r2 = matmul_with(&a2, &b2, &mut s);
        assert!(bits_equal(&r1, &reference::matmul(&a1, &b1)));
        assert!(bits_equal(&r2, &reference::matmul(&a2, &b2)));
    }
}
