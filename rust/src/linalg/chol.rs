//! Cholesky factorization of SPD matrices.
//!
//! K(Y,Y) = RᵀR drives the implicit Gram–Schmidt basis of span φ(Y)
//! (paper Appendix A). Kernel gram matrices are only *semi*-definite,
//! so `chol_psd` adds an adaptive jitter on the diagonal — standard
//! practice, and equivalent to intersecting with a negligible ridge.

use super::Mat;

/// Plain Cholesky: `A = L·Lᵀ`, error if not positive definite.
///
/// The update term Σₖ L[i,k]·L[j,k] is a dot over two *contiguous*
/// row prefixes, so it runs through the 4-accumulator [`super::dot`]
/// (§Perf #6: every worker factorizes K(Y,Y) in disLR, |Y|³/6 flops
/// each — the scalar chain was the last hot spot in `project`).
pub fn cholesky(a: &Mat) -> Result<Mat, String> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = {
                let ri = &l.row(i)[..j];
                let rj = &l.row(j)[..j];
                a[(i, j)] - super::dot(ri, rj)
            };
            if i == j {
                if s <= 0.0 {
                    return Err(format!("not SPD at pivot {i}: {s}"));
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Cholesky with adaptive jitter for PSD (gram) matrices.
/// Returns upper-triangular `R` with `A + jitter·I = RᵀR`, plus the
/// jitter actually used.
pub fn chol_psd(a: &Mat) -> (Mat, f64) {
    let n = a.rows();
    let scale = (0..n).map(|i| a[(i, i)].abs()).fold(0.0, f64::max).max(1e-12);
    let mut jitter = 0.0;
    loop {
        let mut aj = a.clone();
        if jitter > 0.0 {
            for i in 0..n {
                aj[(i, i)] += jitter;
            }
        }
        match cholesky(&aj) {
            Ok(l) => return (l.transpose(), jitter),
            Err(_) => {
                jitter = if jitter == 0.0 { scale * 1e-10 } else { jitter * 10.0 };
                assert!(
                    jitter < scale,
                    "cholesky failed even with jitter {jitter} (scale {scale})"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::seed_from(1);
        let b = Mat::from_fn(8, 5, |_, _| rng.normal());
        let a = b.matmul_at_b(&b); // 5x5 SPD (whp)
        let l = cholesky(&a).unwrap();
        let back = l.matmul(&l.transpose());
        assert!(back.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig: 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn chol_psd_handles_singular() {
        // rank-1 gram matrix
        let v = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let a = v.matmul_at_b(&v);
        let (r, jitter) = chol_psd(&a);
        assert!(jitter > 0.0);
        let back = r.matmul_at_b(&r); // RᵀR... r is upper: A ≈ RᵀR
        assert!(back.max_abs_diff(&a) < 1e-5);
    }

    #[test]
    fn chol_psd_upper_triangular() {
        let mut rng = Rng::seed_from(2);
        let b = Mat::from_fn(10, 6, |_, _| rng.normal());
        let a = b.matmul_at_b(&b);
        let (r, _) = chol_psd(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
        assert!(r.matmul_at_b(&r).max_abs_diff(&a) < 1e-8);
    }
}
