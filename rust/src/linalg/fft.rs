//! Radix-2 complex FFT + fast Walsh–Hadamard transform.
//!
//! The FFT backs the native TensorSketch (polynomial-kernel subspace
//! embedding, paper Lemma 4); the FWHT backs the SRHT sketch option.
//! Both are iterative in-place transforms over power-of-two lengths —
//! sketch dims are chosen as powers of two throughout.

use std::f64::consts::PI;

/// Complex number as (re, im) — no external num crate.
pub type C = (f64, f64);

#[inline]
fn cmul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// In-place iterative radix-2 FFT. `inverse` applies conjugate twiddles
/// and the 1/n scale.
pub fn fft_inplace(x: &mut [C], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = x[i + k];
                let v = cmul(x[i + k + len / 2], w);
                x[i + k] = (u.0 + v.0, u.1 + v.1);
                x[i + k + len / 2] = (u.0 - v.0, u.1 - v.1);
                w = cmul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f64;
        for v in x.iter_mut() {
            v.0 *= inv;
            v.1 *= inv;
        }
    }
}

/// FFT of a real vector → complex spectrum.
pub fn fft_real(x: &[f64]) -> Vec<C> {
    let mut c: Vec<C> = x.iter().map(|&v| (v, 0.0)).collect();
    fft_inplace(&mut c, false);
    c
}

/// Inverse FFT, returning only the real parts.
pub fn ifft_to_real(mut x: Vec<C>) -> Vec<f64> {
    fft_inplace(&mut x, true);
    x.into_iter().map(|c| c.0).collect()
}

/// In-place fast Walsh–Hadamard transform (unnormalized).
///
/// Under the fast tier ([`crate::linalg::simd`]) layers with stride
/// h ≥ 4 run the lane-wise butterfly
/// ([`crate::linalg::simd::fwht_butterfly_fast`]); since a butterfly
/// is pairwise `a+b` / `a−b` with no reassociation, the fast tier is
/// **bit-identical** to the scalar loop here — the one fast-tier
/// kernel with a stronger-than-bound guarantee. The tier is read once
/// per transform.
pub fn fwht_inplace(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two());
    let fast = crate::linalg::simd::fast_tier_active();
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            if fast && h >= 4 {
                // h is a power of two ≥ 4, so both halves are whole
                // multiples of the 4-wide lanes
                let (lo, hi) = x[i..i + 2 * h].split_at_mut(h);
                crate::linalg::simd::fwht_butterfly_fast(lo, hi);
            } else {
                for k in i..i + h {
                    let a = x[k];
                    let b = x[k + h];
                    x[k] = a + b;
                    x[k + h] = a - b;
                }
            }
            i += 2 * h;
        }
        h <<= 1;
    }
}

/// Circular convolution via FFT — the TensorSketch combine step.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let fa = fft_real(a);
    let fb = fft_real(b);
    let prod: Vec<C> = fa.iter().zip(&fb).map(|(&x, &y)| cmul(x, y)).collect();
    ifft_to_real(prod)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn fft_roundtrip() {
        let mut rng = Rng::seed_from(1);
        for &n in &[1usize, 2, 8, 64, 256] {
            let orig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let spec = fft_real(&orig);
            let back = ifft_to_real(spec);
            for i in 0..n {
                assert!((orig[i] - back[i]).abs() < 1e-10, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn fft_known_impulse() {
        // FFT of impulse = all ones
        let spec = fft_real(&[1.0, 0.0, 0.0, 0.0]);
        for &(re, im) in &spec {
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_parseval() {
        let mut rng = Rng::seed_from(2);
        let x: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
        let spec = fft_real(&x);
        let time_e: f64 = x.iter().map(|v| v * v).sum();
        let freq_e: f64 = spec.iter().map(|c| c.0 * c.0 + c.1 * c.1).sum::<f64>() / 128.0;
        assert!((time_e - freq_e).abs() < 1e-9 * time_e);
    }

    #[test]
    fn convolution_matches_naive() {
        let mut rng = Rng::seed_from(3);
        let n = 16;
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let fast = circular_convolve(&a, &b);
        for k in 0..n {
            let mut naive = 0.0;
            for i in 0..n {
                naive += a[i] * b[(k + n - i) % n];
            }
            assert!((fast[k] - naive).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn fwht_involution_up_to_scale() {
        let mut rng = Rng::seed_from(4);
        let n = 64;
        let orig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for i in 0..n {
            assert!((x[i] / n as f64 - orig[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn fwht_orthogonality() {
        // H·Hᵀ = n·I — check via two basis vectors.
        let n = 8;
        let mut e0 = vec![0.0; n];
        e0[0] = 1.0;
        fwht_inplace(&mut e0);
        let mut e1 = vec![0.0; n];
        e1[1] = 1.0;
        fwht_inplace(&mut e1);
        let dot: f64 = e0.iter().zip(&e1).map(|(a, b)| a * b).sum();
        assert!(dot.abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn fft_rejects_non_power_of_two() {
        fft_real(&[1.0, 2.0, 3.0]);
    }
}
