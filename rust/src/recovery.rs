//! Elastic fault tolerance: revive dead worker slots, replay the
//! installed round state, and retry the interrupted unit — with the
//! master-side state frozen in a codec-serializable [`Checkpoint`].
//!
//! The protocol layer detects a dead worker (hang-up marker or send
//! failure) and poisons the cluster; this module owns everything that
//! happens next:
//!
//! 1. **Revive** — a [`ReviveHost`] builds a fresh link + worker for
//!    the dead slot. The slot keeps its index, shard assignment and
//!    per-slot seeds ([`crate::comm::Cluster::install_link`]), which is
//!    what makes the replayed run bit-identical to a fault-free one.
//! 2. **Settle** — stale replies from the aborted round are drained
//!    ([`crate::comm::Cluster::settle`]); markers surfacing while
//!    draining name more dead slots, which are revived too.
//! 3. **Replay** — the checkpoint's installed state (embed spec,
//!    leverage sketch, sampled points, final coefficients or a whole
//!    solution) is re-shipped to each revived slot under the
//!    `"recover"` round label.
//! 4. **Retry** — the interrupted unit re-runs from its start against
//!    the restored cluster, after rewinding the word counters to the
//!    unit-entry snapshot so the final per-round tables are
//!    bit-identical to a fault-free run.
//!
//! Workers are deterministic state machines, so replay + retry
//! reproduces the fault-free bytes exactly; `tests/fault_injection.rs`
//! asserts this for a kill at every round boundary on both transports.
//!
//! # Degraded mode
//!
//! When no replacement can be built — [`ReviveHost::revive`] fails,
//! `--rejoin-wait` expires, or the recovery budget runs out — the
//! failure surfaces as the typed [`CommError::Degraded`]. With
//! rebalancing enabled ([`Recovery::set_rebalance`], `--rebalance`),
//! [`with_rebalance`] catches it: a survivor *adopts* the dead slot's
//! shard (`ReqAdoptShard`, appending the columns after its own), the
//! cluster view shrinks to the renumbered survivors
//! ([`crate::comm::Cluster::shrink`]), and the whole job re-runs cold —
//! the checkpointed state and per-slot seeds were computed against the
//! old worker count, so a unit-level retry cannot be bit-faithful, but
//! a cold re-run over the post-rebalance shard assignment is
//! bit-identical to a fresh fit over that assignment by construction.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use crate::comm::codec::{CodecError, Reader, Writer};
use crate::comm::request as rq;
use crate::comm::{memory, tcp, Cluster, CommError, PointSet, ReplyEvent, WorkerLink};
use crate::coordinator::css::CssSolution;
use crate::coordinator::krr::KrrModel;
use crate::coordinator::worker::Worker;
use crate::coordinator::{master, KpcaSolution, Params, SamplingMode};
use crate::data::Data;
use crate::embed::EmbedSpec;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::Backend;

/// Bump on any change to the checkpoint wire layout.
/// v2 added the data `epoch` the checkpointed fit covers.
pub const CHECKPOINT_VERSION: u8 = 2;

/// Master-side round state a revived worker must be brought up to
/// date with. Fields fill in as the driver's units complete; replay
/// ships whichever are present, in protocol order.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    /// Label of the unit most recently entered — context for error
    /// reports and checkpoint files, not used by replay itself.
    pub round: String,
    /// Protocol seed (per-slot replay seeds derive from it exactly
    /// like the live `5-disLR` scatter).
    pub seed: u64,
    /// Data epoch the checkpointed fit covers (0 until a refit reports
    /// one) — lets a resumed serve master refit against the right
    /// delta instead of re-folding everything.
    pub epoch: u64,
    /// Embedding spec installed by `1-embed` (or warm reuse).
    pub spec: Option<EmbedSpec>,
    /// Leverage sketch factor broadcast by `2-disLS` — replaying it
    /// restores a worker's sampling scores.
    pub z: Option<Mat>,
    /// Representative points sampled by rounds 3–4.
    pub y: Option<PointSet>,
    /// Projection-sketch width used by `5-disLR` (0 = auto `|Y|`).
    pub w_cols: usize,
    /// Final coefficient matrix broadcast by `5-disLR`.
    pub final_w: Option<Mat>,
    /// A directly-installed solution (`dis_set_solution`), which
    /// supersedes `final_w` state when replayed after it.
    pub solution: Option<(PointSet, Mat)>,
}

impl Checkpoint {
    pub fn new(seed: u64) -> Self {
        Self { round: "init".into(), seed, ..Self::default() }
    }

    /// Serialize with the protocol codec (self-delimiting, versioned).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u8(CHECKPOINT_VERSION);
        w.str(&self.round);
        w.u64(self.seed);
        w.u64(self.epoch);
        w.u64(self.w_cols as u64);
        match &self.spec {
            None => w.u8(0),
            Some(s) => {
                w.u8(1);
                w.spec(s);
            }
        }
        match &self.z {
            None => w.u8(0),
            Some(m) => {
                w.u8(1);
                w.mat(m);
            }
        }
        match &self.y {
            None => w.u8(0),
            Some(p) => {
                w.u8(1);
                w.points(p);
            }
        }
        match &self.final_w {
            None => w.u8(0),
            Some(m) => {
                w.u8(1);
                w.mat(m);
            }
        }
        match &self.solution {
            None => w.u8(0),
            Some((p, c)) => {
                w.u8(1);
                w.points(p);
                w.mat(c);
            }
        }
        w.finish()
    }

    /// Decode an [`Checkpoint::encode`] buffer. Rejects truncation,
    /// unknown versions/flags, and trailing bytes — a checkpoint is a
    /// whole file, so "extra bytes after a valid prefix" means
    /// corruption, not success.
    pub fn decode(buf: &[u8]) -> Result<Self, CodecError> {
        let mut r = Reader::new(buf);
        let version = r.u8()?;
        if version != CHECKPOINT_VERSION {
            return Err(CodecError::BadTag(version));
        }
        let round = r.str()?;
        let seed = r.u64()?;
        let epoch = r.u64()?;
        let w_cols = r.u64()? as usize;
        fn flag(r: &mut Reader<'_>) -> Result<bool, CodecError> {
            match r.u8()? {
                0 => Ok(false),
                1 => Ok(true),
                t => Err(CodecError::BadTag(t)),
            }
        }
        let spec = if flag(&mut r)? { Some(r.spec()?) } else { None };
        let z = if flag(&mut r)? { Some(r.mat()?) } else { None };
        let y = if flag(&mut r)? { Some(r.points()?) } else { None };
        let final_w = if flag(&mut r)? { Some(r.mat()?) } else { None };
        let solution = if flag(&mut r)? { Some((r.points()?, r.mat()?)) } else { None };
        if !r.finished() {
            return Err(CodecError::Trailing);
        }
        Ok(Self { round, seed, epoch, w_cols, spec, z, y, final_w, solution })
    }
}

/// What a survivor must ingest to adopt a dead slot's shard during a
/// degraded-mode rebalance ([`Recovery::rebalance`]).
#[derive(Clone, Debug)]
pub enum AdoptSource {
    /// The columns stay on disk: only the `.dkps` path (+ chunk size)
    /// crosses the wire and the adopter opens the store itself.
    Path { path: String, chunk_rows: usize },
    /// The columns cross the wire inline.
    Columns { pts: PointSet, chunk_rows: usize },
}

/// Supplies replacement workers for dead slots. A *revival* must serve
/// the *same shard* as the original — revival preserves slot identity.
/// When no replacement can be built, degraded-mode rebalancing
/// ([`Recovery::rebalance`]) instead asks the host for the dead slot's
/// shard ([`ReviveHost::adopt_source`]) and ships it to a survivor.
pub trait ReviveHost: Send {
    /// Build a fresh link + worker for `slot`, wired into the
    /// cluster's shared reply queue.
    fn revive(&mut self, slot: usize) -> Result<Box<dyn WorkerLink>, String>;

    /// When the replacement starts blank (e.g. a rejoining process),
    /// the on-disk shard path (+ chunk size) to re-ship via
    /// `ReqLoadShard` before any state replay. In-process hosts that
    /// construct the replacement around the shard return `None`.
    fn shard_path(&self, _slot: usize) -> Option<(String, usize)> {
        None
    }

    /// The dead slot's shard, for a survivor to adopt. The default
    /// derives it from [`ReviveHost::shard_path`] (disk-backed hosts
    /// get rebalancing for free); hosts holding shards in memory
    /// override it to ship the columns inline.
    fn adopt_source(&mut self, slot: usize) -> Result<AdoptSource, String> {
        match self.shard_path(slot) {
            Some((path, chunk_rows)) => Ok(AdoptSource::Path { path, chunk_rows }),
            None => Err(format!("host cannot supply slot {slot}'s shard for adoption")),
        }
    }

    /// Bookkeeping hook after a completed rebalance: `dead` has been
    /// removed from the cluster (survivors above it shifted down one)
    /// and the pre-shrink slot `adopter` now serves the combined
    /// shard. Hosts that record per-slot shards must mirror that, so a
    /// *later* revival of the adopter rebuilds the combined shard.
    fn rebalanced(&mut self, _dead: usize, _adopter: usize) {}

    /// Join any replacement workers this host spawned. Called after
    /// the cluster has quit its links; default is a no-op.
    fn join(&mut self) {}
}

/// Which wire the replacement worker talks over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Memory,
    Tcp,
}

/// In-process [`ReviveHost`]: keeps a copy of every slot's shard and
/// spawns a replacement [`Worker`] thread on demand, over the
/// in-memory channel transport or a fresh loopback TCP socket.
pub struct LocalHost {
    shards: Vec<Data>,
    kernel: Kernel,
    backend: Arc<dyn Backend>,
    chunk_rows: usize,
    embed_cache_bytes: Option<usize>,
    reply_tx: Sender<ReplyEvent>,
    transport: Transport,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl LocalHost {
    pub fn new(
        shards: Vec<Data>,
        kernel: Kernel,
        backend: Arc<dyn Backend>,
        chunk_rows: usize,
        reply_tx: Sender<ReplyEvent>,
        transport: Transport,
    ) -> Self {
        Self {
            shards,
            kernel,
            backend,
            chunk_rows,
            embed_cache_bytes: None,
            reply_tx,
            transport,
            handles: Vec::new(),
        }
    }

    /// Give replacements a non-default embed-cache budget (serve mode).
    pub fn set_embed_cache_bytes(&mut self, bytes: usize) {
        self.embed_cache_bytes = Some(bytes);
    }

    /// Join every replacement worker thread spawned so far. Call after
    /// the cluster has shut down (replacements exit on `Quit` / link
    /// close); joining earlier deadlocks.
    pub fn join(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Concatenate two shards column-wise, adopted columns after own —
/// exactly the combined shard the worker-side `AdoptShard` handler
/// builds, so a host's bookkeeping and the live adopter agree bit for
/// bit (a later revival of the adopter must rebuild the same shard).
fn concat_shards(own: &Data, adopted: &Data) -> Data {
    let combined = PointSet::concat(&[shard_points(own), shard_points(adopted)]);
    match combined {
        PointSet::Dense(m) => Data::Dense(m),
        PointSet::Sparse { d, cols } => Data::Sparse(crate::sparse::Csc::from_columns(d, cols)),
    }
}

/// All of a shard's columns in its natural [`PointSet`] encoding.
fn shard_points(shard: &Data) -> PointSet {
    let src = crate::data::ShardSource::Resident(shard.clone());
    let idx: Vec<usize> = (0..src.len()).collect();
    src.point_set(&idx)
}

impl ReviveHost for LocalHost {
    fn revive(&mut self, slot: usize) -> Result<Box<dyn WorkerLink>, String> {
        let shard = self
            .shards
            .get(slot)
            .cloned()
            .ok_or_else(|| format!("no shard recorded for slot {slot}"))?;
        let mut worker =
            Worker::new_chunked(shard, self.kernel, Arc::clone(&self.backend), self.chunk_rows);
        if let Some(bytes) = self.embed_cache_bytes {
            worker.set_embed_cache_budget(bytes);
        }
        match self.transport {
            Transport::Memory => {
                let (link, ep) = memory::pair(slot, self.reply_tx.clone());
                self.handles.push(std::thread::spawn(move || worker.run(ep)));
                Ok(link)
            }
            Transport::Tcp => {
                let (link, ep) = tcp::revive_pair(slot, self.reply_tx.clone())
                    .map_err(|e| format!("tcp revive: {e}"))?;
                self.handles.push(std::thread::spawn(move || worker.run(ep)));
                Ok(link)
            }
        }
    }

    fn adopt_source(&mut self, slot: usize) -> Result<AdoptSource, String> {
        let shard = self
            .shards
            .get(slot)
            .ok_or_else(|| format!("no shard recorded for slot {slot}"))?;
        Ok(AdoptSource::Columns { pts: shard_points(shard), chunk_rows: self.chunk_rows })
    }

    fn rebalanced(&mut self, dead: usize, adopter: usize) {
        let adopted = self.shards.remove(dead);
        // the adopter was named pre-shrink; removing `dead` shifted
        // every higher slot down one
        let at = if adopter > dead { adopter - 1 } else { adopter };
        self.shards[at] = concat_shards(&self.shards[at], &adopted);
    }

    fn join(&mut self) {
        LocalHost::join(self);
    }
}

/// The recovery driver: wraps each unit of protocol rounds in a
/// snapshot → attempt → revive-and-replay → restore → retry loop.
///
/// A *unit* is the smallest span of rounds that can be re-run from its
/// own start against installed worker state (e.g. `2-disLS` alone, or
/// `3-levSample` + `4-adaptive` together — adaptive sampling feeds on
/// residuals the unit itself establishes).
pub struct Recovery {
    host: Box<dyn ReviveHost>,
    /// The master-side state replayed to revived slots; elastic
    /// drivers fill it in as units complete.
    pub checkpoint: Checkpoint,
    grace: Duration,
    max_recoveries: usize,
    recoveries: usize,
    /// Degraded-mode policy: may [`with_rebalance`] adopt a
    /// permanently lost slot's shard onto a survivor? Off by default —
    /// shrinking changes which solution is computed, so it is an
    /// explicit opt-in (`--rebalance`).
    rebalance: bool,
    /// Words the last [`Recovery::rebalance`] spent shipping the shard
    /// (captured before the job re-run's stats rewind erases them).
    last_rebalance_words: usize,
}

impl Recovery {
    pub fn new(host: Box<dyn ReviveHost>) -> Self {
        Self {
            host,
            checkpoint: Checkpoint::new(0),
            grace: Duration::from_millis(100),
            max_recoveries: 16,
            recoveries: 0,
            rebalance: false,
            last_rebalance_words: 0,
        }
    }

    /// How long [`crate::comm::Cluster::settle`] waits for the reply
    /// queue to go quiet during a recovery (default 100ms).
    pub fn set_grace(&mut self, grace: Duration) {
        self.grace = grace;
    }

    /// Cap on revive attempts per driver run (default 16) — a slot
    /// that dies deterministically on replay must not loop forever.
    pub fn set_max_recoveries(&mut self, max: usize) {
        self.max_recoveries = max;
    }

    /// Revive attempts performed so far.
    pub fn recoveries(&self) -> usize {
        self.recoveries
    }

    /// Allow degraded-mode shard rebalancing (see [`with_rebalance`]).
    pub fn set_rebalance(&mut self, on: bool) {
        self.rebalance = on;
    }

    /// Whether degraded-mode rebalancing is allowed.
    pub fn rebalance_enabled(&self) -> bool {
        self.rebalance
    }

    /// Words the most recent [`Recovery::rebalance`] spent shipping
    /// the adopted shard (0 if none has run). The job re-run's stats
    /// rewind erases this traffic from the tables, so benches read it
    /// here.
    pub fn last_rebalance_words(&self) -> usize {
        self.last_rebalance_words
    }

    /// Join replacement workers the host spawned (after cluster quit).
    pub fn join_host(&mut self) {
        self.host.join();
    }

    /// Run one unit with recovery: on a dead-worker error
    /// ([`CommError::Worker`] / [`CommError::Link`]), revive + replay,
    /// rewind the stats to the unit-entry snapshot, and retry the unit
    /// from its start. Timeouts are *not* recovered — a hung-but-alive
    /// worker replaced under a live socket would race its replacement;
    /// the comm layer's reply-timeout retry budget
    /// ([`crate::comm::Cluster::set_comm_retries`]) is the
    /// slow-but-alive path.
    pub fn unit<T>(
        &mut self,
        cluster: &Cluster,
        label: &str,
        mut attempt: impl FnMut(&Cluster) -> Result<T, CommError>,
    ) -> Result<T, CommError> {
        self.checkpoint.round = label.to_string();
        let snap = cluster.stats.snapshot();
        let job = cluster.job_stats();
        let job_snap = job.as_ref().map(|j| j.snapshot());
        loop {
            match attempt(cluster) {
                Ok(v) => return Ok(v),
                Err(err) => {
                    let first_dead = match &err {
                        CommError::Worker { worker, .. } | CommError::Link { worker, .. } => {
                            *worker
                        }
                        _ => return Err(err),
                    };
                    if self.recoveries >= self.max_recoveries {
                        return Err(CommError::Degraded {
                            slot: first_dead,
                            round: err.round().to_string(),
                            detail: format!(
                                "recovery budget exhausted ({} revives): {err}",
                                self.max_recoveries
                            ),
                        });
                    }
                    self.recover(cluster, first_dead)?;
                    cluster.stats.restore(&snap);
                    if let (Some(j), Some(js)) = (&job, &job_snap) {
                        j.restore(js);
                    }
                }
            }
        }
    }

    /// Revive dead slots *without* replaying any round-checkpoint
    /// state: the concurrent serve scheduler reruns failed jobs from
    /// scratch on a quiesced cluster, so the only state a revived
    /// worker needs is its shard (rejoined processes reload it inside
    /// [`Recovery::recover`]'s replay via `LoadShard`). Resets the
    /// checkpoint to empty first so the replay ships nothing but the
    /// shard. Sequential serving keeps using [`Recovery::unit`], which
    /// replays mid-job state and stays bit-identical.
    pub fn revive_only(&mut self, cluster: &Cluster, first_dead: usize) -> Result<(), CommError> {
        self.checkpoint = Checkpoint::new(0);
        self.recover(cluster, first_dead)
    }

    /// Revive `first_dead` plus every further slot whose hang-up
    /// marker surfaces while settling, then replay the checkpoint
    /// state onto each revived slot.
    fn recover(&mut self, cluster: &Cluster, first_dead: usize) -> Result<(), CommError> {
        let mut revived: Vec<usize> = Vec::new();
        let mut dead: Vec<usize> = vec![first_dead];
        while let Some(slot) = dead.pop() {
            if revived.contains(&slot) {
                continue;
            }
            self.recoveries += 1;
            if self.recoveries > self.max_recoveries {
                return Err(CommError::Degraded {
                    slot,
                    round: "recover".into(),
                    detail: format!("recovery budget exhausted ({} revives)", self.max_recoveries),
                });
            }
            cluster.quit_worker(slot);
            // A revive that fails — the host cannot build a
            // replacement, or (in the launcher) no process rejoined
            // within `--rejoin-wait` — means the slot is *permanently*
            // lost: the typed Degraded error is what
            // [`with_rebalance`] catches and `--rebalance` heals.
            let link = self.host.revive(slot).map_err(|detail| CommError::Degraded {
                slot,
                round: "recover".into(),
                detail: format!("revive failed: {detail}"),
            })?;
            cluster.install_link(slot, link);
            revived.push(slot);
            for w in cluster.settle(self.grace) {
                if !revived.contains(&w) && !dead.contains(&w) {
                    dead.push(w);
                }
            }
        }
        cluster.unpoison();
        for &slot in &revived {
            self.replay(cluster, slot)?;
        }
        Ok(())
    }

    /// Bring one revived slot up to the checkpoint, in protocol order:
    /// shard (rejoined processes only) → embed → scores → projection
    /// basis → final coefficients → installed solution. Replies are
    /// discarded (state effects are what matter) and the whole
    /// exchange is erased by the unit's stats rewind.
    fn replay(&mut self, cluster: &Cluster, slot: usize) -> Result<(), CommError> {
        cluster.set_round("recover");
        if let Some((path, chunk_rows)) = self.host.shard_path(slot) {
            cluster.call(slot, rq::LoadShard { path, chunk_rows })?;
        }
        let cp = self.checkpoint.clone();
        if let Some(spec) = &cp.spec {
            cluster.call(slot, rq::Embed { spec: *spec })?;
        }
        if let Some(z) = &cp.z {
            let _mass: f64 = cluster.call(slot, rq::Scores { z: z.clone() })?;
        }
        if let Some(y) = &cp.y {
            // same per-slot seed derivation as the live 5-disLR scatter
            let _r: Mat = cluster.call(
                slot,
                rq::ProjectSketch {
                    pts: y.clone(),
                    w: cp.w_cols,
                    seed: cp.seed ^ (0xd15 + slot as u64),
                },
            )?;
            if let Some(w_mat) = &cp.final_w {
                cluster.call(slot, rq::Final { coeffs: w_mat.clone() })?;
            }
        }
        if let Some((pts, coeffs)) = &cp.solution {
            cluster.call(slot, rq::SetSolution { pts: pts.clone(), coeffs: coeffs.clone() })?;
        }
        Ok(())
    }

    /// Degraded-mode rebalance: adopt the permanently lost slot
    /// `dead`'s shard onto the next live survivor and shrink the
    /// cluster view to s−1 workers. The caller (normally
    /// [`with_rebalance`]) must then re-run its whole job cold — the
    /// checkpoint and every index-derived per-slot seed were computed
    /// against the old worker count. Resets the recovery budget: a
    /// completed rebalance is forward progress, not another attempt at
    /// the same failure.
    pub fn rebalance(&mut self, cluster: &Cluster, dead: usize) -> Result<(), CommError> {
        let degraded = |detail: String| CommError::Degraded {
            slot: dead,
            round: "rebalance".into(),
            detail,
        };
        let s = cluster.num_workers();
        if s <= 1 {
            return Err(degraded("no survivors to adopt the shard".into()));
        }
        // Quiesce: make sure the dead slot's wire is silent and learn
        // of any other slot that died in the same incident.
        cluster.quit_worker(dead);
        let mut dead_now = cluster.settle(self.grace);
        if !dead_now.contains(&dead) {
            dead_now.push(dead);
        }
        // First live survivor after the dead slot, wrapping — a
        // deterministic choice, so reruns and the survivor-layout
        // baseline agree on who holds the combined shard.
        let adopter = (1..s)
            .map(|off| (dead + off) % s)
            .find(|w| !dead_now.contains(w))
            .ok_or_else(|| degraded("every worker is dead; nothing can adopt".into()))?;
        let source = self
            .host
            .adopt_source(dead)
            .map_err(|detail| degraded(format!("host cannot supply the shard: {detail}")))?;
        cluster.unpoison();
        cluster.set_round("rebalance");
        let before = cluster.stats.total_words();
        let (path, pts, chunk_rows) = match source {
            AdoptSource::Path { path, chunk_rows } => {
                (path, PointSet::Sparse { d: 0, cols: Vec::new() }, chunk_rows)
            }
            AdoptSource::Columns { pts, chunk_rows } => (String::new(), pts, chunk_rows),
        };
        cluster.call(adopter, rq::AdoptShard { path, pts, chunk_rows })?;
        self.last_rebalance_words = cluster.stats.total_words() - before;
        cluster.shrink(dead);
        self.host.rebalanced(dead, adopter);
        self.recoveries = 0;
        Ok(())
    }
}

/// Run a whole job (fit + eval together) with degraded-mode healing:
/// when the job fails [`CommError::Degraded`] and the recovery allows
/// rebalancing, adopt the lost shard onto a survivor
/// ([`Recovery::rebalance`]), rewind the word counters to the entry
/// snapshot, and re-run the job cold on the shrunk cluster. The body
/// must be restartable from scratch (every `*_recovering` driver is:
/// each resets the checkpoint on entry) and should pass
/// `embed_installed = false` — the adopter's rebuilt worker holds no
/// spec. On success the solution *and* the per-round word tables are
/// bit-identical to a fresh cold fit over the post-rebalance shard
/// assignment. With rebalancing off (the default) the Degraded error
/// propagates unchanged — the documented exit-code-4 path.
pub fn with_rebalance<T>(
    cluster: &Cluster,
    recovery: &mut Recovery,
    mut body: impl FnMut(&Cluster, &mut Recovery) -> Result<T, CommError>,
) -> Result<T, CommError> {
    let snap = cluster.stats.snapshot();
    let job = cluster.job_stats();
    let job_snap = job.as_ref().map(|j| j.snapshot());
    loop {
        match body(cluster, recovery) {
            Err(CommError::Degraded { slot, .. }) if recovery.rebalance_enabled() => {
                recovery.rebalance(cluster, slot)?;
                cluster.stats.restore(&snap);
                if let (Some(j), Some(js)) = (&job, &job_snap) {
                    j.restore(js);
                }
            }
            other => return other,
        }
    }
}

/// [`crate::coordinator::dis_kpca_mode`] with elastic recovery: every
/// unit runs under [`Recovery::unit`], and the checkpoint fills in as
/// units complete so later faults replay earlier rounds' state.
pub fn dis_kpca_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
    kernel: Kernel,
    params: &Params,
    mode: SamplingMode,
    embed_installed: bool,
) -> Result<KpcaSolution, CommError> {
    params.apply_threads();
    // fresh job, fresh checkpoint: stale state from a previous job
    // must not be replayed over this job's rounds
    recovery.checkpoint = Checkpoint::new(params.seed);
    let y = if mode == SamplingMode::AdaptiveOnly {
        recovery.unit(cluster, "repSample", |c| master::rep_sample_mode(c, params, &[], mode))?
    } else {
        let spec = master::embed_spec_for(kernel, params);
        if !embed_installed {
            recovery.unit(cluster, "1-embed", |c| master::dis_embed(c, spec))?;
        }
        recovery.checkpoint.spec = Some(spec);
        let (masses, z) =
            recovery.unit(cluster, "2-disLS", |c| master::dis_leverage_scores_z(c, params))?;
        recovery.checkpoint.z = Some(z);
        recovery.unit(cluster, "repSample", |c| master::rep_sample_mode(c, params, &masses, mode))?
    };
    let (sol, w_mat, w_cols) =
        recovery.unit(cluster, "5-disLR", |c| master::dis_low_rank_w(c, kernel, params, &y))?;
    recovery.checkpoint.y = Some(y);
    recovery.checkpoint.w_cols = w_cols;
    recovery.checkpoint.final_w = Some(w_mat);
    Ok(sol)
}

/// [`crate::coordinator::dis_kpca_refit`] with elastic recovery.
///
/// The refit's delta rounds feed on worker-retained state (embed spec
/// + disLS sketch accumulator), so mid-refit faults cannot be
/// replayed round by round — a revived slot has neither. Instead the
/// checkpoint is pre-seeded with the embed spec (replayed onto every
/// revived slot, restoring the one piece of state the delta sketch
/// *requires*) and the **whole refit retries as a single unit**: the
/// revived worker's missing accumulator just means a full-fold
/// `ReqDeltaSketch` — bit-identical reply, no savings on that slot —
/// while surviving workers keep their delta-sized work. On success
/// the checkpoint carries the refreshed solution and its epoch, so
/// later units (eval, project) can replay it.
pub fn dis_kpca_refit_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
    kernel: Kernel,
    params: &Params,
    installed_epoch: u64,
    variance_frac: f64,
) -> Result<master::RefitReport, CommError> {
    params.apply_threads();
    recovery.checkpoint = Checkpoint::new(params.seed);
    recovery.checkpoint.epoch = installed_epoch;
    recovery.checkpoint.spec = Some(master::embed_spec_for(kernel, params));
    let report = recovery.unit(cluster, "refit", |c| {
        master::dis_kpca_refit(c, kernel, params, installed_epoch, variance_frac)
    })?;
    recovery.checkpoint.epoch = report.epoch;
    recovery.checkpoint.solution = Some((
        PointSet::Dense(report.solution.y.clone()),
        report.solution.coeffs.clone(),
    ));
    Ok(report)
}

/// [`crate::coordinator::dis_css`] with elastic recovery.
pub fn dis_css_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
    kernel: Kernel,
    params: &Params,
    embed_installed: bool,
) -> Result<CssSolution, CommError> {
    params.apply_threads();
    recovery.checkpoint = Checkpoint::new(params.seed);
    let spec = master::embed_spec_for(kernel, params);
    if !embed_installed {
        recovery.unit(cluster, "1-embed", |c| master::dis_embed(c, spec))?;
    }
    recovery.checkpoint.spec = Some(spec);
    let (masses, z) =
        recovery.unit(cluster, "2-disLS", |c| master::dis_leverage_scores_z(c, params))?;
    recovery.checkpoint.z = Some(z);
    let y = recovery.unit(cluster, "repSample", |c| master::rep_sample(c, params, &masses))?;
    let (residual, trace) = recovery.unit(cluster, "7-cssCert", |c| {
        let sx = c.session("7-cssCert");
        let residual: f64 = sx.broadcast(rq::Residuals { pts: y.clone() })?.into_iter().sum();
        let trace: f64 = sx.broadcast(rq::EvalTrace)?.into_iter().sum();
        Ok((residual, trace))
    })?;
    Ok(CssSolution { y, residual, trace })
}

/// [`crate::coordinator::dis_krr`] with elastic recovery (one unit —
/// both KRR rounds re-run together; they share per-request state).
pub fn dis_krr_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
    kernel: Kernel,
    y: &PointSet,
    lambda: f64,
    teacher_seed: u64,
) -> Result<KrrModel, CommError> {
    recovery.unit(cluster, "9-krr", |c| {
        crate::coordinator::dis_krr(c, kernel, y, lambda, teacher_seed)
    })
}

/// [`crate::coordinator::dis_eval`] with elastic recovery. Requires
/// the checkpoint to hold the solution state (`final_w` path or
/// `solution`) so a revived slot can answer.
pub fn dis_eval_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
) -> Result<(f64, f64), CommError> {
    recovery.unit(cluster, "6-eval", master::dis_eval)
}

/// [`crate::coordinator::dis_set_solution`] with elastic recovery;
/// notes the solution in the checkpoint so later faults re-install it.
pub fn dis_set_solution_recovering(
    cluster: &Cluster,
    recovery: &mut Recovery,
    sol: &KpcaSolution,
) -> Result<(), CommError> {
    recovery.unit(cluster, "5-setSolution", |c| master::dis_set_solution(c, sol))?;
    recovery.checkpoint.solution =
        Some((PointSet::Dense(sol.y.clone()), sol.coeffs.clone()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_checkpoint() -> Checkpoint {
        Checkpoint {
            round: "5-disLR".into(),
            seed: 42,
            epoch: 3,
            w_cols: 7,
            spec: Some(EmbedSpec {
                kernel: Kernel::Gauss { gamma: 0.5 },
                m: 64,
                t2: 32,
                t: 8,
                seed: 42 ^ 0xeb3d,
            }),
            z: Some(Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64 * 0.25)),
            y: Some(PointSet::Dense(Mat::from_fn(2, 5, |i, j| i as f64 - j as f64))),
            final_w: Some(Mat::from_fn(4, 2, |i, j| (i + j) as f64)),
            solution: Some((
                PointSet::Dense(Mat::from_fn(2, 3, |i, j| (i * j) as f64)),
                Mat::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 }),
            )),
        }
    }

    #[test]
    fn checkpoint_roundtrips_all_fields() {
        for cp in [Checkpoint::new(9), full_checkpoint()] {
            let bytes = cp.encode();
            let back = Checkpoint::decode(&bytes).unwrap();
            assert_eq!(back.encode(), bytes);
            assert_eq!(back.round, cp.round);
            assert_eq!(back.seed, cp.seed);
            assert_eq!(back.epoch, cp.epoch);
            assert_eq!(back.w_cols, cp.w_cols);
            assert_eq!(back.spec, cp.spec);
            assert_eq!(back.z.is_some(), cp.z.is_some());
            assert_eq!(back.solution.is_some(), cp.solution.is_some());
        }
    }

    #[test]
    fn checkpoint_rejects_bad_version_and_trailing_bytes() {
        let mut bytes = full_checkpoint().encode();
        bytes[0] = CHECKPOINT_VERSION + 1;
        assert!(Checkpoint::decode(&bytes).is_err());
        bytes[0] = CHECKPOINT_VERSION;
        bytes.push(0);
        assert!(matches!(Checkpoint::decode(&bytes), Err(CodecError::Trailing)));
    }

    #[test]
    fn checkpoint_rejects_every_truncation() {
        let bytes = full_checkpoint().encode();
        for len in 0..bytes.len() {
            assert!(
                Checkpoint::decode(&bytes[..len]).is_err(),
                "prefix of {len} bytes decoded"
            );
        }
    }
}
