//! TensorSketch (Pham–Pagh; Avron–Nguyen–Woodruff NIPS'14).
//!
//! Sketches the degree-q polynomial feature map x^{⊗q} in
//! O(q·(nnz(x) + t log t)) per point via q independent CountSketches
//! combined by circular convolution in the Fourier domain — the
//! polynomial-kernel subspace embedding of the paper's Lemma 4.

use crate::linalg::fft::{fft_inplace, C};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sparse::Csc;

use super::CountSketch;

#[derive(Clone, Debug)]
pub struct TensorSketch {
    t: usize,
    components: Vec<CountSketch>,
}

impl TensorSketch {
    /// Degree-q TensorSketch over input dim `m`, output dim `t`
    /// (must be a power of two for the radix-2 FFT).
    pub fn new(m: usize, t: usize, q: usize, rng: &mut Rng) -> Self {
        assert!(q >= 1);
        assert!(t.is_power_of_two(), "tensorsketch dim {t} not a power of 2");
        let components = (0..q).map(|_| CountSketch::new(m, t, rng)).collect();
        Self { t, components }
    }

    pub fn degree(&self) -> usize {
        self.components.len()
    }

    pub fn output_dim(&self) -> usize {
        self.t
    }

    /// The per-component (h, s) tables — shipped to the XLA embed_poly
    /// artifact so native and AOT paths share one sketch.
    pub fn tables(&self) -> Vec<(&[u32], &[f64])> {
        self.components.iter().map(|c| c.tables()).collect()
    }

    /// Sketch one point, writing the result to `out`: each component
    /// CountSketch is produced by `fill` into a reused buffer, its
    /// spectrum is folded into the running product in **ascending
    /// component order** (the historical per-point order, so results
    /// are bit-identical — the scratch only removes the per-point
    /// allocations, which used to dominate chunked column batches).
    fn sketch_into(
        &self,
        mut fill: impl FnMut(&CountSketch, &mut [f64]),
        scratch: &mut TsScratch,
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), self.t);
        for (ci, cs) in self.components.iter().enumerate() {
            fill(cs, &mut scratch.comp);
            for (f, &v) in scratch.freq.iter_mut().zip(scratch.comp.iter()) {
                *f = (v, 0.0);
            }
            fft_inplace(&mut scratch.freq, false);
            if ci == 0 {
                scratch.acc.copy_from_slice(&scratch.freq);
            } else {
                for (x, &y) in scratch.acc.iter_mut().zip(scratch.freq.iter()) {
                    *x = (x.0 * y.0 - x.1 * y.1, x.0 * y.1 + x.1 * y.0);
                }
            }
        }
        scratch.freq.copy_from_slice(&scratch.acc);
        fft_inplace(&mut scratch.freq, true);
        for (o, c) in out.iter_mut().zip(scratch.freq.iter()) {
            *o = c.0;
        }
    }

    /// Sketch one dense vector.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut scratch = TsScratch::new(self.t);
        let mut out = vec![0.0; self.t];
        self.sketch_into(|cs, buf| cs.apply_vec_into(x, buf), &mut scratch, &mut out);
        out
    }

    /// Sketch a sparse column in O(q·(nnz + t log t)).
    pub fn apply_sparse_col(&self, a: &Csc, j: usize) -> Vec<f64> {
        let mut scratch = TsScratch::new(self.t);
        let mut out = vec![0.0; self.t];
        self.sketch_into(
            |cs, buf| cs.apply_sparse_vec_into(a.col_iter(j), buf),
            &mut scratch,
            &mut out,
        );
        out
    }

    /// Sketch every column of a dense `m×n` matrix → `t×n`.
    ///
    /// Columns are independent (q CountSketches + FFT convolution per
    /// point), so the [`crate::par`] pool splits them into blocks —
    /// per-column results are bit-identical for any thread count. One
    /// [`TsScratch`] (complex FFT buffers + component buffer + column
    /// gather) serves a whole block: zero allocations per point.
    pub fn apply_feature_axis(&self, a: &Mat) -> Mat {
        let n = a.cols();
        let m = a.rows();
        let build = |j0: usize, j1: usize| {
            let mut blk = Mat::zeros(self.t, j1 - j0);
            let mut scratch = TsScratch::new(self.t);
            let mut col = vec![0.0; m];
            let mut out = vec![0.0; self.t];
            for j in j0..j1 {
                for (i, c) in col.iter_mut().enumerate() {
                    *c = a[(i, j)];
                }
                self.sketch_into(|cs, buf| cs.apply_vec_into(&col, buf), &mut scratch, &mut out);
                blk.set_col(j - j0, &out);
            }
            blk
        };
        // per-column cost ~ q·(m + t·log t): skip the pool when tiny
        if crate::linalg::parallel_worthwhile(n, self.t * 32) {
            crate::par::par_col_blocks(self.t, n, build)
        } else {
            build(0, n)
        }
    }

    /// Sketch every column of a CSC matrix → `t×n` (column-parallel,
    /// O(q·(nnz + t log t)) per column, scratch reused per block).
    pub fn apply_feature_axis_sparse(&self, a: &Csc) -> Mat {
        let n = a.cols();
        let build = |j0: usize, j1: usize| {
            let mut blk = Mat::zeros(self.t, j1 - j0);
            let mut scratch = TsScratch::new(self.t);
            let mut out = vec![0.0; self.t];
            for j in j0..j1 {
                self.sketch_into(
                    |cs, buf| cs.apply_sparse_vec_into(a.col_iter(j), buf),
                    &mut scratch,
                    &mut out,
                );
                blk.set_col(j - j0, &out);
            }
            blk
        };
        if crate::linalg::parallel_worthwhile(n, self.t * 32) {
            crate::par::par_col_blocks(self.t, n, build)
        } else {
            build(0, n)
        }
    }
}

/// Reusable per-batch buffers for the FFT-domain combine — one
/// allocation set per column block instead of several per point.
struct TsScratch {
    /// one component's CountSketch output (t).
    comp: Vec<f64>,
    /// scratch spectrum: forward FFT of `comp`, then the inverse-FFT
    /// workspace (t).
    freq: Vec<C>,
    /// running product spectrum across components (t).
    acc: Vec<C>,
}

impl TsScratch {
    fn new(t: usize) -> Self {
        Self { comp: vec![0.0; t], freq: vec![(0.0, 0.0); t], acc: vec![(0.0, 0.0); t] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn unbiased_for_polynomial_kernel() {
        // E[⟨TS(x), TS(y)⟩] = ⟨x,y⟩^q
        let mut rng = Rng::seed_from(1);
        let m = 8;
        let x: Vec<f64> = (0..m).map(|_| rng.normal() * 0.5).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal() * 0.5).collect();
        for q in [2usize, 3] {
            let exact = dot(&x, &y).powi(q as i32);
            let trials = 500;
            let mut acc = 0.0;
            for _ in 0..trials {
                let ts = TensorSketch::new(m, 64, q, &mut rng);
                acc += dot(&ts.apply_vec(&x), &ts.apply_vec(&y));
            }
            acc /= trials as f64;
            assert!((acc - exact).abs() < 0.25, "q={q}: {acc} vs {exact}");
        }
    }

    #[test]
    fn degree1_equals_countsketch() {
        let mut rng = Rng::seed_from(2);
        let m = 16;
        let ts = TensorSketch::new(m, 8, 1, &mut rng);
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let got = ts.apply_vec(&x);
        let want = ts.components[0].apply_vec(&x);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn sparse_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let (m, n) = (20, 6);
        let dense = Mat::from_fn(m, n, |i, j| if (i * 3 + j) % 4 == 0 { rng.normal() } else { 0.0 });
        let sparse = Csc::from_dense(&dense);
        let ts = TensorSketch::new(m, 16, 3, &mut rng);
        let a = ts.apply_feature_axis(&dense);
        let b = ts.apply_feature_axis_sparse(&sparse);
        assert!(a.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn matches_python_oracle_semantics() {
        // Same construction as compile/kernels/ref.py::tensorsketch —
        // fixed tables, compare a hand-computed q=2 case. With
        // h0 = h1 = [0,0], s = [1,1], TS(x) = conv(cs, cs) where
        // cs = [x0+x1, 0, …] ⇒ TS = [(x0+x1)², 0, …].
        let c0 = CountSketch::from_tables(4, vec![0, 0], vec![1.0, 1.0]);
        let c1 = c0.clone();
        let ts = TensorSketch { t: 4, components: vec![c0, c1] };
        let out = ts.apply_vec(&[2.0, 3.0]);
        assert!((out[0] - 25.0).abs() < 1e-9, "{out:?}");
        for v in &out[1..] {
            assert!(v.abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two_dim() {
        let mut rng = Rng::seed_from(4);
        TensorSketch::new(8, 12, 2, &mut rng);
    }
}
