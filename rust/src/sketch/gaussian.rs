//! Dense Gaussian (JLT) sketch.

use crate::linalg::Mat;
use crate::rng::Rng;

/// `G ∈ R^{t×m}` with iid N(0, 1/t) entries — an ε-subspace embedding
/// at t = O(k/ε²) and the final stage of the Lemma-4 concatenation
/// (CountSketch/TensorSketch down to O(k²), Gaussian down to O(k/ε)).
#[derive(Clone, Debug)]
pub struct GaussianSketch {
    mat: Mat, // t×m
}

impl GaussianSketch {
    pub fn new(m: usize, t: usize, rng: &mut Rng) -> Self {
        let scale = 1.0 / (t as f64).sqrt();
        Self {
            mat: Mat::from_fn(t, m, |_, _| rng.normal() * scale),
        }
    }

    pub fn input_dim(&self) -> usize {
        self.mat.cols()
    }

    pub fn output_dim(&self) -> usize {
        self.mat.rows()
    }

    /// The raw t×m matrix (shipped to the XLA embed_poly artifact).
    pub fn matrix(&self) -> &Mat {
        &self.mat
    }

    /// Feature-axis: `G·A`, [m×n] → [t×n].
    pub fn apply_feature_axis(&self, a: &Mat) -> Mat {
        self.mat.matmul(a)
    }

    /// Point-axis: `A·Gᵀ`, [r×m] → [r×t].
    pub fn apply_point_axis(&self, a: &Mat) -> Mat {
        a.matmul_a_bt(&self.mat)
    }

    /// Sketch one vector.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        self.mat.matvec(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims() {
        let mut rng = Rng::seed_from(1);
        let g = GaussianSketch::new(50, 10, &mut rng);
        assert_eq!(g.input_dim(), 50);
        assert_eq!(g.output_dim(), 10);
        let a = Mat::from_fn(50, 3, |_, _| rng.normal());
        assert_eq!(g.apply_feature_axis(&a).rows(), 10);
        let b = Mat::from_fn(3, 50, |_, _| rng.normal());
        assert_eq!(g.apply_point_axis(&b).cols(), 10);
    }

    #[test]
    fn norm_preserved_in_expectation() {
        let mut rng = Rng::seed_from(2);
        let m = 30;
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let exact: f64 = x.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let g = GaussianSketch::new(m, 16, &mut rng);
            acc += g.apply_vec(&x).iter().map(|v| v * v).sum::<f64>();
        }
        acc /= trials as f64;
        assert!((acc - exact).abs() < 0.15 * exact, "{acc} vs {exact}");
    }

    #[test]
    fn point_axis_consistent_with_feature_axis() {
        let mut rng = Rng::seed_from(3);
        let g = GaussianSketch::new(20, 8, &mut rng);
        let a = Mat::from_fn(5, 20, |_, _| rng.normal());
        let got = g.apply_point_axis(&a);
        let want = g.apply_feature_axis(&a.transpose()).transpose();
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn subspace_embedding_on_low_rank() {
        let mut rng = Rng::seed_from(4);
        let u = Mat::from_fn(4, 2, |_, _| rng.normal());
        let v = Mat::from_fn(2, 100, |_, _| rng.normal());
        let a = u.matmul(&v); // rank 2, 100 points
        let g = GaussianSketch::new(100, 48, &mut rng);
        let sk = g.apply_point_axis(&a);
        super::super::tests::check_right_embedding(&a, &sk, 0.6, &mut rng, 10);
    }
}
