//! Subsampled Randomized Hadamard Transform (Ailon–Chazelle).
//!
//! `S = √(m/t)·P·H·D` — D random signs, H Walsh–Hadamard, P row
//! sampling. The "fast Hadamard" option in the paper's Lemma 4 chain.
//! Input dim is padded to the next power of two internally.

use crate::linalg::{fft::fwht_inplace, Mat};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct Srht {
    m: usize,       // logical input dim
    mpad: usize,    // power-of-two padded dim
    signs: Vec<f64>,
    rows: Vec<usize>, // t sampled coordinates of the transformed vector
}

impl Srht {
    pub fn new(m: usize, t: usize, rng: &mut Rng) -> Self {
        let mpad = m.next_power_of_two();
        assert!(t <= mpad, "SRHT output {t} > padded input {mpad}");
        let signs = (0..mpad).map(|_| rng.sign()).collect();
        let rows = rng.sample_without_replacement(mpad, t);
        Self { m, mpad, signs, rows }
    }

    pub fn input_dim(&self) -> usize {
        self.m
    }

    pub fn output_dim(&self) -> usize {
        self.rows.len()
    }

    /// Sketch one vector: O(m log m).
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut buf = Vec::new();
        let mut out = vec![0.0; self.rows.len()];
        self.apply_vec_with(x, &mut buf, &mut out);
        out
    }

    /// [`Srht::apply_vec`] into caller-owned buffers: `buf` is the
    /// padded FWHT workspace, reused allocation-free across a column
    /// batch; `out` receives the t sampled coordinates (overwritten
    /// entirely). Values are bit-identical to [`Srht::apply_vec`].
    ///
    /// The workspace is 32-byte-friendly: one extra 4-lane (32-byte)
    /// slack block is kept past `mpad` and the active window starts on
    /// a 32-byte boundary, so the fast tier's lane-wise FWHT
    /// butterflies ([`crate::linalg::simd`]) run aligned whatever base
    /// the allocator handed the `Vec`. The transform length stays
    /// exactly `mpad` (the FWHT needs a power of two); alignment never
    /// changes the arithmetic, so sketches are bit-identical to a
    /// fresh unaligned buffer — `tests` pin this on odd and
    /// power-of-two-boundary dims.
    fn apply_vec_with(&self, x: &[f64], buf: &mut Vec<f64>, out: &mut [f64]) {
        assert_eq!(x.len(), self.m);
        debug_assert_eq!(out.len(), self.rows.len());
        buf.clear();
        buf.resize(self.mpad + 4, 0.0);
        // elements to skip so the window base is 32-byte aligned
        // (Vec<f64> is always 8-byte aligned)
        let off = (4 - ((buf.as_ptr() as usize >> 3) & 3)) & 3;
        let w = &mut buf[off..off + self.mpad];
        for (i, &v) in x.iter().enumerate() {
            w[i] = v * self.signs[i];
        }
        fwht_inplace(w);
        // S = √(mpad/t)·P·(H/√mpad)·D — the two scales collapse to 1/√t
        // on the unnormalized FWHT output.
        let scale = 1.0 / (self.rows.len() as f64).sqrt();
        for (o, &r) in out.iter_mut().zip(self.rows.iter()) {
            *o = w[r] * scale;
        }
    }

    /// Feature-axis: `S·A`, [m×n] → [t×n]. Column-parallel on the
    /// [`crate::par`] pool (one FWHT per column; columns independent,
    /// so results are bit-identical for any thread count). The padded
    /// FWHT workspace, column gather and output row are each allocated
    /// once per block and reused across its columns.
    pub fn apply_feature_axis(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.m);
        let n = a.cols();
        let t = self.rows.len();
        let build = |j0: usize, j1: usize| {
            let mut blk = Mat::zeros(t, j1 - j0);
            let mut buf = Vec::with_capacity(self.mpad + 4);
            let mut col = vec![0.0; self.m];
            let mut sk = vec![0.0; t];
            for j in j0..j1 {
                for (i, c) in col.iter_mut().enumerate() {
                    *c = a[(i, j)];
                }
                self.apply_vec_with(&col, &mut buf, &mut sk);
                blk.set_col(j - j0, &sk);
            }
            blk
        };
        // per-column cost ~ mpad·log(mpad): skip the pool on tiny inputs
        if crate::linalg::parallel_worthwhile(n, self.mpad * 16) {
            crate::par::par_col_blocks(t, n, build)
        } else {
            build(0, n)
        }
    }

    /// Point-axis: `A·Sᵀ`, [r×m] → [r×t].
    pub fn apply_point_axis(&self, a: &Mat) -> Mat {
        assert_eq!(a.cols(), self.m);
        self.apply_feature_axis(&a.transpose()).transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_with_padding() {
        let mut rng = Rng::seed_from(1);
        let s = Srht::new(100, 32, &mut rng); // pads to 128
        assert_eq!(s.input_dim(), 100);
        assert_eq!(s.output_dim(), 32);
        assert_eq!(s.apply_vec(&vec![1.0; 100]).len(), 32);
    }

    #[test]
    fn norm_preserved_in_expectation() {
        let mut rng = Rng::seed_from(2);
        let m = 64;
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let exact: f64 = x.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 400;
        for _ in 0..trials {
            let s = Srht::new(m, 16, &mut rng);
            acc += s.apply_vec(&x).iter().map(|v| v * v).sum::<f64>();
        }
        acc /= trials as f64;
        assert!((acc - exact).abs() < 0.15 * exact, "{acc} vs {exact}");
    }

    #[test]
    fn full_sampling_is_orthonormal_rotation() {
        // t = mpad ⇒ S is an orthonormal transform times √(m/t)=1:
        // norms preserved exactly.
        let mut rng = Rng::seed_from(3);
        let m = 32;
        let s = Srht::new(m, 32, &mut rng);
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let sx = s.apply_vec(&x);
        let n1: f64 = x.iter().map(|v| v * v).sum();
        let n2: f64 = sx.iter().map(|v| v * v).sum();
        assert!((n1 - n2).abs() < 1e-9 * n1, "{n1} vs {n2}");
    }

    #[test]
    fn reused_workspace_matches_fresh_buffer_bitwise() {
        // odd and power-of-two-boundary input dims: the aligned
        // window's offset and the reused (stale) workspace must never
        // perturb a sketch vs a fresh buffer
        let mut rng = Rng::seed_from(5);
        for m in [1usize, 2, 5, 31, 32, 33, 100] {
            let t = m.next_power_of_two().min(8);
            let s = Srht::new(m, t, &mut rng);
            let a = Mat::from_fn(m, 7, |_, _| rng.normal());
            // one workspace reused across all 7 columns …
            let fa = s.apply_feature_axis(&a);
            for j in 0..7 {
                // … vs a fresh buffer per column
                let want = s.apply_vec(&a.col(j));
                for i in 0..t {
                    assert_eq!(
                        fa[(i, j)].to_bits(),
                        want[i].to_bits(),
                        "m={m} j={j} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn matrix_paths_match_vector_path() {
        let mut rng = Rng::seed_from(4);
        let s = Srht::new(20, 8, &mut rng);
        let a = Mat::from_fn(20, 5, |_, _| rng.normal());
        let fa = s.apply_feature_axis(&a);
        for j in 0..5 {
            let want = s.apply_vec(&a.col(j));
            for i in 0..8 {
                assert!((fa[(i, j)] - want[i]).abs() < 1e-12);
            }
        }
        let b = Mat::from_fn(3, 20, |_, _| rng.normal());
        let pb = s.apply_point_axis(&b);
        assert_eq!((pb.rows(), pb.cols()), (3, 8));
    }
}
