//! Sketching / subspace-embedding substrate (paper §3, Lemma 1).
//!
//! The protocol composes four sketch families:
//! - [`CountSketch`] — input-sparsity-time subspace embedding
//!   (Clarkson–Woodruff); used on both the feature axis (kernel
//!   embeddings) and the point axis (disLS/disLR right-sketches).
//! - [`GaussianSketch`] — dense JLT; concatenated after CountSketch to
//!   reach the optimal `O(k/ε)` dimension (Lemma 4's Ω·T).
//! - [`Srht`] — subsampled randomized Hadamard transform, the
//!   "fast Hadamard" alternative mentioned in Lemma 4.
//! - [`TensorSketch`] — Pham–Pagh polynomial-kernel sketch (Lemma 4).
//!
//! Everything is deterministic from an [`Rng`] stream so worker-side
//! sketches can be re-drawn from a broadcast seed instead of shipping
//! the matrices (this is what keeps disLS at `O(stp)` words).

mod countsketch;
mod gaussian;
mod srht;
mod tensorsketch;

pub use countsketch::CountSketch;
pub use gaussian::GaussianSketch;
pub use srht::Srht;
pub use tensorsketch::TensorSketch;

use crate::linalg::Mat;
use crate::rng::Rng;

/// Right-sketch `A·T` where T is a CountSketch on the *point* axis:
/// compresses `n` columns to `p` columns in O(n·rows) time. This is
/// the `Tⁱ` of Alg. 1 step 1 and Alg. 3 step 1.
pub fn right_countsketch(a: &Mat, p: usize, rng: &mut Rng) -> Mat {
    let cs = CountSketch::new(a.cols(), p, rng);
    cs.apply_point_axis(a)
}

/// Right-sketch with an ε-subspace-embedding pair: CountSketch to
/// `4·p` then dense Gaussian down to `p` (concatenation per Lemma 1).
pub fn right_cs_gauss(a: &Mat, p: usize, rng: &mut Rng) -> Mat {
    let mid = (4 * p).min(a.cols().max(p));
    let cs = right_countsketch(a, mid, rng);
    let g = GaussianSketch::new(mid, p, rng);
    g.apply_point_axis(&cs)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared check: S preserves column-space norms of Aᵀ (i.e.
    /// ‖xᵀA‖ ≈ ‖xᵀAS‖ for right-sketches) to within distortion `eps`
    /// on a handful of random directions.
    pub(super) fn check_right_embedding(
        a: &Mat,
        sketched: &Mat,
        eps: f64,
        rng: &mut Rng,
        trials: usize,
    ) {
        for _ in 0..trials {
            let x: Vec<f64> = (0..a.rows()).map(|_| rng.normal()).collect();
            let xa = a.transpose().matvec(&x);
            let xas = sketched.transpose().matvec(&x);
            let n1: f64 = xa.iter().map(|v| v * v).sum::<f64>().sqrt();
            let n2: f64 = xas.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(
                (n1 - n2).abs() <= eps * n1.max(1e-12),
                "distortion {} > {eps} (n1={n1}, n2={n2})",
                (n1 - n2).abs() / n1.max(1e-12)
            );
        }
    }

    #[test]
    fn right_countsketch_embeds_low_rank() {
        let mut rng = Rng::seed_from(1);
        // rank-4 matrix with many columns
        let u = Mat::from_fn(6, 4, |_, _| rng.normal());
        let v = Mat::from_fn(4, 400, |_, _| rng.normal());
        let a = u.matmul(&v);
        let sk = right_countsketch(&a, 128, &mut rng);
        assert_eq!(sk.rows(), 6);
        assert_eq!(sk.cols(), 128);
        check_right_embedding(&a, &sk, 0.5, &mut rng, 10);
    }

    #[test]
    fn right_cs_gauss_dims() {
        let mut rng = Rng::seed_from(2);
        let u = Mat::from_fn(5, 3, |_, _| rng.normal());
        let v = Mat::from_fn(3, 300, |_, _| rng.normal());
        let a = u.matmul(&v);
        let sk = right_cs_gauss(&a, 96, &mut rng);
        assert_eq!((sk.rows(), sk.cols()), (5, 96));
        check_right_embedding(&a, &sk, 0.6, &mut rng, 10);
    }

    #[test]
    fn right_sketch_preserves_frobenius_in_expectation() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::from_fn(4, 200, |_, _| rng.normal());
        let mut est = 0.0;
        let trials = 30;
        for _ in 0..trials {
            est += right_countsketch(&a, 64, &mut rng).frob_norm_sq();
        }
        est /= trials as f64;
        let exact = a.frob_norm_sq();
        assert!((est - exact).abs() < 0.25 * exact, "{est} vs {exact}");
    }
}
