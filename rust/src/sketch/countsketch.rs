//! CountSketch (Clarkson–Woodruff sparse embedding).

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sparse::Csc;

/// A CountSketch `S ∈ R^{t×m}`: one ±1 per input coordinate, landing
/// in bucket `h[j]`. Applying costs O(nnz) — the "input sparsity time"
/// property the paper leans on for sparse datasets.
#[derive(Clone, Debug)]
pub struct CountSketch {
    t: usize,
    h: Vec<u32>,
    s: Vec<f64>,
    /// CSR-style inverted index bucket → input rows: bucket `b` owns
    /// `bucket_rows[bucket_start[b]..bucket_start[b+1]]`, rows in
    /// ascending input order. Deterministic in `(t, h)`, so it is
    /// built **once at construction** and shared by every
    /// [`CountSketch::apply_feature_axis`] call (it used to be rebuilt
    /// per call, which dominated the chunked sketch paths).
    bucket_start: Vec<u32>,
    bucket_rows: Vec<u32>,
}

/// Counting-sort inversion of the bucket table: ascending input rows
/// within each bucket, matching the serial apply loop's visit order.
fn build_buckets(t: usize, h: &[u32]) -> (Vec<u32>, Vec<u32>) {
    assert!(h.len() <= u32::MAX as usize, "countsketch input dim overflows index");
    let mut start = vec![0u32; t + 1];
    for &b in h {
        start[b as usize + 1] += 1;
    }
    for b in 0..t {
        start[b + 1] += start[b];
    }
    let mut pos: Vec<u32> = start[..t].to_vec();
    let mut rows = vec![0u32; h.len()];
    for (i, &b) in h.iter().enumerate() {
        let p = &mut pos[b as usize];
        rows[*p as usize] = i as u32;
        *p += 1;
    }
    (start, rows)
}

impl CountSketch {
    pub fn new(m: usize, t: usize, rng: &mut Rng) -> Self {
        assert!(t > 0);
        let h: Vec<u32> = (0..m).map(|_| rng.below(t) as u32).collect();
        let s = (0..m).map(|_| rng.sign()).collect();
        let (bucket_start, bucket_rows) = build_buckets(t, &h);
        Self { t, h, s, bucket_start, bucket_rows }
    }

    /// Prefix-stable construction: `(h[j], s[j])` are drawn as one
    /// interleaved pair per input coordinate, so
    /// `new_extendable(m', t, Rng::seed_from(seed))` agrees with
    /// `new_extendable(m, t, Rng::seed_from(seed))` on the first
    /// `min(m, m')` coordinates. [`CountSketch::new`] draws all of `h`
    /// before any of `s`, so growing `m` there reshuffles every sign —
    /// the incremental-refit path needs the prefix to survive appends
    /// (old columns keep their buckets and signs; only new columns'
    /// contributions are folded in).
    pub fn new_extendable(m: usize, t: usize, rng: &mut Rng) -> Self {
        assert!(t > 0);
        let mut h = Vec::with_capacity(m);
        let mut s = Vec::with_capacity(m);
        for _ in 0..m {
            h.push(rng.below(t) as u32);
            s.push(rng.sign());
        }
        let (bucket_start, bucket_rows) = build_buckets(t, &h);
        Self { t, h, s, bucket_start, bucket_rows }
    }

    /// From explicit tables (for cross-checking against the XLA/Pallas
    /// countsketch artifact, which receives h and s as inputs).
    pub fn from_tables(t: usize, h: Vec<u32>, s: Vec<f64>) -> Self {
        assert_eq!(h.len(), s.len());
        assert!(h.iter().all(|&b| (b as usize) < t));
        let (bucket_start, bucket_rows) = build_buckets(t, &h);
        Self { t, h, s, bucket_start, bucket_rows }
    }

    pub fn input_dim(&self) -> usize {
        self.h.len()
    }

    pub fn output_dim(&self) -> usize {
        self.t
    }

    pub fn tables(&self) -> (&[u32], &[f64]) {
        (&self.h, &self.s)
    }

    /// Sketch a single dense vector: `S·x`.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.t];
        self.apply_vec_into(x, &mut out);
        out
    }

    /// [`CountSketch::apply_vec`] into a caller-owned buffer —
    /// allocation-free across a column batch (TensorSketch reuses one
    /// buffer per block). Overwrites `out` entirely.
    pub(crate) fn apply_vec_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.h.len());
        debug_assert_eq!(out.len(), self.t);
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for (j, &v) in x.iter().enumerate() {
            if v != 0.0 {
                out[self.h[j] as usize] += self.s[j] * v;
            }
        }
    }

    /// Sketch a sparse vector given as (row, value) pairs.
    pub fn apply_sparse_vec(&self, entries: impl Iterator<Item = (usize, f64)>) -> Vec<f64> {
        let mut out = vec![0.0; self.t];
        self.apply_sparse_vec_into(entries, &mut out);
        out
    }

    /// [`CountSketch::apply_sparse_vec`] into a caller-owned buffer.
    /// Overwrites `out` entirely.
    pub(crate) fn apply_sparse_vec_into(
        &self,
        entries: impl Iterator<Item = (usize, f64)>,
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), self.t);
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for (j, v) in entries {
            out[self.h[j] as usize] += self.s[j] * v;
        }
    }

    /// Feature-axis sketch of a `m×n` matrix: `S·A → t×n`.
    ///
    /// Bucket-parallel on the [`crate::par`] pool for large inputs:
    /// the inverted bucket→rows index **precomputed at construction**
    /// lets each output row be accumulated independently, in the same
    /// ascending input-row order as the serial loop — results are
    /// bit-identical for any thread count, and repeated applies (the
    /// streaming worker's per-chunk folds) pay no index rebuild.
    pub fn apply_feature_axis(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.h.len());
        let m = a.rows();
        let n = a.cols();
        let mut out = Mat::zeros(self.t, n);
        if n == 0 || m == 0 {
            return out;
        }
        if crate::linalg::parallel_worthwhile(m * n, 2) {
            let body = |b0: usize, chunk: &mut [f64]| {
                let rows = chunk.len() / n;
                for r in 0..rows {
                    let orow = &mut chunk[r * n..(r + 1) * n];
                    let lo = self.bucket_start[b0 + r] as usize;
                    let hi = self.bucket_start[b0 + r + 1] as usize;
                    for &i in &self.bucket_rows[lo..hi] {
                        let sign = self.s[i as usize];
                        let arow = a.row(i as usize);
                        for j in 0..n {
                            orow[j] += sign * arow[j];
                        }
                    }
                }
            };
            crate::par::par_chunks(out.data_mut(), n, body);
        } else {
            for i in 0..m {
                let bucket = self.h[i] as usize;
                let sign = self.s[i];
                let arow = a.row(i);
                let orow = out.row_mut(bucket);
                for j in 0..n {
                    orow[j] += sign * arow[j];
                }
            }
        }
        out
    }

    /// Feature-axis sketch of a CSC matrix in O(nnz). Column-block
    /// parallel (columns are independent, so the split is exact).
    pub fn apply_feature_axis_sparse(&self, a: &Csc) -> Mat {
        assert_eq!(a.rows(), self.h.len());
        let n = a.cols();
        let build = |j0: usize, j1: usize| {
            let mut blk = Mat::zeros(self.t, j1 - j0);
            for j in j0..j1 {
                for (r, v) in a.col_iter(j) {
                    blk[(self.h[r] as usize, j - j0)] += self.s[r] * v;
                }
            }
            blk
        };
        // per-column cost ~ nnz/col (unknown up front): rough gate
        if crate::linalg::parallel_worthwhile(n, 256) {
            crate::par::par_col_blocks(self.t, n, build)
        } else {
            build(0, n)
        }
    }

    /// Accumulate the point-axis sketch of a *column chunk* into
    /// `out` (r×t): column `j` of `a` is treated as global column
    /// `col0 + j`, so folding ascending chunks reproduces
    /// [`CountSketch::apply_point_axis`] on the full matrix **bit for
    /// bit** — per output entry the additions happen in the same
    /// ascending global-column order (with the same `v != 0` skip),
    /// so no floating-point sum is reassociated. This is the streaming
    /// worker's replacement for materializing `A` whole.
    pub fn accumulate_point_axis(&self, a: &Mat, col0: usize, out: &mut Mat) {
        assert!(col0 + a.cols() <= self.h.len(), "chunk exceeds sketch input dim");
        assert_eq!(out.cols(), self.t);
        assert_eq!(out.rows(), a.rows());
        for i in 0..a.rows() {
            let arow = a.row(i);
            let orow = out.row_mut(i);
            for (j, &v) in arow.iter().enumerate() {
                if v != 0.0 {
                    orow[self.h[col0 + j] as usize] += self.s[col0 + j] * v;
                }
            }
        }
    }

    /// Point-axis (right) sketch of an `r×n` matrix: `A·Sᵀ → r×t`.
    /// This compresses the *number of points* — Alg. 1 / Alg. 3.
    /// Row-parallel (each output row depends on one input row only).
    pub fn apply_point_axis(&self, a: &Mat) -> Mat {
        assert_eq!(a.cols(), self.h.len());
        let r = a.rows();
        let n = a.cols();
        let mut out = Mat::zeros(r, self.t);
        if r == 0 {
            return out;
        }
        let t = self.t;
        let body = |i0: usize, chunk: &mut [f64]| {
            let rows = chunk.len() / t;
            for rr in 0..rows {
                let arow = a.row(i0 + rr);
                let orow = &mut chunk[rr * t..(rr + 1) * t];
                for (j, &v) in arow.iter().enumerate() {
                    if v != 0.0 {
                        orow[self.h[j] as usize] += self.s[j] * v;
                    }
                }
            }
        };
        if crate::linalg::parallel_worthwhile(r * n, 2) {
            crate::par::par_chunks(out.data_mut(), t, body);
        } else {
            body(0, out.data_mut());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_equiv(cs: &CountSketch, m: usize) -> Mat {
        // S as an explicit t×m matrix
        Mat::from_fn(cs.t, m, |i, j| {
            if cs.h[j] as usize == i {
                cs.s[j]
            } else {
                0.0
            }
        })
    }

    #[test]
    fn apply_matches_dense_multiply() {
        let mut rng = Rng::seed_from(1);
        let (m, n, t) = (40, 7, 16);
        let cs = CountSketch::new(m, t, &mut rng);
        let s = dense_equiv(&cs, m);
        let a = Mat::from_fn(m, n, |_, _| rng.normal());
        let got = cs.apply_feature_axis(&a);
        let want = s.matmul(&a);
        assert!(got.max_abs_diff(&want) < 1e-12);
        // vector path
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let gv = cs.apply_vec(&x);
        let wv = s.matvec(&x);
        for i in 0..t {
            assert!((gv[i] - wv[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_matches_dense_path() {
        let mut rng = Rng::seed_from(2);
        let (m, n, t) = (30, 9, 8);
        let cs = CountSketch::new(m, t, &mut rng);
        let dense = Mat::from_fn(m, n, |i, j| {
            if (i + j) % 5 == 0 {
                rng.normal()
            } else {
                0.0
            }
        });
        let sparse = Csc::from_dense(&dense);
        let a = cs.apply_feature_axis(&dense);
        let b = cs.apply_feature_axis_sparse(&sparse);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn point_axis_matches_transpose_formulation() {
        let mut rng = Rng::seed_from(3);
        let (r, n, t) = (5, 50, 16);
        let cs = CountSketch::new(n, t, &mut rng);
        let a = Mat::from_fn(r, n, |_, _| rng.normal());
        let got = cs.apply_point_axis(&a);
        // A·Sᵀ == (S·Aᵀ)ᵀ
        let want = cs.apply_feature_axis(&a.transpose()).transpose();
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn accumulate_chunks_bit_identical_to_full_apply() {
        let mut rng = Rng::seed_from(7);
        let (r, n, t) = (6, 53, 16);
        let cs = CountSketch::new(n, t, &mut rng);
        let a = Mat::from_fn(r, n, |i, j| if (i + j) % 4 == 0 { 0.0 } else { rng.normal() });
        let full = cs.apply_point_axis(&a);
        for chunk in [1, 7, 16, 53, 100] {
            let mut out = Mat::zeros(r, t);
            let mut at = 0;
            while at < n {
                let end = (at + chunk).min(n);
                let sub = Mat::from_fn(r, end - at, |i, j| a[(i, at + j)]);
                cs.accumulate_point_axis(&sub, at, &mut out);
                at = end;
            }
            assert!(out.data() == full.data(), "chunk={chunk}: bits differ");
        }
    }

    /// Growing `m` under `new_extendable` must leave the first
    /// `m_old` coordinates' buckets *and* signs untouched — the
    /// property the delta-sketch fold stands on. (`new` does not have
    /// it: the sign stream starts after all of `h`, so a larger `m`
    /// shifts every sign.)
    #[test]
    fn extendable_tables_are_prefix_stable() {
        for (m_old, m_new, t) in [(10, 11, 8), (40, 67, 16), (1, 100, 4)] {
            let a = CountSketch::new_extendable(m_old, t, &mut Rng::seed_from(42));
            let b = CountSketch::new_extendable(m_new, t, &mut Rng::seed_from(42));
            let (ha, sa) = a.tables();
            let (hb, sb) = b.tables();
            assert_eq!(ha, &hb[..m_old], "buckets diverge on the prefix");
            assert_eq!(sa, &sb[..m_old], "signs diverge on the prefix");
        }
        // and the sketch itself still behaves like a CountSketch
        let mut rng = Rng::seed_from(9);
        let (m, n, t) = (40, 7, 16);
        let cs = CountSketch::new_extendable(m, t, &mut rng);
        let s = dense_equiv(&cs, m);
        let a = Mat::from_fn(m, n, |_, _| rng.normal());
        assert!(cs.apply_feature_axis(&a).max_abs_diff(&s.matmul(&a)) < 1e-12);
    }

    #[test]
    fn unbiased_inner_products() {
        // E[⟨Sx, Sy⟩] = ⟨x, y⟩
        let mut rng = Rng::seed_from(4);
        let m = 64;
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let exact: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let trials = 800;
        let mut acc = 0.0;
        for _ in 0..trials {
            let cs = CountSketch::new(m, 16, &mut rng);
            let sx = cs.apply_vec(&x);
            let sy = cs.apply_vec(&y);
            acc += sx.iter().zip(&sy).map(|(a, b)| a * b).sum::<f64>();
        }
        acc /= trials as f64;
        assert!((acc - exact).abs() < 0.6, "{acc} vs {exact}");
    }

    #[test]
    fn norm_preserved_exactly_when_no_collisions() {
        // t ≫ m ⇒ whp no collisions ⇒ ‖Sx‖ = ‖x‖ exactly when h is injective
        let mut rng = Rng::seed_from(5);
        let m = 4;
        loop {
            let cs = CountSketch::new(m, 64, &mut rng);
            let mut hs = cs.h.clone();
            hs.sort_unstable();
            hs.dedup();
            if hs.len() == m {
                let x = vec![1.0, -2.0, 3.0, 0.5];
                let sx = cs.apply_vec(&x);
                let n1: f64 = x.iter().map(|v| v * v).sum();
                let n2: f64 = sx.iter().map(|v| v * v).sum();
                assert!((n1 - n2).abs() < 1e-12);
                break;
            }
        }
    }

    #[test]
    fn from_tables_roundtrip() {
        let cs = CountSketch::from_tables(4, vec![0, 3, 3], vec![1.0, -1.0, 1.0]);
        let out = cs.apply_vec(&[2.0, 5.0, 7.0]);
        assert_eq!(out, vec![2.0, 0.0, 0.0, 2.0]);
        // tables() → from_tables() reproduces the sketch (and its
        // precomputed inverted index) exactly
        let (h, s) = cs.tables();
        let cs2 = CountSketch::from_tables(4, h.to_vec(), s.to_vec());
        assert_eq!(cs2.bucket_start, cs.bucket_start);
        assert_eq!(cs2.bucket_rows, cs.bucket_rows);
        assert_eq!(cs2.apply_vec(&[2.0, 5.0, 7.0]), out);
    }

    /// The construction-time inverted index must list every input row
    /// exactly once, grouped by bucket, ascending within each bucket —
    /// the order the bit-identity contract of `apply_feature_axis`
    /// depends on.
    #[test]
    fn inverted_index_is_exact_and_ascending() {
        let mut rng = Rng::seed_from(8);
        let (m, t) = (97, 16);
        let cs = CountSketch::new(m, t, &mut rng);
        assert_eq!(cs.bucket_start.len(), t + 1);
        assert_eq!(cs.bucket_start[0], 0);
        assert_eq!(cs.bucket_start[t] as usize, m);
        assert_eq!(cs.bucket_rows.len(), m);
        let mut seen = vec![false; m];
        for b in 0..t {
            let rows = &cs.bucket_rows[cs.bucket_start[b] as usize..cs.bucket_start[b + 1] as usize];
            for w in rows.windows(2) {
                assert!(w[0] < w[1], "bucket {b} not ascending");
            }
            for &i in rows {
                assert_eq!(cs.h[i as usize] as usize, b, "row {i} in wrong bucket");
                assert!(!seen[i as usize], "row {i} listed twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "some row missing from the index");
    }
}
