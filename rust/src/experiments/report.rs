//! Row-oriented report: aligned stdout table + CSV file.

use std::fs;
use std::path::Path;

pub struct Report {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([h.len()])
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }

    /// Write `out_dir/<id>.csv`.
    pub fn write_csv(&self, out_dir: &str, id: &str) -> std::io::Result<String> {
        fs::create_dir_all(out_dir)?;
        let path = Path::new(out_dir).join(format!("{id}.csv"));
        let mut text = self.headers.join(",") + "\n";
        for r in &self.rows {
            text.push_str(&r.join(","));
            text.push('\n');
        }
        fs::write(&path, text)?;
        Ok(path.display().to_string())
    }
}

/// 3-sig-fig science formatting for table cells.
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 0.01 && v.abs() < 100000.0 {
        format!("{v:.4}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("test", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.row(vec!["333".into(), sci(0.12345)]);
        r.print();
        let dir = std::env::temp_dir().join("diskpca_report_test");
        let path = r.write_csv(dir.to_str().unwrap(), "t").unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("a,b\n1,2\n"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(vec!["1".into()]);
    }
}
