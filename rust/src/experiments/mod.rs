//! Experiment harness: one driver per paper table/figure.
//!
//! Every driver prints the same rows/series the paper reports and
//! writes `results/<id>.csv`. DESIGN.md §6 maps each driver to the
//! paper's evaluation; EXPERIMENTS.md records paper-vs-measured.

mod figures;
mod report;

pub use figures::*;
pub use report::Report;

use std::sync::Arc;
use std::time::Instant;

use crate::config::Config;
use crate::coordinator::{
    self, dis_eval, dis_kpca, dis_set_solution, run_cluster_chunked, Params,
};
use crate::data::{by_name, Data, DatasetSpec};
use crate::kernels::{median_trick_gamma, Kernel};
use crate::rng::Rng;
use crate::runtime::{backend_from_name, Backend};

/// Shared experiment context built from CLI config.
pub struct Ctx {
    pub scale: f64,
    pub backend: Arc<dyn Backend>,
    pub backend_name: String,
    pub out_dir: String,
    pub seed: u64,
    pub workers_override: Option<usize>,
    pub cfg: Config,
}

impl Ctx {
    pub fn from_config(cfg: &Config) -> anyhow::Result<Self> {
        let backend_name = cfg.str_or("backend", "native").to_string();
        let artifacts = cfg.str_or("artifacts", "artifacts").to_string();
        let backend = backend_from_name(&backend_name, &artifacts)?;
        Self::with_backend(cfg, backend, backend_name)
    }

    /// Build a context around a caller-owned backend (lets examples
    /// keep a handle for inspecting e.g. XLA fallback stats).
    pub fn with_backend(
        cfg: &Config,
        backend: Arc<dyn Backend>,
        backend_name: String,
    ) -> anyhow::Result<Self> {
        // `--threads N` sizes the shared compute pool for every run
        // driven from this context (native and XLA paths alike);
        // absent or 0 leaves the pool (and DISKPCA_THREADS) untouched.
        cfg.params().apply_threads();
        // `--compute-tier exact|fast` selects the numeric kernels for
        // every run driven from this context (default exact)
        crate::linalg::simd::set_compute_tier(cfg.compute_tier());
        Ok(Self {
            scale: cfg.f64_or("scale", 0.1),
            backend,
            backend_name,
            out_dir: cfg.str_or("out", "results").to_string(),
            seed: cfg.u64_or("seed", 0xd15c),
            workers_override: cfg.get("workers").map(|w| w.parse().expect("--workers N")),
            cfg: cfg.clone(),
        })
    }

    pub fn dataset(&self, name: &str) -> anyhow::Result<DatasetSpec> {
        let mut spec = by_name(name, self.scale)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name} (see `diskpca table1`)"))?;
        if let Some(s) = self.workers_override {
            spec.s = s;
        }
        Ok(spec)
    }

    /// The paper's kernel settings (§6.2): gauss σ = 0.2·median
    /// distance over ≤20000 points; poly q=4; arc-cos degree 2.
    pub fn kernel(&self, family: &str, data: &Data) -> Kernel {
        match family {
            "gauss" => {
                let mut rng = Rng::seed_from(self.seed ^ 0x3e0);
                let sample = self.cfg.usize_or("median_sample", 200);
                Kernel::Gauss {
                    gamma: median_trick_gamma(data, 0.2, sample, &mut rng),
                }
            }
            "poly" => Kernel::Poly { q: self.cfg.usize_or("q", 4) as u32 },
            "arccos" => Kernel::ArcCos { degree: self.cfg.usize_or("degree", 2) as u32 },
            "laplace" => {
                let mut rng = Rng::seed_from(self.seed ^ 0x3e1);
                let sample = self.cfg.usize_or("median_sample", 200);
                Kernel::Laplace {
                    gamma: crate::kernels::median_trick_gamma_l1(data, 1.0, sample, &mut rng),
                }
            }
            other => panic!("unknown kernel family {other} (gauss|poly|arccos|laplace)"),
        }
    }
}

/// Which KPCA method to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    DisKpca,
    UniformDisLr,
    UniformBatch,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::DisKpca => "disKPCA",
            Method::UniformDisLr => "uniform+disLR",
            Method::UniformBatch => "uniform+batchKPCA",
        }
    }

    pub fn all() -> [Method; 3] {
        [Method::DisKpca, Method::UniformDisLr, Method::UniformBatch]
    }
}

/// One method run's outcome.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub method: &'static str,
    pub err: f64,
    pub trace: f64,
    /// err / n — the per-point low-rank approximation error plotted
    /// in the paper's figures.
    pub err_per_point: f64,
    pub comm_words: usize,
    pub num_points: usize,
    pub wall_secs: f64,
}

/// Run one method over a freshly partitioned dataset and evaluate
/// distributedly. `total_points` matches |Y| across methods so the
/// comparison is representative-points-for-representative-points.
pub fn run_method(
    ctx: &Ctx,
    spec: &DatasetSpec,
    data: &Data,
    kernel: Kernel,
    params: &Params,
    method: Method,
) -> anyhow::Result<RunResult> {
    let shards = spec.partition(data, ctx.seed ^ 0x9a91);
    let n = data.len();
    let total_points = params.n_lev + params.n_adapt;
    let backend = ctx.backend.clone();
    let params = *params;
    let t0 = Instant::now();
    // `--chunk-rows` flows through to the in-process workers: every
    // experiment driver can run its workers out-of-core-style.
    let (body_result, stats) = run_cluster_chunked(
        shards,
        kernel,
        backend,
        params.chunk_rows,
        move |cluster| -> Result<(f64, f64, usize), crate::comm::CommError> {
            let sol = match method {
                Method::DisKpca => dis_kpca(cluster, kernel, &params)?,
                Method::UniformDisLr => {
                    coordinator::uniform_dis_lr(cluster, kernel, &params, total_points)?
                }
                Method::UniformBatch => {
                    let sol =
                        coordinator::uniform_batch_kpca(cluster, kernel, &params, total_points)?;
                    dis_set_solution(cluster, &sol)?;
                    sol
                }
            };
            let (err, trace) = dis_eval(cluster)?;
            Ok((err, trace, sol.num_points()))
        },
    );
    let (err, trace, num_points) = body_result?;
    Ok(RunResult {
        method: method.name(),
        err,
        trace,
        err_per_point: err / n as f64,
        comm_words: stats.total_words(),
        num_points,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

/// The closed-form communication model from Theorem 1's accounting —
/// printed next to measured words by `bench-comm`.
pub fn comm_model_words(
    s: usize,
    t: usize,
    p: usize,
    y: usize,
    w: usize,
    k: usize,
    rho: f64,
) -> usize {
    // disLS: s·t·p up + s·t² down; sampling: ~2·(s+1)·|Y|·ρ′ with
    // ρ′ = words per point; disLR: s·|Y|·w up + s·|Y|·k down.
    let point_words = rho.ceil() as usize;
    s * t * p + s * t * t + 2 * (s + 1) * y * point_words + s * y * w + s * y * k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Ctx {
        let mut cfg = Config::new();
        cfg.set("scale", "0.03");
        cfg.set("workers", "3");
        Ctx::from_config(&cfg).unwrap()
    }

    fn small_params() -> Params {
        Params {
            k: 4,
            t: 16,
            p: 32,
            n_lev: 10,
            n_adapt: 20,
            w: 0,
            m_rff: 256,
            t2: 128,
            seed: 5,
            threads: 0,
            chunk_rows: 0,
            gather: crate::coordinator::GatherMode::Flat,
        }
    }

    #[test]
    fn run_method_all_methods() {
        let c = ctx();
        let spec = c.dataset("protein_like").unwrap();
        let data = spec.generate(c.seed);
        let kernel = c.kernel("gauss", &data);
        for m in Method::all() {
            let r = run_method(&c, &spec, &data, kernel, &small_params(), m).unwrap();
            assert!(r.err >= 0.0 && r.err <= r.trace * 1.001, "{m:?}: {r:?}");
            assert!(r.comm_words > 0);
            assert!(r.num_points > 0);
        }
    }

    #[test]
    fn diskpca_comm_near_model() {
        let c = ctx();
        let spec = c.dataset("protein_like").unwrap();
        let data = spec.generate(c.seed);
        let kernel = c.kernel("gauss", &data);
        let p = small_params();
        let r = run_method(&c, &spec, &data, kernel, &p, Method::DisKpca).unwrap();
        let y = r.num_points;
        let model = comm_model_words(spec.s, p.t, p.p, y, y, p.k, spec.d as f64);
        // within 3× of the closed form (eval round + alloc scalars on top)
        assert!(
            r.comm_words < 3 * model && r.comm_words > model / 3,
            "measured {} vs model {model}",
            r.comm_words
        );
    }

    #[test]
    fn kernel_selection() {
        let c = ctx();
        let spec = c.dataset("protein_like").unwrap();
        let data = spec.generate(1);
        assert!(matches!(c.kernel("gauss", &data), Kernel::Gauss { .. }));
        assert!(matches!(c.kernel("poly", &data), Kernel::Poly { q: 4 }));
        assert!(matches!(c.kernel("arccos", &data), Kernel::ArcCos { degree: 2 }));
    }
}
