//! Terminal scatter/line plots for the figure drivers — a quick
//! visual check of curve *shapes* (who wins, where curves bend)
//! without leaving the terminal. Multiple labelled series, log-x
//! support for communication axes.

/// One named series of (x, y) points.
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

pub struct AsciiPlot {
    title: String,
    width: usize,
    height: usize,
    log_x: bool,
    series: Vec<Series>,
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

impl AsciiPlot {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            width: 64,
            height: 18,
            log_x: false,
            series: Vec::new(),
        }
    }

    pub fn log_x(mut self) -> Self {
        self.log_x = true;
        self
    }

    pub fn size(mut self, width: usize, height: usize) -> Self {
        self.width = width.max(16);
        self.height = height.max(6);
        self
    }

    pub fn add(&mut self, label: &str, points: Vec<(f64, f64)>) {
        self.series.push(Series { label: label.to_string(), points });
    }

    fn tx(&self, x: f64) -> f64 {
        if self.log_x {
            x.max(1e-300).log10()
        } else {
            x
        }
    }

    /// Render to a string (also what the tests inspect).
    pub fn render(&self) -> String {
        let pts: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|&(x, y)| (self.tx(x), y)))
            .filter(|(x, y)| x.is_finite() && y.is_finite())
            .collect();
        if pts.is_empty() {
            return format!("{}\n(no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, s) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in &s.points {
                let tx = self.tx(x);
                if !tx.is_finite() || !y.is_finite() {
                    continue;
                }
                let cx = ((tx - x0) / (x1 - x0) * (self.width - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = mark;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&format!("{y1:>10.3e} ┐\n"));
        for row in &grid {
            out.push_str("           │");
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!("{y0:>10.3e} └{}\n", "─".repeat(self.width)));
        out.push_str(&format!(
            "            {:<.3e}{}{:>.3e}{}\n",
            if self.log_x { 10f64.powf(x0) } else { x0 },
            " ".repeat(self.width.saturating_sub(22)),
            if self.log_x { 10f64.powf(x1) } else { x1 },
            if self.log_x { "  (log x)" } else { "" },
        ));
        for (si, s) in self.series.iter().enumerate() {
            out.push_str(&format!("            {} {}\n", MARKS[si % MARKS.len()], s.label));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_multiple_series() {
        let mut p = AsciiPlot::new("test plot").size(32, 10);
        p.add("a", vec![(1.0, 1.0), (2.0, 2.0), (3.0, 3.0)]);
        p.add("b", vec![(1.0, 3.0), (3.0, 1.0)]);
        let r = p.render();
        assert!(r.contains("test plot"));
        assert!(r.contains('*') && r.contains('o'));
        assert!(r.contains("a") && r.contains("b"));
        assert!(r.lines().count() > 10);
    }

    #[test]
    fn log_x_handles_wide_ranges() {
        let mut p = AsciiPlot::new("log").log_x().size(32, 8);
        p.add("s", vec![(10.0, 1.0), (1e6, 2.0)]);
        let r = p.render();
        assert!(r.contains("(log x)"));
    }

    #[test]
    fn empty_plot_safe() {
        let p = AsciiPlot::new("empty");
        assert!(p.render().contains("no data"));
    }

    #[test]
    fn degenerate_single_point() {
        let mut p = AsciiPlot::new("one");
        p.add("s", vec![(5.0, 5.0)]);
        let r = p.render();
        assert!(r.contains('*'));
    }
}
