//! Drivers for Table 1 and Figures 2–8 (one function per artifact).

use std::time::Instant;

use super::report::{sci, Report};
use super::{comm_model_words, run_method, Ctx, Method};
use crate::coordinator::{
    batch_kpca, dis_css, dis_kpca, dis_krr, dis_set_solution, kmeans::distributed_kmeans,
    run_cluster, uniform_dis_lr, Params,
};
use crate::data::registry;

fn sweep(ctx: &Ctx, default: &str) -> Vec<usize> {
    ctx.cfg
        .str_or("sweep", default)
        .split(',')
        .map(|v| v.trim().parse().expect("--sweep N,N,..."))
        .collect()
}

fn params_with(ctx: &Ctx, n_adapt: usize) -> Params {
    let mut p = ctx.cfg.params();
    p.n_adapt = n_adapt;
    p
}

/// Table 1: the dataset registry (paper spec → analogue spec).
pub fn table1(ctx: &Ctx) -> anyhow::Result<()> {
    let mut rep = Report::new(
        "Table 1 — datasets (paper → analogue at --scale)",
        &["dataset", "paper_d", "paper_n", "d", "n", "s", "sparse", "rho"],
    );
    for spec in registry(ctx.scale) {
        let data = spec.generate(ctx.seed);
        rep.row(vec![
            spec.name.into(),
            spec.paper_d.to_string(),
            spec.paper_n.to_string(),
            spec.d.to_string(),
            data.len().to_string(),
            spec.s.to_string(),
            matches!(data, crate::data::Data::Sparse(_)).to_string(),
            format!("{:.1}", data.avg_nnz_per_point()),
        ]);
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, "table1")?;
    println!("wrote {path}");
    Ok(())
}

/// Figures 2 (poly) & 3 (gauss): small datasets vs batch KPCA —
/// error and runtime as |Ŷ| grows.
pub fn fig_small_vs_batch(ctx: &Ctx, family: &str, fig_id: &str) -> anyhow::Result<()> {
    let mut rep = Report::new(
        &format!("{fig_id} — {family} kernel vs batch KPCA (small datasets)"),
        &["dataset", "method", "n_adapt", "|Y|", "err/n", "opt_err/n", "wall_s"],
    );
    for name in ["insurance_like", "har_like"] {
        let spec = ctx.dataset(name)?;
        let data = spec.generate(ctx.seed);
        let n = data.len();
        let kernel = ctx.kernel(family, &data);
        // ground truth: batch KPCA on the full dataset
        let t0 = Instant::now();
        let exact = n <= 400;
        let batch = batch_kpca(&data.to_dense(), kernel, ctx.cfg.params().k, exact, ctx.seed);
        let batch_wall = t0.elapsed().as_secs_f64();
        let opt_pp = batch.opt_error / n as f64;
        rep.row(vec![
            name.into(),
            "batchKPCA".into(),
            "-".into(),
            n.to_string(),
            sci(opt_pp),
            sci(opt_pp),
            sci(batch_wall),
        ]);
        for n_adapt in sweep(ctx, "25,50,100,200") {
            let params = params_with(ctx, n_adapt);
            let r = run_method(ctx, &spec, &data, kernel, &params, Method::DisKpca)?;
            rep.row(vec![
                name.into(),
                r.method.into(),
                n_adapt.to_string(),
                r.num_points.to_string(),
                sci(r.err_per_point),
                sci(opt_pp),
                sci(r.wall_secs),
            ]);
        }
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, fig_id)?;
    println!("wrote {path}");
    Ok(())
}

/// Figures 4 (poly), 5 (gauss), 6 (arccos): communication vs error on
/// large datasets, three methods.
pub fn fig_comm_tradeoff(
    ctx: &Ctx,
    family: &str,
    datasets: &[&str],
    fig_id: &str,
) -> anyhow::Result<()> {
    let mut rep = Report::new(
        &format!("{fig_id} — {family} kernel: communication vs low-rank error"),
        &["dataset", "method", "n_adapt", "|Y|", "comm_words", "err/n", "wall_s"],
    );
    for name in datasets {
        let spec = ctx.dataset(name)?;
        let data = spec.generate(ctx.seed);
        let kernel = ctx.kernel(family, &data);
        for n_adapt in sweep(ctx, "50,100,200,400") {
            let params = params_with(ctx, n_adapt);
            for method in Method::all() {
                // uniform+batch becomes too costly at large samples —
                // the paper "stopped it short" too.
                if method == Method::UniformBatch && params.n_lev + params.n_adapt > 300 {
                    continue;
                }
                let r = run_method(ctx, &spec, &data, kernel, &params, method)?;
                rep.row(vec![
                    (*name).into(),
                    r.method.into(),
                    n_adapt.to_string(),
                    r.num_points.to_string(),
                    r.comm_words.to_string(),
                    sci(r.err_per_point),
                    sci(r.wall_secs),
                ]);
            }
        }
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, fig_id)?;
    println!("wrote {path}");
    Ok(())
}

/// Figure 7: runtime scaling with the number of workers. The paper
/// reports computation time (communication excluded) on a real
/// cluster; on this single-core testbed the equivalent quantity is
/// the **critical path** — max over workers of their compute-busy
/// time (a perfectly parallel cluster's wall clock).
pub fn fig7(ctx: &Ctx) -> anyhow::Result<()> {
    let mut rep = Report::new(
        "fig7 — disKPCA compute time vs #workers (gauss kernel)",
        &["dataset", "partition", "workers", "crit_path_s", "total_compute_s", "speedup_vs_1"],
    );
    let worker_counts: Vec<usize> = ctx
        .cfg
        .str_or("worker_sweep", "1,2,4,8,16,32")
        .split(',')
        .map(|v| v.trim().parse().unwrap())
        .collect();
    // Two partition regimes: the paper's α=2 power law (heaviest
    // worker keeps ≥60% of the data — critical path saturates at
    // ~1.6×) and a balanced split (near-linear until fixed per-worker
    // costs dominate, the paper's observed plateau).
    for name in ["mnist8m_like", "susy_like"] {
        let mut spec = ctx.dataset(name)?;
        let data = spec.generate(ctx.seed);
        let kernel = ctx.kernel("gauss", &data);
        let params = ctx.cfg.params();
        for part in ["uniform", "powerlaw"] {
            let mut base = None;
            for &s in &worker_counts {
                if s > data.len() {
                    continue;
                }
                spec.s = s;
                let shards = if part == "uniform" {
                    crate::data::partition_uniform(&data, s)
                } else {
                    spec.partition(&data, ctx.seed ^ 0x9a91)
                };
                let backend = ctx.backend.clone();
                let p2 = params;
                let (busy, _) = crate::coordinator::run_cluster(
                    shards,
                    kernel,
                    backend,
                    move |cluster| -> Result<Vec<f64>, crate::comm::CommError> {
                        let _ = dis_kpca(cluster, kernel, &p2)?;
                        crate::coordinator::master::dis_busy_times(cluster)
                    },
                );
                let busy = busy?;
                let crit = busy.iter().cloned().fold(0.0f64, f64::max);
                let total: f64 = busy.iter().sum();
                let speedup = base.map(|b: f64| b / crit).unwrap_or(1.0);
                if base.is_none() {
                    base = Some(crit);
                }
                rep.row(vec![
                    name.into(),
                    part.into(),
                    s.to_string(),
                    sci(crit),
                    sci(total),
                    sci(speedup),
                ]);
            }
        }
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, "fig7")?;
    println!("wrote {path}");
    Ok(())
}

/// Figure 8: spectral clustering (KPCA + distributed k-means) —
/// k-means objective vs communication.
pub fn fig8(ctx: &Ctx) -> anyhow::Result<()> {
    let mut rep = Report::new(
        "fig8 — KPCA + k-means: feature-space objective vs communication",
        &["dataset", "kernel", "method", "n_adapt", "comm_words", "kmeans_obj", "iters"],
    );
    let cases = [
        ("news20_like", "poly"),
        ("susy_like", "poly"),
        ("ctslice_like", "gauss"),
        ("yearpredmsd_like", "gauss"),
    ];
    for (name, family) in cases {
        let spec = ctx.dataset(name)?;
        let data = spec.generate(ctx.seed);
        let n = data.len();
        let kernel = ctx.kernel(family, &data);
        for n_adapt in sweep(ctx, "50,100,200") {
            let params = params_with(ctx, n_adapt);
            for method in [Method::DisKpca, Method::UniformDisLr] {
                let shards = spec.partition(&data, ctx.seed ^ 0x9a91);
                let backend = ctx.backend.clone();
                let total = params.n_lev + params.n_adapt;
                let kc = ctx.cfg.usize_or("clusters", params.k);
                let seed = ctx.seed;
                let (body, stats) = run_cluster(
                    shards,
                    kernel,
                    backend,
                    move |cluster| -> Result<
                        (crate::coordinator::kmeans::KmeansResult, usize),
                        crate::comm::CommError,
                    > {
                        let sol = match method {
                            Method::DisKpca => dis_kpca(cluster, kernel, &params)?,
                            _ => uniform_dis_lr(cluster, kernel, &params, total)?,
                        };
                        dis_set_solution(cluster, &sol)?;
                        let res = distributed_kmeans(cluster, kc, 30, seed ^ 0x833)?;
                        Ok((res, sol.num_points()))
                    },
                );
                let (res, _sol_pts) = body?;
                rep.row(vec![
                    name.into(),
                    family.into(),
                    method.name().into(),
                    n_adapt.to_string(),
                    stats.total_words().to_string(),
                    sci(res.feature_space_obj(n)),
                    res.iters.to_string(),
                ]);
            }
        }
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, "fig8")?;
    println!("wrote {path}");
    Ok(())
}

/// `css`: kernel column subset selection report (extension) —
/// residual-fraction certificate of the CSS columns vs a uniform
/// selection of the same size, plus the KRR downstream fit, over the
/// |Ŷ| sweep.
pub fn css_report(ctx: &Ctx, dataset: &str) -> anyhow::Result<()> {
    let mut rep = Report::new(
        &format!("css — column subset selection on {dataset} (gauss kernel)"),
        &["n_adapt", "|Y|", "css_resid_frac", "unif_resid_frac", "krr_r2", "comm_words"],
    );
    let spec = ctx.dataset(dataset)?;
    let data = spec.generate(ctx.seed);
    let kernel = ctx.kernel("gauss", &data);
    for n_adapt in sweep(ctx, "25,50,100,200") {
        let params = params_with(ctx, n_adapt);
        let shards = spec.partition(&data, ctx.seed ^ 0x9a91);
        let backend = ctx.backend.clone();
        let seed = ctx.seed;
        let (body, stats) = run_cluster(
            shards,
            kernel,
            backend,
            move |cluster| -> Result<
                (crate::coordinator::CssSolution, f64, f64),
                crate::comm::CommError,
            > {
                let css = dis_css(cluster, kernel, &params)?;
                let unif = crate::coordinator::baselines::dis_uniform_sample(
                    cluster,
                    css.y.len(),
                    seed ^ 0xc55,
                )?;
                let unif_resid: f64 = cluster
                    .broadcast(crate::comm::request::Residuals { pts: unif })?
                    .into_iter()
                    .sum();
                let model = dis_krr(cluster, kernel, &css.y, 1e-3, seed ^ 0x3a3)?;
                Ok((css.clone(), unif_resid / css.trace, model.r_squared()))
            },
        );
        let (css, unif_frac, r2) = body?;
        rep.row(vec![
            n_adapt.to_string(),
            css.y.len().to_string(),
            sci(css.residual_fraction()),
            sci(unif_frac),
            sci(r2),
            stats.total_words().to_string(),
        ]);
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, "css")?;
    println!("wrote {path}");
    Ok(())
}

/// `bench-comm`: one disKPCA run with the per-round communication
/// table and the Theorem-1 closed-form model next to it.
pub fn bench_comm(ctx: &Ctx, dataset: &str) -> anyhow::Result<()> {
    let spec = ctx.dataset(dataset)?;
    let data = spec.generate(ctx.seed);
    let kernel = ctx.kernel(ctx.cfg.str_or("kernel", "gauss"), &data);
    let params = ctx.cfg.params();
    let shards = spec.partition(&data, ctx.seed ^ 0x9a91);
    let backend = ctx.backend.clone();
    let p2 = params;
    let (sol, stats) = run_cluster(shards, kernel, backend, move |cluster| {
        dis_kpca(cluster, kernel, &p2)
    });
    let sol = sol?;
    let mut rep = Report::new(
        &format!("per-round communication on {dataset} (s={}, |Y|={})", spec.s, sol.num_points()),
        &["round", "to_master", "to_workers", "total"],
    );
    for (round, up, down) in stats.table() {
        rep.row(vec![round, up.to_string(), down.to_string(), (up + down).to_string()]);
    }
    rep.print();
    let y = sol.num_points();
    let model = comm_model_words(
        spec.s,
        params.t,
        params.p,
        y,
        if params.w == 0 { y } else { params.w },
        params.k,
        data.avg_nnz_per_point(),
    );
    println!(
        "total measured = {} words | Theorem-1 model ≈ {} words | ratio {:.2}",
        stats.total_words(),
        model,
        stats.total_words() as f64 / model as f64
    );
    let path = rep.write_csv(&ctx.out_dir, &format!("comm_{dataset}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `ablation`: is each stage of the sampling pipeline pulling its
/// weight? Runs Full / LeverageOnly / AdaptiveOnly at matched point
/// budgets (the design choices DESIGN.md calls out).
pub fn ablation(ctx: &Ctx, dataset: &str) -> anyhow::Result<()> {
    use crate::coordinator::{dis_eval, dis_kpca_mode, SamplingMode};
    let spec = ctx.dataset(dataset)?;
    let data = spec.generate(ctx.seed);
    let family = ctx.cfg.str_or("kernel", "gauss").to_string();
    let kernel = ctx.kernel(&family, &data);
    let mut rep = Report::new(
        &format!("ablation — sampling stages on {dataset} ({family})"),
        &["mode", "|Y|", "comm_words", "err/n", "rel_err"],
    );
    for (mode, name) in [
        (SamplingMode::Full, "full (paper)"),
        (SamplingMode::LeverageOnly, "leverage-only"),
        (SamplingMode::AdaptiveOnly, "adaptive-only"),
    ] {
        let shards = spec.partition(&data, ctx.seed ^ 0x9a91);
        let backend = ctx.backend.clone();
        let params = ctx.cfg.params();
        let n = data.len();
        let (body, stats) = crate::coordinator::run_cluster(
            shards,
            kernel,
            backend,
            move |cluster| -> Result<(f64, f64, usize), crate::comm::CommError> {
                let sol = dis_kpca_mode(cluster, kernel, &params, mode)?;
                let (err, trace) = dis_eval(cluster)?;
                Ok((err, trace, sol.num_points()))
            },
        );
        let (err, trace, ny) = body?;
        rep.row(vec![
            name.into(),
            ny.to_string(),
            stats.total_words().to_string(),
            sci(err / n as f64),
            sci(err / trace),
        ]);
    }
    rep.print();
    let path = rep.write_csv(&ctx.out_dir, &format!("ablation_{dataset}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `run`: one disKPCA invocation with a result summary.
pub fn run_one(ctx: &Ctx, dataset: &str) -> anyhow::Result<()> {
    let spec = ctx.dataset(dataset)?;
    let data = spec.generate(ctx.seed);
    let family = ctx.cfg.str_or("kernel", "gauss").to_string();
    let kernel = ctx.kernel(&family, &data);
    let params = ctx.cfg.params();
    println!(
        "disKPCA on {dataset}: n={} d={} s={} kernel={} backend={}",
        data.len(),
        data.dim(),
        spec.s,
        kernel.name(),
        ctx.backend_name,
    );
    let r = run_method(ctx, &spec, &data, kernel, &params, Method::DisKpca)?;
    println!(
        "|Y|={}  err/n={}  rel_err={:.4}  comm={} words  wall={:.2}s",
        r.num_points,
        sci(r.err_per_point),
        r.err / r.trace,
        r.comm_words,
        r.wall_secs
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn tiny_ctx() -> Ctx {
        let mut cfg = Config::new();
        cfg.set("scale", "0.02");
        cfg.set("workers", "3");
        cfg.set("k", "3");
        cfg.set("t", "16");
        cfg.set("p", "32");
        cfg.set("n_lev", "8");
        cfg.set("m_rff", "128");
        cfg.set("t2", "64");
        cfg.set("sweep", "10");
        cfg.set("median_sample", "60");
        cfg.set("out", std::env::temp_dir().join("diskpca_fig_test").to_str().unwrap());
        Ctx::from_config(&cfg).unwrap()
    }

    #[test]
    fn table1_runs() {
        table1(&tiny_ctx()).unwrap();
    }

    #[test]
    fn fig_small_runs() {
        fig_small_vs_batch(&tiny_ctx(), "gauss", "fig3_test").unwrap();
    }

    #[test]
    fn fig_comm_runs() {
        fig_comm_tradeoff(&tiny_ctx(), "gauss", &["protein_like"], "fig5_test").unwrap();
    }

    #[test]
    fn bench_comm_runs() {
        bench_comm(&tiny_ctx(), "protein_like").unwrap();
    }
}
